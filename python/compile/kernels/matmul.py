"""L1 Pallas kernel: tiled matmul — the compute hot-spot of the L2 model.

TPU adaptation (DESIGN.md §3): the paper's consumer is a GPU running cuDNN
convolutions. On TPU-class hardware the same work is tiled matmuls on the
MXU systolic array. This kernel expresses the HBM↔VMEM schedule with a
BlockSpec grid:

  grid = (M/bm, N/bn, K/bk)  —  K innermost so each (i, j) output tile stays
  resident in VMEM while partial products accumulate (revisiting semantics).

VMEM footprint and MXU estimates for the shipped tile sizes are next to the
BM/BK/BN constants below (tuned in the §Perf pass — see EXPERIMENTS.md).
We keep f32 because correctness is validated on the CPU interpreter
(interpret=True — Mosaic custom-calls cannot run on the CPU PJRT plugin);
a bf16 variant would halve the VMEM numbers and double MXU throughput.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes; overridable at AOT time (HOARD_MM_BM/BK/BN) — the
# §Perf block-size sweep lives in EXPERIMENTS.md. 1024×256×128 measured
# 6.8× faster per train step than 64×128×64 (grid iterations drop 32×;
# that is what both the CPU interpreter and TPU pipeline overhead pay
# for). VMEM footprint: x 1024·256·4 = 1 MiB, y 256·128·4 = 128 KiB,
# o 1024·128·4 = 512 KiB ⇒ ~1.6 MiB/step, ~10% of a 16 MiB VMEM —
# double-buffering still has 5× headroom. MXU view: each step streams
# 1024×256 activations through the 128×128 systolic array as 8×2 passes
# with zero re-fetch of the weight tile.
BM = int(os.environ.get("HOARD_MM_BM", "1024"))
BK = int(os.environ.get("HOARD_MM_BK", "256"))
BN = int(os.environ.get("HOARD_MM_BN", "128"))


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i, j] += x[i, k] @ y[k, j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_blocks(x: jax.Array, y: jax.Array, *, bm: int = BM, bk: int = BK,
                  bn: int = BN) -> jax.Array:
    """`x @ y` via the Pallas tile kernel; pads ragged edges to tile size.

    x: (M, K) f32, y: (K, N) f32 -> (M, N) f32. Forward only — use
    `matmul` (custom-VJP wrapper) inside differentiated code.
    """
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[0]:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    # Shrink blocks for small operands so the grid is never empty.
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    mp = pl.cdiv(m, bm) * bm
    kp = pl.cdiv(k, bk) * bk
    np_ = pl.cdiv(n, bn) * bn
    xp = _pad_to(x.astype(jnp.float32), mp, kp)
    yp = _pad_to(y.astype(jnp.float32), kp, np_)
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable `x @ y` on the Pallas tile kernel (default blocks).

    Pallas kernels with revisiting accumulation are not auto-transposable,
    so the backward pass is expressed explicitly — as two more instances of
    the *same* kernel: dX = g @ Yᵀ, dY = Xᵀ @ g. That keeps 100% of the
    model's matmul FLOPs (fwd *and* bwd) on the L1 kernel.
    """
    return matmul_blocks(x, y)


def _matmul_fwd(x, y):
    return matmul_blocks(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    return matmul_blocks(g, y.T), matmul_blocks(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def linear(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fully-connected layer on the Pallas matmul: x @ w + b."""
    return matmul(x, w) + b[None, :]
