"""Pure-jnp oracles for the Pallas kernels (the build-time correctness bar).

Every Pallas kernel in this package has a reference implementation here
written with plain jax.numpy ops only. python/tests/test_kernels.py sweeps
shapes (hypothesis) and asserts allclose between kernel and oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .preprocess import MEAN, STD


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return matmul_ref(x, w) + b[None, :]


def preprocess_ref(images_u8: jax.Array) -> jax.Array:
    x = images_u8.astype(jnp.float32) / 255.0
    m = jnp.asarray(MEAN, dtype=jnp.float32)
    s = jnp.asarray(STD, dtype=jnp.float32)
    return (x - m) / s
