"""L1 Pallas kernel: fused input-pipeline preprocessing.

The paper's input pipeline (tf_cnn_benchmarks) decodes images on the CPU and
normalizes them before they reach the accelerator. We fuse the
uint8→f32 cast, [0,1] scaling and per-channel mean/std normalization into a
single VMEM pass — one HBM read + one HBM write per image instead of three
round-trips for cast / scale / normalize.

Block schedule: grid over the batch dimension; each step owns one image
(H*W*C f32 = 32*32*3*4 = 12 KiB in VMEM — negligible, so Pallas can
double-buffer many images ahead). interpret=True for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# CIFAR-style channel statistics; the synthetic e2e dataset is generated to
# match (see rust workload::datagen).
MEAN = (0.4914, 0.4822, 0.4465)
STD = (0.2470, 0.2435, 0.2616)


def _preprocess_kernel(img_ref, out_ref, *, mean, std):
    # Per-channel python-float constants (Pallas forbids captured array
    # constants; scalars fold into the kernel body).
    x = img_ref[...].astype(jnp.float32) * (1.0 / 255.0)
    chans = [(x[..., c] - mean[c]) * (1.0 / std[c]) for c in range(len(mean))]
    out_ref[...] = jnp.stack(chans, axis=-1)


@jax.jit
def preprocess(images_u8: jax.Array) -> jax.Array:
    """(B, H, W, C) uint8 -> (B, H, W, C) f32, normalized."""
    if images_u8.ndim != 4:
        raise ValueError(f"expected NHWC batch, got {images_u8.shape}")
    b, h, w, c = images_u8.shape
    return pl.pallas_call(
        functools.partial(_preprocess_kernel, mean=MEAN, std=STD),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w, c), jnp.float32),
        interpret=True,
    )(images_u8)
