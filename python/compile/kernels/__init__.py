"""L1: Pallas kernels for the training consumer's compute hot-spots."""

from .matmul import linear, matmul
from .preprocess import preprocess

__all__ = ["matmul", "linear", "preprocess"]
