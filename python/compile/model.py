"""L2: the training consumer — a small CNN classifier in JAX.

This is the "DL job" that Hoard feeds: the reproduction's stand-in for
AlexNet in tf_cnn_benchmarks (DESIGN.md §2). Convolutions are lowered to
im2col + the L1 Pallas matmul kernel so the paper's compute hot-spot runs
through our kernel; the input-pipeline normalization runs through the L1
preprocess kernel. fwd/bwd via jax.grad, SGD with momentum.

Everything here takes/returns *flat tuples of arrays* so the AOT artifacts
have a stable positional calling convention for the Rust runtime (see
aot.py, which also emits a JSON manifest of the signatures).

Architecture (32x32x3 inputs, NUM_CLASSES logits):
  conv3x3(3->16) + relu + maxpool2        # 16x16x16
  conv3x3(16->32) + relu + maxpool2       # 8x8x32
  flatten (2048) -> linear(2048->128) + relu -> linear(128->NUM_CLASSES)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import linear, matmul, preprocess

IMG = 32
CHANNELS = 3
NUM_CLASSES = 10
BATCH = 64
# SGD-momentum; 0.05 diverges on this model (verified in the e2e run), 0.01
# trains stably with the He init below.
LR = 0.01
MOMENTUM = 0.9

# (name, shape) of every parameter, in calling-convention order.
PARAM_SPECS = (
    ("conv1_w", (3, 3, CHANNELS, 16)),
    ("conv1_b", (16,)),
    ("conv2_w", (3, 3, 16, 32)),
    ("conv2_b", (32,)),
    ("fc1_w", (2048, 128)),
    ("fc1_b", (128,)),
    ("fc2_w", (128, NUM_CLASSES)),
    ("fc2_b", (NUM_CLASSES,)),
)
N_PARAMS = len(PARAM_SPECS)


def init_params(seed: jax.Array):
    """He-init parameters from an int32 seed. Returns the flat tuple."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in PARAM_SPECS:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = jnp.sqrt(2.0 / fan_in)
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return tuple(out)


def _im2col(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """(B, H, W, C) -> (B*H*W, kh*kw*C) patches with SAME zero padding."""
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (kh // 2, kh // 2), (kw // 2, kw // 2), (0, 0)))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[:, dy:dy + h, dx:dx + w, :])
    patches = jnp.concatenate(cols, axis=-1)  # (B, H, W, kh*kw*C)
    return patches.reshape(b * h * w, kh * kw * c)


def conv3x3(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """SAME conv as im2col + Pallas matmul. w: (3, 3, Cin, Cout)."""
    bsz, h, wd, _ = x.shape
    cout = w.shape[-1]
    cols = _im2col(x, 3, 3)                      # (B*H*W, 9*Cin)
    wm = w.reshape(-1, cout)                     # (9*Cin, Cout)
    y = matmul(cols, wm) + b[None, :]
    return y.reshape(bsz, h, wd, cout)


def maxpool2(x: jax.Array) -> jax.Array:
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return x.max(axis=(2, 4))


def forward(params, images_f32: jax.Array) -> jax.Array:
    """Logits for a (B, 32, 32, 3) f32 batch."""
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b = params
    x = jax.nn.relu(conv3x3(images_f32, c1w, c1b))
    x = maxpool2(x)
    x = jax.nn.relu(conv3x3(x, c2w, c2b))
    x = maxpool2(x)
    x = x.reshape(x.shape[0], -1)                # (B, 2048)
    x = jax.nn.relu(linear(x, f1w, f1b))
    return linear(x, f2w, f2b)


def loss_fn(params, images_f32: jax.Array, labels: jax.Array) -> jax.Array:
    logits = forward(params, images_f32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, NUM_CLASSES, dtype=jnp.float32)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def train_step(*flat):
    """Positional AOT entrypoint.

    flat = (*params[8], *momentum[8], images_u8(B,32,32,3), labels(B,)i32)
    returns (*new_params[8], *new_momentum[8], loss).
    """
    params = tuple(flat[:N_PARAMS])
    moms = tuple(flat[N_PARAMS:2 * N_PARAMS])
    images_u8, labels = flat[2 * N_PARAMS], flat[2 * N_PARAMS + 1]
    images = preprocess(images_u8)               # L1 kernel
    loss, grads = jax.value_and_grad(loss_fn)(params, images, labels)
    new_moms = tuple(MOMENTUM * m + g for m, g in zip(moms, grads))
    new_params = tuple(p - LR * m for p, m in zip(params, new_moms))
    return (*new_params, *new_moms, loss)


def predict(*flat):
    """flat = (*params[8], images_u8) -> (logits,). Inference entrypoint."""
    params = tuple(flat[:N_PARAMS])
    images = preprocess(flat[N_PARAMS])
    return (forward(params, images),)
