"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
behind the published `xla` 0.1.6 crate) rejects (`proto.id() <= INT_MAX`).
The HLO text parser reassigns ids, so text round-trips cleanly.

Artifacts (written to --out-dir, default ../artifacts):
  init.hlo.txt        (seed i32)                         -> 8 params
  train_step.hlo.txt  (8 params, 8 momenta, images, lbl) -> 8+8 updated + loss
  predict.hlo.txt     (8 params, images)                 -> logits
  preprocess.hlo.txt  (images u8)                        -> normalized f32
  manifest.json       positional signatures for each artifact

Run via `make artifacts`; python never runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import preprocess as pp


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True: the Rust
    side unwraps the single tuple output)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return {"shape": list(shape), "dtype": str(jnp.dtype(dtype).name)}


def build_entrypoints(batch: int):
    """(name, fn, example_args, doc) for every artifact."""
    img = jax.ShapeDtypeStruct((batch, model.IMG, model.IMG, model.CHANNELS),
                               jnp.uint8)
    lbl = jax.ShapeDtypeStruct((batch,), jnp.int32)
    params = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in model.PARAM_SPECS]
    seed = jax.ShapeDtypeStruct((), jnp.int32)

    def init_fn(seed):
        return model.init_params(seed)

    return [
        ("init", init_fn, (seed,), "seed -> initial params"),
        ("train_step", model.train_step, (*params, *params, img, lbl),
         "params, momenta, images_u8, labels -> params', momenta', loss"),
        ("predict", model.predict, (*params, img),
         "params, images_u8 -> logits"),
        ("preprocess", lambda x: (pp(x),), (img,),
         "images_u8 -> normalized f32"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--batch", type=int, default=model.BATCH)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "batch": args.batch,
        "image": [model.IMG, model.IMG, model.CHANNELS],
        "num_classes": model.NUM_CLASSES,
        "lr": model.LR,
        "momentum": model.MOMENTUM,
        "param_specs": [{"name": n, **_spec(s, jnp.float32)}
                        for n, s in model.PARAM_SPECS],
        "entrypoints": {},
    }

    for name, fn, ex_args, doc in build_entrypoints(args.batch):
        lowered = jax.jit(fn).lower(*ex_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *ex_args)
        manifest["entrypoints"][name] = {
            "doc": doc,
            "inputs": [_spec(a.shape, a.dtype) for a in ex_args],
            "outputs": [_spec(o.shape, o.dtype) for o in outs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
