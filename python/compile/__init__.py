"""Build-time-only Python: JAX/Pallas model authoring + AOT lowering.

Nothing in this package is imported at runtime; `make artifacts` runs
`compile.aot` once and the Rust coordinator consumes the emitted HLO text.
"""
