"""L2 correctness: model shapes, gradient flow, training-step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

B = 8


@pytest.fixture(scope="module")
def params():
    return model.init_params(jnp.int32(0))


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(42)
    k1, k2 = jax.random.split(key)
    img = jax.random.randint(k1, (B, model.IMG, model.IMG, model.CHANNELS),
                             0, 256, jnp.uint8)
    lbl = jax.random.randint(k2, (B,), 0, model.NUM_CLASSES, jnp.int32)
    return img, lbl


def test_init_shapes(params):
    assert len(params) == model.N_PARAMS
    for p, (name, shape) in zip(params, model.PARAM_SPECS):
        assert p.shape == shape, name
        assert p.dtype == jnp.float32


def test_init_deterministic():
    a = model.init_params(jnp.int32(7))
    b = model.init_params(jnp.int32(7))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_init_seed_sensitivity():
    a = model.init_params(jnp.int32(0))
    b = model.init_params(jnp.int32(1))
    assert any(not np.allclose(x, y) for x, y in zip(a, b))


def test_forward_shape(params, batch):
    img, _ = batch
    from compile.kernels import preprocess
    logits = model.forward(params, preprocess(img))
    assert logits.shape == (B, model.NUM_CLASSES)
    assert jnp.isfinite(logits).all()


def test_loss_finite_positive(params, batch):
    img, lbl = batch
    from compile.kernels import preprocess
    loss = model.loss_fn(params, preprocess(img), lbl)
    assert jnp.isfinite(loss)
    assert loss > 0  # cross-entropy of an untrained model


def test_train_step_signature(params, batch):
    img, lbl = batch
    zeros = tuple(jnp.zeros_like(p) for p in params)
    out = model.train_step(*params, *zeros, img, lbl)
    assert len(out) == 2 * model.N_PARAMS + 1
    for p, o in zip(params, out[:model.N_PARAMS]):
        assert p.shape == o.shape
    assert out[-1].shape == ()


def test_train_step_reduces_loss_on_fixed_batch(params, batch):
    img, lbl = batch
    p = params
    m = tuple(jnp.zeros_like(x) for x in p)
    losses = []
    for _ in range(8):
        out = model.train_step(*p, *m, img, lbl)
        p = out[:model.N_PARAMS]
        m = out[model.N_PARAMS:2 * model.N_PARAMS]
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0], losses


def test_momentum_accumulates(params, batch):
    img, lbl = batch
    zeros = tuple(jnp.zeros_like(p) for p in params)
    out = model.train_step(*params, *zeros, img, lbl)
    new_moms = out[model.N_PARAMS:2 * model.N_PARAMS]
    # After one step from zero momentum, momentum == gradient (nonzero).
    assert any(float(jnp.abs(mm).max()) > 0 for mm in new_moms)


def test_predict_matches_forward(params, batch):
    img, _ = batch
    from compile.kernels import preprocess
    logits = model.predict(*params, img)[0]
    np.testing.assert_allclose(
        logits, model.forward(params, preprocess(img)), rtol=1e-4, atol=1e-4)


def test_maxpool2():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    out = model.maxpool2(x)
    np.testing.assert_array_equal(out[0, :, :, 0],
                                  jnp.array([[5.0, 7.0], [13.0, 15.0]]))


def test_im2col_reconstructs_conv():
    # conv3x3 via im2col must equal lax.conv_general_dilated.
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (2, 8, 8, 3))
    w = jax.random.normal(k2, (3, 3, 3, 5))
    got = model.conv3x3(x, w, jnp.zeros(5))
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
