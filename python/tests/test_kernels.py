"""L1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes per the session contract; every property
asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, linear, preprocess
from compile.kernels.matmul import matmul_blocks
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DIM = st.integers(min_value=1, max_value=160)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_any_shape(self, m, k, n, seed):
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (m, k), jnp.float32)
        y = jax.random.normal(ky, (k, n), jnp.float32)
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("m,k,n", [
        (1, 1, 1), (64, 128, 64), (65, 129, 63), (128, 2048, 128),
        (7, 3, 5), (256, 27, 16),
    ])
    def test_matches_ref_fixed(self, m, k, n):
        x, y = rand(0, (m, k)), rand(1, (k, n))
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)

    def test_block_edges_pad_correctly(self):
        # Exactly one past a block boundary in each dim.
        x, y = rand(2, (65, 129)), rand(3, (129, 65))
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)

    def test_custom_block_sizes(self):
        x, y = rand(4, (96, 96)), rand(5, (96, 96))
        out = matmul_blocks(x, y, bm=32, bk=32, bn=32)
        np.testing.assert_allclose(out, ref.matmul_ref(x, y),
                                   rtol=1e-4, atol=1e-4)

    def test_gradients_match_ref(self):
        # The custom VJP (backward = two more Pallas matmuls) must agree
        # with jnp.dot's autodiff.
        x, y = rand(9, (24, 40)), rand(10, (40, 16))

        def f_kernel(x, y):
            return jnp.sum(matmul(x, y) ** 2)

        def f_ref(x, y):
            return jnp.sum(ref.matmul_ref(x, y) ** 2)

        gx_k, gy_k = jax.grad(f_kernel, argnums=(0, 1))(x, y)
        gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
        np.testing.assert_allclose(gx_k, gx_r, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(gy_k, gy_r, rtol=1e-3, atol=1e-3)

    def test_identity(self):
        x = rand(6, (33, 33))
        np.testing.assert_allclose(matmul(x, jnp.eye(33)), x,
                                   rtol=1e-5, atol=1e-5)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            matmul(jnp.zeros((2, 3)), jnp.zeros((4, 2)))

    def test_non_2d_raises(self):
        with pytest.raises(ValueError):
            matmul(jnp.zeros((2, 3, 4)), jnp.zeros((4, 2)))

    def test_bf16_inputs_upcast(self):
        x = rand(7, (32, 32)).astype(jnp.bfloat16)
        y = rand(8, (32, 32)).astype(jnp.bfloat16)
        out = matmul(x, y)
        assert out.dtype == jnp.float32
        np.testing.assert_allclose(out, ref.matmul_ref(x, y),
                                   rtol=2e-2, atol=2e-2)


class TestLinear:
    @settings(max_examples=15, deadline=None)
    @given(b=st.integers(1, 64), din=st.integers(1, 96),
           dout=st.integers(1, 96), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, b, din, dout, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(k1, (b, din))
        w = jax.random.normal(k2, (din, dout))
        bias = jax.random.normal(k3, (dout,))
        np.testing.assert_allclose(linear(x, w, bias),
                                   ref.linear_ref(x, w, bias),
                                   rtol=1e-4, atol=1e-4)


class TestPreprocess:
    @settings(max_examples=15, deadline=None)
    @given(b=st.integers(1, 16), h=st.sampled_from([8, 16, 32]),
           w=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, b, h, w, seed):
        img = jax.random.randint(jax.random.PRNGKey(seed), (b, h, w, 3),
                                 0, 256, jnp.uint8)
        np.testing.assert_allclose(preprocess(img), ref.preprocess_ref(img),
                                   rtol=1e-5, atol=1e-5)

    def test_extreme_values(self):
        img = jnp.stack([jnp.zeros((32, 32, 3), jnp.uint8),
                         jnp.full((32, 32, 3), 255, jnp.uint8)])
        out = preprocess(img)
        expect = ref.preprocess_ref(img)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)
        assert jnp.isfinite(out).all()

    def test_rejects_non_batch(self):
        with pytest.raises(ValueError):
            preprocess(jnp.zeros((32, 32, 3), jnp.uint8))
