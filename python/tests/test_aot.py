"""AOT contract tests: the manifest and HLO artifacts the Rust runtime
consumes must stay in lock-step with model.py's calling convention."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def artifacts_built():
    return os.path.exists(os.path.join(ART, "manifest.json"))


@pytest.fixture(scope="module")
def manifest():
    if not artifacts_built():
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_params_match_model(manifest):
    specs = manifest["param_specs"]
    assert [s["name"] for s in specs] == [n for n, _ in model.PARAM_SPECS]
    for s, (_, shape) in zip(specs, model.PARAM_SPECS):
        assert tuple(s["shape"]) == shape
        assert s["dtype"] == "float32"


def test_manifest_train_step_signature(manifest):
    ts = manifest["entrypoints"]["train_step"]
    n = model.N_PARAMS
    assert len(ts["inputs"]) == 2 * n + 2
    assert len(ts["outputs"]) == 2 * n + 1
    img = ts["inputs"][2 * n]
    assert img["dtype"] == "uint8"
    assert img["shape"] == [manifest["batch"], model.IMG, model.IMG, model.CHANNELS]
    assert ts["outputs"][-1]["shape"] == []  # scalar loss


def test_manifest_hyperparams_match(manifest):
    assert manifest["lr"] == pytest.approx(model.LR)
    assert manifest["momentum"] == pytest.approx(model.MOMENTUM)
    assert manifest["num_classes"] == model.NUM_CLASSES


def test_hlo_artifacts_exist_and_parse_shape(manifest):
    for name in manifest["entrypoints"]:
        path = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_entrypoint_shapes_agree_with_eval_shape(manifest):
    # Re-derive the expected output shapes from the model, independent of
    # what aot.py recorded.
    import jax

    ts = manifest["entrypoints"]["train_step"]
    b = manifest["batch"]
    img = jax.ShapeDtypeStruct((b, model.IMG, model.IMG, model.CHANNELS), jnp.uint8)
    lbl = jax.ShapeDtypeStruct((b,), jnp.int32)
    params = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in model.PARAM_SPECS]
    outs = jax.eval_shape(model.train_step, *params, *params, img, lbl)
    assert len(outs) == len(ts["outputs"])
    for o, spec in zip(outs, ts["outputs"]):
        assert list(o.shape) == spec["shape"]
