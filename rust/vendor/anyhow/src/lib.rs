//! Offline, dependency-free subset of the `anyhow` API, vendored so the
//! Hoard build never touches a crate registry. Implements exactly the
//! surface this repository uses:
//!
//!  * [`Error`] — an erased error with a context chain; `Display` prints the
//!    outermost message, `{:#}` prints the whole chain, `Debug` prints the
//!    chain anyhow-style ("Caused by:").
//!  * [`Result`] — `Result<T, Error>` alias.
//!  * [`Context`] — `.context(..)` / `.with_context(|| ..)` on `Result` and
//!    `Option`.
//!  * [`anyhow!`] / [`bail!`] / [`ensure!`] macros.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An erased error: zero or more layers of context wrapped around an
/// optional source error.
pub struct Error {
    /// Context messages, outermost first.
    context: Vec<String>,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a plain message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { context: vec![message.to_string()], source: None }
    }

    /// Wrap `source` (any std error) without extra context.
    pub fn new<E: StdError + Send + Sync + 'static>(source: E) -> Self {
        Error { context: Vec::new(), source: Some(Box::new(source)) }
    }

    fn wrap<C: fmt::Display>(mut self, ctx: C) -> Self {
        self.context.insert(0, ctx.to_string());
        self
    }

    /// Every layer of the error, outermost first: context messages, then
    /// the source chain.
    pub fn chain(&self) -> Vec<String> {
        let mut layers = self.context.clone();
        let mut src: Option<&(dyn StdError + 'static)> =
            self.source.as_ref().map(|s| s.as_ref() as &(dyn StdError + 'static));
        while let Some(s) = src {
            layers.push(s.to_string());
            src = s.source();
        }
        layers
    }

    /// The innermost error message (the original cause).
    pub fn root_cause(&self) -> String {
        self.chain().pop().unwrap_or_else(|| "unknown error".to_string())
    }

    /// Walk the source chain looking for a concrete error type `E` — the
    /// `anyhow::Error::downcast_ref` subset. Context layers are just
    /// strings here, so only the typed source chain is searched; an error
    /// built from `anyhow!`/`bail!` (message-only) never downcasts.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        let mut src: Option<&(dyn StdError + 'static)> =
            self.source.as_ref().map(|s| s.as_ref() as &(dyn StdError + 'static));
        while let Some(s) = src {
            if let Some(e) = s.downcast_ref::<E>() {
                return Some(e);
            }
            src = s.source();
        }
        None
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-separated (anyhow behaviour).
            return write!(f, "{}", self.chain().join(": "));
        }
        match (self.context.first(), &self.source) {
            (Some(c), _) => write!(f, "{c}"),
            (None, Some(s)) => write!(f, "{s}"),
            (None, None) => write!(f, "unknown error"),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let layers = self.chain();
        match layers.split_first() {
            None => write!(f, "unknown error"),
            Some((first, rest)) => {
                write!(f, "{first}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, layer) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {layer}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`
// (the standard anyhow trick).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::new(e)
    }
}

/// Conversion into [`Error`] — implemented for std errors *and* for
/// [`Error`] itself so `.context(..)` chains on both.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::new(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// `.context(..)` / `.with_context(|| ..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// `if !cond { bail!(..) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "file missing");
    }

    #[test]
    fn context_layers_and_alternate() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: file missing");
        let e2 = Err::<(), Error>(e).with_context(|| format!("loading {}", "x")).unwrap_err();
        assert_eq!(e2.to_string(), "loading x");
        assert_eq!(format!("{e2:#}"), "loading x: opening config: file missing");
        assert_eq!(e2.root_cause(), "file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing field").unwrap_err().to_string(), "missing field");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn fails(n: u32) -> Result<()> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("unlucky {}", n);
            }
            Ok(())
        }
        assert!(fails(1).is_ok());
        assert_eq!(fails(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(fails(11).unwrap_err().to_string(), "n too big: 11");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn downcast_ref_finds_typed_source_through_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        let io = e.downcast_ref::<std::io::Error>().expect("typed source survives context");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        // Message-only errors carry no typed source to downcast.
        assert!(anyhow!("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn debug_prints_cause_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"), "{dbg}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("file missing"), "{dbg}");
    }
}
