//! Fluid (rate-based) simulation of concurrent DL training jobs — the
//! engine behind every paper table/figure reproduction.
//!
//! Each job is a continuous consumer of images; at any instant its rate is
//! gated by the slowest of its data sources (buffer cache, local NVMe
//! stripe, peer caches over the network, remote NFS) and by its GPUs.
//! Concurrent transfers contend on shared resources (NFS server, NICs, rack
//! uplinks, cache volumes) resolved by demand-capped max-min fair sharing
//! (`netsim::fair`). The simulation advances in piecewise-constant-rate
//! segments between events (epoch boundaries, sample ticks), which is exact
//! for this model — no time-stepping error.
//!
//! Source-mix model per (mode, epoch):
//!  * `Remote`   — fraction `h` (buffer-cache hit rate, 0 in epoch 1) from
//!    RAM, `1-h` from the NFS server.
//!  * `LocalNvme`— dataset pre-copied to node NVMe (the paper's baseline
//!    excludes the copy, Table 3): `h` from RAM, `1-h` from the volume.
//!  * `Hoard`    — epoch 1 (cold): AFM gateway fetches each byte from NFS
//!    exactly once cluster-wide, at the calibrated cold-miss service rate;
//!    epochs ≥ 2: `h_pp` from the Spectrum pagepool, the rest striped
//!    `1/k` local + `(k-1)/k` from peer cache nodes.
//!
//! Calibration constants are derived from the paper's own numbers
//! (DESIGN.md §5) and asserted in tests below.

use crate::cache::ChunkSet;
use crate::cluster::epoch_hit_rate;
use crate::netsim::{fair_share, Flow, NodeId, Resource, ResourceId, Topology, TrafficAccount};
use crate::remote::RemoteStore;
use crate::storage::Volume;
use crate::workload::TrainJobSpec;

/// AFM cold-miss service rate per job (bytes/s): Hoard's first epoch runs at
/// 0.93× two-epoch speedup (Table 3) ⇒ 1505 s for 144 GB ⇒ ~95.7 MB/s. The
/// physical cause is the AFM gateway's synchronous small-file miss handling.
pub const AFM_COLD_BW_PER_JOB: f64 = 144e9 / 1505.0;

/// Spectrum Scale client efficiency vs raw local reads for the DL pattern:
/// Hoard steady epochs take 418 s vs 385 s NVMe-local (Table 3) ⇒ 0.921.
pub const SPECTRUM_CLIENT_EFF: f64 = 385.4 / 418.4;

/// How a job reaches its dataset — the three systems compared in Figure 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadMode {
    /// Directly from the shared remote store every epoch (REM).
    Remote,
    /// Pre-copied to node-local NVMe (the paper's NVMe baseline).
    LocalNvme,
    /// Through the Hoard distributed cache.
    Hoard,
}

/// One simulated training job.
#[derive(Debug, Clone)]
pub struct TrainJobSim {
    pub spec: TrainJobSpec,
    pub node: NodeId,
    pub mode: ReadMode,
    /// Nodes holding this dataset's stripes (Hoard mode).
    pub cache_nodes: Vec<NodeId>,
    /// Free memory available to the OS buffer cache on the job's node
    /// (varied by the Figure 4 `stress` experiment).
    pub buffer_cache_bytes: f64,
    /// Spectrum pagepool bytes on the job's node (Hoard's RAM tier).
    pub pagepool_bytes: f64,
    /// Dataset already resident when the job starts (returning job /
    /// hyper-parameter sweep round ≥ 2): every epoch is a warm epoch.
    warm_start: bool,
    /// Chunk-granular residency at job start: the *same* accounting the
    /// cache registry keeps ([`ChunkSet`]), so a partially filled dataset
    /// yields a partially warm first epoch — resident chunks stream from
    /// the stripe, missing chunks from the AFM cold path — and sim and
    /// real mode agree by construction.
    residency: Option<ChunkSet>,
    // --- run state ---
    epoch: u32,
    images_done: f64,
    pub finished: bool,
}

impl TrainJobSim {
    pub fn new(spec: TrainJobSpec, node: NodeId, mode: ReadMode) -> Self {
        TrainJobSim {
            spec,
            node,
            mode,
            cache_nodes: vec![],
            buffer_cache_bytes: 0.0,
            pagepool_bytes: 0.0,
            warm_start: false,
            residency: None,
            epoch: 0,
            images_done: 0.0,
            finished: false,
        }
    }

    /// Mark the dataset as already cached before the job starts.
    pub fn set_warm(&mut self) {
        self.warm_start = true;
    }

    /// Seed the job with the cache's chunk residency bitmap. A full
    /// bitmap is exactly a warm start; a partial one makes the first
    /// epoch a *mixed* epoch (resident fraction from the stripe, the rest
    /// through the AFM cold path).
    pub fn set_residency(&mut self, chunks: ChunkSet) {
        if chunks.is_full() {
            self.warm_start = true;
            self.residency = None;
        } else {
            self.residency = Some(chunks);
        }
    }

    /// Is the job currently in its cold (cache-filling) epoch?
    fn is_cold_epoch(&self) -> bool {
        self.epoch == 0 && !self.warm_start
    }

    fn items(&self) -> f64 {
        self.spec.dataset.num_items as f64
    }

    fn item_bytes(&self) -> f64 {
        self.spec.dataset.avg_item_bytes()
    }
}

/// Per-job simulation result.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub name: String,
    pub epoch_durations: Vec<f64>,
    pub total_duration: f64,
    /// (time, images/s) samples at `sample_interval`.
    pub fps_series: Vec<(f64, f64)>,
    /// Total bytes this job read, by source.
    pub bytes_from_remote: f64,
    pub bytes_from_local: f64,
    pub bytes_from_peers: f64,
    pub bytes_from_ram: f64,
}

impl JobOutcome {
    pub fn total_bytes_read(&self) -> f64 {
        self.bytes_from_remote + self.bytes_from_local + self.bytes_from_peers + self.bytes_from_ram
    }

    /// Mean images/s over the whole run.
    pub fn mean_fps(&self, items_per_epoch: f64, epochs: u32) -> f64 {
        items_per_epoch * epochs as f64 / self.total_duration
    }
}

/// Whole-simulation result.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub jobs: Vec<JobOutcome>,
    pub traffic: TrafficAccount,
    pub nfs_resource: ResourceId,
    pub makespan: f64,
}

/// One data-source class of a job's flow mix.
#[derive(Debug, Clone)]
struct SourceClass {
    frac: f64,
    path: Vec<ResourceId>,
    /// Extra per-job rate cap on this class (AFM cold path), bytes/s.
    cap: f64,
    /// Multiplier on NFS bytes actually drawn per byte delivered (cold-epoch
    /// dataset sharing: k jobs share one fetch ⇒ 1/k).
    remote_draw: f64,
    kind: SourceKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SourceKind {
    Ram,
    Local,
    Peer,
    Remote,
}

/// The fluid simulator.
pub struct TrainSim {
    pub topology: Topology,
    pub remote: Box<dyn RemoteStore>,
    pub jobs: Vec<TrainJobSim>,
    /// Per-node cache volume read bandwidth resources.
    volume_res: Vec<ResourceId>,
    nfs_res: ResourceId,
    /// Seconds between fps samples (0 disables series collection).
    pub sample_interval: f64,
    /// Reader threads per job in the *real-mode* data plane this scenario
    /// maps to (`posix::ReaderPool`). The fluid model already aggregates
    /// per-GPU streams through `demand.gpus`, so this is an execution hint
    /// only: every simulated quantity is invariant to it — asserted by the
    /// determinism regression tests. Only the real-file path is threaded.
    pub reader_threads: usize,
}

impl TrainSim {
    pub fn new(mut topology: Topology, remote: Box<dyn RemoteStore>, volumes: &[Volume]) -> Self {
        assert_eq!(volumes.len(), topology.num_nodes(), "one cache volume per node");
        let nfs_res = topology.add_external(format!("{}-server", remote.scheme()), remote.peak_bw());
        let volume_res = volumes
            .iter()
            .enumerate()
            .map(|(i, v)| topology.add_external(format!("node{i}.cachevol"), v.read_bw()))
            .collect();
        TrainSim {
            topology,
            remote,
            jobs: vec![],
            volume_res,
            nfs_res,
            sample_interval: 0.0,
            reader_threads: 1,
        }
    }

    pub fn add_job(&mut self, job: TrainJobSim) {
        assert!(job.node.0 < self.topology.num_nodes());
        if job.mode == ReadMode::Hoard {
            assert!(!job.cache_nodes.is_empty(), "hoard job needs cache nodes");
        }
        self.jobs.push(job);
    }

    /// Source-class mix for `job` in its current epoch.
    fn classes(&self, job: &TrainJobSim) -> Vec<SourceClass> {
        let ds_bytes = job.spec.dataset.total_bytes as f64;
        match job.mode {
            ReadMode::Remote => {
                let h = if job.is_cold_epoch() {
                    0.0
                } else {
                    epoch_hit_rate(job.buffer_cache_bytes, ds_bytes)
                };
                let mut v = vec![];
                if h > 0.0 {
                    v.push(SourceClass {
                        frac: h,
                        path: vec![],
                        cap: f64::INFINITY,
                        remote_draw: 0.0,
                        kind: SourceKind::Ram,
                    });
                }
                if h < 1.0 {
                    v.push(SourceClass {
                        frac: 1.0 - h,
                        path: self.topology.path_from_external(self.nfs_res, job.node),
                        cap: f64::INFINITY,
                        remote_draw: 1.0,
                        kind: SourceKind::Remote,
                    });
                }
                v
            }
            ReadMode::LocalNvme => {
                let h = if job.is_cold_epoch() {
                    0.0
                } else {
                    epoch_hit_rate(job.buffer_cache_bytes, ds_bytes)
                };
                let mut v = vec![];
                if h > 0.0 {
                    v.push(SourceClass {
                        frac: h,
                        path: vec![],
                        cap: f64::INFINITY,
                        remote_draw: 0.0,
                        kind: SourceKind::Ram,
                    });
                }
                if h < 1.0 {
                    v.push(SourceClass {
                        frac: 1.0 - h,
                        path: vec![self.volume_res[job.node.0]],
                        cap: f64::INFINITY,
                        remote_draw: 0.0,
                        kind: SourceKind::Local,
                    });
                }
                v
            }
            ReadMode::Hoard => {
                if job.is_cold_epoch() {
                    // Cold epoch: AFM gateway path. Dataset fetched once
                    // cluster-wide; `sharers` jobs read it concurrently.
                    let sharers = self
                        .jobs
                        .iter()
                        .filter(|j| {
                            j.mode == ReadMode::Hoard
                                && j.spec.dataset.name == job.spec.dataset.name
                                && !j.finished
                                && j.epoch == 0
                        })
                        .count()
                        .max(1);
                    // Chunk-granular partial warmth: the resident fraction
                    // of the bitmap streams from the stripe (1/k local,
                    // rest peers), only the missing chunks pay the AFM
                    // cold path. `None` ⇒ fully cold (the classic path).
                    let rf = job.residency.as_ref().map_or(0.0, |cs| cs.resident_fraction());
                    let k = job.cache_nodes.len() as f64;
                    let mut v = vec![];
                    for &cn in &job.cache_nodes {
                        let frac = rf / k;
                        if frac <= 0.0 {
                            continue;
                        }
                        if cn == job.node {
                            v.push(SourceClass {
                                frac,
                                path: vec![self.volume_res[cn.0]],
                                cap: f64::INFINITY,
                                remote_draw: 0.0,
                                kind: SourceKind::Local,
                            });
                        } else {
                            let mut path = vec![self.volume_res[cn.0]];
                            path.extend(self.topology.path(cn, job.node));
                            v.push(SourceClass {
                                frac,
                                path,
                                cap: f64::INFINITY,
                                remote_draw: 0.0,
                                kind: SourceKind::Peer,
                            });
                        }
                    }
                    if rf < 1.0 {
                        v.push(SourceClass {
                            frac: 1.0 - rf,
                            path: self.topology.path_from_external(self.nfs_res, job.node),
                            cap: AFM_COLD_BW_PER_JOB,
                            remote_draw: 1.0 / sharers as f64,
                            kind: SourceKind::Remote,
                        });
                    }
                    v
                } else {
                    let h = epoch_hit_rate(job.pagepool_bytes, ds_bytes);
                    let k = job.cache_nodes.len() as f64;
                    let local = job.cache_nodes.contains(&job.node);
                    let mut v = vec![];
                    if h > 0.0 {
                        v.push(SourceClass {
                            frac: h,
                            path: vec![],
                            cap: f64::INFINITY,
                            remote_draw: 0.0,
                            kind: SourceKind::Ram,
                        });
                    }
                    for &cn in &job.cache_nodes {
                        let frac = (1.0 - h) / k;
                        if frac <= 0.0 {
                            continue;
                        }
                        if cn == job.node && local {
                            v.push(SourceClass {
                                frac,
                                path: vec![self.volume_res[cn.0]],
                                cap: f64::INFINITY,
                                remote_draw: 0.0,
                                kind: SourceKind::Local,
                            });
                        } else {
                            let mut path = vec![self.volume_res[cn.0]];
                            path.extend(self.topology.path(cn, job.node));
                            v.push(SourceClass {
                                frac,
                                path,
                                cap: f64::INFINITY,
                                remote_draw: 0.0,
                                kind: SourceKind::Peer,
                            });
                        }
                    }
                    v
                }
            }
        }
    }

    /// Per-job image rate cap from the GPUs (Spectrum client overhead
    /// applies whenever reads go through the cache client: warm epochs,
    /// warm starts, and the resident part of a partially-warm first epoch
    /// — so epoch time stays monotone in residency up to the full-bitmap
    /// endpoint, which is exactly the warm path).
    fn gpu_cap_bytes(&self, job: &TrainJobSim) -> f64 {
        let partially_warm =
            job.residency.as_ref().is_some_and(|cs| cs.resident_bytes() > 0);
        let eff = if job.mode == ReadMode::Hoard && (!job.is_cold_epoch() || partially_warm) {
            SPECTRUM_CLIENT_EFF
        } else {
            1.0
        };
        job.spec.demand.images_per_sec() * eff * job.item_bytes()
    }

    /// Solve the instantaneous rate (images/s) of every active job.
    /// Returns (job_rates, per-job class allocations in bytes/s).
    fn solve_rates(&self) -> (Vec<f64>, Vec<Vec<(SourceClass, f64)>>) {
        let active: Vec<usize> =
            (0..self.jobs.len()).filter(|&i| !self.jobs[i].finished).collect();
        let mut resources: Vec<Resource> = self.topology.resources().to_vec();
        let class_sets: Vec<Vec<SourceClass>> =
            active.iter().map(|&i| self.classes(&self.jobs[i])).collect();

        // NFS capacity degrades with concurrent seeky readers. Derived from
        // the class sets built above (building them twice made solve_rates
        // O(jobs²·classes) — §Perf iteration 1).
        let readers: u32 = active
            .iter()
            .zip(&class_sets)
            .filter(|(_, cs)| cs.iter().any(|c| c.kind == SourceKind::Remote))
            .map(|(&i, _)| self.jobs[i].spec.demand.gpus)
            .sum();
        resources[self.nfs_res.0].capacity = self.remote.effective_bw(readers.max(1));
        let gpu_caps: Vec<f64> = active.iter().map(|&i| self.gpu_cap_bytes(&self.jobs[i])).collect();

        // Fixed-point: demands follow the gated job rate; the fair share
        // follows demands. Monotone ⇒ converges in a few iterations.
        let mut job_bytes_rate: Vec<f64> = gpu_caps.clone();
        let mut allocs: Vec<Vec<f64>> = vec![];
        for _iter in 0..32 {
            let mut flows = Vec::new();
            let mut owner = Vec::new();
            for (ji, classes) in class_sets.iter().enumerate() {
                for (ci, c) in classes.iter().enumerate() {
                    let demand = (job_bytes_rate[ji] * c.frac).min(c.cap);
                    flows.push(Flow { path: c.path.clone(), demand });
                    owner.push((ji, ci));
                }
            }
            let rates = fair_share(&resources, &flows);
            // Gate each job by its slowest class (proportional mixing).
            let mut new_rate = vec![f64::INFINITY; active.len()];
            let mut per_job: Vec<Vec<f64>> = class_sets.iter().map(|c| vec![0.0; c.len()]).collect();
            for (fi, &(ji, ci)) in owner.iter().enumerate() {
                per_job[ji][ci] = rates[fi];
                let c = &class_sets[ji][ci];
                if c.frac > 1e-12 {
                    new_rate[ji] = new_rate[ji].min(rates[fi] / c.frac);
                }
            }
            for (ji, r) in new_rate.iter_mut().enumerate() {
                *r = r.min(gpu_caps[ji]);
                if !r.is_finite() {
                    *r = gpu_caps[ji];
                }
            }
            let max_delta = new_rate
                .iter()
                .zip(&job_bytes_rate)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            job_bytes_rate = new_rate;
            allocs = per_job;
            if max_delta < 1.0 {
                break;
            }
        }

        // Final per-class allocation at the gated rate.
        let mut out_rates = vec![0.0; self.jobs.len()];
        let mut out_allocs: Vec<Vec<(SourceClass, f64)>> = vec![vec![]; self.jobs.len()];
        for (ai, &ji) in active.iter().enumerate() {
            let img_rate = job_bytes_rate[ai] / self.jobs[ji].item_bytes();
            out_rates[ji] = img_rate;
            out_allocs[ji] = class_sets[ai]
                .iter()
                .zip(&allocs[ai])
                .map(|(c, _)| (c.clone(), job_bytes_rate[ai] * c.frac))
                .collect();
        }
        (out_rates, out_allocs)
    }

    /// Run to completion; panics if no progress is possible.
    pub fn run(&mut self) -> SimResult {
        let n = self.jobs.len();
        let mut outcomes: Vec<JobOutcome> = self
            .jobs
            .iter()
            .map(|j| JobOutcome {
                name: j.spec.name.clone(),
                epoch_durations: vec![],
                total_duration: 0.0,
                fps_series: vec![],
                bytes_from_remote: 0.0,
                bytes_from_local: 0.0,
                bytes_from_peers: 0.0,
                bytes_from_ram: 0.0,
            })
            .collect();
        let mut traffic = TrafficAccount::new(self.topology.resources().len());
        let mut t = 0.0f64;
        let mut epoch_start = vec![0.0f64; n];
        let mut next_sample = if self.sample_interval > 0.0 { self.sample_interval } else { f64::INFINITY };

        let mut guard = 0u64;
        while self.jobs.iter().any(|j| !j.finished) {
            guard += 1;
            assert!(guard < 10_000_000, "simulation did not converge");
            let (rates, allocs) = self.solve_rates();

            // Next event: earliest epoch completion or sample tick.
            let mut dt = f64::INFINITY;
            for (i, j) in self.jobs.iter().enumerate() {
                if j.finished {
                    continue;
                }
                let remaining = j.items() - j.images_done;
                if rates[i] > 1e-9 {
                    dt = dt.min(remaining / rates[i]);
                }
            }
            dt = dt.min(next_sample - t);
            assert!(dt.is_finite() && dt > 0.0, "stalled at t={t}: rates={rates:?}");

            // Advance.
            for (i, j) in self.jobs.iter_mut().enumerate() {
                if j.finished {
                    continue;
                }
                j.images_done += rates[i] * dt;
                let bytes = rates[i] * dt * j.item_bytes();
                for (c, _alloc) in &allocs[i] {
                    let share = bytes * c.frac;
                    match c.kind {
                        SourceKind::Ram => outcomes[i].bytes_from_ram += share,
                        SourceKind::Local => outcomes[i].bytes_from_local += share,
                        SourceKind::Peer => outcomes[i].bytes_from_peers += share,
                        SourceKind::Remote => outcomes[i].bytes_from_remote += share,
                    }
                    // Account network traffic: remote classes draw
                    // `remote_draw` of their bytes from the NFS resource.
                    let wire = if c.kind == SourceKind::Remote { share * c.remote_draw } else { share };
                    let rate = if dt > 0.0 { wire / dt } else { 0.0 };
                    traffic.record(&c.path, rate, dt);
                }
            }
            t += dt;

            if t >= next_sample - 1e-9 {
                for (i, j) in self.jobs.iter().enumerate() {
                    if !j.finished {
                        outcomes[i].fps_series.push((t, rates[i]));
                    }
                }
                next_sample += self.sample_interval;
            }

            // Epoch/job completions.
            for i in 0..n {
                let j = &mut self.jobs[i];
                if j.finished {
                    continue;
                }
                if j.images_done >= j.items() - 1e-6 {
                    outcomes[i].epoch_durations.push(t - epoch_start[i]);
                    epoch_start[i] = t;
                    j.images_done = 0.0;
                    j.epoch += 1;
                    if j.epoch >= j.spec.epochs {
                        j.finished = true;
                        outcomes[i].total_duration = t;
                    }
                }
            }
        }

        let makespan = t;
        SimResult { jobs: outcomes, traffic, nfs_resource: self.nfs_res, makespan }
    }
}

/// Convenience: the paper's testbed — 4 nodes, one 4-GPU AlexNet job per
/// node, all sharing ImageNet on the 1.05 GB/s NFS server, `epochs` long.
pub fn paper_scenario(mode: ReadMode, epochs: u32) -> TrainSim {
    use crate::remote::NfsModel;
    let topo = Topology::paper_testbed();
    let vols: Vec<Volume> = (0..4).map(|_| Volume::paper_cache_volume()).collect();
    let mut sim = TrainSim::new(topo, Box::new(NfsModel::paper_nfs()), &vols);
    let cache_nodes: Vec<NodeId> = (0..4).map(NodeId).collect();
    for i in 0..4 {
        let mut job = TrainJobSim::new(
            TrainJobSpec::paper_job(format!("job{i}"), epochs),
            NodeId(i),
            mode,
        );
        if mode == ReadMode::Hoard {
            job.cache_nodes = cache_nodes.clone();
            job.pagepool_bytes = 16e9; // modest pagepool (paper §4.2)
        }
        sim.add_job(job);
    }
    sim
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total_hours(res: &SimResult) -> f64 {
        res.makespan / 3600.0
    }

    #[test]
    fn rem_epoch_time_matches_table4() {
        // REM 60 epochs = 14.9 h (Table 4).
        let mut sim = paper_scenario(ReadMode::Remote, 60);
        let res = sim.run();
        let h = total_hours(&res);
        assert!((h - 14.9).abs() / 14.9 < 0.03, "got {h} h");
    }

    #[test]
    fn hoard_duration_matches_table4() {
        // Hoard 60 epochs = 6.97 h (Table 4).
        let mut sim = paper_scenario(ReadMode::Hoard, 60);
        let res = sim.run();
        let h = total_hours(&res);
        assert!((h - 6.97).abs() / 6.97 < 0.05, "got {h} h");
    }

    #[test]
    fn nvme_speedup_matches_table3() {
        let mut rem = paper_scenario(ReadMode::Remote, 2);
        let mut nvme = paper_scenario(ReadMode::LocalNvme, 2);
        let s = rem.run().makespan / nvme.run().makespan;
        assert!((s - 2.28).abs() / 2.28 < 0.05, "2-epoch NVMe speedup {s}");
    }

    #[test]
    fn hoard_2epoch_near_parity_with_rem() {
        // Table 3: Hoard at 2 epochs = 0.93× REM.
        let mut rem = paper_scenario(ReadMode::Remote, 2);
        let mut hoard = paper_scenario(ReadMode::Hoard, 2);
        let s = rem.run().makespan / hoard.run().makespan;
        assert!((s - 0.93).abs() < 0.04, "2-epoch Hoard speedup {s}");
    }

    #[test]
    fn hoard_90epoch_headline_speedup() {
        // The headline: 2.1× at 90 epochs.
        let mut rem = paper_scenario(ReadMode::Remote, 90);
        let mut hoard = paper_scenario(ReadMode::Hoard, 90);
        let s = rem.run().makespan / hoard.run().makespan;
        assert!((s - 2.1).abs() / 2.1 < 0.05, "90-epoch Hoard speedup {s}");
    }

    #[test]
    fn hoard_first_epoch_slow_then_fast() {
        let mut sim = paper_scenario(ReadMode::Hoard, 3);
        let res = sim.run();
        let e = &res.jobs[0].epoch_durations;
        assert_eq!(e.len(), 3);
        assert!(e[0] > 3.0 * e[1], "cold {:.0}s vs warm {:.0}s", e[0], e[1]);
        assert!((e[1] - e[2]).abs() / e[1] < 0.05, "warm epochs stable");
    }

    #[test]
    fn hoard_cold_epoch_fetches_dataset_once() {
        let mut sim = paper_scenario(ReadMode::Hoard, 2);
        let res = sim.run();
        let nfs_bytes = res.traffic.bytes[res.nfs_resource.0];
        let ds = 144e9;
        assert!(
            (nfs_bytes - ds).abs() / ds < 0.05,
            "NFS supplied {:.1} GB, want ~144 (fetch-once)",
            nfs_bytes / 1e9
        );
    }

    #[test]
    fn rem_fetches_dataset_per_job_per_epoch() {
        let mut sim = paper_scenario(ReadMode::Remote, 2);
        let res = sim.run();
        let nfs_bytes = res.traffic.bytes[res.nfs_resource.0];
        let want = 144e9 * 4.0 * 2.0;
        assert!((nfs_bytes - want).abs() / want < 0.02, "NFS {nfs_bytes}");
    }

    #[test]
    fn buffer_cache_accelerates_rem_epochs_when_nearly_resident() {
        // MDR ≈ 0.9: LRU hit rate ≈ 0.68 ⇒ warm epochs much faster.
        let mut sim = paper_scenario(ReadMode::Remote, 3);
        for j in &mut sim.jobs {
            j.buffer_cache_bytes = 130e9;
        }
        let res = sim.run();
        let e = &res.jobs[0].epoch_durations;
        assert!(e[1] < e[0] * 0.8, "warm epoch should benefit from cache: {e:?}");
    }

    #[test]
    fn buffer_cache_at_mdr_half_barely_helps_rem() {
        // The Figure 4 effect: at MDR 0.5 the LRU trashes (h ≈ 0.15) and
        // REM stays NFS-bound.
        let mut sim = paper_scenario(ReadMode::Remote, 3);
        for j in &mut sim.jobs {
            j.buffer_cache_bytes = 72e9;
        }
        let res = sim.run();
        let e = &res.jobs[0].epoch_durations;
        assert!(e[1] > e[0] * 0.75, "MDR 0.5 should trash, not accelerate: {e:?}");
        assert!(e[1] < e[0], "but it should help a little: {e:?}");
    }

    #[test]
    fn nvme_epochs_are_gpu_bound() {
        let mut sim = paper_scenario(ReadMode::LocalNvme, 2);
        let res = sim.run();
        let e1 = res.jobs[0].epoch_durations[1];
        // 1.28M images at ~3324 img/s ⇒ ~385 s.
        assert!((e1 - 385.0).abs() / 385.0 < 0.03, "epoch {e1}");
    }

    #[test]
    fn fps_series_collected() {
        let mut sim = paper_scenario(ReadMode::Hoard, 2);
        sim.sample_interval = 60.0;
        let res = sim.run();
        assert!(res.jobs[0].fps_series.len() > 10);
        // Warm-epoch samples must be faster than cold-epoch samples.
        let first = res.jobs[0].fps_series.first().unwrap().1;
        let last = res.jobs[0].fps_series.last().unwrap().1;
        assert!(last > 2.0 * first, "cold {first} vs warm {last}");
    }

    fn residency(frac: f64) -> ChunkSet {
        let mut cs = ChunkSet::new(144_000_000_000, 64 << 20);
        let n = (cs.num_chunks() as f64 * frac).round() as u64;
        for c in 0..n {
            cs.mark(c);
        }
        cs
    }

    #[test]
    fn partial_residency_interpolates_cold_epoch() {
        let first_epoch = |frac: f64| {
            let mut sim = paper_scenario(ReadMode::Hoard, 2);
            for j in &mut sim.jobs {
                if frac > 0.0 {
                    j.set_residency(residency(frac));
                }
            }
            sim.run().jobs[0].epoch_durations[0]
        };
        let cold = first_epoch(0.0);
        let half = first_epoch(0.5);
        let almost = first_epoch(0.99);
        let full = first_epoch(1.0);
        assert!(
            half < cold * 0.75,
            "half-resident first epoch should be much faster: {half:.0}s vs {cold:.0}s"
        );
        assert!(full < half, "fully resident beats half: {full:.0}s vs {half:.0}s");
        // Monotone through the top end: the resident fraction pays the
        // Spectrum client overhead, so 99% residency cannot be modeled
        // *faster* than the fully-warm endpoint.
        assert!(
            almost < half && full <= almost * 1.001,
            "monotone near full residency: full {full:.0}s, 99% {almost:.0}s, half {half:.0}s"
        );
    }

    #[test]
    fn full_residency_bit_identical_to_warm_start() {
        // A full bitmap is *exactly* the warm-start path — sim and real
        // mode agree on what "fully cached" means by construction.
        let run = |via_chunks: bool| {
            let mut sim = paper_scenario(ReadMode::Hoard, 2);
            for j in &mut sim.jobs {
                if via_chunks {
                    j.set_residency(residency(1.0));
                } else {
                    j.set_warm();
                }
            }
            let res = sim.run();
            (res.makespan.to_bits(), res.jobs[0].epoch_durations[0].to_bits())
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn empty_residency_bit_identical_to_cold_start() {
        let run = |with_empty_bitmap: bool| {
            let mut sim = paper_scenario(ReadMode::Hoard, 2);
            if with_empty_bitmap {
                for j in &mut sim.jobs {
                    j.set_residency(residency(0.0));
                }
            }
            sim.run().makespan.to_bits()
        };
        assert_eq!(run(true), run(false), "empty bitmap must be the classic cold path");
    }

    #[test]
    fn byte_accounting_conserves() {
        let mut sim = paper_scenario(ReadMode::Hoard, 3);
        let res = sim.run();
        for j in &res.jobs {
            let want = 144e9 * 3.0;
            let got = j.total_bytes_read();
            assert!((got - want).abs() / want < 0.02, "{} read {got}", j.name);
        }
    }
}
