//! Synthetic dataset generation for the *real-mode* pipeline (the e2e
//! example): a directory tree of binary "image" records a DL job can read
//! through the Hoard VFS and feed to the AOT train step.
//!
//! Record layout (little-endian): 4-byte magic "HIMG", u32 label,
//! then H*W*C u8 pixels. Pixels are drawn so that class k has a distinct
//! per-channel mean — a learnable signal for the e2e loss-curve check.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::util::Rng;

pub const MAGIC: &[u8; 4] = b"HIMG";

#[derive(Debug, Clone)]
pub struct DataGenConfig {
    pub num_items: u64,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub num_classes: u32,
    pub seed: u64,
    /// Files per subdirectory (ImageNet-style sharding).
    pub files_per_dir: u64,
}

impl Default for DataGenConfig {
    fn default() -> Self {
        DataGenConfig {
            num_items: 4096,
            height: 32,
            width: 32,
            channels: 3,
            num_classes: 10,
            seed: 0xDA7A,
            files_per_dir: 512,
        }
    }
}

impl DataGenConfig {
    pub fn record_bytes(&self) -> usize {
        8 + self.height * self.width * self.channels
    }

    /// Path of item `i` relative to the dataset root.
    pub fn item_rel_path(&self, i: u64) -> PathBuf {
        PathBuf::from(format!("shard{:04}/img{:07}.himg", i / self.files_per_dir, i))
    }
}

/// Deterministically generate record `i` (label + pixels) in memory.
pub fn make_record(cfg: &DataGenConfig, i: u64) -> (u32, Vec<u8>) {
    let mut rng = Rng::new(cfg.seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let label = (rng.next_u64() % cfg.num_classes as u64) as u32;
    let n = cfg.height * cfg.width * cfg.channels;
    let mut px = vec![0u8; n];
    // Class signal: per-channel mean shifted by label; noise on top.
    for (idx, p) in px.iter_mut().enumerate() {
        let ch = idx % cfg.channels;
        let base = 40.0
            + 170.0 * ((label as usize + ch) % cfg.num_classes as usize) as f64
                / cfg.num_classes as f64;
        let noise = rng.range_f64(-30.0, 30.0);
        *p = (base + noise).clamp(0.0, 255.0) as u8;
    }
    let mut rec = Vec::with_capacity(cfg.record_bytes());
    rec.extend_from_slice(MAGIC);
    rec.extend_from_slice(&label.to_le_bytes());
    rec.extend_from_slice(&px);
    (label, rec)
}

/// Parse a record produced by `make_record`. Returns (label, pixels).
pub fn parse_record(cfg: &DataGenConfig, data: &[u8]) -> anyhow::Result<(u32, Vec<u8>)> {
    let need = cfg.record_bytes();
    if data.len() != need {
        anyhow::bail!("record size {} != expected {need}", data.len());
    }
    if &data[..4] != MAGIC {
        anyhow::bail!("bad record magic");
    }
    let label = u32::from_le_bytes([data[4], data[5], data[6], data[7]]);
    Ok((label, data[8..].to_vec()))
}

/// Write the whole dataset under `root`. Returns total bytes written.
pub fn generate(root: &Path, cfg: &DataGenConfig) -> anyhow::Result<u64> {
    let mut total = 0u64;
    for i in 0..cfg.num_items {
        let rel = cfg.item_rel_path(i);
        let path = root.join(&rel);
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let (_, rec) = make_record(cfg, i);
        let mut f = fs::File::create(&path)?;
        f.write_all(&rec)?;
        total += rec.len() as u64;
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrip() {
        let cfg = DataGenConfig::default();
        let (label, rec) = make_record(&cfg, 17);
        let (l2, px) = parse_record(&cfg, &rec).unwrap();
        assert_eq!(label, l2);
        assert_eq!(px.len(), 32 * 32 * 3);
    }

    #[test]
    fn records_deterministic() {
        let cfg = DataGenConfig::default();
        assert_eq!(make_record(&cfg, 5), make_record(&cfg, 5));
        assert_ne!(make_record(&cfg, 5).1, make_record(&cfg, 6).1);
    }

    #[test]
    fn labels_cover_classes() {
        let cfg = DataGenConfig::default();
        let mut seen = std::collections::HashSet::new();
        for i in 0..200 {
            seen.insert(make_record(&cfg, i).0);
        }
        assert_eq!(seen.len() as u32, cfg.num_classes);
    }

    #[test]
    fn class_signal_separates_means() {
        let cfg = DataGenConfig::default();
        // Mean channel-0 intensity must differ across two labels.
        let mut by_label: std::collections::HashMap<u32, (f64, u64)> = Default::default();
        for i in 0..400 {
            let (label, rec) = make_record(&cfg, i);
            let px = &rec[8..];
            let mean: f64 = px.iter().step_by(3).map(|&b| b as f64).sum::<f64>()
                / (px.len() / 3) as f64;
            let e = by_label.entry(label).or_insert((0.0, 0));
            e.0 += mean;
            e.1 += 1;
        }
        let means: Vec<f64> = by_label.values().map(|(s, n)| s / *n as f64).collect();
        let spread = means.iter().cloned().fold(f64::MIN, f64::max)
            - means.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 20.0, "class means too close: {means:?}");
    }

    #[test]
    fn generate_writes_tree() {
        let dir = std::env::temp_dir().join(format!("hoard-datagen-{}", std::process::id()));
        let cfg = DataGenConfig { num_items: 20, files_per_dir: 8, ..Default::default() };
        let total = generate(&dir, &cfg).unwrap();
        assert_eq!(total, 20 * cfg.record_bytes() as u64);
        assert!(dir.join("shard0000/img0000000.himg").exists());
        assert!(dir.join("shard0002/img0000016.himg").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn parse_rejects_corruption() {
        let cfg = DataGenConfig::default();
        let (_, mut rec) = make_record(&cfg, 0);
        rec[0] = b'X';
        assert!(parse_record(&cfg, &rec).is_err());
        assert!(parse_record(&cfg, &rec[..10]).is_err());
    }
}
