//! Per-epoch random-permutation sampling — the DL access pattern that
//! motivates dataset-granular caching (paper §2, Requirement 2): every epoch
//! touches the *entire* dataset exactly once, in fresh random order.

use crate::util::Rng;

/// Iterates item indices epoch by epoch; each epoch is a fresh Fisher–Yates
/// permutation of `0..n`.
#[derive(Debug)]
pub struct EpochSampler {
    n: u64,
    order: Vec<u64>,
    pos: usize,
    pub epoch: u32,
    rng: Rng,
}

impl EpochSampler {
    pub fn new(n: u64, seed: u64) -> Self {
        assert!(n > 0);
        let mut s = EpochSampler {
            n,
            order: (0..n).collect(),
            pos: 0,
            epoch: 0,
            rng: Rng::new(seed),
        };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.pos = 0;
    }

    /// Next item index; rolls into the next epoch transparently and reports
    /// whether this call crossed an epoch boundary.
    pub fn next(&mut self) -> (u64, bool) {
        if self.pos == self.order.len() {
            self.epoch += 1;
            self.reshuffle();
            let item = self.order[self.pos];
            self.pos += 1;
            return (item, true);
        }
        let item = self.order[self.pos];
        self.pos += 1;
        (item, false)
    }

    /// Next `k` items as a batch (may cross an epoch boundary).
    pub fn next_batch(&mut self, k: usize) -> Vec<u64> {
        (0..k).map(|_| self.next().0).collect()
    }

    pub fn items_per_epoch(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn epoch_covers_every_item_once() {
        let mut s = EpochSampler::new(100, 1);
        let items: HashSet<u64> = (0..100).map(|_| s.next().0).collect();
        assert_eq!(items.len(), 100);
    }

    #[test]
    fn epoch_boundary_flag() {
        let mut s = EpochSampler::new(10, 2);
        for _ in 0..10 {
            let (_, boundary) = s.next();
            assert!(!boundary);
        }
        let (_, boundary) = s.next();
        assert!(boundary);
        assert_eq!(s.epoch, 1);
    }

    #[test]
    fn epochs_are_different_permutations() {
        let mut s = EpochSampler::new(50, 3);
        let e0: Vec<u64> = (0..50).map(|_| s.next().0).collect();
        let e1: Vec<u64> = (0..50).map(|_| s.next().0).collect();
        assert_ne!(e0, e1);
        let h0: HashSet<_> = e0.iter().collect();
        let h1: HashSet<_> = e1.iter().collect();
        assert_eq!(h0, h1);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = EpochSampler::new(20, 9);
        let mut b = EpochSampler::new(20, 9);
        for _ in 0..60 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn batch_spans_boundary() {
        let mut s = EpochSampler::new(8, 4);
        let batch = s.next_batch(12);
        assert_eq!(batch.len(), 12);
        assert_eq!(s.epoch, 1);
    }

    #[test]
    fn prop_every_epoch_is_permutation() {
        use crate::util::{prop::forall, Rng};
        forall(
            50,
            |rng: &mut Rng| (1 + rng.gen_range(200), rng.next_u64()),
            |&(n, seed)| {
                let mut s = EpochSampler::new(n, seed);
                for _ in 0..3 {
                    let mut seen = HashSet::new();
                    for _ in 0..n {
                        let (item, _) = s.next();
                        if item >= n {
                            return Err(format!("item {item} out of range {n}"));
                        }
                        if !seen.insert(item) {
                            return Err(format!("item {item} repeated within epoch"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
