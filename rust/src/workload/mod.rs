//! Training workload models: dataset specs, per-epoch random sampling, and
//! the DL-job descriptions the simulations and the real-mode driver share.

pub mod datagen;
pub mod sampler;
pub mod trainsim;

pub use sampler::EpochSampler;
pub use trainsim::{JobOutcome, ReadMode, TrainJobSim, TrainSim};

use crate::cluster::GpuDemand;
use crate::util::fmt::GB;

/// A training dataset as the storage layer sees it.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSpec {
    pub name: String,
    pub num_items: u64,
    pub total_bytes: u64,
}

impl DatasetSpec {
    pub fn new(name: impl Into<String>, num_items: u64, total_bytes: u64) -> Self {
        assert!(num_items > 0, "dataset must have items");
        DatasetSpec { name: name.into(), num_items, total_bytes }
    }

    /// The paper's workload: ImageNet ILSVRC-2012 train split, ~144 GB on
    /// disk, 1.28 M images ⇒ ~112.5 KB average.
    pub fn imagenet() -> Self {
        DatasetSpec::new("imagenet", 1_281_167, 144 * GB)
    }

    pub fn avg_item_bytes(&self) -> f64 {
        self.total_bytes as f64 / self.num_items as f64
    }
}

/// A DL training job description (what a `DlJob` custom resource carries).
#[derive(Debug, Clone)]
pub struct TrainJobSpec {
    pub name: String,
    pub dataset: DatasetSpec,
    pub demand: GpuDemand,
    pub epochs: u32,
}

impl TrainJobSpec {
    /// The paper's evaluation job: AlexNet BS=1536 on 4 P100s over ImageNet.
    pub fn paper_job(name: impl Into<String>, epochs: u32) -> Self {
        TrainJobSpec {
            name: name.into(),
            dataset: DatasetSpec::imagenet(),
            demand: GpuDemand::paper_alexnet_job(),
            epochs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imagenet_item_size() {
        let ds = DatasetSpec::imagenet();
        let avg = ds.avg_item_bytes();
        assert!((avg - 120e3).abs() < 10e3, "avg = {avg}"); // ~112.5 KB (GiB-based)
    }

    #[test]
    #[should_panic(expected = "dataset must have items")]
    fn zero_items_rejected() {
        DatasetSpec::new("empty", 0, 0);
    }

    #[test]
    fn paper_job_shape() {
        let j = TrainJobSpec::paper_job("j0", 90);
        assert_eq!(j.demand.gpus, 4);
        assert_eq!(j.epochs, 90);
    }
}
