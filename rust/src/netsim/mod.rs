//! Flow-level network/resource simulation.
//!
//! Models the data-center fabric of the paper's testbed (nodes on a 100 GbE
//! network; racks with 40 G TOR switches and 3:1-oversubscribed uplinks for
//! the Table 5 analysis) plus any other rate-limited resource (NFS server,
//! NVMe device) as capacity-constrained `Resource`s. Concurrent transfers
//! are `Flow`s over paths of resources; instantaneous rates come from
//! demand-capped **max-min fair** allocation (progressive water-filling),
//! which is the standard fluid approximation for TCP-like fair sharing.

pub mod fair;
pub mod topology;

pub use fair::{fair_share, Flow, FlowId, Resource, ResourceId};
pub use topology::{LinkClass, NodeId, RackId, Topology, TrafficAccount};
