//! Demand-capped max-min fair bandwidth allocation (water-filling).
//!
//! Each `Flow` crosses a set of `Resource`s (links, disks, servers) and has
//! an intrinsic demand cap (e.g. the GPU can only consume 343 MB/s of
//! images). The allocator repeatedly finds the most constrained resource,
//! fixes the fair share of all flows crossing it, removes them, and repeats
//! — the classic progressive-filling algorithm. O(R * F) per round, R
//! rounds worst case; our experiments have tens of flows, so this is
//! microseconds (see benches/perf_fairshare.rs).

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub usize);

/// A capacity-constrained resource, in bytes/second.
#[derive(Debug, Clone)]
pub struct Resource {
    pub name: String,
    pub capacity: f64,
}

/// A flow crossing `path` resources, wanting at most `demand` bytes/second.
#[derive(Debug, Clone)]
pub struct Flow {
    pub path: Vec<ResourceId>,
    pub demand: f64,
}

/// Compute the max-min fair rate for every flow. Returns rates indexed like
/// `flows`. Flows with empty paths are only capped by their demand.
pub fn fair_share(resources: &[Resource], flows: &[Flow]) -> Vec<f64> {
    let nf = flows.len();
    let nr = resources.len();
    let mut rate = vec![0.0f64; nf];
    let mut frozen = vec![false; nf];
    let mut remaining_cap: Vec<f64> = resources.iter().map(|r| r.capacity).collect();

    for (i, f) in flows.iter().enumerate() {
        debug_assert!(f.demand >= 0.0, "negative demand");
        for r in &f.path {
            debug_assert!(r.0 < nr, "flow references unknown resource {}", r.0);
        }
        if f.path.is_empty() {
            rate[i] = f.demand;
            frozen[i] = true;
        }
    }

    // Unfrozen flow indices; shrinks each round so later rounds never
    // rescan settled flows (§Perf iteration 2).
    let mut unfrozen: Vec<usize> = (0..nf).filter(|&i| !frozen[i]).collect();
    let mut active = vec![0usize; nr];

    while !unfrozen.is_empty() {
        // Active flow count per resource.
        active.iter_mut().for_each(|a| *a = 0);
        for &i in &unfrozen {
            for r in &flows[i].path {
                active[r.0] += 1;
            }
        }

        // The binding constraint: min over resources of cap/active, and min
        // over unfrozen flows of their remaining demand.
        let mut level = f64::INFINITY;
        for r in 0..nr {
            if active[r] > 0 {
                level = level.min(remaining_cap[r] / active[r] as f64);
            }
        }
        let mut demand_binds = false;
        for &i in &unfrozen {
            if flows[i].demand <= level {
                level = level.min(flows[i].demand);
                demand_binds = true;
            }
        }
        debug_assert!(level.is_finite(), "no binding constraint");
        let level = level.max(0.0);

        // Freeze flows bound at this level: demand-capped flows first (they
        // may leave capacity for others), otherwise everyone on a saturated
        // resource.
        let mut froze = false;
        if demand_binds {
            unfrozen.retain(|&i| {
                let f = &flows[i];
                if f.demand <= level + 1e-12 {
                    rate[i] = f.demand;
                    frozen[i] = true;
                    froze = true;
                    for r in &f.path {
                        remaining_cap[r.0] = (remaining_cap[r.0] - f.demand).max(0.0);
                    }
                    false
                } else {
                    true
                }
            });
        } else {
            // Freeze flows crossing any resource saturated at this level.
            // The saturated set is computed from a single snapshot (before
            // any freezing this round) — determining it incrementally would
            // mis-freeze flows on resources relieved earlier in the round.
            let saturated: Vec<bool> = (0..nr)
                .map(|r| active[r] > 0 && remaining_cap[r] / active[r] as f64 <= level + 1e-12)
                .collect();
            unfrozen.retain(|&i| {
                let f = &flows[i];
                if f.path.iter().any(|rr| saturated[rr.0]) {
                    rate[i] = level;
                    frozen[i] = true;
                    froze = true;
                    for rr in &f.path {
                        remaining_cap[rr.0] = (remaining_cap[rr.0] - level).max(0.0);
                    }
                    false
                } else {
                    true
                }
            });
        }
        if !froze {
            // Numerical corner: freeze everything at the level and stop.
            for &i in &unfrozen {
                rate[i] = level;
                frozen[i] = true;
            }
            break;
        }
    }
    rate
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(caps: &[f64]) -> Vec<Resource> {
        caps.iter()
            .enumerate()
            .map(|(i, &c)| Resource { name: format!("r{i}"), capacity: c })
            .collect()
    }

    #[test]
    fn single_bottleneck_equal_split() {
        let r = res(&[100.0]);
        let f = vec![
            Flow { path: vec![ResourceId(0)], demand: f64::INFINITY },
            Flow { path: vec![ResourceId(0)], demand: f64::INFINITY },
        ];
        let rates = fair_share(&r, &f);
        assert!((rates[0] - 50.0).abs() < 1e-9);
        assert!((rates[1] - 50.0).abs() < 1e-9);
    }

    #[test]
    fn demand_capped_flow_releases_capacity() {
        let r = res(&[100.0]);
        let f = vec![
            Flow { path: vec![ResourceId(0)], demand: 10.0 },
            Flow { path: vec![ResourceId(0)], demand: f64::INFINITY },
        ];
        let rates = fair_share(&r, &f);
        assert!((rates[0] - 10.0).abs() < 1e-9);
        assert!((rates[1] - 90.0).abs() < 1e-9);
    }

    #[test]
    fn multi_hop_takes_tightest_link() {
        let r = res(&[100.0, 30.0]);
        let f = vec![Flow { path: vec![ResourceId(0), ResourceId(1)], demand: f64::INFINITY }];
        let rates = fair_share(&r, &f);
        assert!((rates[0] - 30.0).abs() < 1e-9);
    }

    #[test]
    fn classic_three_flow_example() {
        // Two links A (cap 10) and B (cap 8). f0 uses A+B, f1 uses A, f2 uses B.
        // Max-min: f0 = 4 (B bottleneck), f2 = 4, then f1 = 6 on A.
        let r = res(&[10.0, 8.0]);
        let f = vec![
            Flow { path: vec![ResourceId(0), ResourceId(1)], demand: f64::INFINITY },
            Flow { path: vec![ResourceId(0)], demand: f64::INFINITY },
            Flow { path: vec![ResourceId(1)], demand: f64::INFINITY },
        ];
        let rates = fair_share(&r, &f);
        assert!((rates[0] - 4.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[1] - 6.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[2] - 4.0).abs() < 1e-9, "{rates:?}");
    }

    #[test]
    fn empty_path_flow_gets_demand() {
        let rates = fair_share(&[], &[Flow { path: vec![], demand: 7.0 }]);
        assert_eq!(rates, vec![7.0]);
    }

    #[test]
    fn zero_demand_flow() {
        let r = res(&[100.0]);
        let f = vec![
            Flow { path: vec![ResourceId(0)], demand: 0.0 },
            Flow { path: vec![ResourceId(0)], demand: f64::INFINITY },
        ];
        let rates = fair_share(&r, &f);
        assert_eq!(rates[0], 0.0);
        assert!((rates[1] - 100.0).abs() < 1e-9);
    }

    // Property: allocations never exceed capacity on any resource, never
    // exceed demand, and the allocation is Pareto-efficient on every
    // bottleneck (some resource is saturated or all demands met).
    #[test]
    fn prop_feasible_and_efficient() {
        use crate::util::{prop::forall, Rng};
        forall(
            300,
            |rng: &mut Rng| {
                let nr = 1 + rng.gen_range(5) as usize;
                let resources: Vec<f64> =
                    (0..nr).map(|_| rng.range_f64(1.0, 100.0)).collect();
                let nf = 1 + rng.gen_range(8) as usize;
                let flows: Vec<(Vec<usize>, f64)> = (0..nf)
                    .map(|_| {
                        let hops = 1 + rng.gen_range(nr as u64) as usize;
                        let mut path: Vec<usize> =
                            (0..nr).collect();
                        rng.shuffle(&mut path);
                        path.truncate(hops);
                        let demand = if rng.bool(0.3) {
                            f64::INFINITY
                        } else {
                            rng.range_f64(0.0, 150.0)
                        };
                        (path, demand)
                    })
                    .collect();
                (resources, flows)
            },
            |(resources, flows)| {
                let rs = res(resources);
                let fs: Vec<Flow> = flows
                    .iter()
                    .map(|(p, d)| Flow {
                        path: p.iter().map(|&i| ResourceId(i)).collect(),
                        demand: *d,
                    })
                    .collect();
                let rates = fair_share(&rs, &fs);
                // Feasibility per resource.
                for (ri, r) in rs.iter().enumerate() {
                    let load: f64 = fs
                        .iter()
                        .zip(&rates)
                        .filter(|(f, _)| f.path.iter().any(|rr| rr.0 == ri))
                        .map(|(_, &rt)| rt)
                        .sum();
                    if load > r.capacity * (1.0 + 1e-6) + 1e-6 {
                        return Err(format!("resource {ri} over capacity: {load} > {}", r.capacity));
                    }
                }
                // Demand caps.
                for (i, f) in fs.iter().enumerate() {
                    if rates[i] > f.demand * (1.0 + 1e-9) + 1e-9 {
                        return Err(format!("flow {i} exceeds demand"));
                    }
                    if rates[i] < 0.0 {
                        return Err(format!("flow {i} negative rate"));
                    }
                }
                // Efficiency: every flow is either demand-met or crosses a
                // saturated resource.
                for (i, f) in fs.iter().enumerate() {
                    if rates[i] + 1e-6 >= f.demand {
                        continue;
                    }
                    let crosses_saturated = f.path.iter().any(|rr| {
                        let load: f64 = fs
                            .iter()
                            .zip(&rates)
                            .filter(|(g, _)| g.path.contains(rr))
                            .map(|(_, &rt)| rt)
                            .sum();
                        load >= rs[rr.0].capacity * (1.0 - 1e-6) - 1e-6
                    });
                    if !crosses_saturated {
                        return Err(format!("flow {i} starved without saturation"));
                    }
                }
                Ok(())
            },
        );
    }
}
