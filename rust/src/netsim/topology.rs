//! Data-center topology: nodes grouped into racks, TOR switches, rack
//! uplinks to a core. Produces the `Resource` list + path lookup used by the
//! fair-share allocator, and accounts per-link traffic (Table 4/5).

use super::fair::{Resource, ResourceId};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RackId(pub usize);

/// What a resource in the topology represents (for accounting/reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// Node NIC (full-duplex modelled as one resource per direction).
    NicTx(usize),
    NicRx(usize),
    /// Rack uplink to the core (the Table 5 resource), per direction.
    UplinkTx(usize),
    UplinkRx(usize),
    /// Extra non-topology resource registered by the caller (NFS server,
    /// NVMe device, ...).
    External,
}

/// A static fat-tree-lite topology: `racks` racks × `nodes_per_rack` nodes.
/// Intra-rack traffic crosses only the two NICs (TOR assumed
/// non-blocking, as in the paper's single-switch 100 GbE testbed);
/// inter-rack traffic additionally crosses both rack uplinks.
#[derive(Debug, Clone)]
pub struct Topology {
    pub racks: usize,
    pub nodes_per_rack: usize,
    resources: Vec<Resource>,
    classes: Vec<LinkClass>,
    nic_tx: Vec<ResourceId>,
    nic_rx: Vec<ResourceId>,
    uplink_tx: Vec<ResourceId>,
    uplink_rx: Vec<ResourceId>,
}

impl Topology {
    /// `nic_bw` and `uplink_bw` in bytes/second.
    pub fn new(racks: usize, nodes_per_rack: usize, nic_bw: f64, uplink_bw: f64) -> Self {
        let mut t = Topology {
            racks,
            nodes_per_rack,
            resources: Vec::new(),
            classes: Vec::new(),
            nic_tx: Vec::new(),
            nic_rx: Vec::new(),
            uplink_tx: Vec::new(),
            uplink_rx: Vec::new(),
        };
        for n in 0..racks * nodes_per_rack {
            let tx = t.add(format!("node{n}.nic.tx"), nic_bw, LinkClass::NicTx(n));
            let rx = t.add(format!("node{n}.nic.rx"), nic_bw, LinkClass::NicRx(n));
            t.nic_tx.push(tx);
            t.nic_rx.push(rx);
        }
        for r in 0..racks {
            let tx = t.add(format!("rack{r}.uplink.tx"), uplink_bw, LinkClass::UplinkTx(r));
            let rx = t.add(format!("rack{r}.uplink.rx"), uplink_bw, LinkClass::UplinkRx(r));
            t.uplink_tx.push(tx);
            t.uplink_rx.push(rx);
        }
        t
    }

    /// The paper's testbed (Table 2): 1 rack, 4 nodes, 100 GbE NICs.
    /// 100 Gb/s = 12.5 GB/s; uplink irrelevant in a single rack (set high).
    pub fn paper_testbed() -> Self {
        Topology::new(1, 4, 12.5e9, f64::INFINITY)
    }

    fn add(&mut self, name: String, capacity: f64, class: LinkClass) -> ResourceId {
        let id = ResourceId(self.resources.len());
        self.resources.push(Resource { name, capacity });
        self.classes.push(class);
        id
    }

    /// Register an external rate-limited resource (NFS server, device...).
    pub fn add_external(&mut self, name: impl Into<String>, capacity: f64) -> ResourceId {
        self.add(name.into(), capacity, LinkClass::External)
    }

    pub fn num_nodes(&self) -> usize {
        self.racks * self.nodes_per_rack
    }

    pub fn rack_of(&self, n: NodeId) -> RackId {
        RackId(n.0 / self.nodes_per_rack)
    }

    pub fn nodes_in_rack(&self, r: RackId) -> impl Iterator<Item = NodeId> {
        let lo = r.0 * self.nodes_per_rack;
        (lo..lo + self.nodes_per_rack).map(NodeId)
    }

    pub fn resources(&self) -> &[Resource] {
        &self.resources
    }

    pub fn class_of(&self, r: ResourceId) -> LinkClass {
        self.classes[r.0]
    }

    pub fn uplink_tx_of(&self, r: RackId) -> ResourceId {
        self.uplink_tx[r.0]
    }

    pub fn uplink_rx_of(&self, r: RackId) -> ResourceId {
        self.uplink_rx[r.0]
    }

    /// Resources crossed by a transfer `from -> to`. Same node: none (local
    /// DMA). Same rack: sender NIC tx + receiver NIC rx. Cross-rack: NICs +
    /// both rack uplinks.
    pub fn path(&self, from: NodeId, to: NodeId) -> Vec<ResourceId> {
        if from == to {
            return vec![];
        }
        let mut p = vec![self.nic_tx[from.0], self.nic_rx[to.0]];
        let (rf, rt) = (self.rack_of(from), self.rack_of(to));
        if rf != rt {
            p.push(self.uplink_tx[rf.0]);
            p.push(self.uplink_rx[rt.0]);
        }
        p
    }

    /// Path for traffic entering the cluster from an external resource
    /// (e.g. the NFS server, which the paper places on a separate network).
    pub fn path_from_external(&self, ext: ResourceId, to: NodeId) -> Vec<ResourceId> {
        vec![ext, self.nic_rx[to.0]]
    }
}

/// Per-resource byte counters, advanced by the fluid simulation.
#[derive(Debug, Clone)]
pub struct TrafficAccount {
    pub bytes: Vec<f64>,
}

impl TrafficAccount {
    pub fn new(num_resources: usize) -> Self {
        TrafficAccount { bytes: vec![0.0; num_resources] }
    }

    /// Record `rate` bytes/s sustained for `dt` seconds over `path`.
    pub fn record(&mut self, path: &[ResourceId], rate: f64, dt: f64) {
        for r in path {
            self.bytes[r.0] += rate * dt;
        }
    }

    pub fn total(&self, ids: &[ResourceId]) -> f64 {
        ids.iter().map(|r| self.bytes[r.0]).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rack_membership() {
        let t = Topology::new(3, 4, 1.0, 1.0);
        assert_eq!(t.num_nodes(), 12);
        assert_eq!(t.rack_of(NodeId(0)), RackId(0));
        assert_eq!(t.rack_of(NodeId(4)), RackId(1));
        assert_eq!(t.rack_of(NodeId(11)), RackId(2));
        let r1: Vec<_> = t.nodes_in_rack(RackId(1)).collect();
        assert_eq!(r1, vec![NodeId(4), NodeId(5), NodeId(6), NodeId(7)]);
    }

    #[test]
    fn local_path_is_empty() {
        let t = Topology::new(1, 4, 1.0, 1.0);
        assert!(t.path(NodeId(2), NodeId(2)).is_empty());
    }

    #[test]
    fn intra_rack_path_two_hops() {
        let t = Topology::new(2, 2, 1.0, 1.0);
        let p = t.path(NodeId(0), NodeId(1));
        assert_eq!(p.len(), 2);
        assert_eq!(t.class_of(p[0]), LinkClass::NicTx(0));
        assert_eq!(t.class_of(p[1]), LinkClass::NicRx(1));
    }

    #[test]
    fn inter_rack_path_crosses_uplinks() {
        let t = Topology::new(2, 2, 1.0, 1.0);
        let p = t.path(NodeId(0), NodeId(3));
        assert_eq!(p.len(), 4);
        assert!(p.contains(&t.uplink_tx_of(RackId(0))));
        assert!(p.contains(&t.uplink_rx_of(RackId(1))));
    }

    #[test]
    fn external_resource_registered() {
        let mut t = Topology::new(1, 2, 1.0, 1.0);
        let nfs = t.add_external("nfs", 1.05e9);
        assert_eq!(t.class_of(nfs), LinkClass::External);
        let p = t.path_from_external(nfs, NodeId(1));
        assert_eq!(p.len(), 2);
        assert_eq!(p[0], nfs);
    }

    #[test]
    fn traffic_accounting() {
        let t = Topology::new(1, 2, 1.0, 1.0);
        let mut acc = TrafficAccount::new(t.resources().len());
        let p = t.path(NodeId(0), NodeId(1));
        acc.record(&p, 100.0, 2.5);
        assert_eq!(acc.total(&p), 500.0);
    }
}
