//! Compute-node model: resource inventory (Table 2), GPU consumption rates,
//! the Linux buffer-cache simulation, and `stress`-style memory pressure.

pub mod buffercache;
pub mod gpu;

pub use buffercache::{epoch_hit_rate, BlockLru};
pub use gpu::{gpu_images_per_sec, DlModel, GpuDemand, GpuKind};

use crate::storage::Volume;
use crate::util::fmt::GB;

/// Static node inventory, defaults from the paper's Table 2.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    pub cpu_cores: u32,
    pub memory: u64,
    pub gpus: u32,
    pub gpu_kind: GpuKind,
    /// Local cache devices handed to the distributed cache layer.
    pub cache_volume: Volume,
    /// NIC bandwidth, bytes/s.
    pub nic_bw: f64,
}

impl NodeSpec {
    /// IBM Power S822LC: 2×8 cores, 512 GB, 4×P100, 100 GbE, 2 NVMe cache.
    pub fn paper_node(name: impl Into<String>) -> Self {
        NodeSpec {
            name: name.into(),
            cpu_cores: 16,
            memory: 512 * GB,
            gpus: 4,
            gpu_kind: GpuKind::P100,
            cache_volume: Volume::paper_cache_volume(),
            nic_bw: 12.5e9,
        }
    }
}

/// Mutable per-node state tracked by the cluster model.
#[derive(Debug, Clone)]
pub struct NodeState {
    pub spec: NodeSpec,
    /// GPUs currently allocated to jobs.
    pub gpus_allocated: u32,
    /// Memory reserved by workloads + `stress` hogs (reduces buffer cache).
    pub memory_reserved: u64,
    /// Memory pinned as Spectrum-Scale-style pagepool (Hoard's in-memory tier).
    pub pagepool: u64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterError {
    NoGpus { want: u32, free: u32 },
    NoMemory { want: u64, free: u64 },
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::NoGpus { want, free } => {
                write!(f, "not enough free GPUs: want {want}, free {free}")
            }
            ClusterError::NoMemory { want, free } => {
                write!(f, "not enough free memory: want {want}, free {free}")
            }
        }
    }
}

impl std::error::Error for ClusterError {}

impl NodeState {
    pub fn new(spec: NodeSpec) -> Self {
        NodeState { spec, gpus_allocated: 0, memory_reserved: 0, pagepool: 0 }
    }

    pub fn gpus_free(&self) -> u32 {
        self.spec.gpus - self.gpus_allocated
    }

    pub fn allocate_gpus(&mut self, n: u32) -> Result<(), ClusterError> {
        if n > self.gpus_free() {
            return Err(ClusterError::NoGpus { want: n, free: self.gpus_free() });
        }
        self.gpus_allocated += n;
        Ok(())
    }

    pub fn release_gpus(&mut self, n: u32) {
        self.gpus_allocated = self.gpus_allocated.saturating_sub(n);
    }

    /// Free memory available to the OS buffer cache (total − reserved −
    /// pagepool). The Figure 4 experiment's `stress` tool raises
    /// `memory_reserved` to tune the memory-to-dataset ratio (MDR).
    pub fn buffer_cache_bytes(&self) -> u64 {
        self.spec.memory.saturating_sub(self.memory_reserved + self.pagepool)
    }

    pub fn reserve_memory(&mut self, bytes: u64) -> Result<(), ClusterError> {
        let free = self.buffer_cache_bytes();
        if bytes > free {
            return Err(ClusterError::NoMemory { want: bytes, free });
        }
        self.memory_reserved += bytes;
        Ok(())
    }

    pub fn set_pagepool(&mut self, bytes: u64) {
        self.pagepool = bytes.min(self.spec.memory);
    }

    /// Apply `stress -m`-style pressure so that free memory = `target`.
    pub fn stress_to_free_memory(&mut self, target: u64) {
        let avail = self.spec.memory.saturating_sub(self.pagepool);
        self.memory_reserved = avail.saturating_sub(target);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_node_spec() {
        let n = NodeSpec::paper_node("n0");
        assert_eq!(n.gpus, 4);
        assert_eq!(n.memory, 512 * GB);
        assert_eq!(n.cache_volume.capacity(), 1024 * GB);
    }

    #[test]
    fn gpu_allocation() {
        let mut s = NodeState::new(NodeSpec::paper_node("n0"));
        s.allocate_gpus(3).unwrap();
        assert_eq!(s.gpus_free(), 1);
        assert!(s.allocate_gpus(2).is_err());
        s.release_gpus(3);
        assert_eq!(s.gpus_free(), 4);
    }

    #[test]
    fn stress_controls_buffer_cache() {
        let mut s = NodeState::new(NodeSpec::paper_node("n0"));
        s.stress_to_free_memory(72 * GB); // MDR 0.5 of a 144 GB dataset
        assert_eq!(s.buffer_cache_bytes(), 72 * GB);
    }

    #[test]
    fn pagepool_subtracts_from_buffer_cache() {
        let mut s = NodeState::new(NodeSpec::paper_node("n0"));
        s.set_pagepool(64 * GB);
        assert_eq!(s.buffer_cache_bytes(), (512 - 64) * GB);
        s.stress_to_free_memory(10 * GB);
        assert_eq!(s.buffer_cache_bytes(), 10 * GB);
    }

    #[test]
    fn memory_reservation_bounds() {
        let mut s = NodeState::new(NodeSpec::paper_node("n0"));
        assert!(s.reserve_memory(600 * GB).is_err());
        s.reserve_memory(500 * GB).unwrap();
        assert!(s.reserve_memory(20 * GB).is_err());
    }
}
