//! Linux buffer-cache model (block-granular LRU) — the mechanism the paper's
//! Figure 4 (MDR sweep) measures against.
//!
//! Two layers:
//!  * `BlockLru` — an actual block-level LRU simulation, exercised by the
//!    unit/property tests and the `ablations` bench to validate the analytic
//!    model below against first principles.
//!  * `epoch_hit_rate` — the closed-form steady-state hit ratio used by the
//!    fluid simulation. Under per-epoch random-permutation access (each of
//!    N blocks touched exactly once per epoch in fresh random order), a
//!    block at position p of epoch e is re-touched at position q of epoch
//!    e+1 after ~(x + y − x·y)·N distinct accesses (x=(N−p)/N, y=q/N,
//!    independent uniforms; the product term is the expected overlap of the
//!    two windows). LRU hits iff that reuse distance < C, giving
//!        P(hit) = ∫₀ʳ (r−x)/(1−x) dx = r + (1−r)·ln(1−r),  r = C/N.
//!    Far *below* r itself — e.g. r=0.5 ⇒ 15% hits — which is exactly the
//!    cache-trashing effect the paper describes in §2 (Requirement 2) and
//!    measures in §4.2/Figure 4. The `analytic_hit_rate_matches_lru_sim`
//!    test validates the formula against the real `BlockLru`.

use std::collections::HashMap;

/// Doubly-linked LRU over u64 block ids, O(1) touch/evict, no deps.
#[derive(Debug)]
pub struct BlockLru {
    capacity: usize,
    map: HashMap<u64, usize>, // block -> slot
    // Slot arena forming a doubly linked list.
    keys: Vec<u64>,
    prev: Vec<usize>,
    next: Vec<usize>,
    head: usize, // most-recent
    tail: usize, // least-recent
    free: Vec<usize>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

const NIL: usize = usize::MAX;

impl BlockLru {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "cache capacity must be > 0");
        BlockLru {
            capacity,
            map: HashMap::with_capacity(capacity + 1),
            keys: Vec::with_capacity(capacity),
            prev: Vec::with_capacity(capacity),
            next: Vec::with_capacity(capacity),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, block: u64) -> bool {
        self.map.contains_key(&block)
    }

    fn unlink(&mut self, slot: usize) {
        let (p, n) = (self.prev[slot], self.next[slot]);
        if p != NIL {
            self.next[p] = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.prev[n] = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, slot: usize) {
        self.prev[slot] = NIL;
        self.next[slot] = self.head;
        if self.head != NIL {
            self.prev[self.head] = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }

    /// Access a block; returns true on hit. Miss inserts (evicting LRU).
    pub fn access(&mut self, block: u64) -> bool {
        if let Some(&slot) = self.map.get(&block) {
            self.hits += 1;
            if self.head != slot {
                self.unlink(slot);
                self.push_front(slot);
            }
            return true;
        }
        self.misses += 1;
        if self.map.len() == self.capacity {
            // Evict tail.
            let victim = self.tail;
            self.unlink(victim);
            self.map.remove(&self.keys[victim]);
            self.free.push(victim);
            self.evictions += 1;
        }
        let slot = if let Some(s) = self.free.pop() {
            self.keys[s] = block;
            s
        } else {
            self.keys.push(block);
            self.prev.push(NIL);
            self.next.push(NIL);
            self.keys.len() - 1
        };
        self.push_front(slot);
        self.map.insert(block, slot);
        false
    }

    /// Drop `n` least-recently-used blocks (memory pressure from `stress`).
    pub fn shrink_by(&mut self, n: usize) {
        for _ in 0..n.min(self.map.len()) {
            let victim = self.tail;
            self.unlink(victim);
            self.map.remove(&self.keys[victim]);
            self.free.push(victim);
            self.evictions += 1;
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Steady-state per-epoch hit fraction of an LRU cache holding
/// `cache_bytes` of a `dataset_bytes` dataset accessed as a fresh random
/// permutation each epoch: `r + (1-r)·ln(1-r)` for r = cache/dataset < 1
/// (see module docs for the derivation), 1.0 once fully resident — the
/// paper's MDR > 1.1 regime.
pub fn epoch_hit_rate(cache_bytes: f64, dataset_bytes: f64) -> f64 {
    if dataset_bytes <= 0.0 {
        return 1.0;
    }
    let r = (cache_bytes / dataset_bytes).clamp(0.0, 1.0);
    if r >= 1.0 {
        return 1.0;
    }
    (r + (1.0 - r) * (1.0 - r).ln()).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn basic_hit_miss() {
        let mut c = BlockLru::new(2);
        assert!(!c.access(1));
        assert!(!c.access(2));
        assert!(c.access(1));
        assert!(!c.access(3)); // evicts 2 (LRU)
        assert!(!c.access(2));
        assert!(c.access(3));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn lru_order_respects_touch() {
        let mut c = BlockLru::new(2);
        c.access(1);
        c.access(2);
        c.access(1); // 1 now MRU
        c.access(3); // evicts 2
        assert!(c.contains(1));
        assert!(!c.contains(2));
    }

    #[test]
    fn shrink_evicts_lru_first() {
        let mut c = BlockLru::new(3);
        c.access(1);
        c.access(2);
        c.access(3);
        c.shrink_by(2);
        assert_eq!(c.len(), 1);
        assert!(c.contains(3));
    }

    #[test]
    fn analytic_hit_rate_matches_lru_sim() {
        // Validate epoch_hit_rate ≈ measured hit rate of the real LRU under
        // permutation access — the foundation of the Figure 4 reproduction.
        for mdr in [0.25, 0.5, 0.75] {
            let n_blocks = 2000usize;
            let cache = (n_blocks as f64 * mdr) as usize;
            let mut c = BlockLru::new(cache);
            let mut rng = Rng::new(99);
            let mut order: Vec<u64> = (0..n_blocks as u64).collect();
            // Warm-up epoch + 4 measured epochs.
            for _ in 0..1 {
                rng.shuffle(&mut order);
                for &b in &order {
                    c.access(b);
                }
            }
            c.hits = 0;
            c.misses = 0;
            for _ in 0..4 {
                rng.shuffle(&mut order);
                for &b in &order {
                    c.access(b);
                }
            }
            let analytic = epoch_hit_rate(cache as f64, n_blocks as f64);
            let measured = c.hit_rate();
            assert!(
                (measured - analytic).abs() < 0.03,
                "mdr={mdr}: analytic {analytic} vs measured {measured}"
            );
        }
    }

    #[test]
    fn full_residency_all_hits_after_warmup() {
        let mut c = BlockLru::new(100);
        for b in 0..100 {
            c.access(b);
        }
        c.hits = 0;
        c.misses = 0;
        for b in 0..100 {
            c.access(b);
        }
        assert_eq!(c.hit_rate(), 1.0);
    }

    #[test]
    fn epoch_hit_rate_clamps() {
        assert_eq!(epoch_hit_rate(2.0, 1.0), 1.0);
        assert_eq!(epoch_hit_rate(1.0, 1.0), 1.0);
        assert_eq!(epoch_hit_rate(0.0, 1.0), 0.0);
        assert_eq!(epoch_hit_rate(1.0, 0.0), 1.0);
    }

    #[test]
    fn epoch_hit_rate_monotone_and_below_r() {
        let mut last = 0.0;
        for i in 1..100 {
            let r = i as f64 / 100.0;
            let h = epoch_hit_rate(r, 1.0);
            assert!(h >= last, "monotone at r={r}");
            assert!(h <= r + 1e-12, "h={h} must be ≤ r={r} (trashing)");
            last = h;
        }
    }

    #[test]
    fn prop_lru_never_exceeds_capacity() {
        use crate::util::prop::forall;
        forall(
            100,
            |rng: &mut Rng| {
                let cap = 1 + rng.gen_range(32) as usize;
                let accesses: Vec<u64> =
                    (0..200).map(|_| rng.gen_range(64)).collect();
                (cap, accesses)
            },
            |(cap, accesses)| {
                let mut c = BlockLru::new(*cap);
                for &a in accesses {
                    c.access(a);
                    if c.len() > *cap {
                        return Err(format!("len {} > cap {}", c.len(), cap));
                    }
                    if !c.contains(a) {
                        return Err(format!("block {a} not resident after access"));
                    }
                }
                // hits + misses == total accesses
                if c.hits + c.misses != accesses.len() as u64 {
                    return Err("accounting mismatch".into());
                }
                Ok(())
            },
        );
    }
}
