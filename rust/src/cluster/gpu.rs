//! GPU compute-rate model.
//!
//! The storage subsystem only observes the accelerator as a data sink with a
//! maximum consumption rate. Rates are calibrated from the paper's own
//! arithmetic (DESIGN.md §5): Table 4 gives REM/Hoard training durations for
//! 60 epochs of AlexNet/ImageNet on 4 × P100; the NVMe row of Table 3 is
//! GPU-bound, yielding 831 img/s per P100 at batch 1536. ResNet50 rates come
//! from the text ("ResNet50 on 16 Tesla V100 requires 15.5k images per
//! second" ⇒ ~970 img/s per V100; P100 ≈ 1/3 of V100 per the paper's §4.5).

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuKind {
    P100,
    V100,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DlModel {
    /// tf_cnn_benchmarks AlexNet — the paper's stressor (high img/s).
    AlexNet,
    /// ResNet50 — the Table 1 benchmark (compute-heavy, lower img/s).
    ResNet50,
}

/// Peak images/second one GPU can train, given the model and batch size.
/// Batch size has a mild throughput effect (pipeline efficiency): we model
/// saturation above the paper's batch sizes.
pub fn gpu_images_per_sec(gpu: GpuKind, model: DlModel, batch_per_gpu: u32) -> f64 {
    // Asymptotic peaks chosen so the *saturated* rate at the paper's batch
    // sizes reproduces the calibration points: 873 × sat(1536) = 831 img/s
    // (AlexNet-P100-BS1536, from Table 3/4 arithmetic).
    let peak = match (gpu, model) {
        (GpuKind::P100, DlModel::AlexNet) => 873.0,   // calibrated, Table 3/4
        (GpuKind::V100, DlModel::AlexNet) => 2619.0,  // paper §4.5: V100 ≈ 3×
        (GpuKind::P100, DlModel::ResNet50) => 347.0,  // 1/3 of V100
        (GpuKind::V100, DlModel::ResNet50) => 1042.0, // 15.5k/16 @ BS128 (HGX)
    };
    // Small batches under-utilize the device; saturate smoothly by BS ~128.
    let sat = match model {
        DlModel::AlexNet => 512.0,
        DlModel::ResNet50 => 64.0,
    };
    let b = batch_per_gpu as f64;
    peak * (b / (b + sat * 0.15)).min(1.0)
}

/// A job's aggregate GPU consumption: images/s across all its GPUs.
#[derive(Debug, Clone, Copy)]
pub struct GpuDemand {
    pub gpus: u32,
    pub gpu: GpuKind,
    pub model: DlModel,
    pub batch_per_gpu: u32,
}

impl GpuDemand {
    pub fn images_per_sec(&self) -> f64 {
        self.gpus as f64 * gpu_images_per_sec(self.gpu, self.model, self.batch_per_gpu)
    }

    /// Bytes/s of training data this job can consume at full speed.
    pub fn bytes_per_sec(&self, avg_image_bytes: f64) -> f64 {
        self.images_per_sec() * avg_image_bytes
    }

    /// The paper's per-node job: 4 × P100, AlexNet, BS 1536.
    pub fn paper_alexnet_job() -> Self {
        GpuDemand { gpus: 4, gpu: GpuKind::P100, model: DlModel::AlexNet, batch_per_gpu: 1536 }
    }

    /// The Table 1 benchmark job: 4 × P100, ResNet50, BS 128.
    pub fn table1_resnet_job() -> Self {
        GpuDemand { gpus: 4, gpu: GpuKind::P100, model: DlModel::ResNet50, batch_per_gpu: 128 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_job_rate_matches_calibration() {
        let d = GpuDemand::paper_alexnet_job();
        let fps = d.images_per_sec();
        // NVMe-bound epoch (Table 3): 1.28M images / 385 s ≈ 3324 img/s.
        assert!((fps - 3324.0).abs() / 3324.0 < 0.02, "fps = {fps}");
    }

    #[test]
    fn v100_is_3x_p100_alexnet() {
        let p = gpu_images_per_sec(GpuKind::P100, DlModel::AlexNet, 1536);
        let v = gpu_images_per_sec(GpuKind::V100, DlModel::AlexNet, 1536);
        assert!((v / p - 3.0).abs() < 0.05);
    }

    #[test]
    fn resnet_slower_than_alexnet() {
        let a = gpu_images_per_sec(GpuKind::P100, DlModel::AlexNet, 128);
        let r = gpu_images_per_sec(GpuKind::P100, DlModel::ResNet50, 128);
        assert!(r < a);
    }

    #[test]
    fn small_batch_underutilizes() {
        let small = gpu_images_per_sec(GpuKind::P100, DlModel::AlexNet, 16);
        let big = gpu_images_per_sec(GpuKind::P100, DlModel::AlexNet, 1536);
        assert!(small < 0.35 * big);
    }

    #[test]
    fn bytes_demand() {
        let d = GpuDemand::paper_alexnet_job();
        let bps = d.bytes_per_sec(112.5e3);
        // ≈ 3324 img/s × 112.5 KB ≈ 374 MB/s
        assert!(bps > 3.5e8 && bps < 4.0e8, "{bps}");
    }
}
