//! Cache-pressure awareness for the prefetch scheduler: bound the bytes
//! the prefetcher holds *ahead* of the readers.
//!
//! Placement reserves a dataset's full footprint up front
//! ([`CacheManager::place`](crate::cache::CacheManager::place) runs the
//! admission plan and allocates every node's share before the first
//! fill), so a fill itself can never overrun a volume. What speculation
//! *can* do is pile bytes into the cache long before any reader needs
//! them — exactly the space the RAM tier, co-scheduled placements and
//! the admission planner compete for. The pressure rule (ROADMAP's
//! iCache-style stretch) is therefore expressed on the prefetcher's
//! **ahead-bytes**: payload it has issued whose first access the readers
//! have not reached yet.
//!
//! * [`Pressure::Unbounded`] — no gauge; the lookahead window is the only
//!   bound.
//! * [`Pressure::Headroom`] — budget the ahead-bytes by the cluster's
//!   unreserved cache headroom ([`SharedCache::headroom_bytes`]), sampled
//!   when the epoch's scheduler starts: prefetch freely into free space,
//!   degrade to just-in-time when the cache is packed (when filling ahead
//!   would force the admission policy toward eviction).
//! * [`Pressure::Budget`] — an explicit byte budget (experiments and
//!   tests pin the constrained variant with it).
//!
//! Deferral, not loss: a denied unit keeps its place in the queue and is
//! re-offered once the cursor passes other units' first accesses and
//! frees their budget. The gauge also floors the budget at one unit, so
//! a budget smaller than a single chunk degrades to strictly
//! just-in-time prefetch instead of deadlock.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Mutex;

use crate::cache::SharedCache;

/// How the scheduler responds to cache pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pressure {
    /// No ahead-bytes bound (the lookahead window still applies).
    Unbounded,
    /// Bound ahead-bytes by the cluster's unreserved cache headroom,
    /// sampled at epoch start.
    Headroom,
    /// Explicit ahead-bytes budget.
    Budget(u64),
}

impl Pressure {
    /// Resolve to a concrete byte budget (`None` ⇔ unbounded).
    pub fn resolve(&self, cache: &SharedCache) -> Option<u64> {
        match *self {
            Pressure::Unbounded => None,
            Pressure::Headroom => Some(cache.headroom_bytes()),
            Pressure::Budget(b) => Some(b),
        }
    }

    /// Table/log tag.
    pub fn name(&self) -> &'static str {
        match self {
            Pressure::Unbounded => "unbounded",
            Pressure::Headroom => "headroom",
            Pressure::Budget(_) => "budget",
        }
    }
}

#[derive(Debug, Default)]
struct GaugeInner {
    /// Bytes issued ahead of the cursor, not yet consumed.
    ahead: u64,
    /// Issued units by first-access position — popped (and their bytes
    /// released) as the cursor passes them.
    issued: BinaryHeap<Reverse<(u64, u64)>>,
}

/// Tracks the prefetcher's ahead-bytes against a budget. Shared by the
/// scheduler's workers; every operation is one short mutex hold.
#[derive(Debug)]
pub struct PressureGauge {
    budget: Option<u64>,
    inner: Mutex<GaugeInner>,
}

impl PressureGauge {
    pub fn new(budget: Option<u64>) -> Self {
        PressureGauge { budget, inner: Mutex::new(GaugeInner::default()) }
    }

    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// May a unit of `bytes` whose first access is at `first_pos` be
    /// issued, given the cursor at `cursor_pos`? Admitting charges the
    /// gauge; a `false` means defer (nothing is charged). Units whose
    /// first access the cursor has already passed are released first —
    /// their bytes are demand, not speculation, from `cursor_pos` on.
    ///
    /// Progress floor: with nothing outstanding the unit is admitted
    /// even when it alone exceeds the budget — the gauge throttles to
    /// just-in-time, it never starves the scheduler outright.
    pub fn admit(&self, first_pos: u64, bytes: u64, cursor_pos: u64) -> bool {
        let Some(budget) = self.budget else { return true };
        let mut g = self.inner.lock().unwrap();
        while let Some(&Reverse((pos, by))) = g.issued.peek() {
            if pos >= cursor_pos {
                break;
            }
            g.issued.pop();
            g.ahead = g.ahead.saturating_sub(by);
        }
        if g.ahead > 0 && g.ahead.saturating_add(bytes) > budget {
            return false;
        }
        g.ahead += bytes;
        g.issued.push(Reverse((first_pos, bytes)));
        true
    }

    /// Ahead-bytes currently charged (test/observability helper).
    pub fn outstanding(&self) -> u64 {
        self.inner.lock().unwrap().ahead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_always_admits() {
        let g = PressureGauge::new(None);
        assert!(g.admit(0, u64::MAX, 0));
        assert_eq!(g.outstanding(), 0, "unbounded gauge charges nothing");
    }

    #[test]
    fn budget_defers_then_releases_as_cursor_passes() {
        let g = PressureGauge::new(Some(100));
        assert!(g.admit(0, 60, 0), "fits");
        assert!(!g.admit(5, 60, 0), "60+60 > 100: deferred");
        assert_eq!(g.outstanding(), 60);
        // Cursor passes position 0: the first unit's bytes are demand now.
        assert!(g.admit(5, 60, 1), "released 60, 0+60 fits");
        assert_eq!(g.outstanding(), 60);
    }

    #[test]
    fn progress_floor_admits_one_oversized_unit() {
        let g = PressureGauge::new(Some(10));
        assert!(g.admit(0, 500, 0), "empty gauge must admit (just-in-time floor)");
        assert!(!g.admit(1, 500, 0), "but only one at a time");
    }

    #[test]
    fn names_and_resolution_tags() {
        assert_eq!(Pressure::Unbounded.name(), "unbounded");
        assert_eq!(Pressure::Headroom.name(), "headroom");
        assert_eq!(Pressure::Budget(1).name(), "budget");
    }
}
