//! Schedule derivation: from a known epoch permutation to per-unit
//! first-access positions, plus the live read cursor the scheduler's
//! lookahead window trails behind.
//!
//! The whole point of clairvoyant prefetching (NoPFS, PAPERS.md) is that
//! a training job's "random" access sequence is not random at all once
//! the seed is fixed: every [`JobSession`](crate::posix::dataplane::
//! JobSession) owns its epoch permutation before the epoch starts. This
//! module turns that permutation into a prefetch schedule:
//!
//! * [`EpochSchedule::for_chunks`] — walk the permutation once and record,
//!   for every chunk, the position of the **first** item that touches it
//!   (an item spanning several chunks credits each of them; a chunk
//!   holding several items keeps only the earliest position — the dedup
//!   the issue calls out). Sorted ascending, this *is* the
//!   time-until-first-access priority order.
//! * [`EpochSchedule::for_items`] — the whole-file degenerate case: one
//!   unit per item file, first access = the item's own position (a
//!   permutation visits each item exactly once).
//! * [`ReadCursor`] — readers count completed items into it (one atomic
//!   add per item); the scheduler reads it to hold the lookahead window
//!   and parks on it (bounded waits) when the window is exhausted.
//!
//! Reader partition note: `run_epoch_order` deals positions round-robin
//! over R readers, so the item at global position `p` is the
//! `p / R`-th read of reader `p mod R`. With readers draining at roughly
//! equal rates, global position order and wall-clock first-access order
//! coincide — which is why the schedule keys on global position and the
//! cursor counts completed items across all readers.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cache::ChunkGeometry;

/// Per-unit first-access positions for one epoch, ascending. A "unit" is
/// whatever the session's fill ledger is keyed by: a stripe chunk
/// (chunked mode) or an item file (whole-file mode).
#[derive(Debug, Clone)]
pub struct EpochSchedule {
    /// `(first_access_position, unit)`, sorted ascending by position —
    /// pop order *is* time-until-first-access order.
    entries: Vec<(u64, u64)>,
    /// Positions in the epoch (= items in the permutation).
    positions: u64,
}

impl EpochSchedule {
    /// Derive the chunk schedule for one epoch permutation: chunk `c`'s
    /// priority is the position of the first item whose byte range
    /// overlaps it. Chunks no item in `order` touches (possible for
    /// partial orders) are absent.
    pub fn for_chunks(order: &[u64], geom: &ChunkGeometry) -> Self {
        let n = geom.num_chunks() as usize;
        let mut first = vec![u64::MAX; n];
        for (pos, &i) in order.iter().enumerate() {
            for c in geom.chunks_of_item(i) {
                let slot = &mut first[c as usize];
                if *slot == u64::MAX {
                    *slot = pos as u64;
                }
            }
        }
        let mut entries: Vec<(u64, u64)> = first
            .iter()
            .enumerate()
            .filter(|(_, &p)| p != u64::MAX)
            .map(|(c, &p)| (p, c as u64))
            .collect();
        entries.sort_unstable();
        EpochSchedule { entries, positions: order.len() as u64 }
    }

    /// Whole-file schedule: unit = item, first access = its position in
    /// the permutation.
    pub fn for_items(order: &[u64]) -> Self {
        EpochSchedule {
            entries: order.iter().enumerate().map(|(p, &i)| (p as u64, i)).collect(),
            positions: order.len() as u64,
        }
    }

    /// `(first_access_position, unit)` pairs, ascending by position.
    pub fn entries(&self) -> &[(u64, u64)] {
        &self.entries
    }

    /// Units scheduled (distinct chunks/items the epoch touches).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Positions in the epoch the schedule was derived from.
    pub fn positions(&self) -> u64 {
        self.positions
    }

    /// First-access position of `unit`, if scheduled (test/debug helper;
    /// linear scan).
    pub fn first_access(&self, unit: u64) -> Option<u64> {
        self.entries.iter().find(|&&(_, u)| u == unit).map(|&(p, _)| p)
    }
}

/// The live epoch read cursor: a completed-item counter the readers
/// advance and the prefetch scheduler trails. Advancing is one atomic
/// add plus one atomic load on the reader hot path (the condvar is only
/// touched when a prefetch worker is actually parked); waiting is
/// timeout-bounded, so a stalled reader can never wedge the scheduler.
#[derive(Debug)]
pub struct ReadCursor {
    done: AtomicU64,
    total: u64,
    stopped: AtomicBool,
    /// Prefetch workers currently parked on `cv` — lets `advance` skip
    /// the lock+notify entirely in the common nobody-waiting case.
    sleepers: AtomicU64,
    lock: Mutex<()>,
    cv: Condvar,
}

impl ReadCursor {
    pub fn new(total: u64) -> Self {
        ReadCursor {
            done: AtomicU64::new(0),
            total,
            stopped: AtomicBool::new(false),
            sleepers: AtomicU64::new(0),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Items completed so far (the window base).
    pub fn position(&self) -> u64 {
        self.done.load(Ordering::Acquire)
    }

    /// Items in the epoch.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// One item completed. Reader hot path: lock-free unless a prefetch
    /// worker is parked.
    pub fn advance(&self) {
        self.done.fetch_add(1, Ordering::AcqRel);
        if self.sleepers.load(Ordering::Acquire) > 0 {
            // Take the lock so the wakeup can't slip between a parker's
            // position check and its wait.
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    /// The epoch is over (readers joined) — release every parked waiter
    /// for good. Idempotent.
    pub fn stop(&self) {
        self.stopped.store(true, Ordering::Release);
        let _g = self.lock.lock().unwrap();
        self.cv.notify_all();
    }

    pub fn stopped(&self) -> bool {
        self.stopped.load(Ordering::Acquire)
    }

    /// Park until the cursor moves past `seen`, the cursor stops, or
    /// `timeout` elapses — whichever first. Returns the position on wake.
    /// The timeout doubles as a liveness backstop: a wakeup lost to the
    /// unlocked `sleepers` fast check costs at most one timeout, never a
    /// hang.
    pub fn wait_for_progress(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut g = self.lock.lock().unwrap();
        self.sleepers.fetch_add(1, Ordering::AcqRel);
        loop {
            if self.position() > seen || self.stopped() {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g2, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
        self.sleepers.fetch_sub(1, Ordering::AcqRel);
        drop(g);
        self.position()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_schedule_is_the_permutation() {
        let order = [3u64, 1, 2, 0];
        let s = EpochSchedule::for_items(&order);
        assert_eq!(s.len(), 4);
        assert_eq!(s.positions(), 4);
        assert_eq!(s.first_access(3), Some(0));
        assert_eq!(s.first_access(0), Some(3));
        assert_eq!(s.first_access(9), None);
    }

    #[test]
    fn cursor_advances_and_stops() {
        let c = ReadCursor::new(4);
        assert_eq!(c.position(), 0);
        c.advance();
        c.advance();
        assert_eq!(c.position(), 2);
        assert_eq!(c.total(), 4);
        // Timeout-bounded wait with no progress returns the position.
        assert_eq!(c.wait_for_progress(2, Duration::from_millis(5)), 2);
        assert!(!c.stopped());
        c.stop();
        assert!(c.stopped());
        // Stopped cursor never blocks.
        assert_eq!(c.wait_for_progress(99, Duration::from_secs(60)), 2);
    }

    #[test]
    fn waiter_is_woken_by_advance() {
        let c = ReadCursor::new(2);
        std::thread::scope(|s| {
            let h = s.spawn(|| c.wait_for_progress(0, Duration::from_secs(30)));
            // Let the waiter park, then advance — it must wake well before
            // the 30 s timeout (the join below would otherwise hang the
            // test harness timeout, not pass silently).
            std::thread::sleep(Duration::from_millis(20));
            c.advance();
            assert_eq!(h.join().unwrap(), 1);
        });
    }
}
