//! The clairvoyant prefetch scheduler: a priority queue of schedule
//! entries ordered by time-until-first-access, drained by a small pool
//! of fill workers inside a lookahead window behind the live read
//! cursor.
//!
//! Coordination invariants (the reason this lives behind the same
//! [`FillTable`] the readers use):
//!
//! * **Fetch-once across jobs** — every issue goes through
//!   [`FillTable::try_claim`] on the dataset's *shared* ledger. A chunk
//!   another co-scheduled session (or this session's own readers)
//!   already filled or holds in flight is skipped without blocking —
//!   never double-fetched. Residency recorded by earlier epochs is
//!   skipped even earlier, via the lock-free snapshot, without touching
//!   the ledger at all.
//! * **Bounded lookahead** — a unit is issued only while its first
//!   access lies within `lookahead` positions of the cursor. The window
//!   is re-checked against the live cursor on every pop, so the
//!   scheduler can trail the readers but never run ahead of the bound
//!   (asserted in `tests/prefetch.rs` via `prefetch_issued`).
//! * **Bounded in-flight budget** — at most `inflight` fills run at
//!   once (one per worker thread); each fill goes through the same
//!   token-bucket-charged cluster helpers as every other remote/NVMe
//!   byte in the system, so the prefetcher shares bandwidth fairly
//!   instead of bursting past the caps.
//! * **Pressure** — before fetching, each issue passes the
//!   [`PressureGauge`]; a denial rolls the claim back (a demand read can
//!   take it immediately), requeues the unit, and waits for the cursor.
//!
//! Error containment: a worker that fails aborts its claim (so readers
//! retry/fill the unit themselves), flags the pool dead, and its
//! *partial* stats shard still merges into the pass result — accounting
//! stays exact even for failed epochs (the satellite bugfix in
//! `run_epoch_order` relies on this shape).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::pressure::{Pressure, PressureGauge};
use super::schedule::{EpochSchedule, ReadCursor};
use crate::cache::{ChunkGeometry, RamTier, ReadLocation, ResidencySnapshot, SharedCache};
use crate::netsim::NodeId;
use crate::posix::reader_pool::{fill_from_remote, FillTable};
use crate::posix::realfs::{chunk_rel_path, fetch_chunk_payload_into, ReadStats, RealCluster};
use crate::workload::datagen::DataGenConfig;

/// Default lookahead window, in epoch positions (items).
pub const DEFAULT_LOOKAHEAD: u64 = 64;

/// Default in-flight fill budget (worker threads).
pub const DEFAULT_INFLIGHT: usize = 2;

/// Backstop poll while parked on the cursor (wakeups normally arrive via
/// [`ReadCursor::advance`]; the timeout only covers a lost fast-path
/// wake or an externally frozen cursor).
const CURSOR_POLL: Duration = Duration::from_millis(5);

/// Knobs a job passes down to the clairvoyant scheduler
/// ([`JobSpec`](crate::posix::dataplane::JobSpec) carries one).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefetchConfig {
    /// How far past the read cursor (in epoch positions) the scheduler
    /// may issue.
    pub lookahead: u64,
    /// Concurrent fills (worker threads) the scheduler may keep in
    /// flight.
    pub inflight: usize,
    /// Cache-pressure rule for ahead-bytes.
    pub pressure: Pressure,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig {
            lookahead: DEFAULT_LOOKAHEAD,
            inflight: DEFAULT_INFLIGHT,
            pressure: Pressure::Unbounded,
        }
    }
}

impl PrefetchConfig {
    pub fn lookahead(mut self, positions: u64) -> Self {
        self.lookahead = positions;
        self
    }

    pub fn inflight(mut self, n: usize) -> Self {
        self.inflight = n;
        self
    }

    pub fn pressure(mut self, p: Pressure) -> Self {
        self.pressure = p;
        self
    }
}

/// What the generic worker loop needs to know about one unit kind.
/// Implemented for stripe chunks and for whole item files; everything
/// else (window, claims, pressure, stats) is shared.
trait PrefetchTarget: Sync {
    /// Payload bytes of `unit` (what the pressure gauge charges).
    fn bytes_of(&self, unit: u64) -> u64;

    /// Already resident per the lock-free snapshot? (Skip without even
    /// touching the ledger — the partially-warm fast path.)
    fn resident(&self, unit: u64) -> bool;

    /// Adoption probe under a held claim: `Ok(true)` ⇔ the payload was
    /// already on its home's disk and residency is now recorded — no
    /// fetch needed.
    fn try_adopt(&self, unit: u64) -> Result<bool>;

    /// Fetch the unit from the remote store onto its home node and
    /// record residency. `buf` is the worker's reusable scratch buffer.
    fn fill(&self, unit: u64, buf: &mut Vec<u8>, stats: &mut ReadStats) -> Result<()>;
}

/// Chunk-granular target (the canonical mode).
struct ChunkTarget<'a> {
    cluster: &'a RealCluster,
    cache: &'a SharedCache,
    ram: Option<&'a RamTier>,
    snapshot: Option<&'a ResidencySnapshot>,
    dataset: &'a str,
    cfg: &'a DataGenConfig,
    geom: &'a ChunkGeometry,
}

impl PrefetchTarget for ChunkTarget<'_> {
    fn bytes_of(&self, c: u64) -> u64 {
        let (s, e) = self.geom.chunk_range(c);
        e - s
    }

    fn resident(&self, c: u64) -> bool {
        self.snapshot.filter(|s| !s.retired()).map(|s| s.contains(c)).unwrap_or(false)
    }

    fn try_adopt(&self, c: u64) -> Result<bool> {
        let g = self.geom;
        let crel = chunk_rel_path(g.dataset_id, g.generation, g.chunk_bytes(), c);
        if !self.cluster.node_has(g.node_of_chunk(c), &crel) {
            return Ok(false);
        }
        self.cache.mark_chunks(self.dataset, &[c])?;
        Ok(true)
    }

    fn fill(&self, c: u64, buf: &mut Vec<u8>, stats: &mut ReadStats) -> Result<()> {
        let g = self.geom;
        fetch_chunk_payload_into(self.cluster, self.cfg, g, c, buf, stats)?;
        self.cache.mark_chunks(self.dataset, &[c])?;
        // Payload in hand: let the RAM tier's second-touch admission
        // decide, same as the sequential pass.
        if let Some(r) = self.ram {
            r.offer((g.dataset_id, g.generation, g.chunk_bytes(), c), buf);
        }
        Ok(())
    }
}

/// Whole-file target (the degenerate one-slot-per-item ledgers).
struct ItemTarget<'a> {
    cluster: &'a RealCluster,
    cache: &'a SharedCache,
    snapshot: Option<&'a ResidencySnapshot>,
    dataset: &'a str,
    cfg: &'a DataGenConfig,
}

impl ItemTarget<'_> {
    fn home_of(&self, i: u64) -> Result<NodeId> {
        Ok(match self.cache.read_location(self.dataset, i, NodeId(0))? {
            ReadLocation::Local => NodeId(0),
            ReadLocation::Peer(p) => p,
            ReadLocation::RemoteFill { fill_node } => fill_node,
        })
    }
}

impl PrefetchTarget for ItemTarget<'_> {
    fn bytes_of(&self, _i: u64) -> u64 {
        self.cfg.record_bytes() as u64
    }

    fn resident(&self, i: u64) -> bool {
        self.snapshot.and_then(|s| s.item_resident(i)).unwrap_or(false)
    }

    fn try_adopt(&self, i: u64) -> Result<bool> {
        let home = self.home_of(i)?;
        if !self.cluster.node_has(home, &self.cfg.item_rel_path(i)) {
            return Ok(false);
        }
        self.cache.mark_item(self.dataset, i)?;
        Ok(true)
    }

    fn fill(&self, i: u64, _buf: &mut Vec<u8>, stats: &mut ReadStats) -> Result<()> {
        let home = self.home_of(i)?;
        fill_from_remote(self.cluster, self.cache, self.dataset, self.cfg, i, home, stats)
            .map(|_| ())
    }
}

/// Run the clairvoyant scheduler for one chunked epoch: derive the
/// schedule from `order` and drain it within the window. Blocks until
/// every scheduled unit is filled/skipped or the cursor stops.
#[allow(clippy::too_many_arguments)]
pub fn run_clairvoyant_chunks(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    ram: Option<&RamTier>,
    snapshot: Option<&ResidencySnapshot>,
    dataset: &str,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    order: &[u64],
    cursor: &ReadCursor,
    pcfg: &PrefetchConfig,
    stats: &mut ReadStats,
) -> Result<()> {
    let schedule = EpochSchedule::for_chunks(order, geom);
    run_scheduled_chunks(
        cluster, cache, fill, ram, snapshot, dataset, cfg, geom, &schedule, cursor, pcfg, stats,
    )
}

/// [`run_clairvoyant_chunks`] with an explicit pre-derived schedule —
/// the window/race tests drive this directly with a frozen cursor.
#[allow(clippy::too_many_arguments)]
pub fn run_scheduled_chunks(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    ram: Option<&RamTier>,
    snapshot: Option<&ResidencySnapshot>,
    dataset: &str,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    schedule: &EpochSchedule,
    cursor: &ReadCursor,
    pcfg: &PrefetchConfig,
    stats: &mut ReadStats,
) -> Result<()> {
    let target = ChunkTarget { cluster, cache, ram, snapshot, dataset, cfg, geom };
    run_scheduled(&target, fill, cache, schedule, cursor, pcfg, stats)
}

/// Run the clairvoyant scheduler for one whole-file epoch (unit = item).
#[allow(clippy::too_many_arguments)]
pub fn run_clairvoyant_items(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    snapshot: Option<&ResidencySnapshot>,
    dataset: &str,
    cfg: &DataGenConfig,
    order: &[u64],
    cursor: &ReadCursor,
    pcfg: &PrefetchConfig,
    stats: &mut ReadStats,
) -> Result<()> {
    let schedule = EpochSchedule::for_items(order);
    let target = ItemTarget { cluster, cache, snapshot, dataset, cfg };
    run_scheduled(&target, fill, cache, schedule_ref(&schedule), cursor, pcfg, stats)
}

/// Identity helper so both public entries share one call shape.
fn schedule_ref(s: &EpochSchedule) -> &EpochSchedule {
    s
}

/// The shared drain loop: `inflight` workers over one priority heap.
/// Per-worker stat shards merge into `stats`; the first error wins (the
/// others' partial shards still merge).
fn run_scheduled(
    target: &dyn PrefetchTarget,
    fill: &FillTable,
    cache: &SharedCache,
    schedule: &EpochSchedule,
    cursor: &ReadCursor,
    pcfg: &PrefetchConfig,
    stats: &mut ReadStats,
) -> Result<()> {
    if schedule.is_empty() {
        return Ok(());
    }
    let gauge = PressureGauge::new(pcfg.pressure.resolve(cache));
    let heap: Mutex<BinaryHeap<Reverse<(u64, u64)>>> =
        Mutex::new(schedule.entries().iter().map(|&e| Reverse(e)).collect());
    let dead = AtomicBool::new(false);
    let workers = pcfg.inflight.max(1);
    let shards: Vec<(ReadStats, Result<()>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| worker(target, fill, &heap, cursor, &gauge, pcfg.lookahead, &dead))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| {
                    (ReadStats::default(), Err(anyhow!("prefetch worker panicked")))
                })
            })
            .collect()
    });
    let mut first_err = None;
    for (shard, res) in shards {
        stats.merge(&shard);
        if let Err(e) = res {
            first_err.get_or_insert(e);
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// One worker: pop the nearest-first-access unit inside the window,
/// claim, adopt-or-fill, repeat. Exits when the heap drains, the cursor
/// stops (epoch over — anything left would be filled after its only
/// use), or a sibling worker died.
fn worker(
    target: &dyn PrefetchTarget,
    fill: &FillTable,
    heap: &Mutex<BinaryHeap<Reverse<(u64, u64)>>>,
    cursor: &ReadCursor,
    gauge: &PressureGauge,
    lookahead: u64,
    dead: &AtomicBool,
) -> (ReadStats, Result<()>) {
    let mut stats = ReadStats::default();
    let mut buf = Vec::new();
    let res = (|| -> Result<()> {
        loop {
            if dead.load(Ordering::Acquire) || cursor.stopped() {
                return Ok(());
            }
            let (pos, unit) = {
                let mut q = heap.lock().unwrap();
                let Some(&Reverse((pos, _))) = q.peek() else { return Ok(()) };
                let now = cursor.position();
                if pos >= now.saturating_add(lookahead.max(1)) {
                    // Nearest unit is outside the window: park until the
                    // readers advance (never issue past the bound).
                    drop(q);
                    cursor.wait_for_progress(now, CURSOR_POLL);
                    continue;
                }
                let Reverse(e) = q.pop().expect("peeked above");
                e
            };
            if target.resident(unit) {
                continue;
            }
            if !fill.try_claim(unit) {
                // A reader or a co-scheduled job's prefetcher owns it:
                // fetch-once says we are done with this unit.
                continue;
            }
            match target.try_adopt(unit) {
                Ok(true) => {
                    fill.mark_resident(unit);
                    continue;
                }
                Ok(false) => {}
                Err(e) => {
                    fill.abort(unit);
                    return Err(e);
                }
            }
            let now = cursor.position();
            if !gauge.admit(pos, target.bytes_of(unit), now) {
                // Pressure: filling now would pile speculative bytes past
                // the budget. Release the claim (a demand read may take
                // it), requeue, wait for the cursor to free budget.
                fill.abort(unit);
                heap.lock().unwrap().push(Reverse((pos, unit)));
                cursor.wait_for_progress(now, CURSOR_POLL);
                continue;
            }
            match target.fill(unit, &mut buf, &mut stats) {
                Ok(()) => {
                    fill.complete_prefetched(unit);
                    stats.prefetch_issued += 1;
                }
                Err(e) => {
                    fill.abort(unit);
                    return Err(e);
                }
            }
        }
    })();
    if res.is_err() {
        dead.store(true, Ordering::Release);
    }
    (stats, res)
}
