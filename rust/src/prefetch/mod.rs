//! Clairvoyant prefetching (NoPFS-style, PAPERS.md): exploit the fact
//! that a training job's epoch access sequence is *known* — the seeded
//! permutation exists before the first read — to warm the cache in
//! time-until-first-access order instead of blind stripe order.
//!
//! Three pieces:
//!
//! * [`schedule`] — derive per-unit first-access positions from the
//!   epoch permutation ([`EpochSchedule`]) and track the live read
//!   cursor the lookahead window trails ([`ReadCursor`]).
//! * [`scheduler`] — the priority-queue drain loop: bounded in-flight
//!   workers issuing fills through the dataset's shared fetch-once
//!   [`FillTable`](crate::posix::FillTable) ledger, so co-scheduled
//!   jobs never double-fetch a chunk.
//! * [`pressure`] — ahead-bytes budgeting against cache headroom
//!   ([`Pressure`], [`PressureGauge`]): defer speculative fills that
//!   would crowd the cache, degrade to just-in-time under a tight
//!   budget, never deadlock.
//!
//! [`JobSession::run_epoch`](crate::posix::dataplane::JobSession)
//! drives all of this; the old blind pass survives as
//! [`PrefetchStrategy::Sequential`] for the ablation
//! (`hoard exp prefetch`).

pub mod pressure;
pub mod schedule;
pub mod scheduler;

pub use pressure::{Pressure, PressureGauge};
pub use schedule::{EpochSchedule, ReadCursor};
pub use scheduler::{
    run_clairvoyant_chunks, run_clairvoyant_items, run_scheduled_chunks, PrefetchConfig,
    DEFAULT_INFLIGHT, DEFAULT_LOOKAHEAD,
};

/// How a job warms the cache during an epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchStrategy {
    /// No prefetch: every miss is a demand fill on the read path.
    Off,
    /// The legacy blind pass: one thread walking units in stripe order,
    /// ignoring the permutation (kept for the ablation).
    Sequential,
    /// The scheduler in this module: priority by time-until-first-access
    /// within a bounded lookahead window behind the read cursor.
    Clairvoyant,
}

impl PrefetchStrategy {
    /// Table/log tag.
    pub fn name(&self) -> &'static str {
        match self {
            PrefetchStrategy::Off => "off",
            PrefetchStrategy::Sequential => "sequential",
            PrefetchStrategy::Clairvoyant => "clairvoyant",
        }
    }
}
