//! Typed object store with revisioned watch events — the API-server slice
//! of the mini-orchestrator. Controllers poll `events_since(rev)` and
//! reconcile; everything is deterministic (no background threads), which
//! keeps the control plane unit-testable step by step.

use std::collections::BTreeMap;

use super::resources::Object;

#[derive(Debug, Clone, PartialEq)]
pub enum WatchEvent<T> {
    Added(T),
    Modified(T),
    Deleted(T),
}

impl<T> WatchEvent<T> {
    pub fn object(&self) -> &T {
        match self {
            WatchEvent::Added(o) | WatchEvent::Modified(o) | WatchEvent::Deleted(o) => o,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    AlreadyExists { kind: &'static str, name: String },
    NotFound { kind: &'static str, name: String },
    Conflict { kind: &'static str, name: String, stored: u64, given: u64 },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::AlreadyExists { kind, name } => write!(f, "{kind} '{name}' already exists"),
            StoreError::NotFound { kind, name } => write!(f, "{kind} '{name}' not found"),
            StoreError::Conflict { kind, name, stored, given } => write!(
                f,
                "{kind} '{name}' conflict: stored version {stored}, update based on {given}"
            ),
        }
    }
}

impl std::error::Error for StoreError {}

/// One kind's storage: objects + ordered event log.
#[derive(Debug)]
pub struct Store<T: Object> {
    objects: BTreeMap<String, T>,
    events: Vec<(u64, WatchEvent<T>)>,
    revision: u64,
    next_uid: u64,
}

impl<T: Object> Default for Store<T> {
    fn default() -> Self {
        Store { objects: BTreeMap::new(), events: vec![], revision: 0, next_uid: 1 }
    }
}

impl<T: Object> Store<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn create(&mut self, mut obj: T) -> Result<T, StoreError> {
        let name = obj.meta().name.clone();
        if name.is_empty() {
            return Err(StoreError::NotFound { kind: T::kind(), name: "(empty)".into() });
        }
        if self.objects.contains_key(&name) {
            return Err(StoreError::AlreadyExists { kind: T::kind(), name });
        }
        self.revision += 1;
        obj.meta_mut().uid = self.next_uid;
        self.next_uid += 1;
        obj.meta_mut().resource_version = self.revision;
        self.objects.insert(name, obj.clone());
        self.events.push((self.revision, WatchEvent::Added(obj.clone())));
        Ok(obj)
    }

    pub fn get(&self, name: &str) -> Option<&T> {
        self.objects.get(name)
    }

    /// Optimistic-concurrency update: `obj.resource_version` must match.
    pub fn update(&mut self, mut obj: T) -> Result<T, StoreError> {
        let name = obj.meta().name.clone();
        let stored = self
            .objects
            .get(&name)
            .ok_or_else(|| StoreError::NotFound { kind: T::kind(), name: name.clone() })?;
        let (sv, gv) = (stored.meta().resource_version, obj.meta().resource_version);
        if sv != gv {
            return Err(StoreError::Conflict { kind: T::kind(), name, stored: sv, given: gv });
        }
        self.revision += 1;
        obj.meta_mut().resource_version = self.revision;
        self.objects.insert(name, obj.clone());
        self.events.push((self.revision, WatchEvent::Modified(obj.clone())));
        Ok(obj)
    }

    pub fn delete(&mut self, name: &str) -> Result<T, StoreError> {
        let obj = self
            .objects
            .remove(name)
            .ok_or_else(|| StoreError::NotFound { kind: T::kind(), name: name.into() })?;
        self.revision += 1;
        self.events.push((self.revision, WatchEvent::Deleted(obj.clone())));
        Ok(obj)
    }

    pub fn list(&self) -> impl Iterator<Item = &T> {
        self.objects.values()
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Events with revision > `since`, plus the new high-water mark.
    pub fn events_since(&self, since: u64) -> (Vec<WatchEvent<T>>, u64) {
        let evs = self
            .events
            .iter()
            .filter(|(r, _)| *r > since)
            .map(|(_, e)| e.clone())
            .collect();
        (evs, self.revision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::k8s::resources::{Dataset, DatasetPhase, ObjectMeta};

    fn ds(name: &str) -> Dataset {
        Dataset {
            meta: ObjectMeta::named(name),
            url: "nfs://s/d".into(),
            total_bytes: 1,
            num_items: 1,
            prefetch: false,
            stripe_width: 0,
            status: DatasetPhase::Pending,
        }
    }

    #[test]
    fn create_get_delete() {
        let mut s = Store::new();
        let created = s.create(ds("a")).unwrap();
        assert_eq!(created.meta.uid, 1);
        assert!(s.get("a").is_some());
        assert!(matches!(s.create(ds("a")), Err(StoreError::AlreadyExists { .. })));
        s.delete("a").unwrap();
        assert!(s.get("a").is_none());
        assert!(matches!(s.delete("a"), Err(StoreError::NotFound { .. })));
    }

    #[test]
    fn optimistic_concurrency() {
        let mut s = Store::new();
        let v1 = s.create(ds("a")).unwrap();
        let mut stale = v1.clone();
        let mut fresh = v1;
        fresh.status = DatasetPhase::Ready;
        s.update(fresh).unwrap();
        stale.status = DatasetPhase::Failed;
        assert!(matches!(s.update(stale), Err(StoreError::Conflict { .. })));
        assert_eq!(s.get("a").unwrap().status, DatasetPhase::Ready);
    }

    #[test]
    fn watch_events_ordered_and_incremental() {
        let mut s = Store::new();
        s.create(ds("a")).unwrap();
        let (evs, rev) = s.events_since(0);
        assert_eq!(evs.len(), 1);
        assert!(matches!(evs[0], WatchEvent::Added(_)));
        let mut a = s.get("a").unwrap().clone();
        a.status = DatasetPhase::Caching;
        s.update(a).unwrap();
        s.delete("a").unwrap();
        let (evs, rev2) = s.events_since(rev);
        assert_eq!(evs.len(), 2);
        assert!(matches!(evs[0], WatchEvent::Modified(_)));
        assert!(matches!(evs[1], WatchEvent::Deleted(_)));
        assert!(rev2 > rev);
        // Nothing new after the high-water mark.
        assert!(s.events_since(rev2).0.is_empty());
    }

    #[test]
    fn uid_monotone() {
        let mut s = Store::new();
        let a = s.create(ds("a")).unwrap();
        let b = s.create(ds("b")).unwrap();
        assert!(b.meta.uid > a.meta.uid);
    }
}
