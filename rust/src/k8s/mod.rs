//! Mini-Kubernetes substrate: typed object stores with watch events,
//! `Dataset`/`DlJob` custom resources, a label-honouring default pod
//! scheduler, and a dynamic volume provisioner. The paper deploys Hoard on
//! real Kubernetes (§3); this module reproduces the integration surface so
//! the coordinator's control loops are exercised faithfully.

pub mod provisioner;
pub mod resources;
pub mod scheduler;
pub mod store;

pub use provisioner::reconcile_pvcs;
pub use resources::{
    labels, Dataset, DatasetPhase, DlJob, JobPhase, Labels, Object, ObjectMeta, Pod, PodPhase, Pvc,
};
pub use scheduler::{schedule_all, schedule_pod, NodeInfo, ScheduleError};
pub use store::{Store, StoreError, WatchEvent};
