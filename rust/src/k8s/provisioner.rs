//! Dynamic volume provisioner (paper §3.2): watches for datasets that
//! reached the cache and exposes them to pods as bound PVCs.

use super::resources::{ObjectMeta, Pvc};
use super::store::{Store, StoreError};
use crate::cache::{CacheManager, DatasetState};

/// Reconcile PVCs against cache state: create a claim per registered
/// dataset, bind it once the dataset is placed (Caching or Cached — AFM
/// serves through the cache from the first byte). Returns bound claims.
pub fn reconcile_pvcs(cache: &CacheManager, pvcs: &mut Store<Pvc>) -> Result<Vec<String>, StoreError> {
    let mut bound = vec![];
    for rec in cache.registry.iter() {
        let claim_name = format!("pvc-{}", rec.spec.name);
        let placed = matches!(rec.state, DatasetState::Caching { .. } | DatasetState::Cached);
        match pvcs.get(&claim_name) {
            None => {
                pvcs.create(Pvc {
                    meta: ObjectMeta::named(&claim_name),
                    dataset: rec.spec.name.clone(),
                    bound: placed,
                })?;
                if placed {
                    bound.push(claim_name);
                }
            }
            Some(existing) if !existing.bound && placed => {
                let mut p = existing.clone();
                p.bound = true;
                pvcs.update(p)?;
                bound.push(claim_name);
            }
            Some(_) => {}
        }
    }
    // Garbage-collect claims whose dataset is gone.
    let orphans: Vec<String> = pvcs
        .list()
        .filter(|p| cache.registry.get(&p.dataset).is_none())
        .map(|p| p.meta.name.clone())
        .collect();
    for name in orphans {
        pvcs.delete(&name)?;
    }
    Ok(bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::EvictionPolicy;
    use crate::netsim::NodeId;
    use crate::storage::{Device, DeviceKind, Volume};
    use crate::workload::DatasetSpec;

    fn cache() -> CacheManager {
        let vols = (0..2)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1000)]))
            .collect();
        CacheManager::new(vols, EvictionPolicy::Manual)
    }

    #[test]
    fn binds_after_placement() {
        let mut c = cache();
        c.register(DatasetSpec::new("a", 10, 100), "nfs://s/a".into()).unwrap();
        let mut pvcs = Store::new();
        let bound = reconcile_pvcs(&c, &mut pvcs).unwrap();
        assert!(bound.is_empty());
        assert!(!pvcs.get("pvc-a").unwrap().bound);

        c.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        let bound = reconcile_pvcs(&c, &mut pvcs).unwrap();
        assert_eq!(bound, vec!["pvc-a".to_string()]);
        assert!(pvcs.get("pvc-a").unwrap().bound);
    }

    #[test]
    fn idempotent() {
        let mut c = cache();
        c.register(DatasetSpec::new("a", 10, 100), "nfs://s/a".into()).unwrap();
        c.place("a", vec![NodeId(0)]).unwrap();
        let mut pvcs = Store::new();
        reconcile_pvcs(&c, &mut pvcs).unwrap();
        let rev = pvcs.revision();
        reconcile_pvcs(&c, &mut pvcs).unwrap();
        assert_eq!(pvcs.revision(), rev, "no-op reconcile must not churn");
    }

    #[test]
    fn garbage_collects_orphans() {
        let mut c = cache();
        c.register(DatasetSpec::new("a", 10, 100), "nfs://s/a".into()).unwrap();
        let mut pvcs = Store::new();
        reconcile_pvcs(&c, &mut pvcs).unwrap();
        c.delete("a").unwrap();
        reconcile_pvcs(&c, &mut pvcs).unwrap();
        assert!(pvcs.get("pvc-a").is_none());
    }
}
