//! The *default* pod scheduler: honours node-selector labels and GPU
//! capacity, nothing else. Hoard's intelligence lives in the coordinator,
//! which encodes its decisions as labels and "delegates the actual
//! scheduling of pods to the default Kubernetes scheduler" (paper §3.2).

use std::collections::BTreeMap;

use super::resources::{labels, Labels, Pod, PodPhase};
use crate::cluster::NodeState;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    Unschedulable(Labels, u32),
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::Unschedulable(sel, gpus) => {
                write!(f, "no node satisfies selector {sel:?} with {gpus} free GPUs")
            }
        }
    }
}

impl std::error::Error for ScheduleError {}

/// Node facts the default scheduler consults.
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub index: usize,
    pub labels: Labels,
    pub gpus_free: u32,
}

impl NodeInfo {
    pub fn from_states(states: &[NodeState], racks: &[usize]) -> Vec<NodeInfo> {
        states
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut l = Labels::new();
                l.insert(labels::NODE.into(), format!("node{i}"));
                l.insert(labels::RACK.into(), format!("rack{}", racks.get(i).copied().unwrap_or(0)));
                NodeInfo { index: i, labels: l, gpus_free: s.gpus_free() }
            })
            .collect()
    }

    fn satisfies(&self, selector: &Labels) -> bool {
        selector.iter().all(|(k, v)| {
            if k == labels::PREFERRED_RACK {
                return true; // soft constraint, scoring only
            }
            self.labels.get(k) == Some(v)
        })
    }
}

/// Assign a pending pod to a node. Hard constraints: selector labels (minus
/// soft ones) and GPU capacity. Soft: preferred rack, then most-free-GPUs
/// (spreading).
pub fn schedule_pod(pod: &mut Pod, nodes: &mut [NodeInfo]) -> Result<usize, ScheduleError> {
    let preferred_rack = pod.node_selector.get(labels::PREFERRED_RACK).cloned();
    let mut best: Option<(i64, usize)> = None;
    for n in nodes.iter() {
        if n.gpus_free < pod.gpus || !n.satisfies(&pod.node_selector) {
            continue;
        }
        let mut score: i64 = n.gpus_free as i64;
        if let Some(r) = &preferred_rack {
            if n.labels.get(labels::RACK) == Some(r) {
                score += 1000;
            }
        }
        if best.map(|(s, _)| score > s).unwrap_or(true) {
            best = Some((score, n.index));
        }
    }
    let (_, idx) = best
        .ok_or_else(|| ScheduleError::Unschedulable(pod.node_selector.clone(), pod.gpus))?;
    let node = nodes.iter_mut().find(|n| n.index == idx).unwrap();
    node.gpus_free -= pod.gpus;
    pod.assigned_node = Some(idx);
    pod.phase = PodPhase::Running;
    Ok(idx)
}

/// Schedule many pods; returns name → node.
pub fn schedule_all(
    pods: &mut [Pod],
    nodes: &mut Vec<NodeInfo>,
) -> BTreeMap<String, Result<usize, ScheduleError>> {
    let mut out = BTreeMap::new();
    for p in pods.iter_mut() {
        if p.phase != PodPhase::Pending {
            continue;
        }
        out.insert(p.meta.name.clone(), schedule_pod(p, nodes));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeSpec;
    use crate::k8s::resources::ObjectMeta;

    fn nodes4() -> Vec<NodeInfo> {
        let states: Vec<NodeState> =
            (0..4).map(|i| NodeState::new(NodeSpec::paper_node(format!("n{i}")))).collect();
        NodeInfo::from_states(&states, &[0, 0, 1, 1])
    }

    fn pod(name: &str, gpus: u32, selector: Labels) -> Pod {
        Pod {
            meta: ObjectMeta::named(name),
            job: "j".into(),
            gpus,
            node_selector: selector,
            assigned_node: None,
            phase: PodPhase::Pending,
        }
    }

    #[test]
    fn respects_node_pin() {
        let mut nodes = nodes4();
        let mut sel = Labels::new();
        sel.insert(labels::NODE.into(), "node2".into());
        let mut p = pod("p", 4, sel);
        assert_eq!(schedule_pod(&mut p, &mut nodes).unwrap(), 2);
        assert_eq!(p.assigned_node, Some(2));
        assert_eq!(nodes[2].gpus_free, 0);
    }

    #[test]
    fn gpu_capacity_enforced() {
        let mut nodes = nodes4();
        let mut sel = Labels::new();
        sel.insert(labels::NODE.into(), "node0".into());
        let mut p1 = pod("p1", 4, sel.clone());
        schedule_pod(&mut p1, &mut nodes).unwrap();
        let mut p2 = pod("p2", 1, sel);
        assert!(matches!(schedule_pod(&mut p2, &mut nodes), Err(ScheduleError::Unschedulable(..))));
    }

    #[test]
    fn prefers_rack_softly() {
        let mut nodes = nodes4();
        let mut sel = Labels::new();
        sel.insert(labels::PREFERRED_RACK.into(), "rack1".into());
        let mut p = pod("p", 4, sel);
        let n = schedule_pod(&mut p, &mut nodes).unwrap();
        assert!(n == 2 || n == 3, "should land in rack1, got node{n}");
    }

    #[test]
    fn preferred_rack_does_not_block() {
        // If the preferred rack is full, schedule elsewhere rather than fail.
        let mut nodes = nodes4();
        nodes[2].gpus_free = 0;
        nodes[3].gpus_free = 0;
        let mut sel = Labels::new();
        sel.insert(labels::PREFERRED_RACK.into(), "rack1".into());
        let mut p = pod("p", 4, sel);
        let n = schedule_pod(&mut p, &mut nodes).unwrap();
        assert!(n == 0 || n == 1);
    }

    #[test]
    fn spreads_by_free_gpus() {
        let mut nodes = nodes4();
        nodes[0].gpus_free = 1;
        let mut p = pod("p", 1, Labels::new());
        let n = schedule_pod(&mut p, &mut nodes).unwrap();
        assert_ne!(n, 0, "should pick an emptier node");
    }

    #[test]
    fn schedule_all_skips_non_pending() {
        let mut nodes = nodes4();
        let mut pods = vec![pod("a", 2, Labels::new()), pod("b", 2, Labels::new())];
        pods[1].phase = PodPhase::Running;
        let out = schedule_all(&mut pods, &mut nodes);
        assert_eq!(out.len(), 1);
        assert!(out["a"].is_ok());
    }
}
