//! Custom resources, mirroring the paper's Kubernetes integration (§3.1):
//! `Dataset` and `DlJob` custom resources, `Pvc`s exposing cached datasets,
//! and `Pod`s the default scheduler places onto nodes via labels.

use std::collections::BTreeMap;

pub type Labels = BTreeMap<String, String>;

/// Kubernetes-style object metadata.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ObjectMeta {
    pub name: String,
    pub labels: Labels,
    pub uid: u64,
    pub resource_version: u64,
}

impl ObjectMeta {
    pub fn named(name: impl Into<String>) -> Self {
        ObjectMeta { name: name.into(), ..Default::default() }
    }
}

pub trait Object: Clone + std::fmt::Debug {
    fn meta(&self) -> &ObjectMeta;
    fn meta_mut(&mut self) -> &mut ObjectMeta;
    fn kind() -> &'static str;
}

macro_rules! object_impl {
    ($ty:ident, $kind:literal) => {
        impl Object for $ty {
            fn meta(&self) -> &ObjectMeta {
                &self.meta
            }
            fn meta_mut(&mut self) -> &mut ObjectMeta {
                &mut self.meta
            }
            fn kind() -> &'static str {
                $kind
            }
        }
    };
}

/// The `dataset` custom resource: remote dataset metadata + cache wishes.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    pub meta: ObjectMeta,
    /// e.g. "nfs://storage1/exports/imagenet" or "s3://bucket/prefix".
    pub url: String,
    pub total_bytes: u64,
    pub num_items: u64,
    /// Start fetching as soon as placed (vs on first access).
    pub prefetch: bool,
    /// Requested stripe width (0 = coordinator decides).
    pub stripe_width: usize,
    pub status: DatasetPhase,
}
object_impl!(Dataset, "Dataset");

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DatasetPhase {
    #[default]
    Pending,
    Caching,
    Ready,
    Failed,
}

/// The `DL job` custom resource (§3.1): training job details + dataset ref.
#[derive(Debug, Clone, PartialEq)]
pub struct DlJob {
    pub meta: ObjectMeta,
    pub dataset: String,
    pub gpus: u32,
    /// Worker count (pods); GPUs are per pod.
    pub replicas: u32,
    pub container_image: String,
    /// Where the dataset volume appears inside the container.
    pub mount_path: String,
    pub epochs: u32,
    pub status: JobPhase,
}
object_impl!(DlJob, "DlJob");

#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum JobPhase {
    #[default]
    Pending,
    /// Coordinator picked nodes; pods created.
    Scheduled { nodes: Vec<usize> },
    Running,
    Succeeded,
    Failed(String),
}

/// Persistent volume claim binding a cached dataset into a pod.
#[derive(Debug, Clone, PartialEq)]
pub struct Pvc {
    pub meta: ObjectMeta,
    pub dataset: String,
    pub bound: bool,
}
object_impl!(Pvc, "Pvc");

/// A scheduled unit of work. The coordinator encodes placement decisions as
/// labels (paper §3.2) and the default scheduler honours them.
#[derive(Debug, Clone, PartialEq)]
pub struct Pod {
    pub meta: ObjectMeta,
    pub job: String,
    pub gpus: u32,
    /// Label selector the target node must satisfy ("hoard.io/node").
    pub node_selector: Labels,
    pub assigned_node: Option<usize>,
    pub phase: PodPhase,
}
object_impl!(Pod, "Pod");

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PodPhase {
    #[default]
    Pending,
    Running,
    Succeeded,
    Failed,
}

/// Well-known label keys.
pub mod labels {
    /// Set by the coordinator on pods to pin them to a chosen node.
    pub const NODE: &str = "hoard.io/node";
    /// Set on nodes: rack membership.
    pub const RACK: &str = "topology.hoard.io/rack";
    /// Set by the coordinator on pods: preferred rack.
    pub const PREFERRED_RACK: &str = "hoard.io/preferred-rack";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds() {
        assert_eq!(Dataset::kind(), "Dataset");
        assert_eq!(DlJob::kind(), "DlJob");
        assert_eq!(Pvc::kind(), "Pvc");
        assert_eq!(Pod::kind(), "Pod");
    }

    #[test]
    fn meta_roundtrip() {
        let mut p = Pod {
            meta: ObjectMeta::named("p0"),
            job: "j".into(),
            gpus: 4,
            node_selector: Labels::new(),
            assigned_node: None,
            phase: PodPhase::Pending,
        };
        p.meta_mut().labels.insert("a".into(), "b".into());
        assert_eq!(p.meta().labels["a"], "b");
        assert_eq!(p.meta().name, "p0");
    }
}
