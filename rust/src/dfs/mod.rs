//! Distributed file-system backends for the cache layer (paper §3.3,
//! Table 1). The paper benchmarked GlusterFS, Alluxio and IBM Spectrum
//! Scale, then picked Spectrum Scale because it alone combines a remote
//! cache mode (AFM) with *node-subset* placement. We model all three behind
//! one trait so the Table 1 comparison — performance **and** feature fit —
//! is reproducible, and so the cache layer stays backend-agnostic
//! (the paper's "flexible enough to integrate a different file system").

use crate::cluster::GpuDemand;
use crate::workload::DatasetSpec;

/// Feature matrix from §3.3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FsFeatures {
    /// Can act as a transparent cache of another store (AFM-style).
    pub cache_mode: bool,
    /// Can constrain a dataset to a chosen subset of nodes (Requirement 1/3).
    pub node_subset: bool,
    /// Exposes full POSIX semantics (Requirement 4).
    pub posix: bool,
}

pub trait DistFs: std::fmt::Debug + Send + Sync {
    fn name(&self) -> &'static str;
    fn features(&self) -> FsFeatures;

    /// Sustained per-client read throughput (bytes/s) for the DL training
    /// pattern (small random file reads, `clients` concurrent trainers per
    /// server). Calibrated from Table 1 — see each backend.
    fn per_client_read_bw(&self, clients: u32) -> f64;

    /// Metadata operation cost (open/stat), seconds. DL epochs open every
    /// file once, so this matters at millions of files.
    fn metadata_op_cost(&self) -> f64;

    /// Whether the Hoard cache layer can be built on this backend at all.
    fn usable_for_hoard(&self) -> bool {
        let f = self.features();
        f.cache_mode && f.node_subset && f.posix
    }

    /// Duration of one training epoch (seconds) for `job` over `ds`, I/O
    /// and compute overlapped (the slower of the two paces the epoch).
    fn epoch_duration(&self, ds: &DatasetSpec, job: &GpuDemand, clients: u32) -> f64 {
        let io = ds.total_bytes as f64 / self.per_client_read_bw(clients)
            + ds.num_items as f64 * self.metadata_op_cost();
        let compute = ds.num_items as f64 / job.images_per_sec();
        io.max(compute)
    }
}

/// IBM Spectrum Scale (GPFS) + AFM: the selected backend.
/// Table 1: 27.5 min for 1 epoch ResNet50 ⇒ ~86.4 MB/s per 4-GPU client at
/// the benchmark's synchronous-read settings.
#[derive(Debug, Clone, Default)]
pub struct SpectrumLike;

/// Alluxio (Tachyon): cache mode yes, node-subset **no** — every dataset is
/// spread over all nodes, defeating co-scheduling. Table 1: 28.6 min.
#[derive(Debug, Clone, Default)]
pub struct AlluxioLike;

/// GlusterFS: no out-of-the-box cache mode (would require code changes).
/// Table 1: 28.9 min.
#[derive(Debug, Clone, Default)]
pub struct GlusterLike;

fn degraded(base: f64, clients: u32, retention: f64) -> f64 {
    if clients <= 1 {
        base
    } else {
        base * retention.powf((clients as f64).log2())
    }
}

impl DistFs for SpectrumLike {
    fn name(&self) -> &'static str {
        "spectrum-scale"
    }

    fn features(&self) -> FsFeatures {
        FsFeatures { cache_mode: true, node_subset: true, posix: true }
    }

    fn per_client_read_bw(&self, clients: u32) -> f64 {
        // 27.5 min total − 1.28 M × 120 µs metadata ⇒ ~96.3 MB/s data path.
        degraded(96.3e6, clients, 0.97)
    }

    fn metadata_op_cost(&self) -> f64 {
        120e-6
    }
}

impl DistFs for AlluxioLike {
    fn name(&self) -> &'static str {
        "alluxio"
    }

    fn features(&self) -> FsFeatures {
        // POSIX via FUSE shim; cache of remote stores supported; placement
        // on a chosen node subset not supported (§3.3).
        FsFeatures { cache_mode: true, node_subset: false, posix: true }
    }

    fn per_client_read_bw(&self, clients: u32) -> f64 {
        // 28.6 min total − 1.28 M × 180 µs metadata ⇒ ~97.0 MB/s data path.
        degraded(97.0e6, clients, 0.96)
    }

    fn metadata_op_cost(&self) -> f64 {
        180e-6
    }
}

impl DistFs for GlusterLike {
    fn name(&self) -> &'static str {
        "glusterfs"
    }

    fn features(&self) -> FsFeatures {
        FsFeatures { cache_mode: false, node_subset: true, posix: true }
    }

    fn per_client_read_bw(&self, clients: u32) -> f64 {
        // 28.9 min total − 1.28 M × 250 µs metadata ⇒ ~101.9 MB/s data path.
        degraded(101.9e6, clients, 0.95)
    }

    fn metadata_op_cost(&self) -> f64 {
        250e-6
    }
}

/// All candidate backends, in the paper's Table 1 order.
pub fn all_backends() -> Vec<Box<dyn DistFs>> {
    vec![Box::new(GlusterLike), Box::new(AlluxioLike), Box::new(SpectrumLike)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::DatasetSpec;

    fn imagenet() -> DatasetSpec {
        DatasetSpec::imagenet()
    }

    #[test]
    fn table1_training_durations() {
        // Paper Table 1: Gluster 28.9, Alluxio 28.6, Spectrum 27.5 minutes.
        let ds = imagenet();
        let job = GpuDemand::table1_resnet_job();
        let cases: Vec<(Box<dyn DistFs>, f64)> = vec![
            (Box::new(GlusterLike), 28.9),
            (Box::new(AlluxioLike), 28.6),
            (Box::new(SpectrumLike), 27.5),
        ];
        for (fs, want_min) in cases {
            let got_min = fs.epoch_duration(&ds, &job, 1) / 60.0;
            let err = (got_min - want_min).abs() / want_min;
            assert!(err < 0.05, "{}: got {got_min:.1} want {want_min}", fs.name());
        }
    }

    #[test]
    fn only_spectrum_usable_for_hoard() {
        assert!(SpectrumLike.usable_for_hoard());
        assert!(!AlluxioLike.usable_for_hoard(), "no node-subset placement");
        assert!(!GlusterLike.usable_for_hoard(), "no cache mode");
    }

    #[test]
    fn spectrum_fastest() {
        let ds = imagenet();
        let job = GpuDemand::table1_resnet_job();
        let s = SpectrumLike.epoch_duration(&ds, &job, 1);
        let a = AlluxioLike.epoch_duration(&ds, &job, 1);
        let g = GlusterLike.epoch_duration(&ds, &job, 1);
        assert!(s < a && a < g);
    }

    #[test]
    fn concurrency_degrades_throughput() {
        for fs in all_backends() {
            assert!(fs.per_client_read_bw(8) < fs.per_client_read_bw(1), "{}", fs.name());
        }
    }

    #[test]
    fn compute_bound_when_fs_is_fast() {
        // A hypothetical infinitely fast FS pins the epoch at GPU speed.
        #[derive(Debug)]
        struct FastFs;
        impl DistFs for FastFs {
            fn name(&self) -> &'static str {
                "fast"
            }
            fn features(&self) -> FsFeatures {
                SpectrumLike.features()
            }
            fn per_client_read_bw(&self, _c: u32) -> f64 {
                f64::INFINITY
            }
            fn metadata_op_cost(&self) -> f64 {
                0.0
            }
        }
        let ds = imagenet();
        let job = GpuDemand::table1_resnet_job();
        let t = FastFs.epoch_duration(&ds, &job, 1);
        let compute = ds.num_items as f64 / job.images_per_sec();
        assert!((t - compute).abs() < 1e-6);
    }
}
