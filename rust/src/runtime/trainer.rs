//! Training session: owns the model/optimizer state as PJRT literals and
//! drives the AOT `init` / `train_step` / `predict` entrypoints. This is the
//! "GPU" of the real-mode pipeline — the consumer Hoard feeds.

use anyhow::{bail, Context, Result};

use super::{literal_i32, literal_i32_scalar, literal_u8, Engine};

pub struct TrainerSession {
    engine: Engine,
    /// 8 params followed by 8 momenta, in manifest order.
    state: Vec<xla::Literal>,
    pub steps_done: u64,
}

impl TrainerSession {
    /// Create a session and initialize parameters with the AOT `init`
    /// computation (deterministic given `seed`); momenta start at zero.
    pub fn new(artifacts_dir: &str, seed: i32) -> Result<Self> {
        let mut engine = Engine::new(artifacts_dir)?;
        let params = engine.execute("init", &[literal_i32_scalar(seed)?])?;
        let n = engine.manifest.num_params();
        if params.len() != n {
            bail!("init returned {} params, manifest says {n}", params.len());
        }
        // Zero momenta with the same shapes.
        let mut state = params;
        for i in 0..n {
            let spec = engine.manifest.param_specs[i].clone();
            let zeros = vec![0f32; spec.elements() as usize];
            state.push(super::literal_f32(&zeros, &spec.shape)?);
        }
        Ok(TrainerSession { engine, state, steps_done: 0 })
    }

    pub fn batch_size(&self) -> usize {
        self.engine.manifest.batch
    }

    pub fn image_dims(&self) -> &[usize] {
        &self.engine.manifest.image
    }

    /// One SGD-momentum step on a raw uint8 NHWC batch. Returns the loss.
    pub fn step(&mut self, images_u8: &[u8], labels: &[i32]) -> Result<f32> {
        let b = self.batch_size();
        let dims = self.image_dims();
        let img_elems = b * dims.iter().product::<usize>();
        if images_u8.len() != img_elems {
            bail!("batch has {} pixels, want {img_elems}", images_u8.len());
        }
        if labels.len() != b {
            bail!("batch has {} labels, want {b}", labels.len());
        }
        let mut full_dims = vec![b];
        full_dims.extend_from_slice(dims);
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.state.len() + 2);
        inputs.append(&mut self.state);
        inputs.push(literal_u8(images_u8, &full_dims)?);
        inputs.push(literal_i32(labels, &[b])?);

        let mut outs = self.engine.execute("train_step", &inputs)?;
        let loss = outs
            .pop()
            .context("train_step returned nothing")?
            .to_vec::<f32>()?
            .first()
            .copied()
            .context("empty loss literal")?;
        self.state = outs; // 8 params + 8 momenta, updated
        self.steps_done += 1;
        Ok(loss)
    }

    /// Inference logits for a raw uint8 NHWC batch: (batch, num_classes)
    /// row-major.
    pub fn predict(&mut self, images_u8: &[u8]) -> Result<Vec<f32>> {
        let b = self.batch_size();
        let dims = self.image_dims();
        let mut full_dims = vec![b];
        full_dims.extend_from_slice(dims);
        let n = self.engine.manifest.num_params();
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(n + 1);
        // Clone param literals by serializing through host vectors is
        // wasteful; instead pass borrowed literals: execute takes Borrow.
        // Our Engine::execute takes &[Literal], so temporarily move params
        // out and restore after.
        let momenta = self.state.split_off(n);
        inputs.append(&mut self.state);
        inputs.push(literal_u8(images_u8, &full_dims)?);
        let result = self.engine.execute("predict", &inputs);
        // Restore state (params back from inputs, momenta appended).
        inputs.pop();
        self.state = inputs;
        self.state.extend(momenta);
        let outs = result?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    /// Argmax accuracy of `predict` against labels.
    pub fn accuracy(&mut self, images_u8: &[u8], labels: &[i32]) -> Result<f64> {
        let logits = self.predict(images_u8)?;
        let b = self.batch_size();
        let c = self.engine.manifest.num_classes;
        let mut correct = 0;
        for i in 0..b {
            let row = &logits[i * c..(i + 1) * c];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax as i32 == labels[i] {
                correct += 1;
            }
        }
        Ok(correct as f64 / b as f64)
    }
}
