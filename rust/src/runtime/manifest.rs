//! Parse `artifacts/manifest.json` — the positional calling convention the
//! AOT step (python/compile/aot.py) emits alongside the HLO artifacts.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U8,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "float32" => Dtype::F32,
            "uint8" => Dtype::U8,
            "int32" => Dtype::I32,
            other => bail!("unsupported dtype '{other}'"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 => 1,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> u64 {
        self.shape.iter().map(|&d| d as u64).product()
    }

    pub fn byte_len(&self) -> usize {
        self.elements() as usize * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .context("spec missing shape")?
            .iter()
            .map(|d| d.as_u64().map(|v| v as usize).context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(j.get("dtype").and_then(|d| d.as_str()).context("missing dtype")?)?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One entrypoint's positional signature.
#[derive(Debug, Clone)]
pub struct EntrySig {
    pub doc: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The full artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub batch: usize,
    pub image: Vec<usize>,
    pub num_classes: usize,
    pub lr: f64,
    pub momentum: f64,
    pub param_names: Vec<String>,
    pub param_specs: Vec<TensorSpec>,
    pub entrypoints: BTreeMap<String, EntrySig>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("manifest is not valid json")?;
        let batch = j.get("batch").and_then(|v| v.as_u64()).context("batch")? as usize;
        let image = j
            .get("image")
            .and_then(|v| v.as_arr())
            .context("image")?
            .iter()
            .map(|d| d.as_u64().unwrap_or(0) as usize)
            .collect();
        let num_classes =
            j.get("num_classes").and_then(|v| v.as_u64()).context("num_classes")? as usize;
        let lr = j.get("lr").and_then(|v| v.as_f64()).context("lr")?;
        let momentum = j.get("momentum").and_then(|v| v.as_f64()).context("momentum")?;

        let mut param_names = vec![];
        let mut param_specs = vec![];
        for p in j.get("param_specs").and_then(|v| v.as_arr()).context("param_specs")? {
            param_names.push(p.get("name").and_then(|n| n.as_str()).context("param name")?.into());
            param_specs.push(TensorSpec::from_json(p)?);
        }

        let mut entrypoints = BTreeMap::new();
        for (name, e) in j.get("entrypoints").and_then(|v| v.as_obj()).context("entrypoints")? {
            let sig = EntrySig {
                doc: e.get("doc").and_then(|d| d.as_str()).unwrap_or("").to_string(),
                inputs: e
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .context("inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: e
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .context("outputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
            };
            entrypoints.insert(name.clone(), sig);
        }
        Ok(Manifest { batch, image, num_classes, lr, momentum, param_names, param_specs, entrypoints })
    }

    pub fn num_params(&self) -> usize {
        self.param_specs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch": 64, "image": [32, 32, 3], "num_classes": 10,
      "lr": 0.05, "momentum": 0.9,
      "param_specs": [
        {"name": "w", "shape": [3, 3], "dtype": "float32"},
        {"name": "b", "shape": [3], "dtype": "float32"}
      ],
      "entrypoints": {
        "f": {"doc": "d",
              "inputs": [{"shape": [64, 32, 32, 3], "dtype": "uint8"}],
              "outputs": [{"shape": [], "dtype": "float32"}]}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 64);
        assert_eq!(m.param_names, vec!["w", "b"]);
        assert_eq!(m.num_params(), 2);
        let f = &m.entrypoints["f"];
        assert_eq!(f.inputs[0].dtype, Dtype::U8);
        assert_eq!(f.inputs[0].elements(), 64 * 32 * 32 * 3);
        assert_eq!(f.outputs[0].elements(), 1); // scalar
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Exercised fully in integration tests; here just tolerate absence.
        let p = Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.entrypoints.contains_key("train_step"));
            assert_eq!(m.num_params(), 8);
            let ts = &m.entrypoints["train_step"];
            assert_eq!(ts.inputs.len(), 2 * 8 + 2);
            assert_eq!(ts.outputs.len(), 2 * 8 + 1);
        }
    }

    #[test]
    fn rejects_bad_dtype() {
        assert!(Dtype::parse("complex64").is_err());
        let bad = SAMPLE.replace("uint8", "complex64");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn byte_len() {
        let t = TensorSpec { shape: vec![2, 3], dtype: Dtype::F32 };
        assert_eq!(t.byte_len(), 24);
        let t = TensorSpec { shape: vec![], dtype: Dtype::I32 };
        assert_eq!(t.byte_len(), 4);
    }
}
