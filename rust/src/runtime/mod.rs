//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client, and
//! executes them from the Rust data path. Python never runs here.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §8).

//! The engine/trainer half requires the out-of-tree `xla` PJRT bindings
//! and is gated behind the `pjrt` cargo feature (off by default, so the
//! offline build compiles without them); the artifact [`manifest`] is
//! plain JSON and always available.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use manifest::{Dtype, EntrySig, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use trainer::TrainerSession;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};

/// A compiled-artifact registry bound to one PJRT client.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load `manifest.json` from `dir` and connect the CPU PJRT client.
    /// Executables are compiled lazily per entrypoint (`prepare`/`execute`).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let manifest = Manifest::load(&manifest_path)
            .with_context(|| format!("loading {}", manifest_path.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, dir, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) the named entrypoint from its HLO text.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        if !self.manifest.entrypoints.contains_key(name) {
            bail!("entrypoint '{name}' not in manifest");
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entrypoint. Inputs must match the manifest signature
    /// (checked); the jax side lowers with `return_tuple=True`, so the
    /// single tuple output is unpacked into one literal per output spec.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.prepare(name)?;
        let sig = &self.manifest.entrypoints[name];
        if inputs.len() != sig.inputs.len() {
            bail!("{name}: {} inputs given, signature wants {}", inputs.len(), sig.inputs.len());
        }
        for (i, (lit, spec)) in inputs.iter().zip(&sig.inputs).enumerate() {
            let n = lit.element_count();
            if n as u64 != spec.elements() {
                bail!("{name}: input {i} has {n} elements, spec {:?} wants {}", spec, spec.elements());
            }
        }
        let exe = &self.executables[name];
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != sig.outputs.len() {
            bail!("{name}: got {} outputs, manifest says {}", outs.len(), sig.outputs.len());
        }
        Ok(outs)
    }
}

/// Build a literal from raw f32 data + dims.
#[cfg(feature = "pjrt")]
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)?)
}

/// Build a literal from raw u8 data + dims.
#[cfg(feature = "pjrt")]
pub fn literal_u8(data: &[u8], dims: &[usize]) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, dims, bytes_of(data))?)
}

/// Build a literal from i32 data + dims.
#[cfg(feature = "pjrt")]
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)?)
}

/// Scalar i32 literal (e.g. the init seed).
#[cfg(feature = "pjrt")]
pub fn literal_i32_scalar(v: i32) -> Result<xla::Literal> {
    literal_i32(&[v], &[])
}

#[cfg(feature = "pjrt")]
fn bytes_of(data: &[u8]) -> &[u8] {
    data
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_roundtrip_u8_i32() {
        let lit = literal_u8(&[7, 8, 9], &[3]).unwrap();
        assert_eq!(lit.to_vec::<u8>().unwrap(), vec![7, 8, 9]);
        let lit = literal_i32(&[-1, 5], &[2]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![-1, 5]);
    }

    #[test]
    fn wrong_size_rejected() {
        assert!(literal_f32(&[1.0], &[2, 2]).is_err());
    }
}
