//! The event-driven connection engine: one loop thread multiplexing every
//! connection over an [`EventLoop`], a small worker pool for request
//! handling, and a [`Service`] trait that both wire protocols
//! (`peer::proto` frames and `api::http` requests) plug into.
//!
//! ## Architecture
//!
//! ```text
//!            ┌────────────────────────── loop thread ─────────────────────────┐
//!  accept ──▶│ conns: token → Conn { inbuf, out: BufferChain, state }         │
//!            │   readable ─▶ read to inbuf ─▶ try_parse ─▶ dispatch ──────────┼──▶ JobQueue
//!            │   writable ─▶ flush out chain (partial writes resume)          │      │ workers
//!            │   deadline wheel ─▶ close idle conns                           │      ▼ svc.handle
//!            │ ◀── completions (token, Reply) + waker ◀──────────────────────────────┘
//!            └────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Per-connection state machine: *Reading* (bytes accumulate in `inbuf`
//! until `try_parse` yields a request) → *Serving* (`in_flight`: the
//! request is on a worker; at most one per connection, so responses keep
//! request order) → *Writing* (the reply's segments drain through the
//! [`BufferChain`] under write readiness) → back to *Reading* (any
//! pipelined bytes already buffered parse immediately).
//!
//! Invariants:
//!  * the loop thread never blocks on a socket, a disk read, or a token
//!    bucket — anything that can block runs on the workers;
//!  * backpressure, not collapse: at the connection budget the listener
//!    answers the service's busy reply and closes *new* sockets — live
//!    connections are never mid-stream dropped;
//!  * io deadlines come from a [`TimerWheel`] (one entry per connection,
//!    lazily re-armed), not per-socket `SO_RCVTIMEO` — O(1) per tick at
//!    any connection count, and an idle-timeout close writes nothing;
//!  * buffers recycle: connection read buffers and drained write segments
//!    return to a shared [`BufPool`].
//!
//! Under light load (small readiness batches) requests the service marks
//! [`Service::serve_inline`] are handled on the loop thread itself,
//! skipping two thread handoffs — at 8 connections the engine matches the
//! thread-per-connection design it replaced; under bursts everything goes
//! through the workers and the loop stays responsive.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use super::chain::BufferChain;
use super::evloop::{Event, EventLoop, Interest, Waker};
use super::wheel::TimerWheel;
use crate::posix::bufpool::BufPool;

const LISTENER_TOKEN: u64 = 0;
const FIRST_CONN_TOKEN: u64 = 1;
/// Per-readiness read bite (and the loop's reusable scratch buffer size).
const READ_CHUNK: usize = 64 << 10;
/// A readiness batch at least this large counts as a burst: inline
/// serving is skipped and every request goes to the workers.
const INLINE_BATCH_CUTOFF: usize = 8;
const WHEEL_SLOTS: usize = 128;

/// A response as a list of byte segments (written in order, zero-copy for
/// payloads the service already owns). `close` ⇒ close the connection
/// once every segment is flushed.
#[derive(Debug)]
pub struct Reply {
    pub segments: Vec<Vec<u8>>,
    pub close: bool,
}

impl Reply {
    pub fn new(segments: Vec<Vec<u8>>) -> Self {
        Reply { segments, close: false }
    }

    pub fn closing(segments: Vec<Vec<u8>>) -> Self {
        Reply { segments, close: true }
    }
}

/// A wire protocol plugged into the [`Engine`]. Parsing runs on the loop
/// thread (must be cheap and incremental); `handle` runs on a worker (may
/// block on disk, locks, token buckets).
pub trait Service: Send + Sync + 'static {
    type Request: Send + 'static;

    /// Incremental parse: inspect `inbuf` and either cut one complete
    /// request out of it (draining the consumed bytes) or report that
    /// more bytes are needed (`Ok(None)`, `inbuf` untouched). An `Err` is
    /// a protocol violation: the connection is closed (after
    /// [`Service::parse_error_reply`], if any). Must reject hostile
    /// lengths *before* allocating.
    fn try_parse(&self, inbuf: &mut Vec<u8>) -> Result<Option<Self::Request>>;

    /// Handle one request (worker thread; blocking is fine).
    fn handle(&self, req: Self::Request) -> Reply;

    /// Per-connection cap on buffered unparsed input. A connection whose
    /// `inbuf` reaches the cap without yielding a request is closed.
    fn max_buffered(&self) -> usize;

    /// Best-effort reply for connections over the budget (written
    /// non-blocking to the fresh socket, then closed). `None` ⇒ just
    /// close.
    fn busy_reply(&self) -> Option<Reply> {
        None
    }

    /// Reply to send (then close) when `try_parse` errors. `None` ⇒ close
    /// silently.
    fn parse_error_reply(&self, _err: &anyhow::Error) -> Option<Reply> {
        None
    }

    /// Whether `req` is cheap enough to serve on the loop thread under
    /// light load (no blocking calls, small payload). Default: never.
    fn serve_inline(&self, _req: &Self::Request) -> bool {
        false
    }
}

#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Idle deadline: a connection with no io progress and no request in
    /// flight for this long is closed (without writing anything).
    pub io_timeout: Duration,
    /// Connection budget: at the cap, new sockets get the busy reply and
    /// are closed. Live connections are never dropped.
    pub max_conns: usize,
    /// Worker threads handling requests.
    pub workers: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            io_timeout: Duration::from_secs(10),
            max_conns: 4096,
            workers: default_workers(),
        }
    }
}

fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8)
}

type Job = Box<dyn FnOnce() + Send>;

/// Mutex+Condvar job queue (not `std::sync::mpsc`: a shared `Receiver`
/// behind a `Mutex` would serialize workers across the blocking `recv`).
/// `close` lets queued jobs drain, then wakes every worker to exit.
struct JobQueue<T> {
    inner: Mutex<(VecDeque<T>, bool)>,
    cv: Condvar,
}

impl<T> JobQueue<T> {
    fn new() -> Self {
        JobQueue { inner: Mutex::new((VecDeque::new(), false)), cv: Condvar::new() }
    }

    fn push(&self, item: T) {
        let mut g = self.inner.lock().unwrap();
        if g.1 {
            return;
        }
        g.0.push_back(item);
        drop(g);
        self.cv.notify_one();
    }

    fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.0.pop_front() {
                return Some(item);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().1 = true;
        self.cv.notify_all();
    }
}

/// A running engine. Dropping (or [`Engine::stop`]) severs every
/// connection, joins the loop, and drains the workers.
pub struct Engine {
    /// Bound address (bind to port 0 and read this back).
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    waker: Waker,
    live: Arc<AtomicUsize>,
    jobs: Arc<JobQueue<Job>>,
    loop_join: Option<std::thread::JoinHandle<()>>,
    worker_joins: Vec<std::thread::JoinHandle<()>>,
}

impl Engine {
    pub fn start<S: Service>(addr: &str, svc: Arc<S>, cfg: EngineConfig) -> Result<Engine> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let evloop = EventLoop::new()?;
        let waker = evloop.waker();
        let stop = Arc::new(AtomicBool::new(false));
        let live = Arc::new(AtomicUsize::new(0));
        let jobs: Arc<JobQueue<Job>> = Arc::new(JobQueue::new());
        let worker_joins = (0..cfg.workers.max(1))
            .map(|i| {
                let jobs = jobs.clone();
                std::thread::Builder::new()
                    .name(format!("hoard-net-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = jobs.pop() {
                            job();
                        }
                    })
                    .context("spawning engine worker")
            })
            .collect::<Result<Vec<_>>>()?;
        let ctx = LoopCtx {
            svc,
            cfg,
            pool: Arc::new(BufPool::new(256, 1 << 20)),
            jobs: jobs.clone(),
            completions: Arc::new(Mutex::new(Vec::new())),
            sleeping: Arc::new(AtomicBool::new(false)),
            waker: waker.clone(),
            live: live.clone(),
            stop: stop.clone(),
            scratch: RefCell::new(vec![0u8; READ_CHUNK]),
        };
        let loop_join = std::thread::Builder::new()
            .name("hoard-net-loop".into())
            .spawn(move || run_loop(listener, evloop, ctx))
            .context("spawning engine loop")?;
        Ok(Engine { addr, stop, waker, live, jobs, loop_join: Some(loop_join), worker_joins })
    }

    /// Connections currently held by the loop (observability; tests use
    /// it to assert churn returns to zero).
    pub fn live_conns(&self) -> usize {
        self.live.load(Ordering::Acquire)
    }

    /// Graceful shutdown: wake the loop (which severs every connection),
    /// join it, then drain and join the workers. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.waker.wake();
        if let Some(j) = self.loop_join.take() {
            let _ = j.join();
        }
        self.jobs.close();
        for j in self.worker_joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Per-connection state (the `Reading → Serving → Writing` machine).
struct Conn {
    sock: TcpStream,
    token: u64,
    /// Buffered unparsed input.
    inbuf: Vec<u8>,
    /// Buffered unwritten output.
    out: BufferChain,
    /// A request is on a worker; parsing pauses (order preservation).
    in_flight: bool,
    /// Close once `out` drains (EOF seen, parse error, or service said
    /// close).
    close_after_write: bool,
    /// Peer half-closed its write side.
    read_closed: bool,
    /// Authoritative idle deadline (the wheel entry is a lazy hint).
    deadline: Instant,
    interest: Interest,
}

enum Verdict {
    Keep,
    Close,
}

struct LoopCtx<S: Service> {
    svc: Arc<S>,
    cfg: EngineConfig,
    pool: Arc<BufPool>,
    jobs: Arc<JobQueue<Job>>,
    completions: Arc<Mutex<Vec<(u64, Reply)>>>,
    /// True while the loop is (about to be) parked in poll — workers only
    /// pay the wake syscall when it is.
    sleeping: Arc<AtomicBool>,
    waker: Waker,
    live: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    /// Loop-thread read scratch (avoids zero-filling `inbuf` tails per
    /// read).
    scratch: RefCell<Vec<u8>>,
}

impl<S: Service> LoopCtx<S> {
    /// Hand a request to the worker pool; the reply comes back through
    /// `completions`.
    fn dispatch(&self, token: u64, req: S::Request) {
        let svc = self.svc.clone();
        let completions = self.completions.clone();
        let waker = self.waker.clone();
        let sleeping = self.sleeping.clone();
        self.jobs.push(Box::new(move || {
            // A panicking handler severs its connection (empty closing
            // reply) instead of wedging it in the Serving state forever.
            let reply =
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| svc.handle(req))) {
                    Ok(r) => r,
                    Err(_) => Reply::closing(vec![]),
                };
            completions.lock().unwrap().push((token, reply));
            if sleeping.load(Ordering::SeqCst) {
                waker.wake();
            }
        }));
    }

    /// Drain the socket's readable bytes into `inbuf` (up to the buffer
    /// cap).
    fn on_readable(&self, conn: &mut Conn) -> Verdict {
        let cap = self.svc.max_buffered();
        let mut scratch = self.scratch.borrow_mut();
        loop {
            if conn.inbuf.len() >= cap {
                break;
            }
            let want = READ_CHUNK.min(cap - conn.inbuf.len());
            match conn.sock.read(&mut scratch[..want]) {
                Ok(0) => {
                    conn.read_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.inbuf.extend_from_slice(&scratch[..n]);
                    conn.deadline = Instant::now() + self.cfg.io_timeout;
                    if n < want {
                        break;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return Verdict::Close,
            }
        }
        Verdict::Keep
    }

    /// Parse-and-dispatch until blocked on bytes or an in-flight request.
    fn pump(&self, conn: &mut Conn, inline_ok: bool) -> Verdict {
        while !conn.in_flight && !conn.close_after_write {
            match self.svc.try_parse(&mut conn.inbuf) {
                Ok(Some(req)) => {
                    if inline_ok && self.svc.serve_inline(&req) {
                        let reply = self.svc.handle(req);
                        queue_reply(conn, reply);
                    } else {
                        conn.in_flight = true;
                        self.dispatch(conn.token, req);
                    }
                }
                Ok(None) => {
                    if conn.inbuf.len() >= self.svc.max_buffered() {
                        // A frame the service can never complete within
                        // its buffer budget.
                        return Verdict::Close;
                    }
                    if conn.read_closed {
                        // EOF with no completable request: flush whatever
                        // is queued, then close.
                        conn.close_after_write = true;
                    }
                    break;
                }
                Err(err) => {
                    conn.read_closed = true;
                    conn.inbuf.clear();
                    match self.svc.parse_error_reply(&err) {
                        Some(reply) => {
                            queue_reply(conn, reply);
                            conn.close_after_write = true;
                        }
                        None => return Verdict::Close,
                    }
                    break;
                }
            }
        }
        Verdict::Keep
    }

    /// Write queued output until the socket blocks, recycling drained
    /// segments.
    fn flush(&self, conn: &mut Conn) -> Verdict {
        let mut recycled = Vec::new();
        let verdict = loop {
            let n = {
                let Some(front) = conn.out.front() else { break Verdict::Keep };
                match conn.sock.write(front) {
                    Ok(0) => break Verdict::Close,
                    Ok(n) => n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break Verdict::Keep,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => break Verdict::Close,
                }
            };
            conn.out.advance(n, &mut recycled);
            conn.deadline = Instant::now() + self.cfg.io_timeout;
        };
        for seg in recycled {
            self.pool.put(seg);
        }
        verdict
    }

    /// Flush, then either close or re-register with the interest the
    /// connection's state implies and park it back in the map.
    fn finish(
        &self,
        evloop: &mut EventLoop,
        conns: &mut HashMap<u64, Conn>,
        mut conn: Conn,
        verdict: Verdict,
    ) {
        let verdict = match verdict {
            Verdict::Keep => self.flush(&mut conn),
            Verdict::Close => Verdict::Close,
        };
        let drained = conn.close_after_write && conn.out.is_empty() && !conn.in_flight;
        if matches!(verdict, Verdict::Close) || drained {
            self.close_conn(evloop, conn);
            return;
        }
        let want = Interest::new(
            !conn.read_closed
                && !conn.close_after_write
                && conn.inbuf.len() < self.svc.max_buffered(),
            !conn.out.is_empty(),
        );
        if want != conn.interest {
            if evloop.reregister(conn.sock.as_raw_fd(), conn.token, want).is_err() {
                self.close_conn(evloop, conn);
                return;
            }
            conn.interest = want;
        }
        conns.insert(conn.token, conn);
    }

    fn close_conn(&self, evloop: &mut EventLoop, mut conn: Conn) {
        let _ = evloop.deregister(conn.sock.as_raw_fd());
        let _ = conn.sock.shutdown(Shutdown::Both);
        self.pool.put(std::mem::take(&mut conn.inbuf));
        let mut recycled = Vec::new();
        conn.out.clear(&mut recycled);
        for seg in recycled {
            self.pool.put(seg);
        }
    }

    /// Accept everything pending; over the budget each fresh socket gets
    /// the busy reply (one non-blocking attempt) and is closed.
    fn accept_burst(
        &self,
        listener: &TcpListener,
        evloop: &mut EventLoop,
        conns: &mut HashMap<u64, Conn>,
        next_token: &mut u64,
        wheel: &mut TimerWheel,
    ) {
        loop {
            match listener.accept() {
                Ok((sock, _peer)) => {
                    let _ = sock.set_nodelay(true);
                    if sock.set_nonblocking(true).is_err() {
                        let _ = sock.shutdown(Shutdown::Both);
                        continue;
                    }
                    if conns.len() >= self.cfg.max_conns {
                        if let Some(reply) = self.svc.busy_reply() {
                            let mut s = &sock;
                            for seg in &reply.segments {
                                if s.write_all(seg).is_err() {
                                    break;
                                }
                            }
                        }
                        let _ = sock.shutdown(Shutdown::Both);
                        continue;
                    }
                    let token = *next_token;
                    *next_token += 1;
                    if evloop.register(sock.as_raw_fd(), token, Interest::READ).is_err() {
                        let _ = sock.shutdown(Shutdown::Both);
                        continue;
                    }
                    let deadline = Instant::now() + self.cfg.io_timeout;
                    wheel.schedule(token, deadline);
                    conns.insert(
                        token,
                        Conn {
                            sock,
                            token,
                            inbuf: self.pool.take(),
                            out: BufferChain::new(),
                            in_flight: false,
                            close_after_write: false,
                            read_closed: false,
                            deadline,
                            interest: Interest::READ,
                        },
                    );
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e)
                    if e.kind() == io::ErrorKind::ConnectionAborted
                        || e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => break,
            }
        }
    }
}

fn queue_reply(conn: &mut Conn, reply: Reply) {
    for seg in reply.segments {
        conn.out.push(seg);
    }
    if reply.close {
        conn.close_after_write = true;
    }
}

fn wheel_tick(io_timeout: Duration) -> Duration {
    (io_timeout / 32).clamp(Duration::from_millis(5), Duration::from_millis(250))
}

fn run_loop<S: Service>(listener: TcpListener, mut evloop: EventLoop, ctx: LoopCtx<S>) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut wheel = TimerWheel::new(wheel_tick(ctx.cfg.io_timeout), WHEEL_SLOTS);
    let mut events: Vec<Event> = Vec::new();
    let mut due: Vec<u64> = Vec::new();
    let mut next_token = FIRST_CONN_TOKEN;
    if evloop.register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READ).is_err() {
        return;
    }
    loop {
        // Park until the next wheel tick, socket readiness, or a wake.
        // `sleeping` goes up *before* the completion check: a worker that
        // posts after the check sees it and wakes us (no lost wakeups).
        ctx.sleeping.store(true, Ordering::SeqCst);
        let timeout = if ctx.completions.lock().unwrap().is_empty() {
            wheel.next_tick_in(Instant::now())
        } else {
            Duration::ZERO
        };
        let poll_res = evloop.poll(&mut events, Some(timeout));
        ctx.sleeping.store(false, Ordering::SeqCst);
        if ctx.stop.load(Ordering::SeqCst) || poll_res.is_err() {
            break;
        }
        // Light load (small readiness batch) ⇒ cheap requests may be
        // served inline on the loop thread; bursts all go to workers.
        let inline_ok = events.len() < INLINE_BATCH_CUTOFF;
        for ev in &events {
            if ev.token == LISTENER_TOKEN {
                ctx.accept_burst(&listener, &mut evloop, &mut conns, &mut next_token, &mut wheel);
                continue;
            }
            let Some(mut conn) = conns.remove(&ev.token) else { continue };
            let mut verdict = Verdict::Keep;
            if ev.readable {
                verdict = ctx.on_readable(&mut conn);
                if matches!(verdict, Verdict::Keep) {
                    verdict = ctx.pump(&mut conn, inline_ok);
                }
            }
            // `finish` always attempts a flush, which covers `ev.writable`.
            ctx.finish(&mut evloop, &mut conns, conn, verdict);
        }
        // Worker completions: queue the reply, resume parsing pipelined
        // bytes, flush.
        let done: Vec<(u64, Reply)> = std::mem::take(&mut *ctx.completions.lock().unwrap());
        for (token, reply) in done {
            let Some(mut conn) = conns.remove(&token) else { continue };
            conn.in_flight = false;
            conn.deadline = Instant::now() + ctx.cfg.io_timeout;
            queue_reply(&mut conn, reply);
            let verdict = ctx.pump(&mut conn, false);
            ctx.finish(&mut evloop, &mut conns, conn, verdict);
        }
        // Deadlines. Lazy: `conn.deadline` is authoritative; a fired
        // entry whose deadline moved (io progress) re-arms, an in-flight
        // request gets a fresh lease, and a truly idle conn closes —
        // without writing anything.
        due.clear();
        let now = Instant::now();
        wheel.advance(now, &mut due);
        for &token in &due {
            let Some(conn) = conns.get(&token) else { continue };
            if conn.in_flight {
                wheel.schedule(token, now + ctx.cfg.io_timeout);
                continue;
            }
            if conn.deadline > now {
                let deadline = conn.deadline;
                wheel.schedule(token, deadline);
                continue;
            }
            let conn = conns.remove(&token).expect("present: looked up above");
            ctx.close_conn(&mut evloop, conn);
        }
        ctx.live.store(conns.len(), Ordering::Release);
    }
    // Shutdown: sever every live connection.
    for (_, conn) in conns.drain() {
        ctx.close_conn(&mut evloop, conn);
    }
    ctx.live.store(0, Ordering::Release);
    let _ = evloop.deregister(listener.as_raw_fd());
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    /// Newline-delimited echo-reversed protocol; the line "die" is a
    /// parse error, "slow" sleeps on the worker.
    struct Echo;

    impl Service for Echo {
        type Request = Vec<u8>;

        fn try_parse(&self, inbuf: &mut Vec<u8>) -> Result<Option<Vec<u8>>> {
            match inbuf.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    let line = inbuf[..i].to_vec();
                    inbuf.drain(..=i);
                    if line == b"die" {
                        anyhow::bail!("poison line");
                    }
                    Ok(Some(line))
                }
                None => Ok(None),
            }
        }

        fn handle(&self, req: Vec<u8>) -> Reply {
            let mut out = req;
            if out == b"slow" {
                std::thread::sleep(Duration::from_millis(100));
            }
            out.reverse();
            out.push(b'\n');
            Reply::new(vec![out])
        }

        fn max_buffered(&self) -> usize {
            1024
        }

        fn busy_reply(&self) -> Option<Reply> {
            Some(Reply::closing(vec![b"busy\n".to_vec()]))
        }
    }

    fn start(cfg: EngineConfig) -> Engine {
        Engine::start("127.0.0.1:0", Arc::new(Echo), cfg).unwrap()
    }

    fn roundtrip(sock: &mut TcpStream, line: &str) -> String {
        sock.write_all(format!("{line}\n").as_bytes()).unwrap();
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut out = String::new();
        r.read_line(&mut out).unwrap();
        out.trim_end().to_string()
    }

    #[test]
    fn echo_roundtrips_across_connections_and_pipelines() {
        let mut eng = start(EngineConfig::default());
        let mut socks: Vec<TcpStream> =
            (0..4).map(|_| TcpStream::connect(eng.addr).unwrap()).collect();
        for (i, s) in socks.iter_mut().enumerate() {
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            assert_eq!(roundtrip(s, &format!("hello{i}")), format!("{i}olleh"));
        }
        // Pipelined: two requests in one write, answers in order.
        let s = &mut socks[0];
        s.write_all(b"ab\nslow\ncd\n").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut lines = Vec::new();
        for _ in 0..3 {
            let mut l = String::new();
            r.read_line(&mut l).unwrap();
            lines.push(l.trim_end().to_string());
        }
        assert_eq!(lines, vec!["ba", "wols", "dc"]);
        eng.stop();
    }

    #[test]
    fn byte_at_a_time_requests_parse_incrementally() {
        let mut eng = start(EngineConfig::default());
        let mut s = TcpStream::connect(eng.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        for b in b"ping\n" {
            s.write_all(&[*b]).unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut out = String::new();
        r.read_line(&mut out).unwrap();
        assert_eq!(out.trim_end(), "gnip");
        eng.stop();
    }

    #[test]
    fn over_budget_connections_get_busy_reply_and_close() {
        let mut eng = start(EngineConfig {
            io_timeout: Duration::from_secs(5),
            max_conns: 1,
            workers: 2,
        });
        let mut first = TcpStream::connect(eng.addr).unwrap();
        first.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        assert_eq!(roundtrip(&mut first, "a"), "a");
        // Budget full: the next socket reads the busy reply then EOF.
        let mut second = TcpStream::connect(eng.addr).unwrap();
        second.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut buf = Vec::new();
        second.read_to_end(&mut buf).unwrap();
        assert_eq!(buf, b"busy\n");
        // The first (in-budget) connection was never disturbed.
        assert_eq!(roundtrip(&mut first, "bc"), "cb");
        eng.stop();
    }

    #[test]
    fn idle_connections_close_at_the_deadline_without_writing() {
        let mut eng = start(EngineConfig {
            io_timeout: Duration::from_millis(150),
            max_conns: 64,
            workers: 2,
        });
        let mut idle = TcpStream::connect(eng.addr).unwrap();
        idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let t0 = Instant::now();
        let mut buf = Vec::new();
        idle.read_to_end(&mut buf).unwrap();
        assert!(buf.is_empty(), "idle-timeout close must write nothing, got {buf:?}");
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline wheel never fired");
        // Connection count returns to zero.
        let t0 = Instant::now();
        while eng.live_conns() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "live_conns stuck nonzero");
            std::thread::sleep(Duration::from_millis(10));
        }
        eng.stop();
    }

    #[test]
    fn parse_errors_close_silently_by_default() {
        let mut eng = start(EngineConfig::default());
        let mut s = TcpStream::connect(eng.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(b"die\n").unwrap();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        assert!(buf.is_empty(), "parse-error close must write nothing (no reply configured)");
        eng.stop();
    }

    #[test]
    fn stop_severs_live_connections() {
        let mut eng = start(EngineConfig::default());
        let mut s = TcpStream::connect(eng.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        assert_eq!(roundtrip(&mut s, "x"), "x");
        eng.stop();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf); // EOF or reset — either way, severed
        assert!(buf.is_empty());
        // Stopped engine refuses new connections (or resets them fast).
        assert!(
            TcpStream::connect(eng.addr)
                .map(|mut c| {
                    let _ = c.set_read_timeout(Some(Duration::from_secs(2)));
                    let mut b = Vec::new();
                    matches!(c.read_to_end(&mut b), Ok(0)) || b.is_empty()
                })
                .unwrap_or(true),
            "stopped engine must not serve"
        );
    }
}
