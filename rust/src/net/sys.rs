//! Thin FFI shim over the handful of POSIX calls the event loop needs:
//! epoll (Linux), poll (portable fallback), a non-blocking wake pipe, and
//! RLIMIT_NOFILE. The offline build has no `libc` crate, but `std`
//! already links libc — declaring the symbols in an `extern "C"` block is
//! all it takes, with the constants spelled out per target.
//!
//! Everything here returns `std::io::Error` (via `last_os_error`) so the
//! layers above never see raw errnos.

use std::io;
use std::time::Duration;

/// Raw file descriptor (what `std::os::fd::RawFd` is on every POSIX
/// target; spelled out so this module stays self-contained).
pub type RawFd = i32;

// ---------------------------------------------------------------- epoll --

/// `struct epoll_event`. Packed on x86 (the kernel ABI packs it there);
/// natural alignment elsewhere. Fields are read *by value* at use sites —
/// never by reference — so the packed layout cannot produce unaligned
/// references.
#[repr(C)]
#[cfg_attr(any(target_arch = "x86_64", target_arch = "x86"), repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

#[cfg(target_os = "linux")]
pub const EPOLLIN: u32 = 0x001;
#[cfg(target_os = "linux")]
pub const EPOLLOUT: u32 = 0x004;
#[cfg(target_os = "linux")]
pub const EPOLLERR: u32 = 0x008;
#[cfg(target_os = "linux")]
pub const EPOLLHUP: u32 = 0x010;
#[cfg(target_os = "linux")]
pub const EPOLLRDHUP: u32 = 0x2000;

#[cfg(target_os = "linux")]
const EPOLL_CTL_ADD: i32 = 1;
#[cfg(target_os = "linux")]
const EPOLL_CTL_DEL: i32 = 2;
#[cfg(target_os = "linux")]
const EPOLL_CTL_MOD: i32 = 3;
#[cfg(target_os = "linux")]
const EPOLL_CLOEXEC: i32 = 0o2000000;

#[cfg(target_os = "linux")]
extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
    fn pipe2(fds: *mut i32, flags: i32) -> i32;
}

#[cfg(target_os = "linux")]
pub fn epoll_create() -> io::Result<RawFd> {
    let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(fd)
}

#[cfg(target_os = "linux")]
fn epoll_op(epfd: RawFd, op: i32, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
    let mut ev = EpollEvent { events, data };
    let rc = unsafe { epoll_ctl(epfd, op, fd, &mut ev) };
    if rc != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

#[cfg(target_os = "linux")]
pub fn epoll_add(epfd: RawFd, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
    epoll_op(epfd, EPOLL_CTL_ADD, fd, events, data)
}

#[cfg(target_os = "linux")]
pub fn epoll_mod(epfd: RawFd, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
    epoll_op(epfd, EPOLL_CTL_MOD, fd, events, data)
}

#[cfg(target_os = "linux")]
pub fn epoll_del(epfd: RawFd, fd: RawFd) -> io::Result<()> {
    // Pre-2.6.9 kernels require a non-null event even for DEL; passing a
    // dummy one costs nothing and works everywhere.
    epoll_op(epfd, EPOLL_CTL_DEL, fd, 0, 0)
}

#[cfg(target_os = "linux")]
pub fn epoll_wait_events(
    epfd: RawFd,
    buf: &mut [EpollEvent],
    timeout: Option<Duration>,
) -> io::Result<usize> {
    let cap = buf.len().min(i32::MAX as usize) as i32;
    let n = unsafe { epoll_wait(epfd, buf.as_mut_ptr(), cap, timeout_ms(timeout)) };
    if n < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(n as usize)
}

// ----------------------------------------------------------------- poll --

/// `struct pollfd` (identical layout on every POSIX target).
#[repr(C)]
#[derive(Clone, Copy)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;
pub const POLLNVAL: i16 = 0x020;

/// `nfds_t`: unsigned long on Linux, unsigned int elsewhere.
#[cfg(target_os = "linux")]
type NFds = u64;
#[cfg(not(target_os = "linux"))]
type NFds = u32;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NFds, timeout_ms: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
}

#[cfg(not(target_os = "linux"))]
extern "C" {
    fn pipe(fds: *mut i32) -> i32;
    fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
}

pub fn sys_poll(fds: &mut [PollFd], timeout: Option<Duration>) -> io::Result<usize> {
    let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms(timeout)) };
    if n < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(n as usize)
}

/// `None` ⇒ block forever (-1); sub-millisecond waits round up to 1 ms so
/// a short deadline never degenerates into a busy spin.
fn timeout_ms(t: Option<Duration>) -> i32 {
    match t {
        None => -1,
        Some(d) if d.is_zero() => 0,
        Some(d) => d.as_millis().clamp(1, i32::MAX as u128) as i32,
    }
}

// ------------------------------------------------------ pipe/read/write --

pub fn sys_read(fd: RawFd, buf: &mut [u8]) -> io::Result<usize> {
    let n = unsafe { read(fd, buf.as_mut_ptr(), buf.len()) };
    if n < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(n as usize)
}

pub fn sys_write(fd: RawFd, buf: &[u8]) -> io::Result<usize> {
    let n = unsafe { write(fd, buf.as_ptr(), buf.len()) };
    if n < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(n as usize)
}

pub fn sys_close(fd: RawFd) {
    unsafe {
        close(fd);
    }
}

/// A non-blocking self-pipe: `(read_end, write_end)`. Writes from any
/// thread make the read end poll-readable — the classic waker.
#[cfg(target_os = "linux")]
pub fn wake_pipe() -> io::Result<(RawFd, RawFd)> {
    const O_NONBLOCK: i32 = 0o4000;
    const O_CLOEXEC: i32 = 0o2000000;
    let mut fds = [0i32; 2];
    if unsafe { pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC) } != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok((fds[0], fds[1]))
}

#[cfg(not(target_os = "linux"))]
pub fn wake_pipe() -> io::Result<(RawFd, RawFd)> {
    const F_SETFL: i32 = 4;
    const O_NONBLOCK: i32 = 0x4;
    let mut fds = [0i32; 2];
    if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
        return Err(io::Error::last_os_error());
    }
    for fd in fds {
        if unsafe { fcntl(fd, F_SETFL, O_NONBLOCK) } < 0 {
            let e = io::Error::last_os_error();
            sys_close(fds[0]);
            sys_close(fds[1]);
            return Err(e);
        }
    }
    Ok((fds[0], fds[1]))
}

// --------------------------------------------------------------- rlimit --

#[repr(C)]
struct RLimit {
    cur: u64,
    max: u64,
}

#[cfg(target_os = "linux")]
const RLIMIT_NOFILE: i32 = 7;
#[cfg(not(target_os = "linux"))]
const RLIMIT_NOFILE: i32 = 8;

/// Best-effort: raise the soft RLIMIT_NOFILE to at least `min` (clamped to
/// the hard limit). Returns the soft limit in effect afterwards — callers
/// opening thousands of sockets (high-connection tests and benches) check
/// it and scale down instead of dying on EMFILE.
pub fn raise_nofile_limit(min: u64) -> u64 {
    unsafe {
        let mut lim = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
            return 0;
        }
        if lim.cur >= min {
            return lim.cur;
        }
        let want = RLimit { cur: min.min(lim.max), max: lim.max };
        if setrlimit(RLIMIT_NOFILE, &want) != 0 {
            return lim.cur;
        }
        want.cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wake_pipe_roundtrip_and_nonblocking() {
        let (rx, tx) = wake_pipe().unwrap();
        // Empty pipe: non-blocking read says WouldBlock instead of hanging.
        let mut buf = [0u8; 8];
        let err = sys_read(rx, &mut buf).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::WouldBlock);
        assert_eq!(sys_write(tx, &[7, 8]).unwrap(), 2);
        assert_eq!(sys_read(rx, &mut buf).unwrap(), 2);
        assert_eq!(&buf[..2], &[7, 8]);
        sys_close(rx);
        sys_close(tx);
    }

    #[test]
    fn poll_reports_pipe_readability() {
        let (rx, tx) = wake_pipe().unwrap();
        let mut fds = [PollFd { fd: rx, events: POLLIN, revents: 0 }];
        // Nothing buffered: poll times out with zero ready fds.
        assert_eq!(sys_poll(&mut fds, Some(Duration::from_millis(10))).unwrap(), 0);
        sys_write(tx, &[1]).unwrap();
        fds[0].revents = 0;
        assert_eq!(sys_poll(&mut fds, Some(Duration::from_millis(1000))).unwrap(), 1);
        assert_ne!(fds[0].revents & POLLIN, 0);
        sys_close(rx);
        sys_close(tx);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_reports_pipe_readability() {
        let (rx, tx) = wake_pipe().unwrap();
        let ep = epoll_create().unwrap();
        epoll_add(ep, rx, EPOLLIN, 42).unwrap();
        let mut buf = [EpollEvent { events: 0, data: 0 }; 8];
        assert_eq!(epoll_wait_events(ep, &mut buf, Some(Duration::from_millis(10))).unwrap(), 0);
        sys_write(tx, &[1]).unwrap();
        let n = epoll_wait_events(ep, &mut buf, Some(Duration::from_millis(1000))).unwrap();
        assert_eq!(n, 1);
        let data = buf[0].data;
        let events = buf[0].events;
        assert_eq!(data, 42);
        assert_ne!(events & EPOLLIN, 0);
        epoll_del(ep, rx).unwrap();
        sys_close(ep);
        sys_close(rx);
        sys_close(tx);
    }

    #[test]
    fn raise_nofile_limit_is_monotone() {
        let before = raise_nofile_limit(0);
        assert!(before > 0, "getrlimit must succeed");
        let after = raise_nofile_limit(before);
        assert!(after >= before);
    }
}
