//! `BufferChain` — a queue of byte segments with a front cursor, the
//! write-side buffer of an event-loop connection.
//!
//! Responses queue as whole segments (for a chunk frame: one pooled
//! header buffer plus the payload `Vec` itself — no copy into a contiguous
//! staging buffer, so a frame larger than one pooled buffer needs no
//! special case). `front`/`advance` drive partial non-blocking writes;
//! fully drained segments are handed back for recycling into a
//! [`BufPool`](crate::posix::bufpool::BufPool).

use std::collections::VecDeque;

#[derive(Debug, Default)]
pub struct BufferChain {
    segs: VecDeque<Vec<u8>>,
    /// Bytes of `segs[0]` already written out.
    front_off: usize,
    /// Total unwritten bytes across all segments.
    bytes: usize,
}

impl BufferChain {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue `seg` for writing (empty segments are dropped, not queued).
    pub fn push(&mut self, seg: Vec<u8>) {
        if seg.is_empty() {
            return;
        }
        self.bytes += seg.len();
        self.segs.push_back(seg);
    }

    /// The next contiguous unwritten bytes, if any.
    pub fn front(&self) -> Option<&[u8]> {
        self.segs.front().map(|s| &s[self.front_off..])
    }

    /// Consume `n` written bytes from the front (`n` may span segments).
    /// Fully drained segments are pushed onto `recycled` for the caller to
    /// return to its pool.
    pub fn advance(&mut self, mut n: usize, recycled: &mut Vec<Vec<u8>>) {
        debug_assert!(n <= self.bytes, "advance {n} past {} buffered bytes", self.bytes);
        self.bytes = self.bytes.saturating_sub(n);
        while n > 0 {
            let rem = match self.segs.front() {
                Some(s) => s.len() - self.front_off,
                None => return,
            };
            if n < rem {
                self.front_off += n;
                return;
            }
            n -= rem;
            self.front_off = 0;
            recycled.push(self.segs.pop_front().expect("front checked above"));
        }
    }

    /// Drop everything buffered, recycling the segments.
    pub fn clear(&mut self, recycled: &mut Vec<Vec<u8>>) {
        self.front_off = 0;
        self.bytes = 0;
        recycled.extend(self.segs.drain(..));
    }

    /// Unwritten bytes buffered.
    pub fn len(&self) -> usize {
        self.bytes
    }

    pub fn is_empty(&self) -> bool {
        self.bytes == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain the chain via front/advance in `step`-byte bites.
    fn drain(chain: &mut BufferChain, step: usize) -> (Vec<u8>, Vec<Vec<u8>>) {
        let mut out = Vec::new();
        let mut recycled = Vec::new();
        while let Some(front) = chain.front() {
            let take = step.min(front.len());
            out.extend_from_slice(&front[..take]);
            chain.advance(take, &mut recycled);
        }
        (out, recycled)
    }

    #[test]
    fn multi_segment_drain_is_byte_exact() {
        for step in [1usize, 2, 3, 5, 100] {
            let mut chain = BufferChain::new();
            chain.push(b"hello ".to_vec());
            chain.push(Vec::new()); // dropped
            chain.push(b"event ".to_vec());
            chain.push(b"loop".to_vec());
            assert_eq!(chain.len(), 16);
            let (out, recycled) = drain(&mut chain, step);
            assert_eq!(out, b"hello event loop");
            assert_eq!(recycled.len(), 3, "every non-empty segment recycles");
            assert!(chain.is_empty());
            assert_eq!(chain.front(), None);
        }
    }

    #[test]
    fn advance_within_one_segment_keeps_offset() {
        let mut chain = BufferChain::new();
        chain.push(vec![1, 2, 3, 4, 5]);
        let mut recycled = Vec::new();
        chain.advance(2, &mut recycled);
        assert!(recycled.is_empty(), "partially written segment stays queued");
        assert_eq!(chain.front().unwrap(), &[3, 4, 5]);
        assert_eq!(chain.len(), 3);
        chain.advance(3, &mut recycled);
        assert_eq!(recycled.len(), 1);
        assert!(chain.is_empty());
    }

    #[test]
    fn clear_recycles_all_segments() {
        let mut chain = BufferChain::new();
        chain.push(vec![1; 10]);
        chain.push(vec![2; 10]);
        let mut recycled = Vec::new();
        chain.advance(5, &mut recycled);
        chain.clear(&mut recycled);
        assert_eq!(recycled.len(), 2);
        assert!(chain.is_empty());
        assert_eq!(chain.front(), None);
    }
}
