//! `TimerWheel` — a hashed timing wheel for connection io deadlines.
//!
//! The thread-per-connection servers leaned on per-socket
//! `SO_RCVTIMEO`/`SO_SNDTIMEO`; a non-blocking loop needs its own clock.
//! The wheel holds one slot vector per tick of a fixed-size ring; an id is
//! scheduled into the slot its deadline falls in (clamped to the ring
//! horizon), and [`TimerWheel::advance`] drains every slot the clock has
//! passed. Deletion is *lazy*: the engine refreshes a connection's
//! deadline field on io progress without touching the wheel, and when an
//! id fires it re-checks the authoritative deadline — still in the future
//! means re-schedule, gone means skip. Each live connection therefore
//! keeps exactly one wheel entry, and schedule/advance are O(1) amortized
//! regardless of connection count.

use std::time::{Duration, Instant};

pub struct TimerWheel {
    slots: Vec<Vec<u64>>,
    tick: Duration,
    /// Slot index the clock is in; entries land at `cursor + k` for a
    /// deadline `k` ticks out.
    cursor: usize,
    /// Wall-clock time of the current cursor position.
    base: Instant,
}

impl TimerWheel {
    pub fn new(tick: Duration, nslots: usize) -> Self {
        assert!(nslots >= 2, "a wheel needs at least two slots");
        assert!(!tick.is_zero(), "a wheel needs a non-zero tick");
        TimerWheel { slots: vec![Vec::new(); nslots], tick, cursor: 0, base: Instant::now() }
    }

    pub fn tick(&self) -> Duration {
        self.tick
    }

    /// Schedule `id` to fire at `deadline` (rounded up to the next tick;
    /// deadlines past the ring horizon fire early and rely on the caller's
    /// lazy re-check to re-schedule).
    pub fn schedule(&mut self, id: u64, deadline: Instant) {
        let ticks = if deadline <= self.base {
            1
        } else {
            let dt = deadline.duration_since(self.base);
            // Round up: firing a hair late is fine, early-in-the-same-tick
            // churn is not.
            (dt.as_nanos().div_ceil(self.tick.as_nanos().max(1)) as usize).max(1)
        };
        let ticks = ticks.min(self.slots.len() - 1);
        let slot = (self.cursor + ticks) % self.slots.len();
        self.slots[slot].push(id);
    }

    /// How long until the next slot boundary — the longest the event loop
    /// may sleep without missing a due timer.
    pub fn next_tick_in(&self, now: Instant) -> Duration {
        (self.base + self.tick).saturating_duration_since(now)
    }

    /// Rotate the wheel up to `now`, appending every fired id to `due`.
    pub fn advance(&mut self, now: Instant, due: &mut Vec<u64>) {
        while now.saturating_duration_since(self.base) >= self.tick {
            self.base += self.tick;
            self.cursor = (self.cursor + 1) % self.slots.len();
            due.append(&mut self.slots[self.cursor]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_after_deadline_not_before() {
        let mut w = TimerWheel::new(Duration::from_millis(10), 16);
        let t0 = Instant::now();
        w.schedule(1, t0 + Duration::from_millis(35));
        let mut due = Vec::new();
        w.advance(t0 + Duration::from_millis(20), &mut due);
        assert!(due.is_empty(), "not due yet");
        w.advance(t0 + Duration::from_millis(60), &mut due);
        assert_eq!(due, vec![1]);
        // Fired entries are gone; further advances stay quiet.
        due.clear();
        w.advance(t0 + Duration::from_millis(500), &mut due);
        assert!(due.is_empty());
    }

    #[test]
    fn past_deadlines_fire_on_the_next_tick() {
        let mut w = TimerWheel::new(Duration::from_millis(10), 8);
        let t0 = Instant::now();
        w.schedule(9, t0); // already due
        let mut due = Vec::new();
        w.advance(t0 + Duration::from_millis(11), &mut due);
        assert_eq!(due, vec![9]);
    }

    #[test]
    fn beyond_horizon_clamps_and_fires_early() {
        // A deadline past the ring horizon fires at the horizon — the
        // caller's lazy re-check re-schedules it, so long timeouts work on
        // a small ring.
        let mut w = TimerWheel::new(Duration::from_millis(10), 4);
        let t0 = Instant::now();
        w.schedule(5, t0 + Duration::from_secs(3600));
        let mut due = Vec::new();
        w.advance(t0 + Duration::from_millis(45), &mut due);
        assert_eq!(due, vec![5], "horizon-clamped entry must fire within the ring");
    }

    #[test]
    fn many_ids_per_slot_and_wraparound() {
        let mut w = TimerWheel::new(Duration::from_millis(5), 4);
        let t0 = Instant::now();
        let mut due = Vec::new();
        for round in 0..5u64 {
            let now = t0 + Duration::from_millis(5 * 3 * round);
            w.advance(now, &mut due);
            w.schedule(2 * round, now + Duration::from_millis(7));
            w.schedule(2 * round + 1, now + Duration::from_millis(7));
        }
        w.advance(t0 + Duration::from_secs(1), &mut due);
        let mut got = due.clone();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<u64>>(), "every id fires exactly once");
    }

    #[test]
    fn next_tick_in_bounds_the_sleep() {
        let w = TimerWheel::new(Duration::from_millis(50), 8);
        let t = w.next_tick_in(Instant::now());
        assert!(t <= Duration::from_millis(50));
    }
}
