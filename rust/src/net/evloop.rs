//! `EventLoop` — readiness notification over epoll (Linux) or poll (the
//! portable fallback), plus a self-pipe [`Waker`] so other threads can
//! interrupt a blocked wait.
//!
//! Level-triggered semantics on both backends: an fd with unread input
//! (or writable space while write interest is registered) reports ready
//! on *every* wait, so the consumer never needs to drain-to-EAGAIN to
//! stay correct — it recomputes interest from its connection state
//! instead. Tokens are caller-chosen `u64`s ([`WAKE_TOKEN`] is reserved
//! for the pipe; wake events are drained internally and never surfaced).

use std::io;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::sys::{self, PollFd, RawFd};

/// Reserved token for the internal wake pipe.
pub const WAKE_TOKEN: u64 = u64::MAX;

/// What readiness a registered fd should report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };

    pub fn new(readable: bool, writable: bool) -> Self {
        Interest { readable, writable }
    }
}

/// One readiness report. `hangup` flags a peer reset/close; it also sets
/// `readable` so the consumer observes EOF through its normal read path.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

/// Write end of the wake pipe, closed when the last clone drops.
struct WakeWriter(RawFd);

impl Drop for WakeWriter {
    fn drop(&mut self) {
        sys::sys_close(self.0);
    }
}

/// Cross-thread wake handle. `wake` never blocks and ignores every error:
/// a full pipe already guarantees a pending wakeup, and a closed one
/// means the loop is gone (Rust ignores SIGPIPE, so the write just
/// returns EPIPE).
#[derive(Clone)]
pub struct Waker {
    tx: Arc<WakeWriter>,
}

impl Waker {
    pub fn wake(&self) {
        let _ = sys::sys_write(self.tx.0, &[1]);
    }
}

enum Backend {
    #[cfg(target_os = "linux")]
    Epoll { epfd: RawFd, buf: Vec<sys::EpollEvent> },
    /// fd → (token, interest); rebuilt into a `pollfd` array per wait.
    /// O(n) per wait, which is why Linux gets epoll — but correct
    /// everywhere and exercised by tests on every platform.
    Poll { entries: Vec<(RawFd, u64, Interest)> },
}

pub struct EventLoop {
    backend: Backend,
    wake_rx: RawFd,
    waker: Waker,
}

impl EventLoop {
    /// The platform-default backend: epoll on Linux, poll elsewhere.
    pub fn new() -> Result<EventLoop> {
        #[cfg(target_os = "linux")]
        {
            let epfd = sys::epoll_create().context("epoll_create1")?;
            let buf = vec![sys::EpollEvent { events: 0, data: 0 }; 1024];
            Self::with_backend(Backend::Epoll { epfd, buf })
        }
        #[cfg(not(target_os = "linux"))]
        {
            Self::new_poll_backend()
        }
    }

    /// Force the portable poll(2) backend (tests exercise it on Linux too,
    /// where epoll is the default).
    pub fn new_poll_backend() -> Result<EventLoop> {
        Self::with_backend(Backend::Poll { entries: Vec::new() })
    }

    fn with_backend(backend: Backend) -> Result<EventLoop> {
        let (rx, tx) = sys::wake_pipe().context("wake pipe")?;
        // Only epoll needs an explicit wake-pipe registration; the poll
        // backend slots the pipe in as `fds[0]` on every wait.
        #[cfg(target_os = "linux")]
        if let Backend::Epoll { epfd, .. } = &backend {
            if let Err(e) = sys::epoll_add(*epfd, rx, sys::EPOLLIN, WAKE_TOKEN) {
                sys::sys_close(rx);
                sys::sys_close(tx);
                sys::sys_close(*epfd);
                return Err(e).context("registering the wake pipe");
            }
        }
        Ok(EventLoop { backend, wake_rx: rx, waker: Waker { tx: Arc::new(WakeWriter(tx)) } })
    }

    /// A cloneable cross-thread wake handle for this loop.
    pub fn waker(&self) -> Waker {
        self.waker.clone()
    }

    fn add(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd, .. } => {
                sys::epoll_add(*epfd, fd, epoll_mask(interest), token).context("epoll_ctl add")?
            }
            Backend::Poll { entries } => entries.push((fd, token, interest)),
        }
        Ok(())
    }

    /// Register `fd` under `token`. One registration per fd.
    pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        if token == WAKE_TOKEN {
            bail!("token {token} is reserved for the wake pipe");
        }
        self.add(fd, token, interest)
    }

    /// Change a registered fd's interest (and/or token).
    pub fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        if token == WAKE_TOKEN {
            bail!("token {token} is reserved for the wake pipe");
        }
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd, .. } => {
                sys::epoll_mod(*epfd, fd, epoll_mask(interest), token).context("epoll_ctl mod")?
            }
            Backend::Poll { entries } => match entries.iter_mut().find(|(f, _, _)| *f == fd) {
                Some(e) => *e = (fd, token, interest),
                None => bail!("fd {fd} is not registered"),
            },
        }
        Ok(())
    }

    /// Remove `fd` from the loop. Must precede closing the fd (a closed
    /// fd deregisters itself from epoll, but the poll backend would keep
    /// polling it and see POLLNVAL).
    pub fn deregister(&mut self, fd: RawFd) -> Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd, .. } => {
                sys::epoll_del(*epfd, fd).context("epoll_ctl del")?
            }
            Backend::Poll { entries } => entries.retain(|(f, _, _)| *f != fd),
        }
        Ok(())
    }

    /// Wait up to `timeout` (`None` ⇒ forever) and fill `out` with ready
    /// events. Wake-pipe readiness is drained internally: a wake (or an
    /// EINTR) shows up as `Ok` with whatever other events were ready,
    /// possibly none.
    pub fn poll(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> Result<()> {
        out.clear();
        let mut woken = false;
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd, buf } => {
                use sys::{EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP};
                match sys::epoll_wait_events(*epfd, buf, timeout) {
                    Ok(n) => {
                        for ev in &buf[..n] {
                            // Copy out of the (packed) struct before use.
                            let events = ev.events;
                            let token = ev.data;
                            if token == WAKE_TOKEN {
                                woken = true;
                                continue;
                            }
                            let err = events & (EPOLLHUP | EPOLLERR) != 0;
                            out.push(Event {
                                token,
                                readable: err || events & (EPOLLIN | EPOLLRDHUP) != 0,
                                writable: err || events & EPOLLOUT != 0,
                                hangup: err,
                            });
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e).context("epoll_wait"),
                }
            }
            Backend::Poll { entries } => {
                use sys::{POLLERR, POLLHUP, POLLIN, POLLNVAL, POLLOUT};
                let mut fds: Vec<PollFd> = Vec::with_capacity(entries.len() + 1);
                fds.push(PollFd { fd: self.wake_rx, events: POLLIN, revents: 0 });
                for &(fd, _, interest) in entries.iter() {
                    let mut events = 0i16;
                    if interest.readable {
                        events |= POLLIN;
                    }
                    if interest.writable {
                        events |= POLLOUT;
                    }
                    fds.push(PollFd { fd, events, revents: 0 });
                }
                match sys::sys_poll(&mut fds, timeout) {
                    Ok(_) => {
                        woken = fds[0].revents != 0;
                        for (pf, &(_, token, _)) in fds[1..].iter().zip(entries.iter()) {
                            let r = pf.revents;
                            if r == 0 {
                                continue;
                            }
                            let err = r & (POLLHUP | POLLERR | POLLNVAL) != 0;
                            out.push(Event {
                                token,
                                readable: err || r & POLLIN != 0,
                                writable: err || r & POLLOUT != 0,
                                hangup: err,
                            });
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e).context("poll"),
                }
            }
        }
        if woken {
            // Coalesce any number of queued wakes into this one return.
            let mut sink = [0u8; 64];
            while matches!(sys::sys_read(self.wake_rx, &mut sink), Ok(n) if n > 0) {}
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
fn epoll_mask(interest: Interest) -> u32 {
    use sys::{EPOLLIN, EPOLLOUT, EPOLLRDHUP};
    let mut mask = 0;
    if interest.readable {
        mask |= EPOLLIN | EPOLLRDHUP;
    }
    if interest.writable {
        mask |= EPOLLOUT;
    }
    mask
}

impl Drop for EventLoop {
    fn drop(&mut self) {
        sys::sys_close(self.wake_rx);
        #[cfg(target_os = "linux")]
        if let Backend::Epoll { epfd, .. } = &self.backend {
            sys::sys_close(*epfd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    fn loop_reports_socket_readability(mut lp: EventLoop) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        lp.register(server.as_raw_fd(), 7, Interest::READ).unwrap();

        let mut out = Vec::new();
        lp.poll(&mut out, Some(Duration::from_millis(20))).unwrap();
        assert!(out.is_empty(), "no data yet, no events");

        client.write_all(b"hi").unwrap();
        lp.poll(&mut out, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].token, 7);
        assert!(out[0].readable);

        // Level-triggered: unread data re-reports on the next wait.
        lp.poll(&mut out, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(out.len(), 1, "level-triggered readiness must re-report");

        // Write interest on an idle socket: instantly writable.
        lp.reregister(server.as_raw_fd(), 7, Interest::new(false, true)).unwrap();
        lp.poll(&mut out, Some(Duration::from_secs(5))).unwrap();
        assert!(out.iter().any(|e| e.token == 7 && e.writable));

        lp.deregister(server.as_raw_fd()).unwrap();
        lp.poll(&mut out, Some(Duration::from_millis(20))).unwrap();
        assert!(out.is_empty(), "deregistered fd must stay silent");
    }

    #[test]
    fn default_backend_reports_readability() {
        loop_reports_socket_readability(EventLoop::new().unwrap());
    }

    #[test]
    fn poll_backend_reports_readability() {
        loop_reports_socket_readability(EventLoop::new_poll_backend().unwrap());
    }

    fn waker_interrupts_blocked_poll(mut lp: EventLoop) {
        let waker = lp.waker();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            waker.wake();
            waker.wake(); // duplicate wakes coalesce
        });
        let mut out = Vec::new();
        let t0 = std::time::Instant::now();
        // Blocking wait with no timeout: only the waker can end it.
        lp.poll(&mut out, None).unwrap();
        assert!(out.is_empty(), "wake events are internal");
        assert!(t0.elapsed() < Duration::from_secs(10));
        t.join().unwrap();
    }

    #[test]
    fn default_backend_waker() {
        waker_interrupts_blocked_poll(EventLoop::new().unwrap());
    }

    #[test]
    fn poll_backend_waker() {
        waker_interrupts_blocked_poll(EventLoop::new_poll_backend().unwrap());
    }

    #[test]
    fn wake_token_is_reserved() {
        let mut lp = EventLoop::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        assert!(lp.register(listener.as_raw_fd(), WAKE_TOKEN, Interest::READ).is_err());
    }
}
