//! Event-driven networking: the readiness-based connection engine behind
//! [`PeerServer`](crate::peer::PeerServer) and the HTTP API server.
//!
//! The thread-per-connection servers of earlier iterations spent a stack
//! and a scheduler slot per socket and capped out at 128 connections. This
//! module replaces that with one loop thread multiplexing every
//! connection (epoll on Linux via the [`sys`] shim, poll(2) elsewhere —
//! std-only, no external crates) plus a small worker pool for anything
//! that can block. Layers:
//!
//! * [`sys`] — FFI shim: epoll / poll / wake pipe / RLIMIT_NOFILE;
//! * [`evloop`] — [`EventLoop`]: register / reregister / deregister fds
//!   with a token and [`Interest`], poll for [`Event`]s, cross-thread
//!   [`Waker`];
//! * [`chain`] — [`BufferChain`]: segmented write buffering for partial
//!   non-blocking writes with pool recycling;
//! * [`wheel`] — [`TimerWheel`]: io deadlines without per-socket
//!   `SO_RCVTIMEO`;
//! * [`engine`] — [`Engine`]: connection state machines, accept and
//!   backpressure at the connection budget, worker-pool handoff, and the
//!   [`Service`] trait the wire protocols implement.

pub mod chain;
pub mod engine;
pub mod evloop;
pub mod sys;
pub mod wheel;

pub use chain::BufferChain;
pub use engine::{Engine, EngineConfig, Reply, Service};
pub use evloop::{Event, EventLoop, Interest, Waker};
pub use sys::raise_nofile_limit;
pub use wheel::TimerWheel;
