//! Lightweight metrics: counters/gauges/histograms plus table/series
//! renderers shared by the experiment harness, the CLI and the benches.

use std::collections::BTreeMap;

/// A monotonically increasing counter.
#[derive(Debug, Default, Clone, Copy)]
pub struct Counter(pub u64);

impl Counter {
    pub fn inc(&mut self) {
        self.0 += 1;
    }

    pub fn add(&mut self, n: u64) {
        self.0 += n;
    }
}

/// Fixed-boundary histogram (latencies in seconds by default).
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    pub sum: f64,
    pub n: u64,
}

impl Histogram {
    pub fn new(bounds: Vec<f64>) -> Self {
        let len = bounds.len() + 1;
        Histogram { bounds, counts: vec![0; len], sum: 0.0, n: 0 }
    }

    /// Exponential bounds from `lo` doubling `steps` times.
    pub fn exponential(lo: f64, steps: usize) -> Self {
        let mut bounds = Vec::with_capacity(steps);
        let mut b = lo;
        for _ in 0..steps {
            bounds.push(b);
            b *= 2.0;
        }
        Self::new(bounds)
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.bounds.last().copied().unwrap_or(f64::INFINITY) * 2.0
                };
            }
        }
        f64::INFINITY
    }
}

/// A named metrics registry (string-keyed; good enough at this scale).
#[derive(Debug, Default)]
pub struct Registry {
    pub counters: BTreeMap<String, Counter>,
    pub gauges: BTreeMap<String, f64>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&mut self, name: &str) -> &mut Counter {
        self.counters.entry(name.to_string()).or_default()
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Prometheus-style text exposition (for the API's /metrics endpoint).
    pub fn expose(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k} {}\n", v.0));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("{k} {v}\n"));
        }
        out
    }
}

/// A result table (what every experiment emits).
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// GitHub-flavoured markdown.
    pub fn markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(self.headers.len())));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Machine-readable JSON — the one format every `hoard exp` table
    /// shares (`hoard exp <id> --json`):
    /// `{"title": …, "headers": […], "rows": [[…], …]}`.
    pub fn json(&self) -> String {
        use crate::util::json::Json;
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "headers",
                Json::arr(self.headers.iter().map(|h| Json::str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|c| Json::str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
        .to_string()
    }

    /// Fixed-width console rendering.
    pub fn console(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Poor-man's line plot for fps-vs-time series (Figure 3/4/5 console view).
pub fn ascii_plot(title: &str, series: &[(&str, &[(f64, f64)])], width: usize, height: usize) -> String {
    let (mut xmax, mut ymax) = (f64::MIN, f64::MIN);
    for (_, pts) in series {
        for &(x, y) in *pts {
            xmax = xmax.max(x);
            ymax = ymax.max(y);
        }
    }
    if !xmax.is_finite() || !ymax.is_finite() || xmax <= 0.0 || ymax <= 0.0 {
        return format!("{title}: (no data)\n");
    }
    let mut grid = vec![vec![' '; width]; height];
    let glyphs = ['*', '+', 'o', 'x', '#'];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in *pts {
            let col = ((x / xmax) * (width - 1) as f64).round() as usize;
            let row = height - 1 - ((y / ymax) * (height - 1) as f64).round() as usize;
            grid[row][col] = glyphs[si % glyphs.len()];
        }
    }
    let mut out = format!("{title}  (ymax={ymax:.0}, xmax={xmax:.0})\n");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", glyphs[si % glyphs.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_registry() {
        let mut r = Registry::new();
        r.counter("reads").inc();
        r.counter("reads").add(4);
        r.set_gauge("cache_used", 0.5);
        let text = r.expose();
        assert!(text.contains("reads 5"));
        assert!(text.contains("cache_used 0.5"));
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::exponential(0.001, 12);
        for i in 1..=100 {
            h.observe(i as f64 * 0.001);
        }
        assert!(h.mean() > 0.04 && h.mean() < 0.06);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert_eq!(h.n, 100);
    }

    #[test]
    fn table_rendering() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        let md = t.markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | x |"));
        let con = t.console();
        assert!(con.contains("Demo"));
    }

    #[test]
    fn table_json_roundtrips() {
        use crate::util::json::Json;
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        t.row(vec!["2".into(), "y".into()]);
        let v = Json::parse(&t.json()).unwrap();
        assert_eq!(v.get("title").unwrap().as_str(), Some("Demo"));
        assert_eq!(v.get("headers").unwrap().as_arr().unwrap().len(), 2);
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].idx(1).unwrap().as_str(), Some("y"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn plot_handles_empty() {
        assert!(ascii_plot("t", &[("s", &[])], 10, 5).contains("no data"));
    }

    #[test]
    fn plot_draws_points() {
        let pts = [(0.0, 1.0), (10.0, 2.0)];
        let out = ascii_plot("t", &[("s", &pts)], 20, 6);
        assert!(out.contains('*'));
    }
}
