//! Minimal HTTP/1.1 server on std::net (the offline build has no
//! tokio/hyper). Enough of the protocol for the Hoard REST API: one
//! request per connection, Content-Length bodies, JSON in/out.
//!
//! Serving runs on the event-driven [`Engine`](crate::net::Engine): one
//! loop thread multiplexes every connection, requests are parsed
//! *incrementally* ([`try_parse_request`]) as bytes arrive — a slow or
//! stalled client costs buffered bytes, never a parked thread — and
//! handlers run on the engine's worker pool. Connections over the budget
//! are answered `503` with a `Retry-After` header and closed; silent
//! connections are dropped at the io deadline without a byte written.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::net::{Engine, EngineConfig, Reply, Service};

/// Io deadline on accepted connections: a client that connects and sends
/// nothing (or stalls mid-request) is dropped instead of holding its
/// connection slot forever.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Default connection budget; connections over the budget are answered
/// `503` (with `Retry-After`) and closed. The event-driven server holds a
/// connection in buffers, not a thread, so the budget is generous.
pub const DEFAULT_MAX_CONNS: usize = 1024;

/// Cap on buffered request-head bytes before the blank line must appear.
const MAX_HEAD: usize = 64 << 10;

/// Cap on a declared request body.
const MAX_BODY: usize = 64 << 20;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Self {
        Response { status, content_type: "application/json", body: body.into_bytes() }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Response { status, content_type: "text/plain", body: body.into().into_bytes() }
    }

    pub fn not_found() -> Self {
        Response::json(404, r#"{"error":"not found"}"#.to_string())
    }

    /// The route exists but not for this verb (`405`): distinct from 404
    /// so clients can tell a typo'd path from a wrong method.
    pub fn method_not_allowed() -> Self {
        Response::json(405, r#"{"error":"method not allowed"}"#.to_string())
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            201 => "201 Created",
            204 => "204 No Content",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            409 => "409 Conflict",
            410 => "410 Gone",
            500 => "500 Internal Server Error",
            503 => "503 Service Unavailable",
            _ => "200 OK",
        }
    }
}

fn parse_request_line(line: &str) -> Result<(String, String)> {
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    if !matches!(method.as_str(), "GET" | "POST" | "PUT" | "DELETE") {
        bail!("unsupported method {method}");
    }
    Ok((method, path))
}

/// Index one past the blank line ending the request head, accepting both
/// `\r\n\r\n` and bare `\n\n` (and the mixed `\n\r\n`).
fn find_head_end(buf: &[u8]) -> Option<usize> {
    for (i, &b) in buf.iter().enumerate() {
        if b != b'\n' {
            continue;
        }
        match (buf.get(i + 1), buf.get(i + 2)) {
            (Some(b'\n'), _) => return Some(i + 2),
            (Some(b'\r'), Some(b'\n')) => return Some(i + 3),
            _ => {}
        }
    }
    None
}

/// Incremental request parse for the event-driven server: if `buf` holds a
/// complete request (head + declared body), cut it out (draining the
/// consumed bytes) and return it; `Ok(None)` means more bytes are needed.
/// Hostile inputs are rejected as early as the bytes allow — a bogus
/// method as soon as the request line is complete, an oversized
/// `Content-Length` as soon as the head is complete (before one body byte
/// is buffered), an endless head at [`MAX_HEAD`].
pub fn try_parse_request(buf: &mut Vec<u8>) -> Result<Option<Request>> {
    let Some(head_end) = find_head_end(buf) else {
        if buf.len() > MAX_HEAD {
            bail!("request head too large");
        }
        // Cheap early rejection: once the request line is in, a non-HTTP
        // client is cut off without waiting for a full head.
        if let Some(nl) = buf.iter().position(|&b| b == b'\n') {
            let line = std::str::from_utf8(&buf[..nl]).context("request line is not UTF-8")?;
            parse_request_line(line)?;
        }
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("request head is not UTF-8")?;
    let mut lines = head.lines();
    let (method, path) = parse_request_line(lines.next().context("missing request line")?)?;
    let mut content_length = 0usize;
    for h in lines {
        let h = h.trim();
        if h.is_empty() {
            continue;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    if content_length > MAX_BODY {
        bail!("body too large");
    }
    if buf.len() < head_end + content_length {
        return Ok(None);
    }
    let body = buf[head_end..head_end + content_length].to_vec();
    buf.drain(..head_end + content_length);
    Ok(Some(Request { method, path, body }))
}

/// Parse one HTTP/1.1 request from a blocking stream (kept for direct
/// stream callers; the server itself parses incrementally via
/// [`try_parse_request`]).
pub fn parse_request(stream: &mut dyn Read) -> Result<Request> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let (method, path) = parse_request_line(&line)?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    if content_length > MAX_BODY {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body })
}

/// Serialize a response, with optional extra headers (e.g.
/// `("Retry-After", "1")` on a 503).
pub fn response_bytes(resp: &Response, extra_headers: &[(&str, &str)]) -> Vec<u8> {
    let mut head = format!(
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
        resp.status_line(),
        resp.content_type,
        resp.body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    let mut out = head.into_bytes();
    out.extend_from_slice(&resp.body);
    out
}

pub fn write_response(stream: &mut dyn Write, resp: &Response) -> Result<()> {
    stream.write_all(&response_bytes(resp, &[]))?;
    Ok(())
}

/// The HTTP protocol as an engine [`Service`].
struct HttpService<F> {
    handler: F,
}

impl<F> Service for HttpService<F>
where
    F: Fn(&Request) -> Response + Send + Sync + 'static,
{
    type Request = Request;

    fn try_parse(&self, inbuf: &mut Vec<u8>) -> Result<Option<Request>> {
        try_parse_request(inbuf)
    }

    fn handle(&self, req: Request) -> Reply {
        let resp = (self.handler)(&req);
        // One request per connection (matching the Connection: close the
        // response advertises).
        Reply::closing(vec![response_bytes(&resp, &[])])
    }

    /// Head cap + body cap with slack: anything needing more buffered
    /// bytes was already rejected by the parser's own caps.
    fn max_buffered(&self) -> usize {
        MAX_HEAD + MAX_BODY + 4096
    }

    /// Over the connection budget: `503` + `Retry-After` so well-behaved
    /// clients back off instead of hammering.
    fn busy_reply(&self) -> Option<Reply> {
        let resp = Response::json(503, r#"{"error":"server busy"}"#.to_string());
        Some(Reply::closing(vec![response_bytes(&resp, &[("Retry-After", "1")])]))
    }

    fn parse_error_reply(&self, err: &anyhow::Error) -> Option<Reply> {
        let resp = Response::json(400, format!(r#"{{"error":"{err}"}}"#));
        Some(Reply::closing(vec![response_bytes(&resp, &[])]))
    }
}

/// A running server; `handler` is called per request on the engine's
/// worker threads.
pub struct Server {
    pub addr: std::net::SocketAddr,
    engine: Engine,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve until dropped/stopped,
    /// with the default per-connection io deadline.
    pub fn start<F>(addr: &str, handler: F) -> Result<Server>
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        Self::start_with_timeout(addr, DEFAULT_IO_TIMEOUT, handler)
    }

    /// Like [`Server::start`], with an explicit per-connection io deadline
    /// (tests use short ones to exercise the silent-client path). The
    /// connection budget is [`DEFAULT_MAX_CONNS`]
    /// ([`Server::start_with_limits`] to tune).
    pub fn start_with_timeout<F>(addr: &str, io_timeout: Duration, handler: F) -> Result<Server>
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        Self::start_with_limits(addr, io_timeout, DEFAULT_MAX_CONNS, handler)
    }

    /// [`Server::start_with_timeout`] plus an explicit connection budget:
    /// once `max_conns` connections are live (idle ones count), further
    /// sockets get a best-effort `503` + `Retry-After` and are closed.
    pub fn start_with_limits<F>(
        addr: &str,
        io_timeout: Duration,
        max_conns: usize,
        handler: F,
    ) -> Result<Server>
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        let svc = Arc::new(HttpService { handler });
        let cfg = EngineConfig { io_timeout, max_conns, ..EngineConfig::default() };
        let engine = Engine::start(addr, svc, cfg)?;
        Ok(Server { addr: engine.addr, engine })
    }

    /// Connections currently held by the engine.
    pub fn live_conns(&self) -> usize {
        self.engine.live_conns()
    }

    /// Graceful shutdown (idempotent; also runs on drop, via the engine).
    pub fn stop(&mut self) {
        self.engine.stop();
    }
}

/// Blocking single-request client (tests, examples, CLI).
pub fn request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, String)> {
    let mut sock = TcpStream::connect(addr)?;
    write!(
        sock,
        "{method} {path} HTTP/1.1\r\nHost: hoard\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut reader = BufReader::new(sock);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("bad status line")?
        .parse()?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse()?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_simple_request() {
        let raw = b"POST /api/x HTTP/1.1\r\nContent-Length: 4\r\nHost: h\r\n\r\nabcd";
        let req = parse_request(&mut &raw[..]).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/api/x");
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn parse_rejects_bad_method() {
        let raw = b"BREW /pot HTTP/1.1\r\n\r\n";
        assert!(parse_request(&mut &raw[..]).is_err());
    }

    #[test]
    fn try_parse_is_incremental_and_byte_exact() {
        let raw: &[u8] =
            b"POST /api/x HTTP/1.1\r\nContent-Length: 4\r\nHost: h\r\n\r\nabcdTRAILING";
        // Fed one byte at a time, the parser stays quiet until the exact
        // byte that completes head + body, then leaves the rest buffered.
        let mut buf = Vec::new();
        let complete = raw.len() - "TRAILING".len();
        for (i, &b) in raw.iter().enumerate() {
            buf.push(b);
            match try_parse_request(&mut buf).unwrap() {
                None => assert!(i + 1 < complete, "complete request at byte {} unparsed", i + 1),
                Some(req) => {
                    assert_eq!(i + 1, complete, "early parse at byte {}", i + 1);
                    assert_eq!(req.method, "POST");
                    assert_eq!(req.path, "/api/x");
                    assert_eq!(req.body, b"abcd");
                    assert_eq!(buf, &raw[complete..i + 1], "consumed bytes must drain");
                }
            }
        }
    }

    #[test]
    fn try_parse_accepts_bare_newline_heads() {
        let mut buf = b"GET /x HTTP/1.1\nHost: h\n\n".to_vec();
        let req = try_parse_request(&mut buf).unwrap().unwrap();
        assert_eq!(req.path, "/x");
        assert!(buf.is_empty());
    }

    #[test]
    fn try_parse_rejects_hostile_input_early() {
        // A bogus method is rejected as soon as the request line is in —
        // no waiting for the rest of the head.
        let mut buf = b"BREW /pot HTTP/1.1\r\n".to_vec();
        assert!(try_parse_request(&mut buf).is_err());
        // An oversized declared body is rejected at the head, before a
        // single body byte is buffered or allocated.
        let mut buf =
            format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1).into_bytes();
        assert!(try_parse_request(&mut buf).is_err());
        // A head that never ends is cut off at MAX_HEAD.
        let mut buf = b"GET /x HTTP/1.1\r\n".to_vec();
        buf.resize(buf.len() + MAX_HEAD + 2, b'a');
        assert!(try_parse_request(&mut buf).is_err());
    }

    #[test]
    fn server_roundtrip() {
        let srv = Server::start("127.0.0.1:0", |req| {
            Response::text(200, format!("{} {}", req.method, req.path))
        })
        .unwrap();
        let (status, body) = request(srv.addr, "GET", "/hello", "").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "GET /hello");
    }

    #[test]
    fn silent_connection_is_dropped_not_pinned() {
        use std::time::Instant;
        let srv = Server::start_with_timeout("127.0.0.1:0", Duration::from_millis(120), |_| {
            Response::text(200, "ok")
        })
        .unwrap();
        // A client that connects and sends nothing…
        let mut idle = TcpStream::connect(srv.addr).unwrap();
        idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // …does not block real requests…
        assert_eq!(request(srv.addr, "GET", "/", "").unwrap().0, 200);
        // …and is dropped at the io deadline — without a byte written —
        // well before our own 5 s guard.
        let t0 = Instant::now();
        let mut buf = Vec::new();
        let _ = idle.read_to_end(&mut buf);
        assert!(buf.is_empty(), "idle-timeout close must write nothing, got {buf:?}");
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "idle connection still open after the server timeout"
        );
    }

    #[test]
    fn connection_flood_is_gated_not_unbounded() {
        // Budget 1: one parked silent connection occupies the only slot,
        // so the next request is answered 503 instead of being served.
        // Once the occupant leaves, service resumes.
        let srv = Server::start_with_limits(
            "127.0.0.1:0",
            Duration::from_millis(400),
            1,
            |_| Response::text(200, "ok"),
        )
        .unwrap();
        let idle = TcpStream::connect(srv.addr).unwrap();
        // Let the loop register the occupant before probing.
        std::thread::sleep(Duration::from_millis(100));
        // Depending on timing the over-budget client reads the
        // best-effort 503 or hits the reset — it must never be served.
        match request(srv.addr, "GET", "/", "") {
            Ok((status, _)) => assert_eq!(status, 503, "over-budget connection must get 503"),
            Err(_) => {} // connection reset before the 503 was read — still gated
        }
        drop(idle);
        // The occupant is dropped at its io deadline; the slot frees and
        // requests succeed again.
        let t0 = std::time::Instant::now();
        loop {
            match request(srv.addr, "GET", "/", "") {
                Ok((200, _)) => break,
                _ if t0.elapsed() > Duration::from_secs(5) => {
                    panic!("gate never released its slot")
                }
                _ => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    #[test]
    fn over_budget_503_carries_retry_after() {
        let srv = Server::start_with_limits(
            "127.0.0.1:0",
            Duration::from_secs(5),
            1,
            |_| Response::text(200, "ok"),
        )
        .unwrap();
        let _idle = TcpStream::connect(srv.addr).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        // Read the raw rejection: status 503 plus the backoff header.
        let mut sock = TcpStream::connect(srv.addr).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut raw = String::new();
        let _ = sock.read_to_string(&mut raw);
        assert!(raw.starts_with("HTTP/1.1 503"), "expected a 503, got: {raw:?}");
        assert!(raw.contains("Retry-After: 1"), "503 must carry Retry-After, got: {raw:?}");
    }

    #[test]
    fn server_concurrent_requests() {
        let srv = Server::start("127.0.0.1:0", |_req| Response::text(200, "ok")).unwrap();
        let addr = srv.addr;
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(move || request(addr, "GET", "/", "").unwrap().0))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
    }
}
