//! Minimal threaded HTTP/1.1 server on std::net (the offline build has no
//! tokio/hyper). Enough of the protocol for the Hoard REST API: one request
//! per connection, Content-Length bodies, JSON in/out.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Socket read/write timeout on accepted connections: a client that
/// connects and sends nothing (or stalls mid-request) is dropped instead
/// of pinning its handler thread forever.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Default cap on concurrent handler threads; connections over the cap
/// are answered `503` and closed, so a connection flood cannot spawn
/// unbounded threads.
pub const DEFAULT_MAX_CONNS: usize = 128;

/// Counting gate over live handler threads (decrements on drop, so every
/// handler exit path releases its slot).
struct HandlerSlot(Arc<AtomicUsize>);

impl Drop for HandlerSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Self {
        Response { status, content_type: "application/json", body: body.into_bytes() }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Response { status, content_type: "text/plain", body: body.into().into_bytes() }
    }

    pub fn not_found() -> Self {
        Response::json(404, r#"{"error":"not found"}"#.to_string())
    }

    /// The route exists but not for this verb (`405`): distinct from 404
    /// so clients can tell a typo'd path from a wrong method.
    pub fn method_not_allowed() -> Self {
        Response::json(405, r#"{"error":"method not allowed"}"#.to_string())
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            201 => "201 Created",
            204 => "204 No Content",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            409 => "409 Conflict",
            500 => "500 Internal Server Error",
            503 => "503 Service Unavailable",
            _ => "200 OK",
        }
    }
}

/// Parse one HTTP/1.1 request from a stream.
pub fn parse_request(stream: &mut dyn Read) -> Result<Request> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    if !matches!(method.as_str(), "GET" | "POST" | "PUT" | "DELETE") {
        bail!("unsupported method {method}");
    }
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    if content_length > 64 << 20 {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body })
}

pub fn write_response(stream: &mut dyn Write, resp: &Response) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status_line(),
        resp.content_type,
        resp.body.len()
    )?;
    stream.write_all(&resp.body)?;
    Ok(())
}

/// A running server; `handler` is called per request on worker threads.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve until dropped/stopped,
    /// with the default per-connection I/O timeout.
    pub fn start<F>(addr: &str, handler: F) -> Result<Server>
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        Self::start_with_timeout(addr, DEFAULT_IO_TIMEOUT, handler)
    }

    /// Like [`Server::start`], with an explicit per-connection read/write
    /// timeout (tests use short ones to exercise the silent-client path).
    /// Handler threads are capped at [`DEFAULT_MAX_CONNS`]
    /// ([`Server::start_with_limits`] to tune).
    pub fn start_with_timeout<F>(addr: &str, io_timeout: Duration, handler: F) -> Result<Server>
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        Self::start_with_limits(addr, io_timeout, DEFAULT_MAX_CONNS, handler)
    }

    /// [`Server::start_with_timeout`] plus an explicit cap on concurrent
    /// handler threads: once `max_conns` handlers are live, further
    /// connections get a best-effort `503` and are closed instead of
    /// spawning a thread.
    pub fn start_with_limits<F>(
        addr: &str,
        io_timeout: Duration,
        max_conns: usize,
        handler: F,
    ) -> Result<Server>
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handler = Arc::new(handler);
        let active: Arc<AtomicUsize> = Arc::new(AtomicUsize::new(0));
        let join = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut sock, _peer)) => {
                        // A silent or stalled client hits the timeout, the
                        // parse fails, and its handler thread exits — no
                        // connection can pin a thread forever.
                        let _ = sock.set_read_timeout(Some(io_timeout));
                        let _ = sock.set_write_timeout(Some(io_timeout));
                        if active.load(Ordering::Acquire) >= max_conns {
                            // Over the gate: 503 (best effort) and close —
                            // never spawn.
                            let _ = write_response(
                                &mut sock,
                                &Response::json(503, r#"{"error":"server busy"}"#.to_string()),
                            );
                            let _ = sock.shutdown(std::net::Shutdown::Both);
                            continue;
                        }
                        active.fetch_add(1, Ordering::AcqRel);
                        let slot = HandlerSlot(active.clone());
                        let h = handler.clone();
                        std::thread::spawn(move || {
                            let _slot = slot;
                            let resp = match parse_request(&mut sock) {
                                Ok(req) => h(&req),
                                Err(e) => Response::json(400, format!(r#"{{"error":"{e}"}}"#)),
                            };
                            let _ = write_response(&mut sock, &resp);
                            let _ = sock.shutdown(std::net::Shutdown::Both);
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    // Client-aborted handshakes are transient — keep
                    // accepting instead of killing the server.
                    Err(ref e)
                        if e.kind() == std::io::ErrorKind::ConnectionAborted
                            || e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => break,
                }
            }
        });
        Ok(Server { addr: local, stop, join: Some(join) })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Blocking single-request client (tests, examples, CLI).
pub fn request(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut sock = TcpStream::connect(addr)?;
    write!(
        sock,
        "{method} {path} HTTP/1.1\r\nHost: hoard\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut reader = BufReader::new(sock);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("bad status line")?
        .parse()?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse()?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_simple_request() {
        let raw = b"POST /api/x HTTP/1.1\r\nContent-Length: 4\r\nHost: h\r\n\r\nabcd";
        let req = parse_request(&mut &raw[..]).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/api/x");
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn parse_rejects_bad_method() {
        let raw = b"BREW /pot HTTP/1.1\r\n\r\n";
        assert!(parse_request(&mut &raw[..]).is_err());
    }

    #[test]
    fn server_roundtrip() {
        let srv = Server::start("127.0.0.1:0", |req| {
            Response::text(200, format!("{} {}", req.method, req.path))
        })
        .unwrap();
        let (status, body) = request(srv.addr, "GET", "/hello", "").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "GET /hello");
    }

    #[test]
    fn silent_connection_is_dropped_not_pinned() {
        use std::time::Instant;
        let srv = Server::start_with_timeout("127.0.0.1:0", Duration::from_millis(120), |_| {
            Response::text(200, "ok")
        })
        .unwrap();
        // A client that connects and sends nothing…
        let mut idle = TcpStream::connect(srv.addr).unwrap();
        idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // …does not block real requests…
        assert_eq!(request(srv.addr, "GET", "/", "").unwrap().0, 200);
        // …and its handler gives up at the read timeout: the server sends
        // its 400 (parse failure) and closes, so the client reaches EOF
        // well before our own 5 s guard.
        let t0 = Instant::now();
        let mut buf = Vec::new();
        let _ = idle.read_to_end(&mut buf);
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "idle connection still open after the server timeout"
        );
    }

    #[test]
    fn connection_flood_is_gated_not_unbounded() {
        // Cap 1: one parked silent connection occupies the only handler
        // slot, so the next request is answered 503 instead of spawning
        // another thread. Once the occupant leaves, service resumes.
        let srv = Server::start_with_limits(
            "127.0.0.1:0",
            Duration::from_millis(400),
            1,
            |_| Response::text(200, "ok"),
        )
        .unwrap();
        let idle = TcpStream::connect(srv.addr).unwrap();
        // Let the accept loop register the occupant before probing.
        std::thread::sleep(Duration::from_millis(100));
        // Depending on timing the over-cap client reads the best-effort
        // 503 or hits the reset — it must never be served a 200.
        match request(srv.addr, "GET", "/", "") {
            Ok((status, _)) => assert_eq!(status, 503, "over-cap connection must get 503"),
            Err(_) => {} // connection reset before the 503 was read — still gated
        }
        drop(idle);
        // The occupant's handler exits at its read timeout; the slot
        // frees and requests succeed again.
        let t0 = std::time::Instant::now();
        loop {
            match request(srv.addr, "GET", "/", "") {
                Ok((200, _)) => break,
                _ if t0.elapsed() > Duration::from_secs(5) => {
                    panic!("gate never released its slot")
                }
                _ => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    #[test]
    fn server_concurrent_requests() {
        let srv = Server::start("127.0.0.1:0", |_req| Response::text(200, "ok")).unwrap();
        let addr = srv.addr;
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(move || request(addr, "GET", "/", "").unwrap().0))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
    }
}
