//! REST routing for the Hoard API server, versioned under `/v1/`. Every
//! mutating control-plane request triggers a reconcile so responses
//! reflect settled state — the user-visible behaviour of the paper's
//! "turnkey" workflow.
//!
//! Two surfaces share the router:
//!
//!  * the **control API** (`/v1/stats`, `/v1/datasets…` — with the
//!    pre-versioning `/api/v1/…` paths kept as aliases, including the
//!    legacy control-plane `DlJob` routes under `/api/v1/jobs`);
//!  * the **data-plane job API** (`/v1/jobs`): `POST /v1/jobs` opens a
//!    [`JobSession`] on the attached [`DataPlane`] (503 when none is
//!    attached), `GET /v1/jobs/:id/stats` reads its per-job counters plus
//!    the plane-wide shared-fill evidence, `POST /v1/jobs/:id/epoch`
//!    drives the next epoch, `DELETE /v1/jobs/:id` closes it. Co-located
//!    sessions opened through this API share one fill ledger per dataset
//!    — the Table 4 cross-job point, reachable over HTTP.
//!
//! Routing discipline: unknown `/v1/` paths answer `404`; a known path
//! with the wrong verb answers `405`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::http::{Request, Response};
use crate::coordinator::{job_controller, Hoard};
use crate::k8s::{Dataset, DatasetPhase, DlJob, JobPhase, ObjectMeta, StoreError};
use crate::posix::dataplane::{DataPlane, DatasetRetired, Granularity, JobSession, JobSpec};
use crate::posix::realfs::ReadStats;
use crate::util::Json;

#[derive(Clone)]
pub struct ApiState {
    pub hoard: Arc<Mutex<Hoard>>,
    /// The shared per-node data plane behind `/v1/jobs`, when attached.
    plane: Option<Arc<DataPlane>>,
    /// Open job sessions by name (the `/v1/jobs/:id` handle).
    sessions: Arc<Mutex<HashMap<String, Arc<JobSession>>>>,
}

impl ApiState {
    pub fn new(hoard: Arc<Mutex<Hoard>>) -> Self {
        ApiState { hoard, plane: None, sessions: Arc::new(Mutex::new(HashMap::new())) }
    }

    /// Attach a [`DataPlane`]: `/v1/jobs` opens real job sessions on it.
    pub fn with_plane(mut self, plane: Arc<DataPlane>) -> Self {
        self.plane = Some(plane);
        self
    }

    pub fn route(&self, req: &Request) -> Response {
        let path: Vec<&str> = req.path.trim_matches('/').split('/').collect();
        let m = req.method.as_str();
        match path.as_slice() {
            ["healthz"] | ["v1", "healthz"] => match m {
                "GET" => Response::text(200, "ok"),
                _ => Response::method_not_allowed(),
            },
            ["v1", "stats"] | ["api", "v1", "stats"] => match m {
                "GET" => self.stats(),
                _ => Response::method_not_allowed(),
            },
            ["v1", "datasets"] | ["api", "v1", "datasets"] => match m {
                "GET" => self.list_datasets(),
                "POST" => self.create_dataset(&req.body),
                _ => Response::method_not_allowed(),
            },
            ["v1", "datasets", name] | ["api", "v1", "datasets", name] => match m {
                "GET" => self.get_dataset(name),
                "DELETE" => self.delete_dataset(name),
                _ => Response::method_not_allowed(),
            },
            // Legacy control-plane DlJobs stay under /api/v1/jobs;
            // /v1/jobs below is the data-plane session surface.
            ["api", "v1", "jobs"] => match m {
                "GET" => self.list_jobs(),
                "POST" => self.create_job(&req.body),
                _ => Response::method_not_allowed(),
            },
            ["api", "v1", "jobs", name] => match m {
                "GET" => self.get_job(name),
                _ => Response::method_not_allowed(),
            },
            ["api", "v1", "jobs", name, "complete"] => match m {
                "POST" => self.complete_job(name),
                _ => Response::method_not_allowed(),
            },
            ["v1", "jobs"] => match m {
                "GET" => self.list_sessions(),
                "POST" => self.open_session(&req.body),
                _ => Response::method_not_allowed(),
            },
            ["v1", "jobs", name] => match m {
                "GET" => self.get_session(name),
                "DELETE" => self.close_session(name),
                _ => Response::method_not_allowed(),
            },
            ["v1", "jobs", name, "stats"] => match m {
                "GET" => self.session_stats(name),
                _ => Response::method_not_allowed(),
            },
            ["v1", "jobs", name, "epoch"] => match m {
                "POST" => self.run_session_epoch(name),
                _ => Response::method_not_allowed(),
            },
            _ => Response::not_found(),
        }
    }

    fn with<T>(&self, f: impl FnOnce(&mut Hoard) -> T) -> T {
        let mut h = self.hoard.lock().unwrap();
        f(&mut h)
    }

    // ----- data-plane job sessions (/v1/jobs) ---------------------------

    fn no_plane() -> Response {
        Response::json(503, r#"{"error":"no data plane attached to this server"}"#.to_string())
    }

    /// An error body built through [`Json`] so user-controlled strings
    /// (job names, dataset names, anyhow messages) are escaped — a quote
    /// in a name must never produce malformed JSON.
    fn error_json(status: u16, msg: impl Into<String>) -> Response {
        Response::json(status, Json::obj(vec![("error", Json::str(msg))]).to_string())
    }

    fn read_stats_json(s: &ReadStats) -> Json {
        Json::obj(vec![
            ("remote_bytes", Json::num(s.remote_bytes as f64)),
            ("local_bytes", Json::num(s.local_bytes as f64)),
            ("peer_bytes", Json::num(s.peer_bytes as f64)),
            ("peer_net_bytes", Json::num(s.peer_net_bytes as f64)),
            ("remote_reads", Json::num(s.remote_reads as f64)),
            ("local_reads", Json::num(s.local_reads as f64)),
            ("peer_reads", Json::num(s.peer_reads as f64)),
            ("peer_net_reads", Json::num(s.peer_net_reads as f64)),
            ("remote_wait_s", Json::num(s.remote_wait_s)),
            ("peer_failures", Json::num(s.peer_failures as f64)),
            ("degraded_reads", Json::num(s.degraded_reads as f64)),
            ("total_reads", Json::num(s.total_reads() as f64)),
            ("total_bytes", Json::num(s.total_bytes() as f64)),
        ])
    }

    /// The dataset's lifecycle state as the plane reports it — surfaced on
    /// every session body so a client polling `/v1/jobs/:id` sees
    /// `degraded(lost=…)` / `replacing` / `retired` instead of guessing
    /// from 500s.
    fn session_lifecycle(&self, sess: &JobSession) -> String {
        self.plane
            .as_ref()
            .map(|p| p.dataset_lifecycle(sess.dataset()))
            .unwrap_or_else(|| "unknown".into())
    }

    fn session_json(&self, name: &str, sess: &JobSession) -> Json {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("id", Json::num(sess.job_id() as f64)),
            ("dataset", Json::str(sess.dataset())),
            ("readers", Json::num(sess.readers() as f64)),
            ("granularity", Json::str(sess.granularity().name())),
            ("epochs_run", Json::num(sess.epochs_run() as f64)),
            ("lifecycle", Json::str(self.session_lifecycle(sess))),
            ("stats", Self::read_stats_json(&sess.stats())),
        ])
    }

    fn session(&self, name: &str) -> Option<Arc<JobSession>> {
        self.sessions.lock().unwrap().get(name).cloned()
    }

    fn open_session(&self, body: &[u8]) -> Response {
        let Some(plane) = &self.plane else { return Self::no_plane() };
        let Ok(text) = std::str::from_utf8(body) else {
            return Response::json(400, r#"{"error":"body is not utf-8"}"#.into());
        };
        let j = match Json::parse(text) {
            Ok(j) => j,
            Err(e) => return Response::json(400, format!(r#"{{"error":"{e}"}}"#)),
        };
        let (Some(name), Some(dataset)) = (
            j.get("name").and_then(|v| v.as_str()).map(str::to_string),
            j.get("dataset").and_then(|v| v.as_str()).map(str::to_string),
        ) else {
            return Response::json(400, r#"{"error":"name and dataset required"}"#.into());
        };
        let Some(cfg) = plane.dataset_cfg(&dataset) else {
            return Self::error_json(
                400,
                format!("dataset '{dataset}' is not registered with the data plane"),
            );
        };
        let granularity = match j.get("granularity").and_then(|v| v.as_str()) {
            None | Some("chunked") => Granularity::Chunked,
            Some("whole-file") => Granularity::WholeFile,
            Some(other) => {
                return Self::error_json(400, format!("unknown granularity '{other}'"));
            }
        };
        let spec = JobSpec::new(dataset, cfg)
            .readers(j.get("readers").and_then(|v| v.as_u64()).unwrap_or(1) as usize)
            .seed(j.get("seed").and_then(|v| v.as_u64()).unwrap_or(0))
            .granularity(granularity)
            .prefetch(j.get("prefetch").and_then(|v| v.as_bool()).unwrap_or(true));
        let epochs = j.get("epochs").and_then(|v| v.as_u64()).unwrap_or(0);
        let sess = match plane.open_job(spec) {
            Ok(sess) => Arc::new(sess),
            Err(e) => return Self::error_json(400, format!("{e:#}")),
        };
        // Reserve the name under ONE lock acquisition (check + insert
        // atomically), so a concurrent same-name POST can never overwrite
        // this session while its warm-up epochs run.
        {
            use std::collections::hash_map::Entry;
            let mut map = self.sessions.lock().unwrap();
            match map.entry(name.clone()) {
                Entry::Occupied(_) => {
                    return Self::error_json(409, format!("job '{name}' exists"));
                }
                Entry::Vacant(slot) => {
                    slot.insert(sess.clone());
                }
            }
        }
        // Synchronous warm-up epochs, when asked for (tiny datasets; the
        // epoch endpoint drives the rest). A failed warm-up releases the
        // name — but only if it still points at *this* session (a
        // concurrent DELETE + re-POST may have replaced it; never remove
        // someone else's healthy session).
        for _ in 0..epochs {
            if let Err(e) = sess.run_next_epoch() {
                let mut map = self.sessions.lock().unwrap();
                if map.get(&name).is_some_and(|cur| Arc::ptr_eq(cur, &sess)) {
                    map.remove(&name);
                }
                return Self::error_json(500, format!("epoch failed: {e:#}"));
            }
        }
        Response::json(201, self.session_json(&name, &sess).to_string())
    }

    fn list_sessions(&self) -> Response {
        if self.plane.is_none() {
            return Self::no_plane();
        }
        let map = self.sessions.lock().unwrap();
        let mut names: Vec<&String> = map.keys().collect();
        names.sort();
        let items: Vec<Json> =
            names.into_iter().map(|n| self.session_json(n, &map[n])).collect();
        Response::json(200, Json::obj(vec![("items", Json::arr(items))]).to_string())
    }

    fn get_session(&self, name: &str) -> Response {
        if self.plane.is_none() {
            return Self::no_plane();
        }
        match self.session(name) {
            // A retired (deleted) dataset answers 410 Gone — the session
            // handle still exists, but nothing behind it will ever serve
            // again; the body carries the lifecycle so clients see why.
            Some(s) => {
                let status =
                    if self.session_lifecycle(&s) == "retired" { 410 } else { 200 };
                Response::json(status, self.session_json(name, &s).to_string())
            }
            None => Response::not_found(),
        }
    }

    fn session_stats(&self, name: &str) -> Response {
        let Some(plane) = &self.plane else { return Self::no_plane() };
        match self.session(name) {
            Some(s) => {
                let body = Json::obj(vec![
                    ("name", Json::str(name)),
                    ("dataset", Json::str(s.dataset())),
                    ("epochs_run", Json::num(s.epochs_run() as f64)),
                    // Plane-wide remote fills on this dataset: with J
                    // co-located jobs this stays at the chunk count —
                    // the shared-fills evidence, readable per job.
                    ("dataset_fills", Json::num(plane.dataset_fills(s.dataset()) as f64)),
                    ("stats", Self::read_stats_json(&s.stats())),
                ]);
                Response::json(200, body.to_string())
            }
            None => Response::not_found(),
        }
    }

    fn close_session(&self, name: &str) -> Response {
        if self.plane.is_none() {
            return Self::no_plane();
        }
        match self.sessions.lock().unwrap().remove(name) {
            Some(_) => Response { status: 204, content_type: "application/json", body: vec![] },
            None => Response::not_found(),
        }
    }

    fn run_session_epoch(&self, name: &str) -> Response {
        if self.plane.is_none() {
            return Self::no_plane();
        }
        let Some(sess) = self.session(name) else { return Response::not_found() };
        match sess.run_next_epoch() {
            Ok(report) => {
                let body = Json::obj(vec![
                    ("name", Json::str(name)),
                    ("epochs_run", Json::num(sess.epochs_run() as f64)),
                    ("wall_s", Json::num(report.wall.as_secs_f64())),
                    (
                        "items_per_sec",
                        Json::num(report.items_per_sec(sess.cfg().num_items)),
                    ),
                    ("stats", Self::read_stats_json(&report.merged)),
                ]);
                Response::json(200, body.to_string())
            }
            // Lifecycle-precise failures: a retired dataset is 410 Gone
            // (permanent), not a generic 500.
            Err(e) if e.downcast_ref::<DatasetRetired>().is_some() => {
                Self::error_json(410, format!("{e:#}"))
            }
            Err(e) => Self::error_json(500, format!("{e:#}")),
        }
    }

    // ----- control plane (datasets + legacy DlJobs) ---------------------

    fn dataset_json(h: &Hoard, d: &Dataset) -> Json {
        let rec = h.cache.registry.get(&d.meta.name);
        let stripe_nodes = rec
            .and_then(|r| r.stripe.as_ref())
            .map(|s| s.nodes().iter().map(|n| Json::num(n.0 as f64)).collect())
            .unwrap_or_default();
        let (resident, pins) = rec
            .map(|r| (r.resident_bytes(), r.pin_count))
            .unwrap_or((0, 0));
        Json::obj(vec![
            ("name", Json::str(&d.meta.name)),
            ("url", Json::str(&d.url)),
            ("total_bytes", Json::num(d.total_bytes as f64)),
            ("num_items", Json::num(d.num_items as f64)),
            ("prefetch", Json::Bool(d.prefetch)),
            ("phase", Json::str(format!("{:?}", d.status))),
            ("resident_bytes", Json::num(resident as f64)),
            ("pin_count", Json::num(pins as f64)),
            ("stripe_nodes", Json::arr(stripe_nodes)),
        ])
    }

    fn job_json(j: &DlJob) -> Json {
        let (phase, nodes) = match &j.status {
            JobPhase::Pending => ("Pending".to_string(), vec![]),
            JobPhase::Scheduled { nodes } => ("Scheduled".to_string(), nodes.clone()),
            JobPhase::Running => ("Running".to_string(), vec![]),
            JobPhase::Succeeded => ("Succeeded".to_string(), vec![]),
            JobPhase::Failed(r) => (format!("Failed: {r}"), vec![]),
        };
        Json::obj(vec![
            ("name", Json::str(&j.meta.name)),
            ("dataset", Json::str(&j.dataset)),
            ("gpus", Json::num(j.gpus as f64)),
            ("replicas", Json::num(j.replicas as f64)),
            ("epochs", Json::num(j.epochs as f64)),
            ("phase", Json::str(phase)),
            ("nodes", Json::arr(nodes.into_iter().map(|n| Json::num(n as f64)).collect())),
        ])
    }

    fn stats(&self) -> Response {
        self.with(|h| {
            let nodes: Vec<Json> = (0..h.nodes.len())
                .map(|i| {
                    let nid = crate::netsim::NodeId(i);
                    Json::obj(vec![
                        ("name", Json::str(&h.nodes[i].spec.name)),
                        ("gpus_free", Json::num(h.nodes[i].gpus_free() as f64)),
                        ("cache_capacity", Json::num(h.cache.volume(nid).capacity() as f64)),
                        ("cache_used", Json::num(h.cache.node_used(nid) as f64)),
                    ])
                })
                .collect();
            let body = Json::obj(vec![
                ("nodes", Json::arr(nodes)),
                ("datasets", Json::num(h.cache.registry.len() as f64)),
                ("cache_resident_bytes", Json::num(h.cache.registry.resident_bytes() as f64)),
            ]);
            Response::json(200, body.to_string())
        })
    }

    fn list_datasets(&self) -> Response {
        self.with(|h| {
            let items: Vec<Json> =
                h.datasets.list().map(|d| Self::dataset_json(h, d)).collect();
            Response::json(200, Json::obj(vec![("items", Json::arr(items))]).to_string())
        })
    }

    fn get_dataset(&self, name: &str) -> Response {
        self.with(|h| match h.datasets.get(name) {
            Some(d) => Response::json(200, Self::dataset_json(h, d).to_string()),
            None => Response::not_found(),
        })
    }

    fn create_dataset(&self, body: &[u8]) -> Response {
        let Ok(text) = std::str::from_utf8(body) else {
            return Response::json(400, r#"{"error":"body is not utf-8"}"#.into());
        };
        let j = match Json::parse(text) {
            Ok(j) => j,
            Err(e) => return Response::json(400, format!(r#"{{"error":"{e}"}}"#)),
        };
        let (Some(name), Some(url)) = (
            j.get("name").and_then(|v| v.as_str()).map(str::to_string),
            j.get("url").and_then(|v| v.as_str()).map(str::to_string),
        ) else {
            return Response::json(400, r#"{"error":"name and url required"}"#.into());
        };
        if crate::remote::DatasetUrl::parse(&url).is_err() {
            return Response::json(400, r#"{"error":"invalid url"}"#.into());
        }
        let ds = Dataset {
            meta: ObjectMeta::named(&name),
            url,
            total_bytes: j.get("total_bytes").and_then(|v| v.as_u64()).unwrap_or(0),
            num_items: j.get("num_items").and_then(|v| v.as_u64()).unwrap_or(1).max(1),
            prefetch: j.get("prefetch").and_then(|v| v.as_bool()).unwrap_or(false),
            stripe_width: j.get("stripe_width").and_then(|v| v.as_u64()).unwrap_or(0) as usize,
            status: DatasetPhase::Pending,
        };
        self.with(|h| match h.datasets.create(ds) {
            Ok(created) => {
                let _ = h.reconcile_to_fixpoint();
                let d = h.datasets.get(&created.meta.name).unwrap().clone();
                Response::json(201, Self::dataset_json(h, &d).to_string())
            }
            Err(StoreError::AlreadyExists { .. }) => {
                Response::json(409, format!(r#"{{"error":"dataset '{name}' exists"}}"#))
            }
            Err(e) => Response::json(500, format!(r#"{{"error":"{e}"}}"#)),
        })
    }

    fn delete_dataset(&self, name: &str) -> Response {
        self.with(|h| {
            if h.datasets.get(name).is_none() {
                return Response::not_found();
            }
            // Refuse deletion while pinned by running jobs.
            if let Some(rec) = h.cache.registry.get(name) {
                if rec.pin_count > 0 {
                    return Response::json(
                        409,
                        format!(r#"{{"error":"dataset '{name}' pinned by {} job(s)"}}"#, rec.pin_count),
                    );
                }
            }
            h.datasets.delete(name).unwrap();
            let _ = h.reconcile_to_fixpoint();
            Response { status: 204, content_type: "application/json", body: vec![] }
        })
    }

    fn list_jobs(&self) -> Response {
        self.with(|h| {
            let items: Vec<Json> = h.jobs.list().map(Self::job_json).collect();
            Response::json(200, Json::obj(vec![("items", Json::arr(items))]).to_string())
        })
    }

    fn get_job(&self, name: &str) -> Response {
        self.with(|h| match h.jobs.get(name) {
            Some(j) => Response::json(200, Self::job_json(j).to_string()),
            None => Response::not_found(),
        })
    }

    fn create_job(&self, body: &[u8]) -> Response {
        let Ok(text) = std::str::from_utf8(body) else {
            return Response::json(400, r#"{"error":"body is not utf-8"}"#.into());
        };
        let j = match Json::parse(text) {
            Ok(j) => j,
            Err(e) => return Response::json(400, format!(r#"{{"error":"{e}"}}"#)),
        };
        let (Some(name), Some(dataset)) = (
            j.get("name").and_then(|v| v.as_str()).map(str::to_string),
            j.get("dataset").and_then(|v| v.as_str()).map(str::to_string),
        ) else {
            return Response::json(400, r#"{"error":"name and dataset required"}"#.into());
        };
        let job = DlJob {
            meta: ObjectMeta::named(&name),
            dataset,
            gpus: j.get("gpus").and_then(|v| v.as_u64()).unwrap_or(1) as u32,
            replicas: j.get("replicas").and_then(|v| v.as_u64()).unwrap_or(1) as u32,
            container_image: j
                .get("image")
                .and_then(|v| v.as_str())
                .unwrap_or("tf-cnn-benchmarks:latest")
                .to_string(),
            mount_path: j.get("mount_path").and_then(|v| v.as_str()).unwrap_or("/data").to_string(),
            epochs: j.get("epochs").and_then(|v| v.as_u64()).unwrap_or(1) as u32,
            status: JobPhase::Pending,
        };
        self.with(|h| match h.jobs.create(job) {
            Ok(created) => {
                let _ = h.reconcile_to_fixpoint();
                let out = Self::job_json(h.jobs.get(&created.meta.name).unwrap());
                Response::json(201, out.to_string())
            }
            Err(StoreError::AlreadyExists { .. }) => {
                Response::json(409, format!(r#"{{"error":"job '{name}' exists"}}"#))
            }
            Err(e) => Response::json(500, format!(r#"{{"error":"{e}"}}"#)),
        })
    }

    fn complete_job(&self, name: &str) -> Response {
        self.with(|h| {
            if h.jobs.get(name).is_none() {
                return Response::not_found();
            }
            match job_controller::complete_job(h, name) {
                Ok(()) => {
                    let _ = h.reconcile_to_fixpoint();
                    Response::json(200, Self::job_json(h.jobs.get(name).unwrap()).to_string())
                }
                Err(e) => Response::json(500, format!(r#"{{"error":"{e}"}}"#)),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full lifecycle is covered in api::tests; here: pinned-delete guard.
    #[test]
    fn delete_pinned_dataset_conflicts() {
        let hoard = Arc::new(Mutex::new(Hoard::paper_testbed()));
        let state = ApiState::new(hoard);
        let mk = |method: &str, path: &str, body: &str| Request {
            method: method.into(),
            path: path.into(),
            body: body.as_bytes().to_vec(),
        };
        let r = state.route(&mk(
            "POST",
            "/api/v1/datasets",
            r#"{"name":"d","url":"nfs://s/d","total_bytes":1000,"num_items":10,"prefetch":true}"#,
        ));
        assert_eq!(r.status, 201);
        let r = state.route(&mk(
            "POST",
            "/api/v1/jobs",
            r#"{"name":"j","dataset":"d","gpus":4,"replicas":1,"epochs":1}"#,
        ));
        assert_eq!(r.status, 201);
        let r = state.route(&mk("DELETE", "/api/v1/datasets/d", ""));
        assert_eq!(r.status, 409, "{}", String::from_utf8_lossy(&r.body));
        state.route(&mk("POST", "/api/v1/jobs/j/complete", ""));
        let r = state.route(&mk("DELETE", "/api/v1/datasets/d", ""));
        assert_eq!(r.status, 204);
    }
}
