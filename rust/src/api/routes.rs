//! REST routing for the Hoard API server. Every mutating request triggers a
//! control-plane reconcile so responses reflect settled state — the
//! user-visible behaviour of the paper's "turnkey" workflow.

use std::sync::{Arc, Mutex};

use super::http::{Request, Response};
use crate::coordinator::{job_controller, Hoard};
use crate::k8s::{Dataset, DatasetPhase, DlJob, JobPhase, ObjectMeta, StoreError};
use crate::util::Json;

#[derive(Clone)]
pub struct ApiState {
    pub hoard: Arc<Mutex<Hoard>>,
}

impl ApiState {
    pub fn route(&self, req: &Request) -> Response {
        let path: Vec<&str> = req.path.trim_matches('/').split('/').collect();
        match (req.method.as_str(), path.as_slice()) {
            ("GET", ["healthz"]) => Response::text(200, "ok"),
            ("GET", ["api", "v1", "stats"]) => self.stats(),
            ("GET", ["api", "v1", "datasets"]) => self.list_datasets(),
            ("POST", ["api", "v1", "datasets"]) => self.create_dataset(&req.body),
            ("GET", ["api", "v1", "datasets", name]) => self.get_dataset(name),
            ("DELETE", ["api", "v1", "datasets", name]) => self.delete_dataset(name),
            ("GET", ["api", "v1", "jobs"]) => self.list_jobs(),
            ("POST", ["api", "v1", "jobs"]) => self.create_job(&req.body),
            ("GET", ["api", "v1", "jobs", name]) => self.get_job(name),
            ("POST", ["api", "v1", "jobs", name, "complete"]) => self.complete_job(name),
            _ => Response::not_found(),
        }
    }

    fn with<T>(&self, f: impl FnOnce(&mut Hoard) -> T) -> T {
        let mut h = self.hoard.lock().unwrap();
        f(&mut h)
    }

    fn dataset_json(h: &Hoard, d: &Dataset) -> Json {
        let rec = h.cache.registry.get(&d.meta.name);
        let stripe_nodes = rec
            .and_then(|r| r.stripe.as_ref())
            .map(|s| s.nodes().iter().map(|n| Json::num(n.0 as f64)).collect())
            .unwrap_or_default();
        let (resident, pins) = rec
            .map(|r| (r.resident_bytes(), r.pin_count))
            .unwrap_or((0, 0));
        Json::obj(vec![
            ("name", Json::str(&d.meta.name)),
            ("url", Json::str(&d.url)),
            ("total_bytes", Json::num(d.total_bytes as f64)),
            ("num_items", Json::num(d.num_items as f64)),
            ("prefetch", Json::Bool(d.prefetch)),
            ("phase", Json::str(format!("{:?}", d.status))),
            ("resident_bytes", Json::num(resident as f64)),
            ("pin_count", Json::num(pins as f64)),
            ("stripe_nodes", Json::arr(stripe_nodes)),
        ])
    }

    fn job_json(j: &DlJob) -> Json {
        let (phase, nodes) = match &j.status {
            JobPhase::Pending => ("Pending".to_string(), vec![]),
            JobPhase::Scheduled { nodes } => ("Scheduled".to_string(), nodes.clone()),
            JobPhase::Running => ("Running".to_string(), vec![]),
            JobPhase::Succeeded => ("Succeeded".to_string(), vec![]),
            JobPhase::Failed(r) => (format!("Failed: {r}"), vec![]),
        };
        Json::obj(vec![
            ("name", Json::str(&j.meta.name)),
            ("dataset", Json::str(&j.dataset)),
            ("gpus", Json::num(j.gpus as f64)),
            ("replicas", Json::num(j.replicas as f64)),
            ("epochs", Json::num(j.epochs as f64)),
            ("phase", Json::str(phase)),
            ("nodes", Json::arr(nodes.into_iter().map(|n| Json::num(n as f64)).collect())),
        ])
    }

    fn stats(&self) -> Response {
        self.with(|h| {
            let nodes: Vec<Json> = (0..h.nodes.len())
                .map(|i| {
                    let nid = crate::netsim::NodeId(i);
                    Json::obj(vec![
                        ("name", Json::str(&h.nodes[i].spec.name)),
                        ("gpus_free", Json::num(h.nodes[i].gpus_free() as f64)),
                        ("cache_capacity", Json::num(h.cache.volume(nid).capacity() as f64)),
                        ("cache_used", Json::num(h.cache.node_used(nid) as f64)),
                    ])
                })
                .collect();
            let body = Json::obj(vec![
                ("nodes", Json::arr(nodes)),
                ("datasets", Json::num(h.cache.registry.len() as f64)),
                ("cache_resident_bytes", Json::num(h.cache.registry.resident_bytes() as f64)),
            ]);
            Response::json(200, body.to_string())
        })
    }

    fn list_datasets(&self) -> Response {
        self.with(|h| {
            let items: Vec<Json> =
                h.datasets.list().map(|d| Self::dataset_json(h, d)).collect();
            Response::json(200, Json::obj(vec![("items", Json::arr(items))]).to_string())
        })
    }

    fn get_dataset(&self, name: &str) -> Response {
        self.with(|h| match h.datasets.get(name) {
            Some(d) => Response::json(200, Self::dataset_json(h, d).to_string()),
            None => Response::not_found(),
        })
    }

    fn create_dataset(&self, body: &[u8]) -> Response {
        let Ok(text) = std::str::from_utf8(body) else {
            return Response::json(400, r#"{"error":"body is not utf-8"}"#.into());
        };
        let j = match Json::parse(text) {
            Ok(j) => j,
            Err(e) => return Response::json(400, format!(r#"{{"error":"{e}"}}"#)),
        };
        let (Some(name), Some(url)) = (
            j.get("name").and_then(|v| v.as_str()).map(str::to_string),
            j.get("url").and_then(|v| v.as_str()).map(str::to_string),
        ) else {
            return Response::json(400, r#"{"error":"name and url required"}"#.into());
        };
        if crate::remote::DatasetUrl::parse(&url).is_err() {
            return Response::json(400, r#"{"error":"invalid url"}"#.into());
        }
        let ds = Dataset {
            meta: ObjectMeta::named(&name),
            url,
            total_bytes: j.get("total_bytes").and_then(|v| v.as_u64()).unwrap_or(0),
            num_items: j.get("num_items").and_then(|v| v.as_u64()).unwrap_or(1).max(1),
            prefetch: j.get("prefetch").and_then(|v| v.as_bool()).unwrap_or(false),
            stripe_width: j.get("stripe_width").and_then(|v| v.as_u64()).unwrap_or(0) as usize,
            status: DatasetPhase::Pending,
        };
        self.with(|h| match h.datasets.create(ds) {
            Ok(created) => {
                let _ = h.reconcile_to_fixpoint();
                let d = h.datasets.get(&created.meta.name).unwrap().clone();
                Response::json(201, Self::dataset_json(h, &d).to_string())
            }
            Err(StoreError::AlreadyExists { .. }) => {
                Response::json(409, format!(r#"{{"error":"dataset '{name}' exists"}}"#))
            }
            Err(e) => Response::json(500, format!(r#"{{"error":"{e}"}}"#)),
        })
    }

    fn delete_dataset(&self, name: &str) -> Response {
        self.with(|h| {
            if h.datasets.get(name).is_none() {
                return Response::not_found();
            }
            // Refuse deletion while pinned by running jobs.
            if let Some(rec) = h.cache.registry.get(name) {
                if rec.pin_count > 0 {
                    return Response::json(
                        409,
                        format!(r#"{{"error":"dataset '{name}' pinned by {} job(s)"}}"#, rec.pin_count),
                    );
                }
            }
            h.datasets.delete(name).unwrap();
            let _ = h.reconcile_to_fixpoint();
            Response { status: 204, content_type: "application/json", body: vec![] }
        })
    }

    fn list_jobs(&self) -> Response {
        self.with(|h| {
            let items: Vec<Json> = h.jobs.list().map(Self::job_json).collect();
            Response::json(200, Json::obj(vec![("items", Json::arr(items))]).to_string())
        })
    }

    fn get_job(&self, name: &str) -> Response {
        self.with(|h| match h.jobs.get(name) {
            Some(j) => Response::json(200, Self::job_json(j).to_string()),
            None => Response::not_found(),
        })
    }

    fn create_job(&self, body: &[u8]) -> Response {
        let Ok(text) = std::str::from_utf8(body) else {
            return Response::json(400, r#"{"error":"body is not utf-8"}"#.into());
        };
        let j = match Json::parse(text) {
            Ok(j) => j,
            Err(e) => return Response::json(400, format!(r#"{{"error":"{e}"}}"#)),
        };
        let (Some(name), Some(dataset)) = (
            j.get("name").and_then(|v| v.as_str()).map(str::to_string),
            j.get("dataset").and_then(|v| v.as_str()).map(str::to_string),
        ) else {
            return Response::json(400, r#"{"error":"name and dataset required"}"#.into());
        };
        let job = DlJob {
            meta: ObjectMeta::named(&name),
            dataset,
            gpus: j.get("gpus").and_then(|v| v.as_u64()).unwrap_or(1) as u32,
            replicas: j.get("replicas").and_then(|v| v.as_u64()).unwrap_or(1) as u32,
            container_image: j
                .get("image")
                .and_then(|v| v.as_str())
                .unwrap_or("tf-cnn-benchmarks:latest")
                .to_string(),
            mount_path: j.get("mount_path").and_then(|v| v.as_str()).unwrap_or("/data").to_string(),
            epochs: j.get("epochs").and_then(|v| v.as_u64()).unwrap_or(1) as u32,
            status: JobPhase::Pending,
        };
        self.with(|h| match h.jobs.create(job) {
            Ok(created) => {
                let _ = h.reconcile_to_fixpoint();
                let out = Self::job_json(h.jobs.get(&created.meta.name).unwrap());
                Response::json(201, out.to_string())
            }
            Err(StoreError::AlreadyExists { .. }) => {
                Response::json(409, format!(r#"{{"error":"job '{name}' exists"}}"#))
            }
            Err(e) => Response::json(500, format!(r#"{{"error":"{e}"}}"#)),
        })
    }

    fn complete_job(&self, name: &str) -> Response {
        self.with(|h| {
            if h.jobs.get(name).is_none() {
                return Response::not_found();
            }
            match job_controller::complete_job(h, name) {
                Ok(()) => {
                    let _ = h.reconcile_to_fixpoint();
                    Response::json(200, Self::job_json(h.jobs.get(name).unwrap()).to_string())
                }
                Err(e) => Response::json(500, format!(r#"{{"error":"{e}"}}"#)),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full lifecycle is covered in api::tests; here: pinned-delete guard.
    #[test]
    fn delete_pinned_dataset_conflicts() {
        let hoard = Arc::new(Mutex::new(Hoard::paper_testbed()));
        let state = ApiState { hoard };
        let mk = |method: &str, path: &str, body: &str| Request {
            method: method.into(),
            path: path.into(),
            body: body.as_bytes().to_vec(),
        };
        let r = state.route(&mk(
            "POST",
            "/api/v1/datasets",
            r#"{"name":"d","url":"nfs://s/d","total_bytes":1000,"num_items":10,"prefetch":true}"#,
        ));
        assert_eq!(r.status, 201);
        let r = state.route(&mk(
            "POST",
            "/api/v1/jobs",
            r#"{"name":"j","dataset":"d","gpus":4,"replicas":1,"epochs":1}"#,
        ));
        assert_eq!(r.status, 201);
        let r = state.route(&mk("DELETE", "/api/v1/datasets/d", ""));
        assert_eq!(r.status, 409, "{}", String::from_utf8_lossy(&r.body));
        state.route(&mk("POST", "/api/v1/jobs/j/complete", ""));
        let r = state.route(&mk("DELETE", "/api/v1/datasets/d", ""));
        assert_eq!(r.status, 204);
    }
}
