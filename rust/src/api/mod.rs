//! The Hoard API server (paper §3.1): REST endpoints to create/query/delete
//! dataset resources and to submit/track DL jobs, backed by the coordinator
//! control plane. This is the "turnkey cloud service" surface the paper
//! contrasts with bare Alluxio/cachefsd setups.

pub mod http;
pub mod routes;

pub use http::{request, Request, Response, Server};
pub use routes::ApiState;

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::Hoard;
use crate::posix::dataplane::DataPlane;

/// Start the API server on `addr` over a shared control plane. The
/// `/v1/jobs` data-plane endpoints answer 503 until a [`DataPlane`] is
/// attached ([`serve_with_plane`]).
pub fn serve(addr: &str, hoard: Arc<Mutex<Hoard>>) -> Result<Server> {
    let state = ApiState::new(hoard);
    Server::start(addr, move |req| state.route(req))
}

/// [`serve`] with a real-mode [`DataPlane`] attached: `POST /v1/jobs`
/// opens co-scheduled [`JobSession`](crate::posix::dataplane::JobSession)s
/// that share the plane's fill ledgers and buffers.
pub fn serve_with_plane(
    addr: &str,
    hoard: Arc<Mutex<Hoard>>,
    plane: Arc<DataPlane>,
) -> Result<Server> {
    let state = ApiState::new(hoard).with_plane(plane);
    Server::start(addr, move |req| state.route(req))
}

/// [`serve_with_plane`] with a tunable connection budget (optional plane):
/// what `hoard serve --max-conns N` reaches for.
pub fn serve_with_opts(
    addr: &str,
    hoard: Arc<Mutex<Hoard>>,
    plane: Option<Arc<DataPlane>>,
    max_conns: usize,
) -> Result<Server> {
    let mut state = ApiState::new(hoard);
    if let Some(p) = plane {
        state = state.with_plane(p);
    }
    Server::start_with_limits(addr, http::DEFAULT_IO_TIMEOUT, max_conns, move |req| {
        state.route(req)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    fn post_dataset(addr: std::net::SocketAddr, name: &str, bytes: u64) -> (u16, String) {
        let body = format!(
            r#"{{"name":"{name}","url":"nfs://storage1/{name}","total_bytes":{bytes},"num_items":1000,"prefetch":true}}"#
        );
        request(addr, "POST", "/api/v1/datasets", &body).unwrap()
    }

    #[test]
    fn dataset_job_lifecycle_over_http() {
        let hoard = Arc::new(Mutex::new(Hoard::paper_testbed()));
        let srv = serve("127.0.0.1:0", hoard.clone()).unwrap();

        // Health.
        let (st, body) = request(srv.addr, "GET", "/healthz", "").unwrap();
        assert_eq!((st, body.as_str()), (200, "ok"));

        // Create a dataset.
        let (st, body) = post_dataset(srv.addr, "imagenet", 144_000_000_000);
        assert_eq!(st, 201, "{body}");

        // List datasets — should be cached (prefetch) after reconcile.
        let (st, body) = request(srv.addr, "GET", "/api/v1/datasets", "").unwrap();
        assert_eq!(st, 200);
        let j = Json::parse(&body).unwrap();
        let items = j.get("items").unwrap().as_arr().unwrap();
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].get("phase").unwrap().as_str(), Some("Ready"));
        assert_eq!(items[0].get("stripe_nodes").unwrap().as_arr().unwrap().len(), 4);

        // Submit a job.
        let job = r#"{"name":"train1","dataset":"imagenet","gpus":4,"replicas":1,"epochs":2}"#;
        let (st, body) = request(srv.addr, "POST", "/api/v1/jobs", job).unwrap();
        assert_eq!(st, 201, "{body}");
        let (st, body) = request(srv.addr, "GET", "/api/v1/jobs/train1", "").unwrap();
        assert_eq!(st, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("phase").unwrap().as_str(), Some("Running"));

        // Complete it; dataset unpins but stays cached.
        let (st, _) = request(srv.addr, "POST", "/api/v1/jobs/train1/complete", "").unwrap();
        assert_eq!(st, 200);
        let (_, body) = request(srv.addr, "GET", "/api/v1/datasets/imagenet", "").unwrap();
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("pin_count").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("phase").unwrap().as_str(), Some("Ready"));

        // Delete the dataset.
        let (st, _) = request(srv.addr, "DELETE", "/api/v1/datasets/imagenet", "").unwrap();
        assert_eq!(st, 204);
        let (st, _) = request(srv.addr, "GET", "/api/v1/datasets/imagenet", "").unwrap();
        assert_eq!(st, 404);
    }

    #[test]
    fn stats_and_errors() {
        let hoard = Arc::new(Mutex::new(Hoard::paper_testbed()));
        let srv = serve("127.0.0.1:0", hoard).unwrap();

        let (st, body) = request(srv.addr, "GET", "/api/v1/stats", "").unwrap();
        assert_eq!(st, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("nodes").unwrap().as_arr().unwrap().len(), 4);

        // Duplicate dataset -> 409.
        post_dataset(srv.addr, "a", 1000);
        let (st, _) = post_dataset(srv.addr, "a", 1000);
        assert_eq!(st, 409);

        // Bad JSON -> 400.
        let (st, _) = request(srv.addr, "POST", "/api/v1/datasets", "{oops").unwrap();
        assert_eq!(st, 400);

        // Job for unknown dataset -> pending (not failed), visible in list.
        let job = r#"{"name":"j","dataset":"ghost","gpus":4,"replicas":1,"epochs":1}"#;
        let (st, _) = request(srv.addr, "POST", "/api/v1/jobs", job).unwrap();
        assert_eq!(st, 201);
        let (_, body) = request(srv.addr, "GET", "/api/v1/jobs/j", "").unwrap();
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("phase").unwrap().as_str(), Some("Pending"));

        // Unknown route -> 404.
        let (st, _) = request(srv.addr, "GET", "/api/v2/nope", "").unwrap();
        assert_eq!(st, 404);
    }
}
