//! Co-placement of cache nodes and compute nodes (paper §3.1/§4.5):
//! "these two sets are co-selected to maximize locality of containers and
//! cache-nodes, also taking into account the data-center topology (rack-
//! locality is prioritized if node-locality cannot be satisfied)".

use crate::netsim::{NodeId, RackId, Topology};

/// Inputs the placement algorithm consults per node.
#[derive(Debug, Clone)]
pub struct PlacementInput {
    pub node: NodeId,
    pub gpus_free: u32,
    pub cache_free_bytes: u64,
}

/// Achieved locality class for a (job, dataset) pairing — reported in the
/// ablations and Table 5 reproduction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Locality {
    NodeLocal,
    RackLocal,
    Misplaced,
}

/// Choose `width` cache nodes for a dataset of `bytes`, preferring nodes
/// with the most free cache, breaking ties toward packing a single rack
/// (minimizes future cross-rack reads).
pub fn select_cache_nodes(
    inputs: &[PlacementInput],
    topo: &Topology,
    width: usize,
    bytes: u64,
) -> Option<Vec<NodeId>> {
    if width == 0 || width > inputs.len() {
        return None;
    }
    // Rank racks by aggregate free cache, then fill from the best rack out.
    let mut racks: Vec<(RackId, u64)> = (0..topo.racks)
        .map(|r| {
            let free: u64 = inputs
                .iter()
                .filter(|i| topo.rack_of(i.node) == RackId(r))
                .map(|i| i.cache_free_bytes)
                .sum();
            (RackId(r), free)
        })
        .collect();
    racks.sort_by(|a, b| b.1.cmp(&a.1).then(a.0 .0.cmp(&b.0 .0)));

    let mut chosen: Vec<&PlacementInput> = Vec::with_capacity(width);
    for (rack, _) in &racks {
        let mut members: Vec<&PlacementInput> = inputs
            .iter()
            .filter(|i| topo.rack_of(i.node) == *rack && !chosen.iter().any(|c| c.node == i.node))
            .collect();
        members.sort_by(|a, b| b.cache_free_bytes.cmp(&a.cache_free_bytes).then(a.node.0.cmp(&b.node.0)));
        for m in members {
            if chosen.len() == width {
                break;
            }
            chosen.push(m);
        }
        if chosen.len() == width {
            break;
        }
    }
    let total_free: u64 = chosen.iter().map(|c| c.cache_free_bytes).sum();
    if total_free < bytes {
        return None;
    }
    let mut nodes: Vec<NodeId> = chosen.iter().map(|c| c.node).collect();
    nodes.sort_by_key(|n| n.0);
    Some(nodes)
}

/// Choose `replicas` compute nodes (each needing `gpus_per_replica`) for a
/// job whose dataset lives on `cache_nodes`. Preference order per replica:
/// node-local (on a cache node) > rack-local (same rack as a cache node) >
/// anywhere with GPUs.
pub fn select_compute_nodes(
    inputs: &[PlacementInput],
    topo: &Topology,
    cache_nodes: &[NodeId],
    replicas: u32,
    gpus_per_replica: u32,
) -> Option<Vec<(NodeId, Locality)>> {
    let cache_racks: Vec<RackId> = cache_nodes.iter().map(|&n| topo.rack_of(n)).collect();
    let mut free: Vec<(NodeId, u32)> = inputs.iter().map(|i| (i.node, i.gpus_free)).collect();
    let mut out = Vec::with_capacity(replicas as usize);
    for _ in 0..replicas {
        // Score every node that still has room.
        let mut best: Option<(u32, u32, NodeId)> = None; // (locality_rank, free, node)
        for &(n, f) in &free {
            if f < gpus_per_replica {
                continue;
            }
            let rank = if cache_nodes.contains(&n) {
                0
            } else if cache_racks.contains(&topo.rack_of(n)) {
                1
            } else {
                2
            };
            let better = match best {
                None => true,
                Some((br, bf, bn)) => {
                    (rank, std::cmp::Reverse(f), n.0) < (br, std::cmp::Reverse(bf), bn.0)
                }
            };
            if better {
                best = Some((rank, f, n));
            }
        }
        let (rank, _, node) = best?;
        let slot = free.iter_mut().find(|(n, _)| *n == node).unwrap();
        slot.1 -= gpus_per_replica;
        let loc = match rank {
            0 => Locality::NodeLocal,
            1 => Locality::RackLocal,
            _ => Locality::Misplaced,
        };
        out.push((node, loc));
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(n: usize, gpus: u32, cache_free: u64) -> Vec<PlacementInput> {
        (0..n)
            .map(|i| PlacementInput { node: NodeId(i), gpus_free: gpus, cache_free_bytes: cache_free })
            .collect()
    }

    fn topo_2x4() -> Topology {
        Topology::new(2, 4, 12.5e9, 40e9)
    }

    #[test]
    fn cache_nodes_pack_one_rack() {
        let topo = topo_2x4();
        let inp = inputs(8, 4, 1000);
        let nodes = select_cache_nodes(&inp, &topo, 4, 3000).unwrap();
        let racks: std::collections::HashSet<_> =
            nodes.iter().map(|&n| topo.rack_of(n)).collect();
        assert_eq!(racks.len(), 1, "width-4 stripe should fit one rack: {nodes:?}");
    }

    #[test]
    fn cache_selection_respects_capacity() {
        let topo = topo_2x4();
        let mut inp = inputs(8, 4, 10);
        assert!(select_cache_nodes(&inp, &topo, 4, 1000).is_none());
        inp[0].cache_free_bytes = 2000;
        let nodes = select_cache_nodes(&inp, &topo, 1, 1000).unwrap();
        assert_eq!(nodes, vec![NodeId(0)]);
    }

    #[test]
    fn cache_selection_prefers_freest_nodes() {
        let topo = topo_2x4();
        let mut inp = inputs(8, 4, 100);
        inp[5].cache_free_bytes = 5000;
        inp[6].cache_free_bytes = 5000;
        let nodes = select_cache_nodes(&inp, &topo, 2, 6000).unwrap();
        assert_eq!(nodes, vec![NodeId(5), NodeId(6)]);
    }

    #[test]
    fn compute_prefers_node_local() {
        let topo = topo_2x4();
        let inp = inputs(8, 4, 1000);
        let cache = vec![NodeId(2), NodeId(3)];
        let placed = select_compute_nodes(&inp, &topo, &cache, 2, 4).unwrap();
        for (n, loc) in &placed {
            assert!(cache.contains(n));
            assert_eq!(*loc, Locality::NodeLocal);
        }
    }

    #[test]
    fn compute_falls_back_to_rack_local() {
        let topo = topo_2x4();
        let mut inp = inputs(8, 4, 1000);
        // Cache nodes have no free GPUs; rack-mates do.
        inp[2].gpus_free = 0;
        inp[3].gpus_free = 0;
        let cache = vec![NodeId(2), NodeId(3)];
        let placed = select_compute_nodes(&inp, &topo, &cache, 1, 4).unwrap();
        let (n, loc) = placed[0];
        assert_eq!(topo.rack_of(n), topo.rack_of(NodeId(2)));
        assert_eq!(loc, Locality::RackLocal);
    }

    #[test]
    fn compute_misplaced_as_last_resort() {
        let topo = topo_2x4();
        let mut inp = inputs(8, 4, 1000);
        for i in 0..4 {
            inp[i].gpus_free = 0; // all of rack0 (cache rack) busy
        }
        let cache = vec![NodeId(0), NodeId(1)];
        let placed = select_compute_nodes(&inp, &topo, &cache, 1, 4).unwrap();
        assert_eq!(placed[0].1, Locality::Misplaced);
    }

    #[test]
    fn compute_multi_replica_spreads() {
        let topo = topo_2x4();
        let inp = inputs(8, 4, 1000);
        let cache: Vec<NodeId> = (0..4).map(NodeId).collect();
        let placed = select_compute_nodes(&inp, &topo, &cache, 4, 4).unwrap();
        let nodes: std::collections::HashSet<_> = placed.iter().map(|(n, _)| *n).collect();
        assert_eq!(nodes.len(), 4, "4×4-GPU replicas need 4 distinct nodes");
    }

    #[test]
    fn insufficient_gpus_is_none() {
        let topo = topo_2x4();
        let inp = inputs(2, 2, 1000);
        assert!(select_compute_nodes(&inp, &topo, &[NodeId(0)], 1, 4).is_none());
    }

    #[test]
    fn prop_compute_selection_sound() {
        use crate::util::{prop::forall, Rng};
        forall(
            150,
            |rng: &mut Rng| {
                let gpus: Vec<u32> = (0..8).map(|_| rng.gen_range(5) as u32).collect();
                let cache_k = 1 + rng.gen_range(4) as usize;
                let replicas = 1 + rng.gen_range(4) as u32;
                let per = 1 + rng.gen_range(4) as u32;
                (gpus, cache_k, replicas, per)
            },
            |(gpus, cache_k, replicas, per)| {
                let topo = topo_2x4();
                let inp: Vec<PlacementInput> = gpus
                    .iter()
                    .enumerate()
                    .map(|(i, &g)| PlacementInput {
                        node: NodeId(i),
                        gpus_free: g,
                        cache_free_bytes: 1000,
                    })
                    .collect();
                let cache: Vec<NodeId> = (0..*cache_k).map(NodeId).collect();
                match select_compute_nodes(&inp, &topo, &cache, *replicas, *per) {
                    None => {
                        // Must genuinely not fit: total feasible replica slots.
                        let slots: u32 = gpus.iter().map(|g| g / per).sum();
                        if slots >= *replicas {
                            return Err(format!("refused feasible placement ({slots} slots)"));
                        }
                    }
                    Some(placed) => {
                        if placed.len() != *replicas as usize {
                            return Err("wrong replica count".into());
                        }
                        // Per-node GPU budget respected.
                        let mut used = std::collections::HashMap::new();
                        for (n, _) in &placed {
                            *used.entry(n.0).or_insert(0u32) += per;
                        }
                        for (n, u) in used {
                            if u > gpus[n] {
                                return Err(format!("node {n} over-committed"));
                            }
                        }
                        // Locality labels truthful.
                        for (n, loc) in &placed {
                            let is_local = cache.contains(n);
                            let is_rack = cache.iter().any(|c| topo.rack_of(*c) == topo.rack_of(*n));
                            let want = if is_local {
                                Locality::NodeLocal
                            } else if is_rack {
                                Locality::RackLocal
                            } else {
                                Locality::Misplaced
                            };
                            if *loc != want {
                                return Err(format!("locality mislabeled for {n:?}"));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
