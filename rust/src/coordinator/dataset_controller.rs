//! Dataset controller: watches `Dataset` custom resources and drives the
//! cache layer — register, select cache nodes, place, prefetch, and reflect
//! progress back into the resource status (paper §3.2).

use anyhow::Result;

use super::placement::{select_cache_nodes, PlacementInput};
use super::Hoard;
use crate::cache::{CacheError, DatasetState};
use crate::k8s::{Dataset, DatasetPhase};
use crate::netsim::NodeId;
use crate::workload::DatasetSpec;

/// Default stripe width when the resource doesn't request one: all nodes,
/// capped at 4 (the paper's testbed width — wider stripes add peer hops
/// without adding bandwidth once NICs stop being the bottleneck).
pub fn default_stripe_width(cluster_nodes: usize) -> usize {
    cluster_nodes.min(4).max(1)
}

pub fn reconcile_datasets(h: &mut Hoard) -> Result<()> {
    let names: Vec<String> = h.datasets.list().map(|d| d.meta.name.clone()).collect();
    for name in names {
        let mut ds = h.datasets.get(&name).unwrap().clone();
        // Repair loop: a dataset that lost its stripe placement (cache-node
        // failure) while Caching/Ready goes back to Pending so it is
        // re-placed on healthy nodes and re-fetched from the remote copy.
        if matches!(ds.status, DatasetPhase::Caching | DatasetPhase::Ready)
            && h.cache
                .registry
                .get(&name)
                .map(|r| r.stripe.is_none())
                .unwrap_or(false)
        {
            ds.status = DatasetPhase::Pending;
            ds = h.datasets.update(ds)?;
        }
        match ds.status {
            DatasetPhase::Pending => reconcile_pending(h, ds)?,
            DatasetPhase::Caching => reconcile_caching(h, ds)?,
            DatasetPhase::Ready | DatasetPhase::Failed => {}
        }
    }
    // Deleted resources: evict + drop from cache.
    let cached: Vec<String> = h.cache.registry.iter().map(|r| r.spec.name.clone()).collect();
    for name in cached {
        if h.datasets.get(&name).is_none() {
            // Ignore pin errors: the job controller unpins on completion and
            // the next tick retries.
            let _ = h.cache.delete(&name);
        }
    }
    Ok(())
}

fn reconcile_pending(h: &mut Hoard, mut ds: Dataset) -> Result<()> {
    // 1. Register with the cache layer (idempotent across ticks).
    if h.cache.registry.get(&ds.meta.name).is_none() {
        h.cache.register(
            DatasetSpec::new(ds.meta.name.clone(), ds.num_items, ds.total_bytes),
            ds.url.clone(),
        )?;
    }
    // 2. Choose cache nodes (healthy only) and place.
    let inputs: Vec<PlacementInput> = h
        .nodes
        .iter()
        .enumerate()
        .filter(|(i, _)| h.cache.node_healthy(NodeId(*i)))
        .map(|(i, n)| PlacementInput {
            node: NodeId(i),
            gpus_free: n.gpus_free(),
            // Free space plus what the eviction policy could reclaim —
            // the cache manager performs the actual eviction at placement.
            cache_free_bytes: h.cache.volume(NodeId(i)).free()
                + h.cache.evictable_bytes_on(NodeId(i)),
        })
        .collect();
    let width = if ds.stripe_width > 0 {
        ds.stripe_width.min(inputs.len())
    } else {
        default_stripe_width(inputs.len())
    };
    let Some(nodes) = select_cache_nodes(&inputs, &h.topology, width, ds.total_bytes) else {
        ds.status = DatasetPhase::Failed;
        h.datasets.update(ds)?;
        return Ok(());
    };
    match h.cache.place(&ds.meta.name, nodes) {
        Ok(()) => {
            ds.status = DatasetPhase::Caching;
            h.datasets.update(ds)?;
        }
        Err(CacheError::Full { .. }) => {
            ds.status = DatasetPhase::Failed;
            h.datasets.update(ds)?;
        }
        Err(e) => return Err(e.into()),
    }
    Ok(())
}

fn reconcile_caching(h: &mut Hoard, mut ds: Dataset) -> Result<()> {
    // Prefetch-enabled datasets pull from the remote store every tick;
    // on-demand datasets fill as jobs read (driven by the data path).
    if ds.prefetch {
        h.cache.prefetch_tick(&ds.meta.name, h.prefetch_bytes_per_tick)?;
    }
    if matches!(h.cache.registry.get(&ds.meta.name).map(|r| &r.state), Some(DatasetState::Cached)) {
        ds.status = DatasetPhase::Ready;
        h.datasets.update(ds)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::k8s::ObjectMeta;

    fn dataset(name: &str, bytes: u64, prefetch: bool) -> Dataset {
        Dataset {
            meta: ObjectMeta::named(name),
            url: format!("nfs://storage1/{name}"),
            total_bytes: bytes,
            num_items: 1000,
            prefetch,
            stripe_width: 0,
            status: DatasetPhase::Pending,
        }
    }

    #[test]
    fn pending_to_caching_places_stripes() {
        let mut h = Hoard::paper_testbed();
        h.datasets.create(dataset("imagenet", 144e9 as u64, false)).unwrap();
        h.reconcile().unwrap();
        assert_eq!(h.datasets.get("imagenet").unwrap().status, DatasetPhase::Caching);
        let rec = h.cache.registry.get("imagenet").unwrap();
        assert_eq!(rec.stripe.as_ref().unwrap().width(), 4);
    }

    #[test]
    fn prefetch_reaches_ready() {
        let mut h = Hoard::paper_testbed();
        h.datasets.create(dataset("d", 16 << 30, true)).unwrap();
        let ticks = h.reconcile_to_fixpoint().unwrap();
        assert!(ticks >= 1);
        assert_eq!(h.datasets.get("d").unwrap().status, DatasetPhase::Ready);
        assert_eq!(h.cache.registry.get("d").unwrap().state, DatasetState::Cached);
    }

    #[test]
    fn on_demand_stays_caching_until_data_path_fills() {
        let mut h = Hoard::paper_testbed();
        h.datasets.create(dataset("d", 16 << 30, false)).unwrap();
        h.reconcile_to_fixpoint().unwrap();
        assert_eq!(h.datasets.get("d").unwrap().status, DatasetPhase::Caching);
        // Data path reports fill completion (e.g. first epoch done).
        h.cache.prefetch_tick("d", 16 << 30).unwrap();
        h.reconcile_to_fixpoint().unwrap();
        assert_eq!(h.datasets.get("d").unwrap().status, DatasetPhase::Ready);
    }

    #[test]
    fn oversized_dataset_fails() {
        let mut h = Hoard::paper_testbed(); // 4 TB aggregate
        h.datasets.create(dataset("huge", 5 << 40, true)).unwrap();
        h.reconcile_to_fixpoint().unwrap();
        assert_eq!(h.datasets.get("huge").unwrap().status, DatasetPhase::Failed);
    }

    #[test]
    fn resource_deletion_evicts() {
        let mut h = Hoard::paper_testbed();
        h.datasets.create(dataset("d", 1 << 30, true)).unwrap();
        h.reconcile_to_fixpoint().unwrap();
        assert!(h.cache.registry.get("d").is_some());
        h.datasets.delete("d").unwrap();
        h.reconcile_to_fixpoint().unwrap();
        assert!(h.cache.registry.get("d").is_none());
    }

    #[test]
    fn explicit_stripe_width_honoured() {
        let mut h = Hoard::paper_testbed();
        let mut d = dataset("d", 1 << 30, false);
        d.stripe_width = 2;
        h.datasets.create(d).unwrap();
        h.reconcile().unwrap();
        assert_eq!(h.cache.registry.get("d").unwrap().stripe.as_ref().unwrap().width(), 2);
    }
}
