//! The Hoard scheduling layer (paper §3.2): two custom-resource controllers
//! plus the co-scheduler, glued to the cache layer through the dataset
//! manager. This is the paper's *system contribution* — placement decisions
//! flow downward (controllers → dataset manager → cache), never upward.

pub mod dataset_controller;
pub mod job_controller;
pub mod placement;

pub use dataset_controller::reconcile_datasets;
pub use job_controller::reconcile_jobs;
pub use placement::{select_cache_nodes, select_compute_nodes, Locality, PlacementInput};

use crate::cache::{CacheManager, EvictionPolicy};
use crate::cluster::{NodeSpec, NodeState};
use crate::k8s::{Dataset, DlJob, Pod, Pvc, Store};
use crate::netsim::Topology;

/// The assembled control plane: object stores + cluster model + cache.
pub struct Hoard {
    pub datasets: Store<Dataset>,
    pub jobs: Store<DlJob>,
    pub pods: Store<Pod>,
    pub pvcs: Store<Pvc>,
    pub nodes: Vec<NodeState>,
    pub topology: Topology,
    pub cache: CacheManager,
    /// Remote-fetch bytes applied per reconcile tick in prefetch mode
    /// (simulated AFM gateway ingest; real mode drives this from the VFS).
    pub prefetch_bytes_per_tick: u64,
}

impl Hoard {
    pub fn new(specs: Vec<NodeSpec>, topology: Topology, policy: EvictionPolicy) -> Self {
        assert_eq!(specs.len(), topology.num_nodes());
        let volumes = specs.iter().map(|s| s.cache_volume.clone()).collect();
        Hoard {
            datasets: Store::new(),
            jobs: Store::new(),
            pods: Store::new(),
            pvcs: Store::new(),
            nodes: specs.into_iter().map(NodeState::new).collect(),
            topology,
            cache: CacheManager::new(volumes, policy),
            prefetch_bytes_per_tick: 8 << 30,
        }
    }

    /// The paper's 4-node testbed with the default manual eviction.
    pub fn paper_testbed() -> Self {
        let specs = (0..4).map(|i| NodeSpec::paper_node(format!("node{i}"))).collect();
        Hoard::new(specs, Topology::paper_testbed(), EvictionPolicy::Manual)
    }

    /// One control-plane tick: reconcile datasets, jobs, then PVCs.
    /// Deterministic and idempotent — tests drive it step by step.
    pub fn reconcile(&mut self) -> anyhow::Result<()> {
        reconcile_datasets(self)?;
        reconcile_jobs(self)?;
        crate::k8s::reconcile_pvcs(&self.cache, &mut self.pvcs)?;
        Ok(())
    }

    /// Run ticks until nothing changes (fixpoint), with a safety bound.
    pub fn reconcile_to_fixpoint(&mut self) -> anyhow::Result<u32> {
        let fingerprint = |h: &Hoard| {
            (
                h.datasets.revision(),
                h.jobs.revision(),
                h.pods.revision(),
                h.pvcs.revision(),
                h.cache.events.len(),
                h.cache.registry.resident_bytes(), // prefetch progress
            )
        };
        for tick in 0..1024 {
            let before = fingerprint(self);
            self.reconcile()?;
            if fingerprint(self) == before {
                return Ok(tick);
            }
        }
        anyhow::bail!("control plane did not reach a fixpoint in 1024 ticks")
    }
}
