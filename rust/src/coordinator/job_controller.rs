//! DL-job controller: watches `DlJob` custom resources, co-selects compute
//! nodes against the dataset's cache nodes, encodes the decision as pod
//! labels, lets the default scheduler bind pods, and manages dataset pins
//! across the job's life cycle (paper §3.1/§3.2).

use anyhow::Result;

use super::placement::{select_compute_nodes, PlacementInput};
use super::Hoard;
use crate::k8s::{labels, JobPhase, Labels, NodeInfo, ObjectMeta, Pod, PodPhase};
use crate::netsim::NodeId;

pub fn reconcile_jobs(h: &mut Hoard) -> Result<()> {
    let names: Vec<String> = h.jobs.list().map(|j| j.meta.name.clone()).collect();
    for name in names {
        let job = h.jobs.get(&name).unwrap().clone();
        match &job.status {
            JobPhase::Pending => reconcile_pending(h, job)?,
            JobPhase::Scheduled { .. } => reconcile_scheduled(h, job)?,
            JobPhase::Running | JobPhase::Succeeded | JobPhase::Failed(_) => {}
        }
    }
    Ok(())
}

fn reconcile_pending(h: &mut Hoard, mut job: crate::k8s::DlJob) -> Result<()> {
    // The dataset must exist and be placed before compute is chosen —
    // co-scheduling requires knowing where the stripes live.
    let Some(rec) = h.cache.registry.get(&job.dataset) else {
        return Ok(()); // dataset resource not reconciled yet; retry next tick
    };
    let Some(stripe) = rec.stripe.as_ref() else {
        return Ok(());
    };
    let cache_nodes: Vec<NodeId> = stripe.nodes().to_vec();

    // Free GPUs minus reservations held by pods that are created but not
    // yet bound by the default scheduler — otherwise several jobs decided
    // in the same tick would all pick the same "free" node and deadlock on
    // their own node-pinning labels.
    let mut pending_gpus = vec![0u32; h.nodes.len()];
    for p in h.pods.list().filter(|p| p.phase == PodPhase::Pending) {
        if let Some(target) = p.node_selector.get(labels::NODE) {
            if let Some(idx) = target.strip_prefix("node").and_then(|s| s.parse::<usize>().ok()) {
                if idx < pending_gpus.len() {
                    pending_gpus[idx] += p.gpus;
                }
            }
        }
    }
    let inputs: Vec<PlacementInput> = h
        .nodes
        .iter()
        .enumerate()
        .map(|(i, n)| PlacementInput {
            node: NodeId(i),
            gpus_free: n.gpus_free().saturating_sub(pending_gpus[i]),
            cache_free_bytes: h.cache.volume(NodeId(i)).free(),
        })
        .collect();
    let Some(placement) =
        select_compute_nodes(&inputs, &h.topology, &cache_nodes, job.replicas, job.gpus)
    else {
        job.status = JobPhase::Failed("insufficient GPUs".into());
        h.jobs.update(job)?;
        return Ok(());
    };

    // Pin the dataset for the job's lifetime (Requirement 2 life cycle).
    h.cache.registry.pin(&job.dataset)?;

    // Encode decisions as pod labels; the default scheduler binds them.
    let mut nodes = vec![];
    for (ri, (node, _loc)) in placement.iter().enumerate() {
        let mut selector = Labels::new();
        selector.insert(labels::NODE.into(), format!("node{}", node.0));
        selector.insert(
            labels::PREFERRED_RACK.into(),
            format!("rack{}", h.topology.rack_of(*node).0),
        );
        h.pods.create(Pod {
            meta: ObjectMeta::named(format!("{}-{ri}", job.meta.name)),
            job: job.meta.name.clone(),
            gpus: job.gpus,
            node_selector: selector,
            assigned_node: None,
            phase: PodPhase::Pending,
        })?;
        nodes.push(node.0);
    }
    job.status = JobPhase::Scheduled { nodes };
    h.jobs.update(job)?;
    Ok(())
}

fn reconcile_scheduled(h: &mut Hoard, mut job: crate::k8s::DlJob) -> Result<()> {
    // Run the default scheduler over this job's pending pods.
    let racks: Vec<usize> = (0..h.nodes.len())
        .map(|i| h.topology.rack_of(NodeId(i)).0)
        .collect();
    let mut infos = NodeInfo::from_states(&h.nodes, &racks);
    let mut pods: Vec<Pod> = h
        .pods
        .list()
        .filter(|p| p.job == job.meta.name)
        .cloned()
        .collect();
    let mut all_running = true;
    for p in pods.iter_mut() {
        if p.phase == PodPhase::Pending {
            match crate::k8s::schedule_pod(p, &mut infos) {
                Ok(node) => {
                    h.nodes[node].allocate_gpus(p.gpus)?;
                    h.pods.update(p.clone())?;
                }
                Err(_) => {
                    all_running = false; // retry next tick
                }
            }
        }
    }
    if all_running && pods.iter().all(|p| h.pods.get(&p.meta.name).unwrap().phase == PodPhase::Running) {
        job.status = JobPhase::Running;
        h.jobs.update(job)?;
    }
    Ok(())
}

/// Mark a running job finished: release GPUs, unpin the dataset, succeed
/// pods. Called by the workload driver when training completes.
pub fn complete_job(h: &mut Hoard, name: &str) -> Result<()> {
    let Some(job) = h.jobs.get(name).cloned() else {
        anyhow::bail!("job '{name}' not found");
    };
    let pods: Vec<Pod> = h.pods.list().filter(|p| p.job == name).cloned().collect();
    for mut p in pods {
        if let Some(node) = p.assigned_node {
            if p.phase == PodPhase::Running {
                h.nodes[node].release_gpus(p.gpus);
            }
        }
        p.phase = PodPhase::Succeeded;
        h.pods.update(p)?;
    }
    h.cache.registry.unpin(&job.dataset)?;
    let mut job = h.jobs.get(name).unwrap().clone();
    job.status = JobPhase::Succeeded;
    h.jobs.update(job)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::k8s::{Dataset, DatasetPhase, DlJob};

    fn dataset(name: &str, bytes: u64) -> Dataset {
        Dataset {
            meta: ObjectMeta::named(name),
            url: format!("nfs://storage1/{name}"),
            total_bytes: bytes,
            num_items: 1000,
            prefetch: true,
            stripe_width: 0,
            status: DatasetPhase::Pending,
        }
    }

    fn dljob(name: &str, dataset: &str, replicas: u32, gpus: u32) -> DlJob {
        DlJob {
            meta: ObjectMeta::named(name),
            dataset: dataset.into(),
            gpus,
            replicas,
            container_image: "tf-cnn-bench:latest".into(),
            mount_path: "/data".into(),
            epochs: 2,
            status: JobPhase::Pending,
        }
    }

    #[test]
    fn job_waits_for_dataset_then_runs_colocated() {
        let mut h = Hoard::paper_testbed();
        h.jobs.create(dljob("j0", "imagenet", 1, 4)).unwrap();
        h.reconcile().unwrap();
        // No dataset yet: still pending.
        assert_eq!(h.jobs.get("j0").unwrap().status, JobPhase::Pending);

        h.datasets.create(dataset("imagenet", 144e9 as u64)).unwrap();
        h.reconcile_to_fixpoint().unwrap();
        let job = h.jobs.get("j0").unwrap();
        assert_eq!(job.status, JobPhase::Running);
        let pod = h.pods.get("j0-0").unwrap();
        let node = pod.assigned_node.unwrap();
        // Dataset striped over all 4 nodes ⇒ every placement is node-local.
        let rec = h.cache.registry.get("imagenet").unwrap();
        assert!(rec.stripe.as_ref().unwrap().contains(NodeId(node)));
        assert_eq!(rec.pin_count, 1);
    }

    #[test]
    fn four_jobs_fill_the_testbed() {
        let mut h = Hoard::paper_testbed();
        h.datasets.create(dataset("imagenet", 144e9 as u64)).unwrap();
        for i in 0..4 {
            h.jobs.create(dljob(&format!("j{i}"), "imagenet", 1, 4)).unwrap();
        }
        h.reconcile_to_fixpoint().unwrap();
        let mut nodes_used: Vec<usize> = h
            .pods
            .list()
            .map(|p| p.assigned_node.expect("all pods scheduled"))
            .collect();
        nodes_used.sort_unstable();
        assert_eq!(nodes_used, vec![0, 1, 2, 3], "one 4-GPU job per node");
        assert_eq!(h.cache.registry.get("imagenet").unwrap().pin_count, 4);
    }

    #[test]
    fn gpu_exhaustion_fails_job() {
        let mut h = Hoard::paper_testbed();
        h.datasets.create(dataset("d", 1 << 30)).unwrap();
        for i in 0..4 {
            h.jobs.create(dljob(&format!("j{i}"), "d", 1, 4)).unwrap();
        }
        h.reconcile_to_fixpoint().unwrap();
        h.jobs.create(dljob("j-extra", "d", 1, 4)).unwrap();
        h.reconcile_to_fixpoint().unwrap();
        assert!(matches!(h.jobs.get("j-extra").unwrap().status, JobPhase::Failed(_)));
    }

    #[test]
    fn completion_releases_and_unpins() {
        let mut h = Hoard::paper_testbed();
        h.datasets.create(dataset("d", 1 << 30)).unwrap();
        h.jobs.create(dljob("j0", "d", 2, 4)).unwrap();
        h.reconcile_to_fixpoint().unwrap();
        assert_eq!(h.jobs.get("j0").unwrap().status, JobPhase::Running);
        complete_job(&mut h, "j0").unwrap();
        assert_eq!(h.jobs.get("j0").unwrap().status, JobPhase::Succeeded);
        assert_eq!(h.cache.registry.get("d").unwrap().pin_count, 0);
        assert_eq!(h.nodes.iter().map(|n| n.gpus_free()).sum::<u32>(), 16);
        // Data remains cached for returning jobs (Requirement 2).
        assert!(h.cache.registry.get("d").unwrap().stripe.is_some());
    }

    #[test]
    fn hyperparameter_sweep_reuses_cache() {
        // The paper's motivating workflow: N sequential jobs, one fetch.
        let mut h = Hoard::paper_testbed();
        h.datasets.create(dataset("d", 4 << 30)).unwrap();
        h.reconcile_to_fixpoint().unwrap();
        let fetch_events = |h: &Hoard| {
            h.cache
                .events
                .iter()
                .filter(|e| matches!(e, crate::cache::CacheEvent::Placed { .. }))
                .count()
        };
        assert_eq!(fetch_events(&h), 1);
        for round in 0..3 {
            let jn = format!("sweep-{round}");
            h.jobs.create(dljob(&jn, "d", 1, 4)).unwrap();
            h.reconcile_to_fixpoint().unwrap();
            assert_eq!(h.jobs.get(&jn).unwrap().status, JobPhase::Running);
            complete_job(&mut h, &jn).unwrap();
        }
        assert_eq!(fetch_events(&h), 1, "dataset must be placed exactly once");
    }
}
