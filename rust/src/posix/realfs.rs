//! Real-file data path: a cluster of per-node cache directories plus a
//! throttled remote-store directory, with three mount flavours matching the
//! Figure 3 systems:
//!
//!  * [`RemoteMount`] — the REM baseline: every read hits the throttled
//!    remote store.
//!  * [`LocalMount`]  — the NVMe baseline: dataset pre-copied to the
//!    reader's node directory.
//!  * [`HoardMount`]  — the cache: reads resolve through the
//!    `CacheManager` (local stripe / peer / AFM remote-fill) and misses
//!    populate the cache, exactly the transparent-caching behaviour of
//!    §3.2 but with real bytes.

use std::fs;
use std::io::Read as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::throttle::TokenBucket;
use crate::cache::{CacheManager, ReadLocation};
use crate::netsim::NodeId;
use crate::workload::datagen::DataGenConfig;

/// On-disk layout for a real-mode cluster.
#[derive(Debug)]
pub struct RealCluster {
    pub root: PathBuf,
    pub remote_dir: PathBuf,
    pub node_dirs: Vec<PathBuf>,
    /// Shared remote-store bandwidth (the "NFS server").
    pub remote_bw: Mutex<TokenBucket>,
    /// Bytes served per source, for the e2e report.
    pub stats: Mutex<ReadStats>,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct ReadStats {
    pub remote_bytes: u64,
    pub local_bytes: u64,
    pub peer_bytes: u64,
    pub remote_reads: u64,
    pub local_reads: u64,
    pub peer_reads: u64,
}

impl RealCluster {
    /// Create (or reuse) the directory layout under `root`.
    pub fn create(root: impl AsRef<Path>, nodes: usize, remote_bw: f64) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let remote_dir = root.join("remote-store");
        fs::create_dir_all(&remote_dir)?;
        let mut node_dirs = vec![];
        for i in 0..nodes {
            let d = root.join(format!("node{i}-cache"));
            fs::create_dir_all(&d)?;
            node_dirs.push(d);
        }
        Ok(RealCluster {
            root,
            remote_dir,
            node_dirs,
            remote_bw: Mutex::new(TokenBucket::new(remote_bw, remote_bw / 4.0)),
            stats: Mutex::new(ReadStats::default()),
        })
    }

    pub fn num_nodes(&self) -> usize {
        self.node_dirs.len()
    }

    /// Throttled read from the remote store.
    pub fn read_remote(&self, rel: &Path) -> Result<Vec<u8>> {
        let path = self.remote_dir.join(rel);
        let mut buf = Vec::new();
        fs::File::open(&path)
            .with_context(|| format!("remote open {}", path.display()))?
            .read_to_end(&mut buf)?;
        self.remote_bw.lock().unwrap().take(buf.len() as u64);
        let mut s = self.stats.lock().unwrap();
        s.remote_bytes += buf.len() as u64;
        s.remote_reads += 1;
        Ok(buf)
    }

    /// Unthrottled read from a node cache dir (NVMe-class local storage).
    pub fn read_node(&self, node: NodeId, rel: &Path, reader: NodeId) -> Result<Vec<u8>> {
        let path = self.node_dirs[node.0].join(rel);
        let mut buf = Vec::new();
        fs::File::open(&path)
            .with_context(|| format!("node{} open {}", node.0, path.display()))?
            .read_to_end(&mut buf)?;
        let mut s = self.stats.lock().unwrap();
        if node == reader {
            s.local_bytes += buf.len() as u64;
            s.local_reads += 1;
        } else {
            s.peer_bytes += buf.len() as u64;
            s.peer_reads += 1;
        }
        Ok(buf)
    }

    pub fn write_node(&self, node: NodeId, rel: &Path, data: &[u8]) -> Result<()> {
        let path = self.node_dirs[node.0].join(rel);
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(&path, data)?;
        Ok(())
    }

    pub fn node_has(&self, node: NodeId, rel: &Path) -> bool {
        self.node_dirs[node.0].join(rel).exists()
    }

    pub fn take_stats(&self) -> ReadStats {
        std::mem::take(&mut *self.stats.lock().unwrap())
    }
}

/// A mounted dataset: item-indexed read API (what the training loop uses).
pub trait Mount {
    /// Read item `i` as seen by a trainer running on `reader`.
    fn read_item(&mut self, i: u64, reader: NodeId) -> Result<Vec<u8>>;
    fn num_items(&self) -> u64;
}

/// REM baseline: always from the throttled remote store.
pub struct RemoteMount<'a> {
    pub cluster: &'a RealCluster,
    pub cfg: DataGenConfig,
}

impl Mount for RemoteMount<'_> {
    fn read_item(&mut self, i: u64, _reader: NodeId) -> Result<Vec<u8>> {
        self.cluster.read_remote(&self.cfg.item_rel_path(i))
    }

    fn num_items(&self) -> u64 {
        self.cfg.num_items
    }
}

/// NVMe baseline: dataset pre-copied into the reader's node directory
/// (call [`LocalMount::precopy`] first — the paper excludes this from
/// training time, Table 3).
pub struct LocalMount<'a> {
    pub cluster: &'a RealCluster,
    pub cfg: DataGenConfig,
}

impl LocalMount<'_> {
    /// Copy the whole dataset from the remote store to `node`'s directory,
    /// through the remote throttle (this is what users pay per job).
    pub fn precopy(&self, node: NodeId) -> Result<u64> {
        let mut total = 0;
        for i in 0..self.cfg.num_items {
            let rel = self.cfg.item_rel_path(i);
            let data = self.cluster.read_remote(&rel)?;
            total += data.len() as u64;
            self.cluster.write_node(node, &rel, &data)?;
        }
        Ok(total)
    }
}

impl Mount for LocalMount<'_> {
    fn read_item(&mut self, i: u64, reader: NodeId) -> Result<Vec<u8>> {
        self.cluster.read_node(reader, &self.cfg.item_rel_path(i), reader)
    }

    fn num_items(&self) -> u64 {
        self.cfg.num_items
    }
}

/// The Hoard mount: placement and residency decisions come from the
/// `CacheManager`; misses fill the cache (AFM behaviour).
pub struct HoardMount<'a> {
    pub cluster: &'a RealCluster,
    pub cache: &'a mut CacheManager,
    pub dataset: String,
    pub cfg: DataGenConfig,
}

impl Mount for HoardMount<'_> {
    fn read_item(&mut self, i: u64, reader: NodeId) -> Result<Vec<u8>> {
        let rel = self.cfg.item_rel_path(i);
        // The control-plane fill front is an *estimate* (it models AFM's
        // sequential prefetch); real fills happen in the job's random read
        // order, so actual file presence on the home node is authoritative
        // — exactly how AFM consults its inode cache state.
        let home = match self.cache.read_location(&self.dataset, i, reader)? {
            ReadLocation::Local => reader,
            ReadLocation::Peer(p) => p,
            ReadLocation::RemoteFill { fill_node } => fill_node,
        };
        if self.cluster.node_has(home, &rel) {
            return self.cluster.read_node(home, &rel, reader);
        }
        let data = self.cluster.read_remote(&rel)?;
        self.cluster.write_node(home, &rel, &data)?;
        self.cache.prefetch_tick(&self.dataset, data.len() as u64)?;
        Ok(data)
    }

    fn num_items(&self) -> u64 {
        self.cfg.num_items
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::EvictionPolicy;
    use crate::storage::{Device, DeviceKind, Volume};
    use crate::workload::datagen::{self, DataGenConfig};
    use crate::workload::DatasetSpec;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hoard-realfs-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn small_cfg() -> DataGenConfig {
        DataGenConfig { num_items: 24, files_per_dir: 10, ..Default::default() }
    }

    fn setup(tag: &str, cfg: &DataGenConfig) -> (RealCluster, u64) {
        let root = tmpdir(tag);
        let cluster = RealCluster::create(&root, 4, 500e6).unwrap();
        let total = datagen::generate(&cluster.remote_dir, cfg).unwrap();
        (cluster, total)
    }

    #[test]
    fn remote_mount_reads_everything_remote() {
        let cfg = small_cfg();
        let (cluster, _) = setup("rem", &cfg);
        let mut m = RemoteMount { cluster: &cluster, cfg: cfg.clone() };
        for i in 0..cfg.num_items {
            let data = m.read_item(i, NodeId(0)).unwrap();
            assert_eq!(data.len(), cfg.record_bytes());
        }
        let s = cluster.take_stats();
        assert_eq!(s.remote_reads, cfg.num_items);
        assert_eq!(s.local_reads + s.peer_reads, 0);
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn local_mount_after_precopy_never_remote() {
        let cfg = small_cfg();
        let (cluster, total) = setup("local", &cfg);
        let mut m = LocalMount { cluster: &cluster, cfg: cfg.clone() };
        let copied = m.precopy(NodeId(1)).unwrap();
        assert_eq!(copied, total);
        cluster.take_stats();
        for i in 0..cfg.num_items {
            m.read_item(i, NodeId(1)).unwrap();
        }
        let s = cluster.take_stats();
        assert_eq!(s.remote_reads, 0);
        assert_eq!(s.local_reads, cfg.num_items);
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn hoard_mount_fills_then_serves_from_cache() {
        let cfg = small_cfg();
        let (cluster, total) = setup("hoard", &cfg);
        let vols = (0..4)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 10 << 20)]))
            .collect();
        let mut cache = CacheManager::new(vols, EvictionPolicy::Manual);
        cache
            .register(DatasetSpec::new("d", cfg.num_items, total), "nfs://r/d".into())
            .unwrap();
        cache.place("d", (0..4).map(NodeId).collect()).unwrap();

        let mut m = HoardMount { cluster: &cluster, cache: &mut cache, dataset: "d".into(), cfg: cfg.clone() };
        // Epoch 1: cold — every item comes from remote exactly once.
        for i in 0..cfg.num_items {
            m.read_item(i, NodeId(0)).unwrap();
        }
        let s1 = cluster.take_stats();
        assert_eq!(s1.remote_reads, cfg.num_items);
        // Epoch 2: warm — zero remote reads, mix of local + peer.
        for i in 0..cfg.num_items {
            m.read_item(i, NodeId(0)).unwrap();
        }
        let s2 = cluster.take_stats();
        assert_eq!(s2.remote_reads, 0, "warm epoch must not touch remote");
        assert!(s2.local_reads > 0 && s2.peer_reads > 0);
        // Striping: node 0 holds ~1/4 of items.
        let frac = s2.local_reads as f64 / cfg.num_items as f64;
        assert!((frac - 0.25).abs() < 0.1, "local fraction {frac}");
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn hoard_mount_shared_fill_across_readers() {
        // Two "jobs" on different nodes share one dataset: total remote
        // reads stay ≤ num_items (fetch-once, the Table 4 point).
        let cfg = small_cfg();
        let (cluster, total) = setup("share", &cfg);
        let vols = (0..4)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 10 << 20)]))
            .collect();
        let mut cache = CacheManager::new(vols, EvictionPolicy::Manual);
        cache
            .register(DatasetSpec::new("d", cfg.num_items, total), "nfs://r/d".into())
            .unwrap();
        cache.place("d", (0..4).map(NodeId).collect()).unwrap();
        let mut m = HoardMount { cluster: &cluster, cache: &mut cache, dataset: "d".into(), cfg: cfg.clone() };
        for i in 0..cfg.num_items {
            m.read_item(i, NodeId(0)).unwrap();
            m.read_item(i, NodeId(1)).unwrap();
        }
        let s = cluster.take_stats();
        assert!(
            s.remote_reads <= cfg.num_items,
            "remote reads {} exceed fetch-once bound {}",
            s.remote_reads,
            cfg.num_items
        );
        fs::remove_dir_all(&cluster.root).unwrap();
    }
}
