//! Real-file data path: a cluster of per-node cache directories plus a
//! throttled remote-store directory, with three mount flavours matching the
//! Figure 3 systems:
//!
//!  * [`RemoteMount`] — the REM baseline: every read hits the throttled
//!    remote store.
//!  * [`LocalMount`]  — the NVMe baseline: dataset pre-copied to the
//!    reader's node directory.
//!  * [`HoardMount`]  — the cache: reads resolve through the
//!    `CacheManager` (local stripe / peer / AFM remote-fill) and misses
//!    populate the cache, exactly the transparent-caching behaviour of
//!    §3.2 but with real bytes.
//!
//! Concurrency model (the Hoard claim under test — many GPUs streaming
//! from striped local disks in parallel, §3.2/Table 3):
//!
//!  * one [`SharedTokenBucket`] **per node** models that node's NVMe
//!    bandwidth — parallel readers on different stripes never contend on a
//!    shared lock;
//!  * one shared remote bucket models the NFS server, optionally re-rated
//!    per concurrent reader through a [`RemoteStore`] concurrency curve
//!    (`effective_bw`), so piling readers onto remote degrades aggregate
//!    bandwidth exactly like the fluid model;
//!  * all token waits sleep **outside** any lock ([`SharedTokenBucket`]);
//!  * stats are sharded: threaded readers record into their own
//!    [`ReadStats`] and merge on epoch end ([`RealCluster::merge_stats`]),
//!    while the single-threaded mounts keep the old behaviour of recording
//!    into the cluster-wide accumulator per read;
//!  * non-local chunk segments move through a
//!    [`ChunkTransport`](crate::peer::ChunkTransport)
//!    ([`ChunkedMount::with_transport`]); the default
//!    [`DirTransport`](crate::peer::DirTransport) is the same-FS peer-dir
//!    read, the degenerate case. ([`HoardMount`] is the single-threaded
//!    whole-file baseline and stays dir-based by construction.)

use std::fs;
use std::io::{Read as _, Seek as _, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{Context, Result};

use super::throttle::SharedTokenBucket;
use crate::cache::{CacheManager, ChunkGeometry, ReadLocation};
use crate::netsim::NodeId;
use crate::peer::{ChunkTransport, DirTransport};
use crate::remote::{RemoteReaderGauge, RemoteStore};
use crate::workload::datagen::DataGenConfig;

/// On-node path of chunk `c`'s payload for dataset `dataset_id` under the
/// `chunk_bytes` grid of placement `generation`. Chunk-granular striping
/// stores one file per chunk, so presence-on-disk stays authoritative per
/// chunk exactly like per-item files are in whole-file mode. The grid's
/// chunk size is part of the path: a dataset re-placed with a different
/// `chunk_bytes` misses cleanly instead of adopting stale chunk files
/// whose byte ranges no longer line up. The dataset ID is part of the path
/// too — it is the peer protocol's wire address
/// (`GetChunk { dataset_id, generation, chunk, grid_bytes }` resolves to
/// exactly this path on the serving node), and it keeps two datasets that
/// share a grid from adopting each other's chunks. The placement
/// generation sits above the grid: files written under an evicted
/// placement live in a different `g<N>` tree, so a same-grid re-place can
/// never adopt pre-evict bytes, and the GC reclaims whole generations
/// ([`gc_dataset_chunks`]).
pub fn chunk_rel_path(dataset_id: u64, generation: u64, chunk_bytes: u64, c: u64) -> PathBuf {
    PathBuf::from(format!("chunks/d{dataset_id:04}/g{generation}/b{chunk_bytes}/c{c:07}.bin"))
}

/// Per-dataset chunk tree on a node: everything GC removes when the
/// dataset is evicted (all generations, all grids).
pub fn dataset_chunk_dir(dataset_id: u64) -> PathBuf {
    PathBuf::from(format!("chunks/d{dataset_id:04}"))
}

/// Recursively sum file sizes under `dir` (0 if it does not exist).
fn tree_bytes(dir: &Path) -> u64 {
    let Ok(entries) = fs::read_dir(dir) else { return 0 };
    let mut total = 0;
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            total += tree_bytes(&p);
        } else if let Ok(md) = e.metadata() {
            total += md.len();
        }
    }
    total
}

/// On-disk chunk GC: delete dataset `dataset_id`'s chunk trees from every
/// node directory, returning the bytes reclaimed. With
/// `keep_generation: None` the whole `chunks/d<id>/` tree goes (evict /
/// delete / node-failure cleanup); with `Some(g)` every generation
/// directory **except** `g<g>` goes (post-re-place GC of retired
/// generations). Missing trees are fine — GC is idempotent and best-effort
/// (a file vanishing mid-walk is already reclaimed).
pub fn gc_dataset_chunks(
    cluster: &RealCluster,
    dataset_id: u64,
    keep_generation: Option<u64>,
) -> u64 {
    let mut reclaimed = 0u64;
    for nd in &cluster.node_dirs {
        let droot = nd.join(dataset_chunk_dir(dataset_id));
        match keep_generation {
            None => {
                let bytes = tree_bytes(&droot);
                if fs::remove_dir_all(&droot).is_ok() {
                    reclaimed += bytes;
                }
            }
            Some(keep) => {
                let keep_name = format!("g{keep}");
                let Ok(entries) = fs::read_dir(&droot) else { continue };
                for e in entries.flatten() {
                    let p = e.path();
                    if p.is_dir() && e.file_name() != *keep_name.as_str() {
                        let bytes = tree_bytes(&p);
                        if fs::remove_dir_all(&p).is_ok() {
                            reclaimed += bytes;
                        }
                    }
                }
            }
        }
    }
    reclaimed
}

/// Per-node chunk GC: delete dataset `dataset_id`'s chunk tree from
/// **one** node's directory only, returning the bytes reclaimed. This is
/// the `Degraded` cleanup: a failed node's chunks are unreachable and get
/// reclaimed, while the survivors' trees keep serving untouched (no full
/// cold start). Idempotent and best-effort like [`gc_dataset_chunks`].
pub fn gc_node_chunks(cluster: &RealCluster, node: NodeId, dataset_id: u64) -> u64 {
    let Some(nd) = cluster.node_dirs.get(node.0) else { return 0 };
    let droot = nd.join(dataset_chunk_dir(dataset_id));
    let bytes = tree_bytes(&droot);
    if fs::remove_dir_all(&droot).is_ok() {
        bytes
    } else {
        0
    }
}

/// Fetch chunk `c`'s payload from the remote store — one ranged read per
/// overlapped item file — and persist it on the chunk's home node.
/// Recording residency (SharedCache vs `&mut CacheManager`) is the
/// caller's job; this is the single implementation of chunk assembly both
/// the concurrent pool and [`ChunkedMount`] share.
pub fn fetch_chunk_payload(
    cluster: &RealCluster,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    c: u64,
    stats: &mut ReadStats,
) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    fetch_chunk_payload_into(cluster, cfg, geom, c, &mut buf, stats)?;
    Ok(buf)
}

/// [`fetch_chunk_payload`] into a caller-provided (reusable) buffer: the
/// buffer is cleared and filled with chunk `c`'s payload, each per-item
/// sub-range read straight into its final position (single-copy; no
/// per-part temporaries). Pair with a [`super::BufPool`] so steady-state
/// fills recycle chunk-sized allocations.
pub fn fetch_chunk_payload_into(
    cluster: &RealCluster,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    c: u64,
    buf: &mut Vec<u8>,
    stats: &mut ReadStats,
) -> Result<()> {
    let (cs, ce) = geom.chunk_range(c);
    buf.clear();
    buf.reserve((ce - cs) as usize);
    for i in geom.items_of_chunk(c) {
        let (is_, ie) = geom.item_range(i);
        if is_ == ie {
            continue;
        }
        let lo = cs.max(is_);
        let hi = ce.min(ie);
        let pos = buf.len();
        buf.resize(pos + (hi - lo) as usize, 0);
        cluster.read_remote_range_into_sharded(
            &cfg.item_rel_path(i),
            lo - is_,
            &mut buf[pos..],
            stats,
        )?;
    }
    cluster.write_node(
        geom.node_of_chunk(c),
        &chunk_rel_path(geom.dataset_id, geom.generation, geom.chunk_bytes(), c),
        buf,
    )?;
    Ok(())
}

/// Default per-node cache-volume bandwidth (NVMe class). High enough to be
/// invisible to the existing correctness tests; benches lower it (or add
/// per-read latency) to surface the scaling behaviour.
const DEFAULT_NODE_BW: f64 = 2e9;
const DEFAULT_NODE_BURST: f64 = 64e6;

/// Shared handle to an on-disk cluster: a cheap `Arc` clone, so the
/// per-node [`DataPlane`](crate::posix::dataplane::DataPlane), its
/// [`JobSession`](crate::posix::dataplane::JobSession)s, reader pools and
/// tests can all hold the same cluster without borrow lifetimes. All state
/// lives in [`ClusterState`]; `Deref` keeps field access
/// (`cluster.remote_dir`, `cluster.node_bw[n]`) working unchanged.
#[derive(Debug, Clone)]
pub struct RealCluster {
    inner: std::sync::Arc<ClusterState>,
}

impl std::ops::Deref for RealCluster {
    type Target = ClusterState;

    fn deref(&self) -> &ClusterState {
        &self.inner
    }
}

/// On-disk layout for a real-mode cluster (owned by [`RealCluster`]).
#[derive(Debug)]
pub struct ClusterState {
    pub root: PathBuf,
    pub remote_dir: PathBuf,
    pub node_dirs: Vec<PathBuf>,
    /// Shared remote-store bandwidth (the "NFS server"), fair-shared by
    /// every concurrent reader and the background prefetcher.
    pub remote_bw: SharedTokenBucket,
    /// Per-node cache-volume bandwidth (one bucket per NVMe volume).
    pub node_bw: Vec<SharedTokenBucket>,
    /// Concurrency model for the remote store: when set, the remote
    /// bucket's aggregate rate follows `effective_bw(active_readers)`.
    remote_model: Option<Box<dyn RemoteStore>>,
    /// Live count of in-flight remote readers (per-reader accounting).
    pub remote_readers: RemoteReaderGauge,
    /// Simulated per-request service time on node reads, microseconds
    /// (seek + syscall + FS client overhead). Zero by default.
    node_read_latency_us: AtomicU64,
    /// Simulated per-request service time on remote reads, microseconds.
    remote_read_latency_us: AtomicU64,
    /// Bytes served per source, for the e2e report (the cluster-wide
    /// accumulator; threaded readers merge their shards into it).
    pub stats: Mutex<ReadStats>,
}

#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct ReadStats {
    pub remote_bytes: u64,
    pub local_bytes: u64,
    /// Peer bytes served by reading the peer's directory on the same
    /// filesystem (the `DirTransport` degenerate case).
    pub peer_bytes: u64,
    /// Peer bytes that crossed the node interconnect (socket transport) —
    /// split from `peer_bytes` so the network leg is visible on its own.
    pub peer_net_bytes: u64,
    /// Bytes served straight from the in-memory `RamTier` (one memcpy, no
    /// chunk-file open) — split from `local_bytes` so the disk-local vs
    /// RAM-local mix is visible on its own.
    pub ram_bytes: u64,
    pub remote_reads: u64,
    pub local_reads: u64,
    pub peer_reads: u64,
    /// Socket-peer requests, split from the disk-peer `peer_reads`.
    pub peer_net_reads: u64,
    /// Segments served from the `RamTier`, split from the disk-local
    /// `local_reads`.
    pub ram_hits: u64,
    /// Peer requests that failed at the connection level (dead peer):
    /// refused, reset, or timed out after the bounded redial. Each one
    /// produced a degradation decision, never a wrong byte.
    pub peer_failures: u64,
    /// Segments re-planned as remote fills because their serving peer was
    /// down — the visible cost of surviving node death mid-epoch.
    pub degraded_reads: u64,
    /// Seconds spent waiting on the shared remote bucket.
    pub remote_wait_s: f64,
    /// Units (chunks or item files) a prefetcher fetched from the remote
    /// store through the fill ledger (adoptions of already-on-disk data
    /// excluded). Not a read — excluded from `total_reads`.
    pub prefetch_issued: u64,
    /// Demand reads that landed on a slot a prefetcher had filled and
    /// whose credit was still unconsumed — each prefetched unit yields at
    /// most one hit, so `hits ≤ issued` always.
    pub prefetch_hits: u64,
    /// Prefetched units no reader consumed by epoch end (fetched, never
    /// read) — the clairvoyant scheduler's windowing keeps this at 0 for
    /// full epochs; the blind pass can waste under partial orders.
    pub prefetch_wasted: u64,
}

impl ReadStats {
    /// Fold another shard into this one (epoch-end merge).
    pub fn merge(&mut self, other: &ReadStats) {
        self.remote_bytes += other.remote_bytes;
        self.local_bytes += other.local_bytes;
        self.peer_bytes += other.peer_bytes;
        self.peer_net_bytes += other.peer_net_bytes;
        self.ram_bytes += other.ram_bytes;
        self.remote_reads += other.remote_reads;
        self.local_reads += other.local_reads;
        self.peer_reads += other.peer_reads;
        self.peer_net_reads += other.peer_net_reads;
        self.ram_hits += other.ram_hits;
        self.peer_failures += other.peer_failures;
        self.degraded_reads += other.degraded_reads;
        self.remote_wait_s += other.remote_wait_s;
        self.prefetch_issued += other.prefetch_issued;
        self.prefetch_hits += other.prefetch_hits;
        self.prefetch_wasted += other.prefetch_wasted;
    }

    pub fn total_reads(&self) -> u64 {
        self.remote_reads + self.local_reads + self.peer_reads + self.peer_net_reads
            + self.ram_hits
    }

    pub fn total_bytes(&self) -> u64 {
        self.remote_bytes + self.local_bytes + self.peer_bytes + self.peer_net_bytes
            + self.ram_bytes
    }
}

impl RealCluster {
    /// Create (or reuse) the directory layout under `root`.
    pub fn create(root: impl AsRef<Path>, nodes: usize, remote_bw: f64) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let remote_dir = root.join("remote-store");
        fs::create_dir_all(&remote_dir)?;
        let mut node_dirs = vec![];
        for i in 0..nodes {
            let d = root.join(format!("node{i}-cache"));
            fs::create_dir_all(&d)?;
            node_dirs.push(d);
        }
        let node_bw = (0..nodes)
            .map(|_| SharedTokenBucket::new(DEFAULT_NODE_BW, DEFAULT_NODE_BURST))
            .collect();
        Ok(RealCluster {
            inner: std::sync::Arc::new(ClusterState {
                root,
                remote_dir,
                node_dirs,
                remote_bw: SharedTokenBucket::new(remote_bw, remote_bw / 4.0),
                node_bw,
                remote_model: None,
                remote_readers: RemoteReaderGauge::default(),
                node_read_latency_us: AtomicU64::new(0),
                remote_read_latency_us: AtomicU64::new(0),
                stats: Mutex::new(ReadStats::default()),
            }),
        })
    }

    /// Attach a remote-store concurrency model: the shared remote bucket's
    /// rate is re-derived from `effective_bw(active_readers)` on every
    /// remote read, giving per-reader effective-bandwidth accounting.
    /// Builder-style: must run before the handle is cloned/shared.
    pub fn with_remote_model(mut self, model: Box<dyn RemoteStore>) -> Self {
        let state = std::sync::Arc::get_mut(&mut self.inner)
            .expect("with_remote_model must run before the cluster handle is shared");
        state.remote_bw.set_rate(model.peak_bw());
        state.remote_model = Some(model);
        self
    }

    /// Point the shared remote store at a pre-generated directory (sweep
    /// points reuse one dataset across runs). Builder-style: must run
    /// before the handle is cloned/shared.
    pub fn set_remote_dir(&mut self, dir: PathBuf) {
        std::sync::Arc::get_mut(&mut self.inner)
            .expect("set_remote_dir must run before the cluster handle is shared")
            .remote_dir = dir;
    }

    /// Set per-request service time for node (NVMe) reads.
    pub fn set_node_read_latency(&self, d: Duration) {
        self.node_read_latency_us.store(d.as_micros() as u64, Ordering::Relaxed);
    }

    /// Set per-request service time for remote reads.
    pub fn set_remote_read_latency(&self, d: Duration) {
        self.remote_read_latency_us.store(d.as_micros() as u64, Ordering::Relaxed);
    }

    /// Re-rate every per-node bucket (e.g. to model slower cache volumes).
    pub fn set_node_bandwidth(&self, bytes_per_s: f64) {
        for b in &self.node_bw {
            b.set_rate(bytes_per_s);
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.node_dirs.len()
    }

    /// Throttled read from the remote store, recording into the
    /// cluster-wide stats (single-threaded callers).
    pub fn read_remote(&self, rel: &Path) -> Result<Vec<u8>> {
        let mut shard = ReadStats::default();
        let data = self.read_remote_sharded(rel, &mut shard)?;
        self.merge_stats(&shard);
        Ok(data)
    }

    /// Throttle + account one remote request of `n` bytes (shared bucket,
    /// concurrency-degraded rate, per-request latency, caller's shard).
    fn remote_account(&self, n: u64, stats: &mut ReadStats) {
        let active = self.remote_readers.enter();
        if let Some(model) = &self.remote_model {
            // Aggregate NFS bandwidth degrades with concurrent seeky
            // readers; every in-flight reader shares the degraded rate
            // through the one bucket.
            self.remote_bw.set_rate(model.effective_bw(active));
        }
        let waited = self.remote_bw.acquire(n);
        self.remote_readers.exit();
        if let Some(model) = &self.remote_model {
            // Re-rate for the remaining concurrency so idle-period refill
            // does not keep accruing at this burst's degraded rate.
            self.remote_bw.set_rate(model.effective_bw(self.remote_readers.active().max(1)));
        }
        let lat = self.remote_read_latency_us.load(Ordering::Relaxed);
        if lat > 0 {
            std::thread::sleep(Duration::from_micros(lat));
        }
        stats.remote_bytes += n;
        stats.remote_reads += 1;
        stats.remote_wait_s += waited.as_secs_f64();
    }

    /// Throttled read from the remote store, recording into the caller's
    /// own stats shard (concurrent readers; no shared-stats lock taken).
    pub fn read_remote_sharded(&self, rel: &Path, stats: &mut ReadStats) -> Result<Vec<u8>> {
        let path = self.remote_dir.join(rel);
        let mut buf = Vec::new();
        fs::File::open(&path)
            .with_context(|| format!("remote open {}", path.display()))?
            .read_to_end(&mut buf)?;
        self.remote_account(buf.len() as u64, stats);
        Ok(buf)
    }

    /// Ranged remote read into a caller-provided buffer: fills `out`
    /// exactly from `offset` of `rel` (single-copy — the assembly path
    /// reads each segment straight into its final position; the
    /// chunk-fill path fetches per-item sub-ranges, not whole files).
    /// This is the **one** canonical ranged remote read: the allocating
    /// variants were delegating shims and are gone — callers size their
    /// own buffer (usually from a [`super::BufPool`]).
    pub fn read_remote_range_into_sharded(
        &self,
        rel: &Path,
        offset: u64,
        out: &mut [u8],
        stats: &mut ReadStats,
    ) -> Result<()> {
        let path = self.remote_dir.join(rel);
        let mut f = fs::File::open(&path)
            .with_context(|| format!("remote open {}", path.display()))?;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(out).with_context(|| {
            format!("remote short read {}+{} {}", offset, out.len(), path.display())
        })?;
        self.remote_account(out.len() as u64, stats);
        Ok(())
    }

    /// Read from a node cache dir (NVMe-class local storage), through that
    /// node's own token bucket, recording into the cluster-wide stats.
    pub fn read_node(&self, node: NodeId, rel: &Path, reader: NodeId) -> Result<Vec<u8>> {
        let mut shard = ReadStats::default();
        let data = self.read_node_sharded(node, rel, reader, &mut shard)?;
        self.merge_stats(&shard);
        Ok(data)
    }

    /// Throttle + account one node (NVMe) request of `n` bytes.
    fn node_account(&self, node: NodeId, n: u64, reader: NodeId, stats: &mut ReadStats) {
        self.node_bw[node.0].acquire(n);
        let lat = self.node_read_latency_us.load(Ordering::Relaxed);
        if lat > 0 {
            std::thread::sleep(Duration::from_micros(lat));
        }
        if node == reader {
            stats.local_bytes += n;
            stats.local_reads += 1;
        } else {
            stats.peer_bytes += n;
            stats.peer_reads += 1;
        }
    }

    /// Node read recording into the caller's own stats shard.
    pub fn read_node_sharded(
        &self,
        node: NodeId,
        rel: &Path,
        reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Vec<u8>> {
        let path = self.node_dirs[node.0].join(rel);
        let mut buf = Vec::new();
        fs::File::open(&path)
            .with_context(|| format!("node{} open {}", node.0, path.display()))?
            .read_to_end(&mut buf)?;
        self.node_account(node, buf.len() as u64, reader, stats);
        Ok(buf)
    }

    /// Ranged node read into a caller-provided buffer: fills `out` exactly
    /// from `offset` of `rel` on `node` — how the warm assembly path lands
    /// a resident local segment straight in the item buffer (one copy),
    /// and how mounts serve one chunk-aligned segment of an item. The
    /// **one** canonical ranged node read (allocating variants removed).
    pub fn read_node_range_into_sharded(
        &self,
        node: NodeId,
        rel: &Path,
        offset: u64,
        reader: NodeId,
        out: &mut [u8],
        stats: &mut ReadStats,
    ) -> Result<()> {
        let path = self.node_dirs[node.0].join(rel);
        let mut f = fs::File::open(&path)
            .with_context(|| format!("node{} open {}", node.0, path.display()))?;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(out).with_context(|| {
            format!("node{} short read {offset}+{} {}", node.0, out.len(), path.display())
        })?;
        self.node_account(node, out.len() as u64, reader, stats);
        Ok(())
    }

    pub fn write_node(&self, node: NodeId, rel: &Path, data: &[u8]) -> Result<()> {
        let path = self.node_dirs[node.0].join(rel);
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(&path, data)?;
        Ok(())
    }

    pub fn node_has(&self, node: NodeId, rel: &Path) -> bool {
        self.node_dirs[node.0].join(rel).exists()
    }

    /// Fold a per-thread shard into the cluster-wide accumulator.
    pub fn merge_stats(&self, shard: &ReadStats) {
        self.stats.lock().unwrap().merge(shard);
    }

    pub fn take_stats(&self) -> ReadStats {
        std::mem::take(&mut *self.stats.lock().unwrap())
    }
}

/// A mounted dataset: item-indexed read API (what the training loop uses).
pub trait Mount {
    /// Read item `i` as seen by a trainer running on `reader`.
    fn read_item(&mut self, i: u64, reader: NodeId) -> Result<Vec<u8>>;
    fn num_items(&self) -> u64;
}

/// REM baseline: always from the throttled remote store.
pub struct RemoteMount<'a> {
    pub cluster: &'a RealCluster,
    pub cfg: DataGenConfig,
}

impl Mount for RemoteMount<'_> {
    fn read_item(&mut self, i: u64, _reader: NodeId) -> Result<Vec<u8>> {
        self.cluster.read_remote(&self.cfg.item_rel_path(i))
    }

    fn num_items(&self) -> u64 {
        self.cfg.num_items
    }
}

/// NVMe baseline: dataset pre-copied into the reader's node directory
/// (call [`LocalMount::precopy`] first — the paper excludes this from
/// training time, Table 3).
pub struct LocalMount<'a> {
    pub cluster: &'a RealCluster,
    pub cfg: DataGenConfig,
}

impl LocalMount<'_> {
    /// Copy the whole dataset from the remote store to `node`'s directory,
    /// through the remote throttle (this is what users pay per job).
    pub fn precopy(&self, node: NodeId) -> Result<u64> {
        let mut total = 0;
        for i in 0..self.cfg.num_items {
            let rel = self.cfg.item_rel_path(i);
            let data = self.cluster.read_remote(&rel)?;
            total += data.len() as u64;
            self.cluster.write_node(node, &rel, &data)?;
        }
        Ok(total)
    }
}

impl Mount for LocalMount<'_> {
    fn read_item(&mut self, i: u64, reader: NodeId) -> Result<Vec<u8>> {
        self.cluster.read_node(reader, &self.cfg.item_rel_path(i), reader)
    }

    fn num_items(&self) -> u64 {
        self.cfg.num_items
    }
}

/// The Hoard mount: placement and residency decisions come from the
/// `CacheManager`; misses fill the cache (AFM behaviour). Single-threaded
/// (`&mut CacheManager`); the concurrent equivalent is
/// [`crate::posix::reader_pool::SharedMount`].
pub struct HoardMount<'a> {
    pub cluster: &'a RealCluster,
    pub cache: &'a mut CacheManager,
    pub dataset: String,
    pub cfg: DataGenConfig,
}

impl Mount for HoardMount<'_> {
    fn read_item(&mut self, i: u64, reader: NodeId) -> Result<Vec<u8>> {
        let rel = self.cfg.item_rel_path(i);
        // The residency bitmap tracks real fills exactly, but fills happen
        // in the job's random read order across *processes* too, so actual
        // file presence on the home node stays authoritative — exactly how
        // AFM consults its inode cache state.
        let home = match self.cache.read_location(&self.dataset, i, reader)? {
            ReadLocation::Local => reader,
            ReadLocation::Peer(p) => p,
            ReadLocation::RemoteFill { fill_node } => fill_node,
        };
        if self.cluster.node_has(home, &rel) {
            return self.cluster.read_node(home, &rel, reader);
        }
        let data = self.cluster.read_remote(&rel)?;
        self.cluster.write_node(home, &rel, &data)?;
        // Mark the item's exact chunks (not a sequential front): the
        // registry's bitmap now mirrors what is really on disk.
        self.cache.mark_item(&self.dataset, i)?;
        Ok(data)
    }

    fn num_items(&self) -> u64 {
        self.cfg.num_items
    }
}

/// Chunk-granular Hoard mount: items are assembled from chunk files, each
/// chunk homed by `node_of_chunk` and fetched from the remote store as a
/// byte *range* spanning the items it overlaps. One item can therefore be
/// served from a mix of local, peer and remote-fill segments in a single
/// `read_item` — the partial-hit behaviour whole-file caching cannot give.
/// Single-threaded (`&mut CacheManager`); the concurrent equivalent is the
/// chunked mode of [`crate::posix::reader_pool::ReaderPool`].
pub struct ChunkedMount<'a> {
    pub cluster: &'a RealCluster,
    pub cache: &'a mut CacheManager,
    pub dataset: String,
    pub cfg: DataGenConfig,
    geom: ChunkGeometry,
    /// How non-local segments are fetched (defaults to the same-FS
    /// [`DirTransport`]; swap in a `SocketTransport` for real peers).
    transport: Box<dyn ChunkTransport>,
}

impl<'a> ChunkedMount<'a> {
    pub fn new(
        cluster: &'a RealCluster,
        cache: &'a mut CacheManager,
        dataset: impl Into<String>,
        cfg: DataGenConfig,
    ) -> Result<Self> {
        let dataset = dataset.into();
        let geom = cache.geometry(&dataset)?;
        Ok(ChunkedMount {
            cluster,
            cache,
            dataset,
            cfg,
            geom,
            transport: Box::new(DirTransport),
        })
    }

    /// Route every non-local segment through `transport`.
    pub fn with_transport(mut self, transport: Box<dyn ChunkTransport>) -> Self {
        self.transport = transport;
        self
    }

    pub fn geometry(&self) -> &ChunkGeometry {
        &self.geom
    }

    /// Fetch + persist chunk `c` (shared [`fetch_chunk_payload`] path) and
    /// mark it in the residency bitmap. Returns the chunk payload.
    fn fetch_chunk(&mut self, c: u64) -> Result<Vec<u8>> {
        let mut shard = ReadStats::default();
        let buf = fetch_chunk_payload(self.cluster, &self.cfg, &self.geom, c, &mut shard)?;
        self.cluster.merge_stats(&shard);
        self.cache.mark_chunks(&self.dataset, std::iter::once(c))?;
        Ok(buf)
    }
}

impl Mount for ChunkedMount<'_> {
    fn read_item(&mut self, i: u64, reader: NodeId) -> Result<Vec<u8>> {
        let plan = self.cache.read_plan(&self.dataset, i, reader)?;
        let (s, e) = self.geom.item_range(i);
        let mut out = Vec::with_capacity((e - s) as usize);
        let chunks: Vec<u64> = self.geom.chunks_of_item(i).collect();
        debug_assert_eq!(chunks.len(), plan.segments.len());
        for (c, (seg, loc)) in chunks.into_iter().zip(plan.segments) {
            let g = &self.geom;
            let crel = chunk_rel_path(g.dataset_id, g.generation, g.chunk_bytes(), c);
            let home = self.geom.node_of_chunk(c);
            let (cs, _) = self.geom.chunk_range(c);
            let off = s + seg.start - cs; // segment offset within the chunk
            let len = seg.end - seg.start;
            // Local segments come straight off this node's disk; every
            // non-local byte moves through the transport.
            let mut shard = ReadStats::default();
            let got = if home == reader {
                if self.cluster.node_has(home, &crel) {
                    let mut buf = vec![0u8; len as usize];
                    self.cluster.read_node_range_into_sharded(
                        home, &crel, off, reader, &mut buf, &mut shard,
                    )?;
                    Some(buf)
                } else {
                    None
                }
            } else {
                match self.transport.fetch_chunk_range(
                    self.cluster,
                    &self.geom,
                    c,
                    off,
                    len,
                    reader,
                    &mut shard,
                ) {
                    Ok(got) => got,
                    // A dead peer is a degradation signal, not an error:
                    // re-plan this segment as a remote fill (byte-correct,
                    // just slower) and account the decision.
                    Err(err) if crate::peer::peer_down(&err).is_some() => {
                        shard.peer_failures += 1;
                        shard.degraded_reads += 1;
                        None
                    }
                    Err(err) => return Err(err),
                }
            };
            self.cluster.merge_stats(&shard);
            match got {
                Some(bytes) => {
                    if matches!(loc, ReadLocation::RemoteFill { .. }) {
                        // Resident chunk the bitmap missed (e.g. another
                        // mount filled it): adopt it.
                        self.cache.mark_chunks(&self.dataset, std::iter::once(c))?;
                    }
                    out.extend_from_slice(&bytes);
                }
                None => {
                    // Missing on its home node (`NotResident` from a peer,
                    // or no file locally): remote-fill and record
                    // residency.
                    let chunk_buf = self.fetch_chunk(c)?;
                    out.extend_from_slice(&chunk_buf[off as usize..(off + len) as usize]);
                }
            }
        }
        Ok(out)
    }

    fn num_items(&self) -> u64 {
        self.cfg.num_items
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::EvictionPolicy;
    use crate::storage::{Device, DeviceKind, Volume};
    use crate::workload::datagen::{self, DataGenConfig};
    use crate::workload::DatasetSpec;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hoard-realfs-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn small_cfg() -> DataGenConfig {
        DataGenConfig { num_items: 24, files_per_dir: 10, ..Default::default() }
    }

    fn setup(tag: &str, cfg: &DataGenConfig) -> (RealCluster, u64) {
        let root = tmpdir(tag);
        let cluster = RealCluster::create(&root, 4, 500e6).unwrap();
        let total = datagen::generate(&cluster.remote_dir, cfg).unwrap();
        (cluster, total)
    }

    #[test]
    fn remote_mount_reads_everything_remote() {
        let cfg = small_cfg();
        let (cluster, _) = setup("rem", &cfg);
        let mut m = RemoteMount { cluster: &cluster, cfg: cfg.clone() };
        for i in 0..cfg.num_items {
            let data = m.read_item(i, NodeId(0)).unwrap();
            assert_eq!(data.len(), cfg.record_bytes());
        }
        let s = cluster.take_stats();
        assert_eq!(s.remote_reads, cfg.num_items);
        assert_eq!(s.local_reads + s.peer_reads, 0);
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn local_mount_after_precopy_never_remote() {
        let cfg = small_cfg();
        let (cluster, total) = setup("local", &cfg);
        let mut m = LocalMount { cluster: &cluster, cfg: cfg.clone() };
        let copied = m.precopy(NodeId(1)).unwrap();
        assert_eq!(copied, total);
        cluster.take_stats();
        for i in 0..cfg.num_items {
            m.read_item(i, NodeId(1)).unwrap();
        }
        let s = cluster.take_stats();
        assert_eq!(s.remote_reads, 0);
        assert_eq!(s.local_reads, cfg.num_items);
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn hoard_mount_fills_then_serves_from_cache() {
        let cfg = small_cfg();
        let (cluster, total) = setup("hoard", &cfg);
        let vols = (0..4)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 10 << 20)]))
            .collect();
        let mut cache = CacheManager::new(vols, EvictionPolicy::Manual);
        cache
            .register(DatasetSpec::new("d", cfg.num_items, total), "nfs://r/d".into())
            .unwrap();
        cache.place("d", (0..4).map(NodeId).collect()).unwrap();

        let mut m = HoardMount {
            cluster: &cluster,
            cache: &mut cache,
            dataset: "d".into(),
            cfg: cfg.clone(),
        };
        // Epoch 1: cold — every item comes from remote exactly once.
        for i in 0..cfg.num_items {
            m.read_item(i, NodeId(0)).unwrap();
        }
        let s1 = cluster.take_stats();
        assert_eq!(s1.remote_reads, cfg.num_items);
        // Epoch 2: warm — zero remote reads, mix of local + peer.
        for i in 0..cfg.num_items {
            m.read_item(i, NodeId(0)).unwrap();
        }
        let s2 = cluster.take_stats();
        assert_eq!(s2.remote_reads, 0, "warm epoch must not touch remote");
        assert!(s2.local_reads > 0 && s2.peer_reads > 0);
        // Striping: node 0 holds ~1/4 of items.
        let frac = s2.local_reads as f64 / cfg.num_items as f64;
        assert!((frac - 0.25).abs() < 0.1, "local fraction {frac}");
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn hoard_mount_shared_fill_across_readers() {
        // Two "jobs" on different nodes share one dataset: total remote
        // reads stay ≤ num_items (fetch-once, the Table 4 point).
        let cfg = small_cfg();
        let (cluster, total) = setup("share", &cfg);
        let vols = (0..4)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 10 << 20)]))
            .collect();
        let mut cache = CacheManager::new(vols, EvictionPolicy::Manual);
        cache
            .register(DatasetSpec::new("d", cfg.num_items, total), "nfs://r/d".into())
            .unwrap();
        cache.place("d", (0..4).map(NodeId).collect()).unwrap();
        let mut m = HoardMount {
            cluster: &cluster,
            cache: &mut cache,
            dataset: "d".into(),
            cfg: cfg.clone(),
        };
        for i in 0..cfg.num_items {
            m.read_item(i, NodeId(0)).unwrap();
            m.read_item(i, NodeId(1)).unwrap();
        }
        let s = cluster.take_stats();
        assert!(
            s.remote_reads <= cfg.num_items,
            "remote reads {} exceed fetch-once bound {}",
            s.remote_reads,
            cfg.num_items
        );
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn chunked_mount_assembles_items_byte_correct() {
        let cfg = DataGenConfig { num_items: 8, files_per_dir: 10, ..Default::default() };
        let (cluster, total) = setup("chunked", &cfg);
        let vols = (0..4)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 10 << 20)]))
            .collect();
        let mut cache = CacheManager::new(vols, EvictionPolicy::Manual);
        cache.chunk_bytes = 1000; // record is 3080 B ⇒ each item spans 4–5 chunks
        cache
            .register(DatasetSpec::new("d", cfg.num_items, total), "nfs://r/d".into())
            .unwrap();
        cache.place("d", (0..4).map(NodeId).collect()).unwrap();
        let mut m = ChunkedMount::new(&cluster, &mut cache, "d", cfg.clone()).unwrap();
        assert_eq!(m.geometry().chunk_bytes(), 1000);
        // Cold epoch: items assemble byte-correct from ranged chunk fills,
        // and the remote store supplies every byte exactly once.
        for i in 0..cfg.num_items {
            let rec = m.read_item(i, NodeId(0)).unwrap();
            let (_, want) = datagen::make_record(&cfg, i);
            assert_eq!(rec, want, "item {i}");
        }
        let s1 = cluster.take_stats();
        assert_eq!(s1.remote_bytes, total, "chunk fetch-once: remote bytes == dataset");
        assert_eq!(
            cache.registry.get("d").unwrap().state,
            crate::cache::DatasetState::Cached,
            "all chunks marked ⇒ Cached"
        );
        // Warm epoch: zero remote, mixed local/peer segments, still correct.
        let mut m = ChunkedMount::new(&cluster, &mut cache, "d", cfg.clone()).unwrap();
        for i in 0..cfg.num_items {
            let rec = m.read_item(i, NodeId(0)).unwrap();
            let (_, want) = datagen::make_record(&cfg, i);
            assert_eq!(rec, want, "warm item {i}");
        }
        let s2 = cluster.take_stats();
        assert_eq!(s2.remote_reads, 0, "warm chunked epoch must not touch remote");
        assert!(s2.local_reads > 0 && s2.peer_reads > 0, "{s2:?}");
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn ranged_into_reads_slice_exactly_and_account_once() {
        let cfg = small_cfg();
        let (cluster, _) = setup("range", &cfg);
        let rel = cfg.item_rel_path(5);
        let whole = cluster.read_remote(&rel).unwrap();
        cluster.write_node(NodeId(2), &rel, &whole).unwrap();
        cluster.take_stats();
        // Remote range: exactly the requested slice, one accounted read.
        let mut a = ReadStats::default();
        let mut mid = vec![0u8; 100];
        cluster.read_remote_range_into_sharded(&rel, 10, &mut mid, &mut a).unwrap();
        assert_eq!(mid, whole[10..110]);
        assert_eq!((a.remote_reads, a.remote_bytes), (1, 100));
        // Node range: tail slice through the peer-accounted path.
        let mut b = ReadStats::default();
        let mut tail = vec![0u8; 7];
        let tail_off = whole.len() as u64 - 7;
        cluster
            .read_node_range_into_sharded(NodeId(2), &rel, tail_off, NodeId(0), &mut tail, &mut b)
            .unwrap();
        assert_eq!(tail, whole[whole.len() - 7..]);
        assert_eq!((b.peer_reads, b.peer_bytes), (1, 7));
        // Past-EOF ranges fail loudly instead of returning short data, and
        // a failed range read is never accounted.
        let mut over = vec![0u8; 10];
        let mut c = ReadStats::default();
        assert!(cluster
            .read_remote_range_into_sharded(&rel, whole.len() as u64 - 3, &mut over, &mut c)
            .is_err());
        assert!(cluster
            .read_node_range_into_sharded(
                NodeId(2),
                &rel,
                whole.len() as u64 - 3,
                NodeId(0),
                &mut over,
                &mut c
            )
            .is_err());
        assert_eq!(c, ReadStats::default(), "failed range reads are not accounted");
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn cluster_handle_clones_share_state() {
        let cfg = small_cfg();
        let (cluster, _) = setup("handle", &cfg);
        let other = cluster.clone();
        let mut shard = ReadStats::default();
        other.read_remote_sharded(&cfg.item_rel_path(0), &mut shard).unwrap();
        other.merge_stats(&shard);
        // Stats recorded through the clone are visible through the
        // original: both handles are the same cluster.
        assert_eq!(cluster.take_stats().remote_reads, 1);
        assert_eq!(other.take_stats(), ReadStats::default(), "take drained the shared state");
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn sharded_reads_do_not_touch_global_stats_until_merged() {
        let cfg = small_cfg();
        let (cluster, _) = setup("shard", &cfg);
        let mut shard = ReadStats::default();
        cluster.read_remote_sharded(&cfg.item_rel_path(0), &mut shard).unwrap();
        assert_eq!(shard.remote_reads, 1);
        assert_eq!(cluster.take_stats(), ReadStats::default(), "global untouched");
        cluster.merge_stats(&shard);
        assert_eq!(cluster.take_stats().remote_reads, 1);
        fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn remote_model_degrades_bucket_rate() {
        use crate::remote::NfsModel;
        let cfg = small_cfg();
        let root = tmpdir("model");
        let cluster = RealCluster::create(&root, 2, 1.0e9)
            .unwrap()
            .with_remote_model(Box::new(NfsModel::new(1.0e9)));
        datagen::generate(&cluster.remote_dir, &cfg).unwrap();
        // A single reader sees the peak rate.
        cluster.read_remote(&cfg.item_rel_path(0)).unwrap();
        assert!((cluster.remote_bw.rate() - 1.0e9).abs() < 1.0, "single reader ⇒ peak");
        fs::remove_dir_all(&cluster.root).unwrap();
    }
}
