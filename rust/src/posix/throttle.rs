//! Token-bucket bandwidth throttling — stands in for the NFS server's
//! limited read bandwidth (and `tc`-style throttling for the Figure 5
//! sweep) in the real-mode pipeline.

use std::time::{Duration, Instant};

/// Classic token bucket: `rate` bytes/s refill, `burst` bytes capacity.
/// `take(n)` blocks (sleeps) until n bytes of budget are available.
#[derive(Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(rate_bytes_per_s: f64, burst_bytes: f64) -> Self {
        assert!(rate_bytes_per_s > 0.0);
        TokenBucket {
            rate: rate_bytes_per_s,
            burst: burst_bytes.max(1.0),
            tokens: burst_bytes.max(1.0),
            last: Instant::now(),
        }
    }

    fn refill(&mut self) {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
    }

    /// Duration we'd need to wait before `n` bytes are available.
    pub fn wait_needed(&mut self, n: u64) -> Duration {
        self.refill();
        let deficit = n as f64 - self.tokens;
        if deficit <= 0.0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(deficit / self.rate)
        }
    }

    /// Consume `n` bytes of budget, sleeping as required.
    pub fn take(&mut self, n: u64) {
        let wait = self.wait_needed(n);
        if !wait.is_zero() {
            std::thread::sleep(wait);
            self.refill();
        }
        self.tokens -= n as f64; // may go briefly negative on rounding
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_passes_instantly() {
        let mut tb = TokenBucket::new(1000.0, 4096.0);
        let t0 = Instant::now();
        tb.take(4096);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn sustained_rate_enforced() {
        let mut tb = TokenBucket::new(100_000.0, 1000.0);
        let t0 = Instant::now();
        // 11 KB over a 100 KB/s bucket with 1 KB burst ⇒ ≥ ~0.1 s.
        for _ in 0..11 {
            tb.take(1000);
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.08, "took {dt}s, throttle too lax");
        assert!(dt < 0.5, "took {dt}s, throttle too strict");
    }

    #[test]
    fn wait_needed_scales() {
        let mut tb = TokenBucket::new(1000.0, 10.0);
        tb.take(10); // drain burst
        let w = tb.wait_needed(1000);
        assert!(w >= Duration::from_millis(900), "{w:?}");
    }
}
