//! Token-bucket bandwidth throttling — stands in for the NFS server's
//! limited read bandwidth (and `tc`-style throttling for the Figure 5
//! sweep) in the real-mode pipeline.
//!
//! Two layers:
//!  * [`TokenBucket`] — the raw single-owner bucket. The concurrency-safe
//!    primitive is [`TokenBucket::try_take`], which never sleeps; callers
//!    that hold a lock around the bucket use it plus the returned wait hint
//!    so no thread ever sleeps while holding the lock.
//!  * [`SharedTokenBucket`] — `Arc<Mutex<TokenBucket>>` with an acquire
//!    loop that always **sleeps outside the lock**; this is what the
//!    concurrent data plane (per-node NVMe buckets, the shared remote
//!    bucket) hands to reader/prefetcher threads.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Classic token bucket: `rate` bytes/s refill, `burst` bytes capacity.
#[derive(Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(rate_bytes_per_s: f64, burst_bytes: f64) -> Self {
        assert!(rate_bytes_per_s > 0.0);
        TokenBucket {
            rate: rate_bytes_per_s,
            burst: burst_bytes.max(1.0),
            tokens: burst_bytes.max(1.0),
            last: Instant::now(),
        }
    }

    fn refill(&mut self) {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
    }

    /// Duration we'd need to wait before `n` bytes are available.
    pub fn wait_needed(&mut self, n: u64) -> Duration {
        self.refill();
        let deficit = n as f64 - self.tokens;
        if deficit <= 0.0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(deficit / self.rate)
        }
    }

    /// Non-blocking take: consume `n` bytes of budget if available right
    /// now, otherwise report how long the caller should wait (outside any
    /// lock) before retrying. Never sleeps.
    pub fn try_take(&mut self, n: u64) -> Result<(), Duration> {
        self.refill();
        let need = n as f64;
        if self.tokens >= need {
            self.tokens -= need;
            Ok(())
        } else {
            Err(Duration::from_secs_f64((need - self.tokens) / self.rate))
        }
    }

    /// Consume `n` bytes of budget, sleeping as required. Single-owner
    /// convenience; concurrent callers must go through
    /// [`SharedTokenBucket::acquire`] instead so the sleep happens outside
    /// the shared lock. Requests larger than the burst are granted in
    /// burst-sized chunks, so the bucket never goes into debt.
    pub fn take(&mut self, n: u64) {
        let mut remaining = n;
        while remaining > 0 {
            let chunk = remaining.min(self.burst as u64).max(1);
            loop {
                match self.try_take(chunk) {
                    Ok(()) => break,
                    Err(wait) => std::thread::sleep(wait),
                }
            }
            remaining -= chunk;
        }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    pub fn burst(&self) -> f64 {
        self.burst
    }

    /// Re-rate the bucket (effective-bandwidth accounting: the remote
    /// store's aggregate rate degrades as concurrent readers pile up).
    /// Accrual up to now is settled at the old rate first.
    pub fn set_rate(&mut self, rate_bytes_per_s: f64) {
        assert!(rate_bytes_per_s > 0.0);
        self.refill();
        self.rate = rate_bytes_per_s;
    }
}

/// A token bucket shared between threads. All sleeping happens *outside*
/// the internal mutex: contenders only hold the lock for a `try_take`, so
/// a waiting reader never blocks the others from draining their budget.
#[derive(Debug, Clone)]
pub struct SharedTokenBucket {
    inner: Arc<Mutex<TokenBucket>>,
}

impl SharedTokenBucket {
    pub fn new(rate_bytes_per_s: f64, burst_bytes: f64) -> Self {
        let bucket = TokenBucket::new(rate_bytes_per_s, burst_bytes);
        SharedTokenBucket { inner: Arc::new(Mutex::new(bucket)) }
    }

    pub fn rate(&self) -> f64 {
        self.inner.lock().unwrap().rate()
    }

    pub fn burst(&self) -> f64 {
        self.inner.lock().unwrap().burst()
    }

    pub fn set_rate(&self, rate_bytes_per_s: f64) {
        self.inner.lock().unwrap().set_rate(rate_bytes_per_s);
    }

    /// Consume `n` bytes, sleeping (outside the lock) until granted.
    /// Returns the total time slept, so callers can account stall time.
    /// Grants happen in burst-sized chunks: total grant never exceeds
    /// `burst + rate × elapsed`, the invariant the stress tests assert.
    pub fn acquire(&self, n: u64) -> Duration {
        self.acquire_inner(n, None).expect("acquire without deadline cannot give up")
    }

    /// Non-blocking acquire: `true` if the whole request fit right now.
    /// Requests above the burst can never succeed atomically and return
    /// `false` without consuming anything.
    pub fn try_acquire(&self, n: u64) -> bool {
        let mut b = self.inner.lock().unwrap();
        if (n as f64) > b.burst() {
            return false;
        }
        b.try_take(n).is_ok()
    }

    /// Deadline acquire: like [`Self::acquire`] but gives up (returning
    /// `false`) once waiting any longer would pass `deadline`. A `false`
    /// return means the tail of the request was abandoned; the portion
    /// already granted stays consumed (callers treat this as best-effort
    /// budget, e.g. the background prefetcher backing off).
    pub fn acquire_until(&self, n: u64, deadline: Instant) -> bool {
        self.acquire_inner(n, Some(deadline)).is_ok()
    }

    /// The one pacing loop both acquire flavours share. `Ok(slept)` when
    /// fully granted; `Err(())` when the deadline cut the request short.
    /// Burst is immutable after construction, so it is read once — each
    /// grant then costs a single lock round-trip.
    fn acquire_inner(&self, n: u64, deadline: Option<Instant>) -> Result<Duration, ()> {
        let burst = self.inner.lock().unwrap().burst() as u64;
        let mut slept = Duration::ZERO;
        let mut remaining = n;
        while remaining > 0 {
            let chunk = remaining.min(burst).max(1);
            loop {
                let wait = match self.inner.lock().unwrap().try_take(chunk) {
                    Ok(()) => break,
                    Err(wait) => wait,
                };
                if let Some(d) = deadline {
                    if Instant::now() + wait > d {
                        return Err(());
                    }
                }
                // Lock released — sleep without blocking other readers.
                std::thread::sleep(wait);
                slept += wait;
            }
            remaining -= chunk;
        }
        Ok(slept)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_passes_instantly() {
        let mut tb = TokenBucket::new(1000.0, 4096.0);
        let t0 = Instant::now();
        tb.take(4096);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn sustained_rate_enforced() {
        let mut tb = TokenBucket::new(100_000.0, 1000.0);
        let t0 = Instant::now();
        // 11 KB over a 100 KB/s bucket with 1 KB burst ⇒ ≥ ~0.1 s.
        for _ in 0..11 {
            tb.take(1000);
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.08, "took {dt}s, throttle too lax");
        assert!(dt < 0.5, "took {dt}s, throttle too strict");
    }

    #[test]
    fn wait_needed_scales() {
        let mut tb = TokenBucket::new(1000.0, 10.0);
        tb.take(10); // drain burst
        let w = tb.wait_needed(1000);
        assert!(w >= Duration::from_millis(900), "{w:?}");
    }

    #[test]
    fn try_take_never_sleeps() {
        let mut tb = TokenBucket::new(1000.0, 100.0);
        let t0 = Instant::now();
        assert!(tb.try_take(100).is_ok());
        let wait = tb.try_take(500).unwrap_err();
        assert!(t0.elapsed() < Duration::from_millis(20), "try_take must not block");
        assert!(wait >= Duration::from_millis(400), "{wait:?}");
        // Nothing was consumed by the failed attempt.
        assert!(tb.wait_needed(500) >= Duration::from_millis(400));
    }

    #[test]
    fn take_larger_than_burst_chunks() {
        let mut tb = TokenBucket::new(1_000_000.0, 1000.0);
        let t0 = Instant::now();
        tb.take(5000); // 5× the burst: must still terminate, paced at rate
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.003, "5 KB minus 1 KB burst at 1 MB/s ⇒ ≥ 4 ms, got {dt}s");
        assert!(dt < 0.5);
    }

    #[test]
    fn set_rate_applies_forward() {
        let mut tb = TokenBucket::new(1000.0, 10.0);
        tb.take(10);
        tb.set_rate(1_000_000.0);
        let w = tb.wait_needed(1000);
        assert!(w < Duration::from_millis(50), "new rate must apply: {w:?}");
    }

    #[test]
    fn shared_bucket_deadline_gives_up() {
        let b = SharedTokenBucket::new(1000.0, 10.0);
        b.acquire(10); // drain
        let t0 = Instant::now();
        let ok = b.acquire_until(5000, Instant::now() + Duration::from_millis(50));
        assert!(!ok, "5 KB at 1 KB/s cannot fit a 50 ms deadline");
        assert!(t0.elapsed() < Duration::from_millis(300), "must give up promptly");
    }

    #[test]
    fn shared_bucket_try_acquire() {
        let b = SharedTokenBucket::new(1000.0, 100.0);
        assert!(b.try_acquire(100));
        assert!(!b.try_acquire(100), "drained bucket must refuse");
        assert!(!b.try_acquire(1000), "above-burst requests refuse without blocking");
    }
}
