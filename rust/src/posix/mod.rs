//! POSIX-style data path (paper Requirement 4): datasets are exposed to
//! training code as plain files. Real mode backs this with actual
//! directories — one per "node" cache volume plus a bandwidth-throttled
//! "remote store" directory — so the e2e example moves real bytes through
//! the same placement/miss logic the simulations model.

pub mod realfs;
pub mod throttle;

pub use realfs::{HoardMount, LocalMount, Mount, RealCluster, RemoteMount};
pub use throttle::TokenBucket;
