//! POSIX-style data path (paper Requirement 4): datasets are exposed to
//! training code as plain files. Real mode backs this with actual
//! directories — one per "node" cache volume plus a bandwidth-throttled
//! "remote store" directory — so the e2e example moves real bytes through
//! the same placement/miss logic the simulations model.
//!
//! The canonical concurrent API is [`dataplane`]: one shared per-node
//! [`DataPlane`] and per-job [`JobSession`]s dispatching every read
//! through [`ReadRequest`]. [`reader_pool`] keeps the pre-DataPlane
//! function surface and the [`ReaderPool`] shim.

pub mod bufpool;
pub mod dataplane;
pub mod reader_pool;
pub mod realfs;
pub mod throttle;

pub use bufpool::BufPool;
pub use dataplane::{DataPlane, Granularity, JobSession, JobSpec, ReadRequest};
pub use reader_pool::{EpochReport, FillTable, ReaderPool, SharedMount};
pub use realfs::{
    chunk_rel_path, ChunkedMount, HoardMount, LocalMount, Mount, ReadStats, RealCluster,
    RemoteMount,
};
pub use throttle::{SharedTokenBucket, TokenBucket};
