//! A small pool of reusable byte buffers for the chunk data plane.
//!
//! Chunk fills and peer fetches used to allocate a fresh `Vec<u8>` per
//! chunk (and a warm 8-reader epoch churns thousands of them). A
//! [`BufPool`] keeps a bounded stack of cleared buffers so steady-state
//! readers recycle chunk-sized allocations instead of hitting the
//! allocator per chunk. The pool is deliberately simple: one mutex popped
//! once per chunk (microseconds of file I/O dwarf it), bounded both in
//! buffer count and per-buffer capacity so a pathological payload cannot
//! pin memory forever.

use std::sync::Mutex;

/// Bounded stack of reusable buffers. `take` hands out an empty buffer
/// (pooled or fresh); `put` returns it cleared, dropping it instead when
/// the pool is full or the buffer outgrew the per-buffer cap.
#[derive(Debug)]
pub struct BufPool {
    bufs: Mutex<Vec<Vec<u8>>>,
    max_bufs: usize,
    max_buf_bytes: usize,
}

impl BufPool {
    /// Keep at most `max_bufs` buffers, each of at most `max_buf_bytes`
    /// capacity (buffers that grew past the cap are dropped on `put`).
    pub fn new(max_bufs: usize, max_buf_bytes: usize) -> Self {
        BufPool { bufs: Mutex::new(Vec::new()), max_bufs, max_buf_bytes }
    }

    /// An empty buffer — recycled when the pool has one, fresh otherwise.
    pub fn take(&self) -> Vec<u8> {
        self.bufs.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a buffer to the pool (cleared; capacity kept for reuse).
    pub fn put(&self, mut buf: Vec<u8>) {
        buf.clear();
        if buf.capacity() == 0 || buf.capacity() > self.max_buf_bytes {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < self.max_bufs {
            bufs.push(buf);
        }
    }

    /// Buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_recycles_capacity() {
        let pool = BufPool::new(2, 1 << 20);
        let mut a = pool.take();
        assert_eq!(a.capacity(), 0, "fresh buffer from an empty pool");
        a.extend_from_slice(&[1u8; 4096]);
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.take();
        assert!(b.is_empty(), "pooled buffers come back cleared");
        assert_eq!(b.capacity(), cap, "capacity survives the round trip");
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn bounds_respected() {
        let pool = BufPool::new(1, 100);
        // Over the per-buffer cap: dropped, not pooled.
        pool.put(Vec::with_capacity(1000));
        assert_eq!(pool.pooled(), 0);
        // Zero-capacity buffers are not worth pooling.
        pool.put(Vec::new());
        assert_eq!(pool.pooled(), 0);
        // Count cap: the second buffer is dropped.
        pool.put(Vec::with_capacity(50));
        pool.put(Vec::with_capacity(50));
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn put_drops_buffers_grown_past_the_cap_but_keeps_cap_sized_ones() {
        let pool = BufPool::new(4, 4096);
        // A recycled buffer grown past the cap in use (a coarse-grid
        // chunk promotion resizes to the full chunk) is dropped on
        // re-insertion, not pooled forever.
        let mut b = pool.take();
        b.resize(64 << 10, 0);
        assert!(b.capacity() > 4096);
        pool.put(b);
        assert_eq!(pool.pooled(), 0, "oversized buffer must not re-enter the pool");
        // Exactly at the cap is still worth pooling.
        pool.put(Vec::with_capacity(4096));
        assert_eq!(pool.pooled(), 1);
        // The count bound holds even when every buffer is cap-sized.
        for _ in 0..8 {
            pool.put(Vec::with_capacity(4096));
        }
        assert_eq!(pool.pooled(), 4);
    }

    #[test]
    fn shared_across_threads() {
        let pool = std::sync::Arc::new(BufPool::new(8, 1 << 16));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = pool.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        let mut b = pool.take();
                        b.resize(1024, 7);
                        pool.put(b);
                    }
                });
            }
        });
        assert!(pool.pooled() <= 8);
    }
}
