//! The concurrent real-mode read path: the fetch-once [`FillTable`]
//! ledger, the whole-file and chunk-granular item-assembly functions, the
//! background AFM prefetch passes, and the [`ReaderPool`] epoch driver.
//!
//! This is where the reproduction actually *demonstrates* the paper's
//! parallelism claim (§3.2, Table 3's 2.1×): warm-epoch reads hit
//! per-node NVMe token buckets concurrently, while cold-epoch remote
//! fetches share the one throttled remote bucket (the NFS server does not
//! get faster because we added readers — the cache does).
//!
//! **The canonical API surface lives one module over**: a per-node
//! [`DataPlane`](super::dataplane::DataPlane) owns the shared cache,
//! fetch-once ledgers, buffer pool and transport, and per-job
//! [`JobSession`](super::dataplane::JobSession)s dispatch every read
//! through one [`ReadRequest`](super::dataplane::ReadRequest) entry point.
//! [`ReaderPool`] is kept as a thin epoch-driver shim over a private
//! `DataPlane` + one `JobSession` (the pre-DataPlane constructors and
//! call shape, unchanged), and the free functions below are the shared
//! implementation both surfaces call.
//!
//! Fetch-once is enforced by a [`FillTable`]: per-slot claim states
//! (`Empty → InFlight → Done`) sharded over S independent mutex+condvar
//! pairs (slot `i` → shard `i mod S`). The filler does its remote I/O
//! **outside** the lock; concurrent readers of the same slot park on their
//! shard's condvar until the fill lands, so the remote store sees every
//! slot exactly once no matter how many readers race — the Table 4
//! fetch-once invariant, now under real concurrency and without a global
//! lock or `notify_all` thundering herd on the warm path. Completed
//! remote fills are counted per shard ([`FillTable::fills_completed`]),
//! which is what lets co-located jobs *prove* they shared fills: J jobs
//! cold-racing one dataset end with exactly `num_chunks` fills, not
//! `J × num_chunks`.
//!
//! Warm reads take the **fast lane**: residency resolves through the
//! lock-free [`ResidencySnapshot`] (atomic loads, zero `RwLock`
//! acquisitions — [`read_item_concurrent_fast`] /
//! [`read_item_chunked_fast`]), items assemble single-copy into one
//! preallocated buffer, chunk fills recycle buffers from a [`BufPool`],
//! and resident chunks homed on the same peer are pulled with one batched
//! [`ChunkTransport::fetch_chunk_ranges`] call per peer. The `RwLock`ed
//! [`SharedCache`] stays the slow/fallback lane (cold bookkeeping,
//! retired snapshots) and the differential-testing oracle.
//!
//! The table is keyed per `(dataset, chunk)`: in whole-file mode a "chunk"
//! is an item (one slot per file, today's behaviour); in chunked mode
//! slots are the stripe's fixed-size chunks, so two readers racing on
//! *different chunks of the same item* both make progress, and a reader
//! blocked on chunk *k* no longer waits for the whole file.
//!
//! Stats are sharded: every reader (and the prefetcher) accumulates its
//! own [`ReadStats`] and the session merges them on epoch end — no shared
//! stats lock on the hot path.
//!
//! Every **non-local** byte moves through a
//! [`ChunkTransport`](crate::peer::ChunkTransport): the default
//! [`DirTransport`](crate::peer::DirTransport) reads the peer's directory
//! on the same filesystem (bit-identical to the pre-transport code), while
//! [`SocketTransport`](crate::peer::SocketTransport) crosses a real TCP
//! data plane at chunk granularity. A peer's `NotResident` answer falls
//! back to a remote fill that re-records residency. The prefetcher is
//! transport-free by design: it only moves remote→home bytes.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use super::bufpool::BufPool;
use super::dataplane::{DataPlane, Granularity, JobSession, JobSpec};
use super::realfs::{chunk_rel_path, fetch_chunk_payload_into, ReadStats, RealCluster};
use crate::cache::{ChunkGeometry, RamTier, ReadLocation, ResidencySnapshot, SharedCache};
use crate::netsim::NodeId;
use crate::peer::{ChunkTransport, DirTransport};
use crate::workload::datagen::DataGenConfig;

/// Per-item fill state for fetch-once coordination across threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FillState {
    Empty,
    InFlight,
    Done,
}

/// Outcome of [`FillTable::claim_or_wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Claim {
    /// Caller owns the fill: fetch from remote, then `complete` (or
    /// `abort` on error).
    Filler,
    /// Item is resident on its home node — read it there.
    Resident,
}

/// Shards per [`FillTable`]: slots spread round-robin over independently
/// locked shards, so readers racing on *different* chunks rarely touch the
/// same mutex, and a fill completion wakes at most one shard's waiters
/// instead of the whole pool.
const FILL_SHARDS: usize = 16;

#[derive(Debug)]
struct FillShardState {
    slots: Vec<FillState>,
    /// Shard-local Done count, so [`FillTable::done_count`] sums S
    /// counters instead of scanning every slot under one lock.
    done: u64,
    /// Shard-local count of Done transitions that were **remote fills**
    /// (`complete`), as opposed to adoptions (`mark_resident`) — the
    /// cross-job fills-shared-once evidence.
    fills: u64,
    /// Per-slot "a prefetcher filled this and no reader has consumed the
    /// credit yet" flag — the first [`FillTable::claim_or_wait_credit`]
    /// to land on the slot takes it as a `prefetch_hits` tick.
    prefetched: Vec<bool>,
    /// Shard-local count of set `prefetched` flags, so
    /// [`FillTable::prefetch_outstanding`] (the `prefetch_wasted` source)
    /// sums S counters instead of scanning slots.
    pf_out: u64,
    /// Threads currently parked on this shard's condvar — what makes
    /// `notify_one`-where-safe decidable (see [`FillTable::complete`]).
    waiters: u64,
}

#[derive(Debug)]
struct FillShard {
    state: Mutex<FillShardState>,
    cv: Condvar,
}

/// Shared fetch-once ledger for one dataset, sharded S ways: slot `i`
/// lives in shard `i mod S`, each shard its own mutex + condvar. Claiming,
/// completing and waiting only ever lock one shard, so the old global
/// `Mutex<Vec<FillState>>` bottleneck (every reader of every chunk on one
/// lock) and its `notify_all` thundering herd are both gone.
///
/// Wakeup policy (`notify_one`-where-safe): a completion with **zero**
/// registered waiters on the shard skips the syscall entirely (the common
/// warm case); with exactly **one** waiter it uses `notify_one` — even if
/// that waiter is parked on a different slot of the shard it just
/// re-checks and re-parks, and there is no second waiter to lose a wakeup
/// to; with **several** waiters (which may be parked on different slots of
/// this shard) only `notify_all` is correct, and the herd is bounded to
/// the shard.
#[derive(Debug)]
pub struct FillTable {
    shards: Vec<FillShard>,
}

impl FillTable {
    pub fn new(num_slots: u64) -> Self {
        let s = FILL_SHARDS.min(num_slots.max(1) as usize);
        let per_shard = (num_slots as usize).div_ceil(s);
        FillTable {
            shards: (0..s)
                .map(|_| FillShard {
                    state: Mutex::new(FillShardState {
                        slots: vec![FillState::Empty; per_shard],
                        done: 0,
                        fills: 0,
                        prefetched: vec![false; per_shard],
                        pf_out: 0,
                        waiters: 0,
                    }),
                    cv: Condvar::new(),
                })
                .collect(),
        }
    }

    /// Independently locked shards in this table.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, i: u64) -> (&FillShard, usize) {
        let s = self.shards.len() as u64;
        (&self.shards[(i % s) as usize], (i / s) as usize)
    }

    fn wake(shard: &FillShard, st: &FillShardState) {
        match st.waiters {
            0 => {}
            1 => shard.cv.notify_one(),
            _ => shard.cv.notify_all(),
        }
    }

    /// Claim slot `i` for filling, or wait until the in-flight fill lands.
    /// Waiting releases the shard lock (condvar), so fillers are never
    /// blocked by waiters.
    pub fn claim_or_wait(&self, i: u64) -> Claim {
        let (shard, idx) = self.shard_of(i);
        let mut st = shard.state.lock().unwrap();
        loop {
            match st.slots[idx] {
                FillState::Done => return Claim::Resident,
                FillState::Empty => {
                    st.slots[idx] = FillState::InFlight;
                    return Claim::Filler;
                }
                FillState::InFlight => {
                    st.waiters += 1;
                    st = shard.cv.wait(st).unwrap();
                    st.waiters -= 1;
                }
            }
        }
    }

    /// [`FillTable::claim_or_wait`] that also consumes the slot's
    /// prefetch credit: the second element is `true` iff the slot is
    /// `Done` *because a prefetcher filled it* and this caller is the
    /// first reader to arrive since — i.e. a `prefetch_hits` tick. The
    /// credit is taken exactly once; later readers (and co-scheduled
    /// jobs' readers) see plain residency.
    pub fn claim_or_wait_credit(&self, i: u64) -> (Claim, bool) {
        let (shard, idx) = self.shard_of(i);
        let mut st = shard.state.lock().unwrap();
        loop {
            match st.slots[idx] {
                FillState::Done => {
                    let credit = st.prefetched[idx];
                    if credit {
                        st.prefetched[idx] = false;
                        st.pf_out -= 1;
                    }
                    return (Claim::Resident, credit);
                }
                FillState::Empty => {
                    st.slots[idx] = FillState::InFlight;
                    return (Claim::Filler, false);
                }
                FillState::InFlight => {
                    st.waiters += 1;
                    st = shard.cv.wait(st).unwrap();
                    st.waiters -= 1;
                }
            }
        }
    }

    /// Non-blocking claim (the prefetcher: skip items someone is already
    /// fetching). `true` ⇒ caller owns the fill.
    pub fn try_claim(&self, i: u64) -> bool {
        let (shard, idx) = self.shard_of(i);
        let mut st = shard.state.lock().unwrap();
        if st.slots[idx] == FillState::Empty {
            st.slots[idx] = FillState::InFlight;
            true
        } else {
            false
        }
    }

    fn finish(&self, i: u64, remote_fill: bool) {
        let (shard, idx) = self.shard_of(i);
        let mut st = shard.state.lock().unwrap();
        if st.slots[idx] != FillState::Done {
            st.slots[idx] = FillState::Done;
            st.done += 1;
            if remote_fill {
                st.fills += 1;
            }
        }
        Self::wake(shard, &st);
    }

    /// Mark slot `i` done after a **remote fill** — counted in
    /// [`FillTable::fills_completed`].
    pub fn complete(&self, i: u64) {
        self.finish(i, true);
    }

    /// Mark an item resident without a fill (found on disk — adoption).
    /// Not counted as a fill.
    pub fn mark_resident(&self, i: u64) {
        self.finish(i, false);
    }

    /// [`FillTable::complete`] from a *prefetcher*: the Done slot also
    /// carries a one-shot credit the first subsequent
    /// [`FillTable::claim_or_wait_credit`] consumes as a `prefetch_hits`
    /// tick. Credits still outstanding when the epoch ends are the
    /// `prefetch_wasted` count (fetched, never read).
    pub fn complete_prefetched(&self, i: u64) {
        let (shard, idx) = self.shard_of(i);
        let mut st = shard.state.lock().unwrap();
        if st.slots[idx] != FillState::Done {
            st.slots[idx] = FillState::Done;
            st.done += 1;
            st.fills += 1;
            if !st.prefetched[idx] {
                st.prefetched[idx] = true;
                st.pf_out += 1;
            }
        }
        Self::wake(shard, &st);
    }

    /// Whether slot `i` is `Done`, without claiming anything — the node
    /// rejoin re-admission probe ([`DataPlane::recover_node`]
    /// (crate::posix::dataplane::DataPlane::recover_node) vouches a
    /// rejoined node's refilled files back into residency with it).
    pub fn is_done(&self, i: u64) -> bool {
        let (shard, idx) = self.shard_of(i);
        shard.state.lock().unwrap().slots[idx] == FillState::Done
    }

    /// Roll a failed fill back to `Empty` so another reader can retry.
    pub fn abort(&self, i: u64) {
        let (shard, idx) = self.shard_of(i);
        let mut st = shard.state.lock().unwrap();
        if st.slots[idx] == FillState::Done {
            st.done -= 1;
        }
        if st.prefetched[idx] {
            st.prefetched[idx] = false;
            st.pf_out -= 1;
        }
        st.slots[idx] = FillState::Empty;
        Self::wake(shard, &st);
    }

    /// Slots in `Done` — an O(shards) counter sum, not an O(slots) scan.
    pub fn done_count(&self) -> u64 {
        self.shards.iter().map(|s| s.state.lock().unwrap().done).sum()
    }

    /// Remote fills completed through this ledger (adoptions excluded).
    /// A monotone attempt counter: rolling a *completed* slot back with
    /// [`FillTable::abort`] does not decrement it — with J co-located
    /// jobs sharing one ledger over a cold dataset, this lands on exactly
    /// the slot count, not J× it.
    pub fn fills_completed(&self) -> u64 {
        self.shards.iter().map(|s| s.state.lock().unwrap().fills).sum()
    }

    /// Prefetch credits not yet consumed by a reader. Sampled before and
    /// after an epoch, the delta is that epoch's `prefetch_wasted`.
    pub fn prefetch_outstanding(&self) -> u64 {
        self.shards.iter().map(|s| s.state.lock().unwrap().pf_out).sum()
    }
}

/// One epoch's merged accounting.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub wall: Duration,
    /// Sum of every shard below (what `cluster.take_stats()` also sees).
    pub merged: ReadStats,
    /// One shard per reader thread, in reader order.
    pub per_reader: Vec<ReadStats>,
    /// The background prefetcher's shard, when it ran this epoch.
    pub prefetcher: Option<ReadStats>,
}

impl EpochReport {
    /// Epoch throughput; `0.0` for zero-duration epochs (smoke-mode runs
    /// can finish in ~0 ns — a 0 here beats an inf/NaN in tables and
    /// `BENCH_*.json`). One guard implementation: [`crate::util::per_sec`].
    pub fn items_per_sec(&self, items: u64) -> f64 {
        crate::util::per_sec(items, self.wall.as_secs_f64())
    }
}

/// Read item `i` through the concurrent Hoard path with the default
/// same-FS [`DirTransport`] (pre-DataPlane call shape, kept for existing
/// callers). Resolves the dataset ID per read; epoch drivers hoist that
/// lookup out of the loop (one per reader pass).
#[allow(clippy::too_many_arguments)]
pub fn read_item_concurrent(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    dataset: &str,
    cfg: &DataGenConfig,
    i: u64,
    reader: NodeId,
    stats: &mut ReadStats,
) -> Result<Vec<u8>> {
    let dataset_id = cache.dataset_id(dataset)?;
    read_item_concurrent_via(
        cluster,
        cache,
        fill,
        &DirTransport,
        dataset_id,
        dataset,
        cfg,
        i,
        reader,
        stats,
    )
}

/// Resolve the serving home of item `i`: through the lock-free residency
/// snapshot when one is live (plain atomic loads — the warm fast lane),
/// through the `RwLock`ed cache otherwise (the slow/fallback lane; also
/// taken when the snapshot retires mid-epoch, e.g. on eviction).
fn resolve_item_home(
    cache: &SharedCache,
    residency: Option<&ResidencySnapshot>,
    dataset: &str,
    i: u64,
    reader: NodeId,
) -> Result<NodeId> {
    let loc = match residency.and_then(|s| s.read_location(i, reader)) {
        Some(loc) => loc,
        None => cache.read_location(dataset, i, reader)?,
    };
    Ok(match loc {
        ReadLocation::Local => reader,
        ReadLocation::Peer(p) => p,
        ReadLocation::RemoteFill { fill_node } => fill_node,
    })
}

/// Read item `i` through the concurrent Hoard path: resolve the home node
/// via the shared cache, consult the fill table, and either serve from the
/// home node (local disk, or `transport` for non-local homes) or own the
/// remote fill. A peer's `NotResident` answer (or a vanished local file)
/// falls back to a remote fill that re-records residency. `stats` is the
/// caller's private shard. `dataset_id` is `dataset`'s stable registry ID
/// (the wire address) — callers resolve it once, not per read.
#[allow(clippy::too_many_arguments)]
pub fn read_item_concurrent_via(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    transport: &dyn ChunkTransport,
    dataset_id: u64,
    dataset: &str,
    cfg: &DataGenConfig,
    i: u64,
    reader: NodeId,
    stats: &mut ReadStats,
) -> Result<Vec<u8>> {
    read_item_concurrent_fast(
        cluster, cache, fill, transport, None, dataset_id, dataset, cfg, i, reader, stats,
    )
}

/// [`read_item_concurrent_via`] with the warm fast lane: when `residency`
/// holds a live [`ResidencySnapshot`], location resolution is pure atomic
/// loads — zero `RwLock` acquisitions per read (epoch drivers pass their
/// per-epoch snapshot here).
#[allow(clippy::too_many_arguments)]
pub fn read_item_concurrent_fast(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    transport: &dyn ChunkTransport,
    residency: Option<&ResidencySnapshot>,
    dataset_id: u64,
    dataset: &str,
    cfg: &DataGenConfig,
    i: u64,
    reader: NodeId,
    stats: &mut ReadStats,
) -> Result<Vec<u8>> {
    let rel = cfg.item_rel_path(i);
    let home = resolve_item_home(cache, residency, dataset, i, reader)?;
    // Serve from the home node: local homes read their own disk, non-local
    // homes go through the transport (every non-local byte does).
    let serve = |stats: &mut ReadStats| -> Result<Option<Vec<u8>>> {
        if home == reader {
            if cluster.node_has(home, &rel) {
                return cluster.read_node_sharded(home, &rel, reader, stats).map(Some);
            }
            return Ok(None);
        }
        match transport.fetch_item(cluster, dataset_id, &rel, i, home, reader, stats) {
            // A dead peer degrades to a remote fill (same fallback as a
            // `NotResident` answer) — byte-correct, accounted, no hang.
            Err(err) if crate::peer::peer_down(&err).is_some() => {
                stats.peer_failures += 1;
                stats.degraded_reads += 1;
                Ok(None)
            }
            other => other,
        }
    };
    let (claim, pf_hit) = fill.claim_or_wait_credit(i);
    if pf_hit {
        stats.prefetch_hits += 1;
    }
    match claim {
        Claim::Resident => match serve(stats)? {
            Some(data) => Ok(data),
            // Resident per the ledger but gone at the source (peer lost
            // it): re-fill from remote and record residency again.
            None => fill_from_remote(cluster, cache, dataset, cfg, i, home, stats),
        },
        Claim::Filler => {
            // File presence is authoritative (items may predate this pool,
            // e.g. a warm run over existing cache dirs): adopt it in both
            // the fill table and the residency bitmap (idempotent). When
            // the lock-free bitmap already records the item, the exclusive
            // registry lock is skipped entirely.
            match serve(stats) {
                Ok(Some(data)) => {
                    fill.mark_resident(i);
                    if !residency.and_then(|s| s.item_resident(i)).unwrap_or(false) {
                        cache.mark_item(dataset, i)?;
                    }
                    Ok(data)
                }
                Ok(None) => match fill_from_remote(cluster, cache, dataset, cfg, i, home, stats)
                {
                    Ok(data) => {
                        fill.complete(i);
                        Ok(data)
                    }
                    Err(e) => {
                        fill.abort(i);
                        Err(e)
                    }
                },
                Err(e) => {
                    // The adoption probe failed mid-claim: roll the claim
                    // back so another reader can retry, never deadlock.
                    fill.abort(i);
                    Err(e)
                }
            }
        }
    }
}

/// One sequential AFM prefetch pass: walk the dataset in stripe order,
/// filling whatever no reader has claimed yet. Items already in flight or
/// done are skipped without blocking, so the prefetcher stays ahead of
/// (never behind) the random-order readers. Shared by
/// [`JobSession`](super::dataplane::JobSession) and [`SharedMount`].
pub(crate) fn prefetch_items(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    dataset: &str,
    cfg: &DataGenConfig,
    stats: &mut ReadStats,
) -> Result<()> {
    for i in 0..cfg.num_items {
        if !fill.try_claim(i) {
            continue;
        }
        let home = match cache.read_location(dataset, i, NodeId(0))? {
            ReadLocation::Local => NodeId(0),
            ReadLocation::Peer(p) => p,
            ReadLocation::RemoteFill { fill_node } => fill_node,
        };
        let rel = cfg.item_rel_path(i);
        if cluster.node_has(home, &rel) {
            fill.mark_resident(i);
            cache.mark_item(dataset, i)?;
            continue;
        }
        match fill_from_remote(cluster, cache, dataset, cfg, i, home, stats) {
            Ok(_) => {
                fill.complete_prefetched(i);
                stats.prefetch_issued += 1;
            }
            Err(e) => {
                fill.abort(i);
                return Err(e);
            }
        }
    }
    Ok(())
}

/// The fill itself: remote fetch (shared throttled bucket), write to the
/// home node's stripe, and mark the item's exact chunks in the residency
/// bitmap (out-of-order fills no longer pretend to be a sequential front).
/// `pub(crate)` so the clairvoyant scheduler's whole-file target
/// ([`crate::prefetch`]) issues through the same single implementation.
pub(crate) fn fill_from_remote(
    cluster: &RealCluster,
    cache: &SharedCache,
    dataset: &str,
    cfg: &DataGenConfig,
    i: u64,
    home: NodeId,
    stats: &mut ReadStats,
) -> Result<Vec<u8>> {
    let rel = cfg.item_rel_path(i);
    let data = cluster.read_remote_sharded(&rel, stats)?;
    cluster.write_node(home, &rel, &data)?;
    cache.mark_item(dataset, i)?;
    Ok(data)
}

/// Read item `i` through the chunk-granular path with the default same-FS
/// [`DirTransport`] (pre-DataPlane call shape, kept for existing callers).
#[allow(clippy::too_many_arguments)]
pub fn read_item_chunked(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    dataset: &str,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    i: u64,
    reader: NodeId,
    stats: &mut ReadStats,
) -> Result<Vec<u8>> {
    read_item_chunked_via(
        cluster,
        cache,
        fill,
        &DirTransport,
        dataset,
        cfg,
        geom,
        i,
        reader,
        stats,
    )
}

/// Read item `i` through the chunk-granular path: every chunk the item
/// overlaps is resolved independently against the per-chunk [`FillTable`],
/// so racing readers serialize per *chunk*, not per file, and a partial
/// hit serves its resident segments from cache while only the missing
/// chunks go to remote. Local chunks come off this node's disk; every
/// non-local byte moves through `transport`, and a peer's `NotResident`
/// answer falls back to a remote fill that records residency.
#[allow(clippy::too_many_arguments)]
pub fn read_item_chunked_via(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    transport: &dyn ChunkTransport,
    dataset: &str,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    i: u64,
    reader: NodeId,
    stats: &mut ReadStats,
) -> Result<Vec<u8>> {
    read_item_chunked_fast(
        cluster, cache, fill, transport, None, None, None, dataset, cfg, geom, i, reader, stats,
    )
}

/// One pooled remote fill: fetch + persist chunk `c` through a reusable
/// buffer (from `bufs` when provided), record residency, and land the
/// `offset..offset+dst.len()` slice of the payload in `dst`. The full
/// payload is already in hand here, so the RAM tier is offered it for free
/// (second-touch admission decides whether it sticks).
#[allow(clippy::too_many_arguments)]
fn refill_segment(
    cluster: &RealCluster,
    cache: &SharedCache,
    bufs: Option<&BufPool>,
    ram: Option<&RamTier>,
    dataset: &str,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    c: u64,
    offset: u64,
    dst: &mut [u8],
    stats: &mut ReadStats,
) -> Result<()> {
    let mut buf = bufs.map(|b| b.take()).unwrap_or_default();
    let result = fetch_chunk_payload_into(cluster, cfg, geom, c, &mut buf, stats).and_then(|()| {
        cache.mark_chunks(dataset, &[c])?;
        if let Some(r) = ram {
            r.offer((geom.dataset_id, geom.generation, geom.chunk_bytes(), c), &buf);
        }
        dst.copy_from_slice(&buf[offset as usize..offset as usize + dst.len()]);
        Ok(())
    });
    if let Some(b) = bufs {
        b.put(buf);
    }
    result
}

/// [`read_item_chunked_via`] with the full warm fast lane, the path
/// session reader threads run (the whole-item case of
/// [`read_item_range_chunked_fast`]):
///
///  * **single-copy assembly** — the item buffer is allocated once and
///    every resident local segment is read straight into its final
///    position ([`RealCluster::read_node_range_into_sharded`]); remote
///    fills go through a reusable [`BufPool`] buffer instead of a fresh
///    `Vec` per chunk;
///  * **RAM-tier hits** — when the plane carries a [`RamTier`], resident
///    chunks are consulted in RAM *before* any chunk-file open: a hit is
///    one `copy_from_slice` into the final buffer (`stats.ram_hits` /
///    `stats.ram_bytes`), a repeated disk miss promotes the whole chunk
///    (second-touch admission), and fills offer their payloads on the way
///    through;
///  * **batched peer fetches** — resident non-local chunks are grouped by
///    home node during the claim walk and pulled with one
///    [`ChunkTransport::fetch_chunk_ranges`] call per peer (one wire round
///    trip per peer for `SocketTransport`, bit-identical serial reads for
///    `DirTransport`). Filler chunks are handled inline, exactly as
///    before, so no Filler claim is ever held across a blocking
///    `claim_or_wait` — the fetch-once protocol stays deadlock-free by
///    construction;
///  * **snapshot-aware adoption** — when the lock-free `residency` bitmap
///    already records an adopted chunk, the exclusive registry lock is
///    skipped.
#[allow(clippy::too_many_arguments)]
pub fn read_item_chunked_fast(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    transport: &dyn ChunkTransport,
    residency: Option<&ResidencySnapshot>,
    bufs: Option<&BufPool>,
    ram: Option<&RamTier>,
    dataset: &str,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    i: u64,
    reader: NodeId,
    stats: &mut ReadStats,
) -> Result<Vec<u8>> {
    let (s, e) = geom.item_range(i);
    read_item_range_chunked_fast(
        cluster,
        cache,
        fill,
        transport,
        residency,
        bufs,
        ram,
        dataset,
        cfg,
        geom,
        i,
        0,
        e - s,
        reader,
        stats,
    )
}

/// The range-aware chunk-assembly core: read the item-local byte range
/// `[lo, hi)` of item `i`. Only chunks overlapping the range are claimed
/// and touched — a sub-range read of a cold item fills exactly the chunks
/// it needs, never the whole item. `lo == 0 ∧ hi == item len` is the
/// whole-item case ([`read_item_chunked_fast`]); the unified
/// [`ReadRequest`](super::dataplane::ReadRequest) dispatch lands here for
/// every chunked read, ranged or not.
#[allow(clippy::too_many_arguments)]
pub fn read_item_range_chunked_fast(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    transport: &dyn ChunkTransport,
    residency: Option<&ResidencySnapshot>,
    bufs: Option<&BufPool>,
    ram: Option<&RamTier>,
    dataset: &str,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    i: u64,
    lo: u64,
    hi: u64,
    reader: NodeId,
    stats: &mut ReadStats,
) -> Result<Vec<u8>> {
    let residency = residency.filter(|s| !s.retired());
    let (s, e) = geom.item_range(i);
    if lo > hi || hi > e - s {
        bail!("range {lo}..{hi} out of bounds for item {i} of {} bytes", e - s);
    }
    // Global byte bounds of the requested slice.
    let (gs, ge) = (s + lo, s + hi);
    let mut out = vec![0u8; (hi - lo) as usize];
    // Deferred resident non-local segments, grouped per home node in
    // first-encounter order: (home, [(chunk, chunk_off, out_pos, len)]).
    let mut batches: Vec<(NodeId, Vec<(u64, u64, usize, u64)>)> = Vec::new();
    for c in geom.chunks_of_item(i) {
        let home = geom.node_of_chunk(c);
        let (cs, ce) = geom.chunk_range(c);
        let seg_lo = gs.max(cs);
        let seg_hi = ge.min(ce);
        if seg_lo >= seg_hi {
            // Chunk outside the requested range: not claimed, not read.
            continue;
        }
        let (off, pos, len) = (seg_lo - cs, (seg_lo - gs) as usize, seg_hi - seg_lo);
        let (claim, pf_hit) = fill.claim_or_wait_credit(c);
        if pf_hit {
            stats.prefetch_hits += 1;
        }
        match claim {
            Claim::Resident if home != reader => {
                // A tier hit beats a peer round trip too: co-scheduled jobs
                // on this plane (or an earlier refill) may have parked the
                // chunk in RAM already.
                if let Some(r) = ram {
                    let key = (geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
                    let dst = &mut out[pos..pos + len as usize];
                    if r.read_into(key, off, dst) {
                        stats.ram_hits += 1;
                        stats.ram_bytes += len;
                        continue;
                    }
                }
                match batches.iter().position(|(n, _)| *n == home) {
                    Some(k) => batches[k].1.push((c, off, pos, len)),
                    None => batches.push((home, vec![(c, off, pos, len)])),
                }
            }
            Claim::Resident => {
                let key = (geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
                let dst = &mut out[pos..pos + len as usize];
                // RAM tier first: a hit is one memcpy into the final
                // buffer — no chunk-file open at all.
                if let Some(r) = ram {
                    if r.read_into(key, off, dst) {
                        stats.ram_hits += 1;
                        stats.ram_bytes += len;
                        continue;
                    }
                }
                let crel = chunk_rel_path(geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
                if cluster.node_has(home, &crel) {
                    // Second-touch promotion: when the tier wants this
                    // chunk, read it in FULL through a pooled buffer and
                    // insert — one widened disk read funds every later RAM
                    // hit. First touches read just the segment.
                    if ram.map(|r| r.note_touch(key)).unwrap_or(false) {
                        let clen = (ce - cs) as usize;
                        let mut buf = bufs.map(|b| b.take()).unwrap_or_default();
                        buf.clear();
                        buf.resize(clen, 0);
                        let res = cluster
                            .read_node_range_into_sharded(home, &crel, 0, reader, &mut buf, stats)
                            .map(|()| {
                                ram.expect("promotion implies a tier").insert(key, &buf);
                                dst.copy_from_slice(
                                    &buf[off as usize..off as usize + dst.len()],
                                );
                            });
                        if let Some(b) = bufs {
                            b.put(buf);
                        }
                        res?;
                    } else {
                        cluster
                            .read_node_range_into_sharded(home, &crel, off, reader, dst, stats)?;
                    }
                } else {
                    // Resident per the ledger but gone at the source:
                    // re-fill from remote and re-record residency.
                    refill_segment(
                        cluster, cache, bufs, ram, dataset, cfg, geom, c, off, dst, stats,
                    )?;
                }
            }
            Claim::Filler => {
                let crel = chunk_rel_path(geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
                let dst = &mut out[pos..pos + len as usize];
                // Adoption probe: the chunk may predate this pool (warm
                // run over existing cache dirs). `Ok(false)` ⇔ the home
                // does not hold it.
                let probe: Result<bool> = if home == reader {
                    if cluster.node_has(home, &crel) {
                        cluster
                            .read_node_range_into_sharded(home, &crel, off, reader, dst, stats)
                            .map(|()| true)
                    } else {
                        Ok(false)
                    }
                } else {
                    match transport.fetch_chunk_range(cluster, geom, c, off, len, reader, stats) {
                        Ok(Some(bytes)) => {
                            if bytes.len() as u64 != len {
                                fill.abort(c);
                                bail!(
                                    "chunk {c} range read returned {} bytes, expected {len}",
                                    bytes.len()
                                );
                            }
                            dst.copy_from_slice(&bytes);
                            Ok(true)
                        }
                        Ok(None) => Ok(false),
                        // Dead peer ⇒ degrade this segment to a remote
                        // fill (the `Ok(false)` path below): byte-correct,
                        // fetch-once through the claim we already hold.
                        Err(err) if crate::peer::peer_down(&err).is_some() => {
                            stats.peer_failures += 1;
                            stats.degraded_reads += 1;
                            Ok(false)
                        }
                        Err(e) => Err(e),
                    }
                };
                match probe {
                    Ok(true) => {
                        // Adopt it in the fill table; skip the registry
                        // write when the lock-free bitmap already has it.
                        fill.mark_resident(c);
                        if !residency.map(|r| r.contains(c)).unwrap_or(false) {
                            cache.mark_chunks(dataset, &[c])?;
                        }
                    }
                    Ok(false) => {
                        match refill_segment(
                            cluster, cache, bufs, ram, dataset, cfg, geom, c, off, dst, stats,
                        ) {
                            Ok(()) => fill.complete(c),
                            Err(err) => {
                                fill.abort(c);
                                return Err(err);
                            }
                        }
                    }
                    Err(err) => {
                        // Adoption probe failed mid-claim: roll the claim
                        // back so another reader can retry, never deadlock.
                        fill.abort(c);
                        return Err(err);
                    }
                }
            }
        }
    }
    // Batched peer round: one transport call per home node covering every
    // resident chunk it serves for this item.
    for (_home, reqs) in batches {
        let trip: Vec<(u64, u64, u64)> =
            reqs.iter().map(|&(c, off, _, len)| (c, off, len)).collect();
        let got = match transport.fetch_chunk_ranges(cluster, geom, &trip, reader, stats) {
            Ok(got) => got,
            // The whole serving peer is down: re-plan every segment of
            // this batch as a remote fill. Resident chunks stay marked —
            // the refill re-lands the payload and the epoch completes
            // byte-identical, just slower.
            Err(err) if crate::peer::peer_down(&err).is_some() => {
                stats.peer_failures += 1;
                stats.degraded_reads += reqs.len() as u64;
                for (c, off, pos, len) in reqs {
                    let dst = &mut out[pos..pos + len as usize];
                    refill_segment(
                        cluster, cache, bufs, ram, dataset, cfg, geom, c, off, dst, stats,
                    )?;
                }
                continue;
            }
            Err(err) => return Err(err),
        };
        if got.len() != reqs.len() {
            // A short response must never zip-truncate into silently
            // zero-filled segments.
            bail!("batched fetch answered {} entries for {} requests", got.len(), reqs.len());
        }
        for ((c, off, pos, len), payload) in reqs.into_iter().zip(got) {
            let dst = &mut out[pos..pos + len as usize];
            match payload {
                Some(bytes) => {
                    if bytes.len() as u64 != len {
                        bail!(
                            "chunk {c} batched range read returned {} bytes, expected {len}",
                            bytes.len()
                        );
                    }
                    dst.copy_from_slice(&bytes);
                }
                // Resident per the ledger but gone at the peer: re-fill
                // from remote and re-record residency.
                None => refill_segment(
                    cluster, cache, bufs, ram, dataset, cfg, geom, c, off, dst, stats,
                )?,
            }
        }
    }
    Ok(out)
}

/// One sequential AFM prefetch pass at chunk granularity: walk the chunk
/// grid in stripe order, filling whatever no reader has claimed yet. One
/// buffer is reused across every fill of the pass (the payload is only
/// persisted, never returned), so the cold-epoch prefetcher allocates
/// once, not once per chunk.
pub(crate) fn prefetch_chunks(
    cluster: &RealCluster,
    cache: &SharedCache,
    fill: &FillTable,
    ram: Option<&RamTier>,
    dataset: &str,
    cfg: &DataGenConfig,
    geom: &ChunkGeometry,
    stats: &mut ReadStats,
) -> Result<()> {
    let mut buf = Vec::new();
    for c in 0..geom.num_chunks() {
        if !fill.try_claim(c) {
            continue;
        }
        let home = geom.node_of_chunk(c);
        let crel = chunk_rel_path(geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
        if cluster.node_has(home, &crel) {
            fill.mark_resident(c);
            cache.mark_chunks(dataset, &[c])?;
            continue;
        }
        match fetch_chunk_payload_into(cluster, cfg, geom, c, &mut buf, stats)
            .and_then(|()| cache.mark_chunks(dataset, &[c]).map_err(Into::into))
            .map(|()| {
                // The payload is in hand: let second-touch admission decide.
                if let Some(r) = ram {
                    r.offer((geom.dataset_id, geom.generation, geom.chunk_bytes(), c), &buf);
                }
            }) {
            Ok(()) => {
                fill.complete_prefetched(c);
                stats.prefetch_issued += 1;
            }
            Err(e) => {
                fill.abort(c);
                return Err(e);
            }
        }
    }
    Ok(())
}

/// N reader threads over one mounted dataset — the pre-DataPlane epoch
/// driver, kept as a **deprecated shim**: each pool owns a private
/// [`DataPlane`] with one [`JobSession`] in it and delegates everything.
/// Two pools built this way share *nothing* (each has its own fill ledger
/// and buffer pool) — exactly the old semantics. New code that wants
/// co-located jobs to share fills should hold one
/// [`DataPlane`](super::dataplane::DataPlane) and open a
/// [`JobSession`](super::dataplane::JobSession) per job instead.
pub struct ReaderPool {
    session: JobSession,
}

impl ReaderPool {
    /// Whole-file pool (deprecated shim): one fill-table slot per item
    /// file. Prefer `DataPlane::open_job` with
    /// [`Granularity::WholeFile`].
    pub fn new(
        cluster: &RealCluster,
        cache: SharedCache,
        dataset: impl Into<String>,
        cfg: DataGenConfig,
        readers: usize,
    ) -> Self {
        assert!(readers > 0, "pool needs at least one reader");
        let plane = std::sync::Arc::new(DataPlane::new(cluster.clone(), cache));
        let session = plane
            .open_job(
                JobSpec::new(dataset, cfg).readers(readers).granularity(Granularity::WholeFile),
            )
            .expect("whole-file sessions need no placement");
        ReaderPool { session }
    }

    /// Chunk-granular pool (deprecated shim): the fill table is keyed by
    /// `(dataset, chunk)` using the placed stripe's chunk grid, so racing
    /// readers fetch-once per chunk and partial items serve their resident
    /// segments. The dataset must already be placed (the geometry comes
    /// from its stripe). Prefer `DataPlane::open_job` with
    /// [`Granularity::Chunked`].
    pub fn new_chunked(
        cluster: &RealCluster,
        cache: SharedCache,
        dataset: impl Into<String>,
        cfg: DataGenConfig,
        readers: usize,
    ) -> Result<Self> {
        assert!(readers > 0, "pool needs at least one reader");
        let plane = std::sync::Arc::new(DataPlane::new(cluster.clone(), cache));
        let session = plane.open_job(
            JobSpec::new(dataset, cfg).readers(readers).granularity(Granularity::Chunked),
        )?;
        Ok(ReaderPool { session })
    }

    /// Toggle the background prefetcher (on by default).
    pub fn with_prefetch(mut self, on: bool) -> Self {
        self.session = self.session.with_prefetch(on);
        self
    }

    /// Route every non-local read through `transport` (shared by all
    /// reader threads). The prefetcher is unaffected: it only moves
    /// remote→home bytes, never peer→reader bytes.
    pub fn with_transport(mut self, transport: Box<dyn ChunkTransport>) -> Self {
        self.session = self.session.with_transport(transport);
        self
    }

    /// Tag of the active transport ("dir" / "socket").
    pub fn transport_name(&self) -> &'static str {
        self.session.transport_name()
    }

    pub fn readers(&self) -> usize {
        self.session.readers()
    }

    /// Node the `r`-th reader runs on.
    pub fn reader_node(&self, r: usize) -> NodeId {
        self.session.reader_node(r)
    }

    /// A fresh epoch permutation (Fisher–Yates over all items),
    /// deterministic in `(seed, epoch)`.
    pub fn epoch_order(&self, seed: u64, epoch: u32) -> Vec<u64> {
        self.session.epoch_order_with(seed, epoch)
    }

    /// Stream one epoch over the underlying session (see
    /// [`JobSession::run_epoch_order`]).
    pub fn run_epoch(&self, order: &[u64]) -> Result<EpochReport> {
        self.session.run_epoch_order(order)
    }

    /// The [`JobSession`] this pool drives (per-job stats live there).
    pub fn session(&self) -> &JobSession {
        &self.session
    }
}

/// Thread-safe Hoard mount: the concurrent counterpart of
/// [`super::realfs::HoardMount`]. `read_item` takes `&self`, so any number
/// of threads can stream batches while a session prefetcher (or other
/// readers) share the same [`FillTable`] fetch-once ledger. Stats go
/// straight to the cluster-wide accumulator (one merge per read).
pub struct SharedMount<'a> {
    pub cluster: &'a RealCluster,
    pub cache: SharedCache,
    pub fill: std::sync::Arc<FillTable>,
    pub dataset: String,
    pub cfg: DataGenConfig,
}

impl SharedMount<'_> {
    pub fn read_item(&self, i: u64, reader: NodeId) -> Result<Vec<u8>> {
        let mut shard = ReadStats::default();
        let data = read_item_concurrent(
            self.cluster,
            &self.cache,
            &self.fill,
            &self.dataset,
            &self.cfg,
            i,
            reader,
            &mut shard,
        )?;
        self.cluster.merge_stats(&shard);
        Ok(data)
    }

    pub fn num_items(&self) -> u64 {
        self.cfg.num_items
    }

    /// Run one sequential prefetch pass over the dataset (the AFM fill),
    /// recording into the cluster-wide stats. Intended to run on its own
    /// thread alongside readers; items claimed by readers are skipped.
    pub fn prefetch_pass(&self) -> Result<()> {
        let mut shard = ReadStats::default();
        let result = prefetch_items(
            self.cluster, &self.cache, &self.fill, &self.dataset, &self.cfg, &mut shard,
        );
        self.cluster.merge_stats(&shard);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheManager, EvictionPolicy};
    use crate::storage::{Device, DeviceKind, Volume};
    use crate::workload::datagen::{self, DataGenConfig};
    use crate::workload::DatasetSpec;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hoard-pool-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn build(tag: &str, items: u64) -> (RealCluster, SharedCache, DataGenConfig) {
        let root = tmpdir(tag);
        let cluster = RealCluster::create(&root, 4, 500e6).unwrap();
        let cfg = DataGenConfig { num_items: items, files_per_dir: 32, ..Default::default() };
        let total = datagen::generate(&cluster.remote_dir, &cfg).unwrap();
        let vols = (0..4)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
            .collect();
        let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
        manager
            .register(DatasetSpec::new("d", cfg.num_items, total), "nfs://r/d".into())
            .unwrap();
        manager.place("d", (0..4).map(NodeId).collect()).unwrap();
        (cluster, SharedCache::new(manager), cfg)
    }

    fn build_chunked(
        tag: &str,
        items: u64,
        chunk_bytes: u64,
    ) -> (RealCluster, SharedCache, DataGenConfig) {
        let root = tmpdir(tag);
        let cluster = RealCluster::create(&root, 4, 500e6).unwrap();
        let cfg = DataGenConfig { num_items: items, files_per_dir: 32, ..Default::default() };
        let total = datagen::generate(&cluster.remote_dir, &cfg).unwrap();
        let vols = (0..4)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
            .collect();
        let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
        manager.chunk_bytes = chunk_bytes;
        manager
            .register(DatasetSpec::new("d", cfg.num_items, total), "nfs://r/d".into())
            .unwrap();
        manager.place("d", (0..4).map(NodeId).collect()).unwrap();
        (cluster, SharedCache::new(manager), cfg)
    }

    #[test]
    fn chunked_pool_cold_fetches_every_byte_once_then_warms() {
        // Records are 3080 B; 1000-B chunks ⇒ each item spans 4–5 chunks
        // and most chunks straddle two items.
        let (cluster, cache, cfg) = build_chunked("cpool", 32, 1000);
        let total = cfg.num_items * cfg.record_bytes() as u64;
        let pool = ReaderPool::new_chunked(&cluster, cache.clone(), "d", cfg.clone(), 4).unwrap();
        let report = pool.run_epoch(&pool.epoch_order(5, 0)).unwrap();
        assert_eq!(
            report.merged.remote_bytes, total,
            "chunk fetch-once: remote supplies every byte exactly once"
        );
        assert!(cache.is_cached("d"), "all chunks marked ⇒ Cached");
        // Warm epoch: all segments from chunk files, zero remote.
        cluster.take_stats();
        let report = pool.run_epoch(&pool.epoch_order(5, 1)).unwrap();
        assert_eq!(report.merged.remote_reads, 0, "warm chunked epoch touched remote");
        assert!(report.prefetcher.is_none(), "prefetcher skipped once cached");
        assert!(report.merged.local_reads + report.merged.peer_reads > 0);
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn chunked_reads_assemble_byte_correct_items() {
        let (cluster, cache, cfg) = build_chunked("cbytes", 12, 777);
        let geom = cache.geometry("d").unwrap();
        let fill = FillTable::new(geom.num_chunks());
        let mut stats = ReadStats::default();
        for i in 0..cfg.num_items {
            let got = read_item_chunked(
                &cluster, &cache, &fill, "d", &cfg, &geom, i, NodeId(0), &mut stats,
            )
            .unwrap();
            let (_, want) = datagen::make_record(&cfg, i);
            assert_eq!(got, want, "item {i}");
        }
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn ranged_chunked_reads_slice_exactly_and_claim_only_overlaps() {
        let (cluster, cache, cfg) = build_chunked("crange", 8, 777);
        let geom = cache.geometry("d").unwrap();
        let fill = FillTable::new(geom.num_chunks());
        let mut stats = ReadStats::default();
        let (_, want) = datagen::make_record(&cfg, 2);
        // A sub-range spanning a chunk boundary assembles byte-exact.
        let mut ranged = |lo: u64, hi: u64| {
            read_item_range_chunked_fast(
                &cluster,
                &cache,
                &fill,
                &DirTransport,
                None,
                None,
                None,
                "d",
                &cfg,
                &geom,
                2,
                lo,
                hi,
                NodeId(0),
                &mut stats,
            )
        };
        let got = ranged(700, 900).unwrap();
        assert_eq!(got, want[700..900]);
        // Out-of-bounds / inverted ranges fail loudly.
        assert!(ranged(100, 90).is_err());
        assert!(ranged(0, 4000).is_err());
        // Only the overlapped chunks were claimed/filled.
        let (s, _) = geom.item_range(2);
        let touched: u64 = geom
            .chunks_of_item(2)
            .filter(|&c| {
                let (cs, ce) = geom.chunk_range(c);
                cs < s + 900 && ce > s + 700
            })
            .count() as u64;
        assert_eq!(fill.done_count(), touched, "untouched chunks must stay unclaimed");
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn fill_table_claims_complete_and_abort() {
        let t = FillTable::new(4);
        assert_eq!(t.claim_or_wait(0), Claim::Filler);
        assert!(!t.try_claim(0), "in-flight item is not claimable");
        t.complete(0);
        assert_eq!(t.claim_or_wait(0), Claim::Resident);
        assert!(t.try_claim(1));
        t.abort(1);
        assert!(t.try_claim(1), "aborted fill is claimable again");
        assert_eq!(t.done_count(), 1);
    }

    #[test]
    fn fill_table_shards_scale_with_slots() {
        assert_eq!(FillTable::new(1).num_shards(), 1);
        assert_eq!(FillTable::new(5).num_shards(), 5);
        assert_eq!(FillTable::new(1000).num_shards(), 16);
        // Zero-slot tables are legal (empty dataset): nothing to claim.
        assert_eq!(FillTable::new(0).done_count(), 0);
    }

    #[test]
    fn done_count_sums_shard_counters_exactly() {
        let t = FillTable::new(100);
        // Spread Done slots over every shard, including idempotent
        // re-completes and a done→abort rollback.
        for i in [0u64, 1, 15, 16, 17, 31, 63, 99] {
            t.complete(i);
            t.complete(i); // idempotent: counted once
        }
        assert_eq!(t.done_count(), 8);
        t.abort(17);
        assert_eq!(t.done_count(), 7, "abort of a Done slot decrements");
        t.abort(17); // abort of an Empty slot is a no-op for the counter
        assert_eq!(t.done_count(), 7);
        t.complete(17);
        assert_eq!(t.done_count(), 8);
    }

    #[test]
    fn fills_counter_splits_remote_fills_from_adoptions() {
        let t = FillTable::new(64);
        t.complete(0); // remote fill
        t.complete(0); // idempotent: still one fill
        t.mark_resident(1); // adoption: a Done, not a fill
        t.mark_resident(17);
        t.complete(33); // remote fill on another shard
        assert_eq!(t.done_count(), 4);
        assert_eq!(t.fills_completed(), 2, "adoptions must not count as fills");
        // complete() on an adopted slot is a no-op (already Done).
        t.complete(1);
        assert_eq!(t.fills_completed(), 2);
    }

    #[test]
    fn same_shard_different_slot_waiter_survives_unrelated_complete() {
        // Slots 0 and 16 share shard 0 of a 16-shard table. A waiter on
        // slot 16 must not be lost when slot 0 completes (the wrong-slot
        // notify_one wakes it, it re-checks and re-parks), and must wake
        // when its own slot lands.
        let t = std::sync::Arc::new(FillTable::new(32));
        assert_eq!(t.claim_or_wait(0), Claim::Filler);
        assert_eq!(t.claim_or_wait(16), Claim::Filler);
        let t2 = t.clone();
        let waiter = std::thread::spawn(move || t2.claim_or_wait(16));
        std::thread::sleep(Duration::from_millis(30));
        t.complete(0); // unrelated slot, same shard
        std::thread::sleep(Duration::from_millis(30));
        assert!(!waiter.is_finished(), "waiter on slot 16 woke for slot 0's fill");
        t.complete(16);
        assert_eq!(waiter.join().unwrap(), Claim::Resident);
    }

    #[test]
    fn fill_table_waiter_unblocks_on_complete() {
        let t = std::sync::Arc::new(FillTable::new(1));
        assert_eq!(t.claim_or_wait(0), Claim::Filler);
        let t2 = t.clone();
        let waiter = std::thread::spawn(move || t2.claim_or_wait(0));
        std::thread::sleep(Duration::from_millis(30));
        t.complete(0);
        assert_eq!(waiter.join().unwrap(), Claim::Resident);
    }

    #[test]
    fn pool_cold_epoch_fetches_each_item_once() {
        let (cluster, cache, cfg) = build("cold", 64);
        let pool = ReaderPool::new(&cluster, cache, "d", cfg.clone(), 4);
        let order = pool.epoch_order(7, 0);
        let report = pool.run_epoch(&order).unwrap();
        assert_eq!(report.merged.remote_reads, cfg.num_items, "fetch-once under concurrency");
        assert_eq!(report.per_reader.len(), 4);
        // Warm epoch: all cache, split local/peer, zero remote.
        cluster.take_stats();
        let order = pool.epoch_order(7, 1);
        let report = pool.run_epoch(&order).unwrap();
        assert_eq!(report.merged.remote_reads, 0, "warm epoch must not touch remote");
        assert_eq!(report.merged.local_reads + report.merged.peer_reads, cfg.num_items);
        assert!(report.prefetcher.is_none(), "prefetcher skipped once cached");
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn pool_merged_stats_equal_shard_sum() {
        let (cluster, cache, cfg) = build("merge", 48);
        let pool = ReaderPool::new(&cluster, cache, "d", cfg, 3);
        let order = pool.epoch_order(3, 0);
        let report = pool.run_epoch(&order).unwrap();
        let mut sum = ReadStats::default();
        for s in &report.per_reader {
            sum.merge(s);
        }
        if let Some(p) = &report.prefetcher {
            sum.merge(p);
        }
        assert_eq!(sum, report.merged);
        // And the cluster-wide accumulator saw exactly the merged shard.
        assert_eq!(cluster.take_stats(), report.merged);
        // The shim's session accumulated the same totals (job stats).
        assert_eq!(pool.session().stats(), report.merged);
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn pool_without_prefetch_is_deterministic_in_stats() {
        let (cluster, cache, cfg) = build("det", 40);
        // Run 1: cold with 2 readers, no prefetcher.
        let pool =
            ReaderPool::new(&cluster, cache.clone(), "d", cfg.clone(), 2).with_prefetch(false);
        let order = pool.epoch_order(11, 0);
        let r1 = pool.run_epoch(&order).unwrap();
        assert!(r1.prefetcher.is_none());
        // Warm runs with different reader counts: identical merged stats
        // (remote 0; local/peer split fixed by stripe × reader pinning
        // only when the partition is the same — so compare same-N runs).
        cluster.take_stats();
        let w1 = pool.run_epoch(&pool.epoch_order(11, 1)).unwrap();
        cluster.take_stats();
        let w2 = pool.run_epoch(&pool.epoch_order(11, 1)).unwrap();
        assert_eq!(w1.merged, w2.merged, "same order + same pool ⇒ same stats");
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn zero_duration_epoch_reports_zero_throughput() {
        let report = EpochReport {
            wall: Duration::ZERO,
            merged: ReadStats::default(),
            per_reader: vec![],
            prefetcher: None,
        };
        assert_eq!(report.items_per_sec(1000), 0.0, "zero wall must not yield inf/NaN");
        let report = EpochReport { wall: Duration::from_secs(2), ..report };
        assert_eq!(report.items_per_sec(1000), 500.0);
    }
}
