//! The per-node data plane and its per-job sessions — the unified
//! real-mode read API.
//!
//! One [`DataPlane`] per node fleet owns everything co-located jobs must
//! **share**: the [`SharedCache`] (placements + residency snapshots), one
//! per-`(dataset, chunk)` sharded [`FillTable`] fetch-once ledger per
//! dataset, the reusable [`BufPool`], and the default
//! [`ChunkTransport`]. Each job opens a [`JobSession`]
//! ([`DataPlane::open_job`]) carrying everything jobs must **not** share:
//! its own epoch order and seed, reader set, prefetch toggle, optional
//! transport override, and per-job accumulated [`ReadStats`].
//!
//! That split is the paper's Table 4 cross-job point made real: J
//! hyper-parameter-tuning jobs streaming one cached dataset trigger each
//! remote fill exactly **once** (the shared ledger), instead of J times
//! (the old one-`ReaderPool`-per-job world, where every pool privately
//! owned its ledger and raced the others for the same bytes) —
//! `hoard exp jobs` measures exactly this.
//!
//! Every read goes through **one** entry point: build a [`ReadRequest`]
//! (`item`, optional item-local byte `range`, optional granularity
//! `mode` check) and call [`JobSession::read`]. Snapshot fast lane vs
//! locked fallback, whole-file vs chunked assembly, dir vs socket
//! transport, buffer reuse and batched peer fetches are all internal
//! dispatch — the six historical `read_item_*` function names survive in
//! [`reader_pool`](super::reader_pool) as thin wrappers over the same
//! implementation, and [`ReaderPool`](super::reader_pool::ReaderPool) is
//! a shim that owns a private plane with one session.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::bufpool::BufPool;
use super::reader_pool::{
    prefetch_chunks, prefetch_items, read_item_concurrent_fast, read_item_range_chunked_fast,
    EpochReport, FillTable,
};
use super::realfs::{chunk_rel_path, gc_dataset_chunks, gc_node_chunks, ReadStats, RealCluster};
use crate::cache::{CacheEvent, ChunkGeometry, RamTier, ResidencySnapshot, SharedCache};
use crate::netsim::NodeId;
use crate::peer::{ChunkTransport, DirTransport};
use crate::prefetch::{
    run_clairvoyant_chunks, run_clairvoyant_items, PrefetchConfig, PrefetchStrategy, Pressure,
    ReadCursor,
};
use crate::util::Rng;
use crate::workload::datagen::DataGenConfig;

/// How a dataset is addressed by the fill ledger and on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// One fetch-once slot per item file (the degenerate case of chunking
    /// when `chunk_bytes` ≥ item size).
    WholeFile,
    /// One slot per stripe chunk: fills fetch byte ranges and readers
    /// assemble items from chunk files.
    Chunked,
}

impl Granularity {
    /// Wire/table tag ("whole-file" / "chunked").
    pub fn name(self) -> &'static str {
        match self {
            Granularity::WholeFile => "whole-file",
            Granularity::Chunked => "chunked",
        }
    }
}

/// What a job asks of the plane when it opens a session.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub dataset: String,
    /// On-disk item layout of the dataset (paths + sizes).
    pub cfg: DataGenConfig,
    pub readers: usize,
    /// Seed for this job's epoch permutations — co-located jobs keep
    /// their own stochastic read order.
    pub seed: u64,
    pub granularity: Granularity,
    /// How this job warms the cache during an epoch (see
    /// [`PrefetchStrategy`]); clairvoyant by default.
    pub prefetch: PrefetchStrategy,
    /// Lookahead/in-flight/pressure knobs for the clairvoyant scheduler
    /// (ignored by `Off`/`Sequential`).
    pub prefetch_cfg: PrefetchConfig,
}

impl JobSpec {
    /// Defaults: 1 reader, seed 0, chunked addressing, clairvoyant
    /// prefetch with default knobs.
    pub fn new(dataset: impl Into<String>, cfg: DataGenConfig) -> Self {
        JobSpec {
            dataset: dataset.into(),
            cfg,
            readers: 1,
            seed: 0,
            granularity: Granularity::Chunked,
            prefetch: PrefetchStrategy::Clairvoyant,
            prefetch_cfg: PrefetchConfig::default(),
        }
    }

    pub fn readers(mut self, n: usize) -> Self {
        self.readers = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn granularity(mut self, g: Granularity) -> Self {
        self.granularity = g;
        self
    }

    /// On/off convenience kept for existing callers: `true` ⇒ the default
    /// clairvoyant strategy, `false` ⇒ no prefetch.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch =
            if on { PrefetchStrategy::Clairvoyant } else { PrefetchStrategy::Off };
        self
    }

    /// Pick the prefetch strategy explicitly (the ablation knob).
    pub fn prefetch_strategy(mut self, s: PrefetchStrategy) -> Self {
        self.prefetch = s;
        self
    }

    /// Clairvoyant lookahead window, in epoch positions.
    pub fn lookahead(mut self, positions: u64) -> Self {
        self.prefetch_cfg.lookahead = positions;
        self
    }

    /// Clairvoyant in-flight fill budget (worker threads).
    pub fn prefetch_inflight(mut self, n: usize) -> Self {
        self.prefetch_cfg.inflight = n;
        self
    }

    /// Cache-pressure rule for the clairvoyant scheduler's ahead-bytes.
    pub fn prefetch_pressure(mut self, p: Pressure) -> Self {
        self.prefetch_cfg.pressure = p;
        self
    }
}

/// One read, in full: which item, optionally which item-local byte range,
/// optionally which granularity the caller insists on. Everything else —
/// fast lane vs locked lane, chunk assembly, transport, buffers — is the
/// session's dispatch, not the caller's function choice.
#[derive(Debug, Clone)]
pub struct ReadRequest {
    pub item: u64,
    /// Item-local byte range; `None` ⇒ the whole item. Chunked sessions
    /// claim and fill **only** the chunks the range overlaps.
    pub range: Option<std::ops::Range<u64>>,
    /// When set, the request errors unless the dataset ledger uses this
    /// granularity — an assertion for callers that depend on one
    /// addressing mode. `None` follows the ledger.
    pub mode: Option<Granularity>,
}

impl ReadRequest {
    /// Read all of item `i`.
    pub fn item(i: u64) -> Self {
        ReadRequest { item: i, range: None, mode: None }
    }

    /// Read the item-local byte range `r` of item `i`.
    pub fn range(i: u64, r: std::ops::Range<u64>) -> Self {
        ReadRequest { item: i, range: Some(r), mode: None }
    }
}

/// Why a dataset's ledger was poisoned — the lifecycle decision sessions
/// report instead of one generic "reset" message for every cause.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoisonReason {
    /// Evicted (or manually reset): the placement is gone; the dataset
    /// can be re-placed and reopened.
    Reset,
    /// Re-placed onto a new node set under a bumped generation
    /// ([`DataPlane::replace_dataset`]): reopen to read the new placement.
    Replaced,
    /// Deleted entirely — the dataset no longer exists on this plane. The
    /// API layer maps this to `410 Gone`.
    Retired,
}

const POISON_NONE: u8 = 0;
const POISON_RESET: u8 = 1;
const POISON_REPLACED: u8 = 2;
const POISON_RETIRED: u8 = 3;

/// Typed marker for reads against a **retired** (deleted) dataset, raised
/// as the source of the session error so the API layer can answer
/// `410 Gone` instead of a generic 500. Recover with
/// `anyhow::Error::downcast_ref::<DatasetRetired>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DatasetRetired {
    pub dataset: String,
}

impl std::fmt::Display for DatasetRetired {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "dataset '{}' is retired (deleted); it no longer serves reads", self.dataset)
    }
}

impl std::error::Error for DatasetRetired {}

/// Per-dataset shared state: the fetch-once ledger plus how it addresses
/// the dataset. One per dataset per plane — every session on the dataset
/// holds the same `Arc`, which is what makes fills shared.
#[derive(Debug)]
struct Ledger {
    fill: FillTable,
    mode: LedgerMode,
    /// Fetch-once slots the table was sized for (items in whole-file
    /// mode, chunks in chunked mode) — re-validated on every reuse so a
    /// mismatched `cfg` or a stale grid errors instead of indexing out
    /// of bounds.
    slots: u64,
    /// Lifecycle poison ([`PoisonReason`] as a `u8`, `POISON_NONE` while
    /// live). Set by evict / re-place / delete: sessions still holding
    /// this ledger refuse further reads with a reason-precise error
    /// instead of trusting its Done slots — the files those slots vouch
    /// for may be gone or belong to a dead placement generation. Node
    /// **degradation** deliberately does *not* poison: survivor chunks
    /// keep serving and lost chunks re-plan as remote fills.
    poison: AtomicU8,
}

impl Ledger {
    fn poison(&self, why: PoisonReason) {
        let code = match why {
            PoisonReason::Reset => POISON_RESET,
            PoisonReason::Replaced => POISON_REPLACED,
            PoisonReason::Retired => POISON_RETIRED,
        };
        self.poison.store(code, Ordering::Release);
    }

    fn poisoned(&self) -> Option<PoisonReason> {
        match self.poison.load(Ordering::Acquire) {
            POISON_RESET => Some(PoisonReason::Reset),
            POISON_REPLACED => Some(PoisonReason::Replaced),
            POISON_RETIRED => Some(PoisonReason::Retired),
            _ => None,
        }
    }
}

#[derive(Debug)]
enum LedgerMode {
    WholeFile,
    Chunked(ChunkGeometry),
}

impl LedgerMode {
    fn granularity(&self) -> Granularity {
        match self {
            LedgerMode::WholeFile => Granularity::WholeFile,
            LedgerMode::Chunked(_) => Granularity::Chunked,
        }
    }
}

/// Reusable chunk buffers kept pooled on the plane, shared by every
/// session's readers (remote fills recycle chunk-sized allocations
/// instead of one fresh `Vec` each). Bounded in count and per-buffer
/// capacity.
const PLANE_BUFS: usize = 32;
const PLANE_BUF_BYTES: usize = 64 << 20;

/// What [`DataPlane::place_dataset`] did beyond the placement itself:
/// which datasets the admission policy evicted to make room, and how many
/// on-disk chunk-tree bytes their GC freed across the cluster.
#[derive(Debug, Clone, Default)]
pub struct PlacementOutcome {
    pub evicted: Vec<String>,
    pub reclaimed_bytes: u64,
}

/// What [`DataPlane::replace_dataset`] accomplished: the new placement
/// generation, how much of the old placement was migrated warm instead of
/// re-fetched, and the old-generation bytes GC'd from disk.
#[derive(Debug, Clone, Default)]
pub struct ReplaceOutcome {
    /// Generation of the new placement (old + 1).
    pub generation: u64,
    /// Surviving chunks renamed into the new generation's trees (these
    /// never touch the remote store again).
    pub migrated_chunks: u64,
    /// Payload bytes those migrated chunk files carried.
    pub migrated_bytes: u64,
    /// Old-generation on-disk bytes GC'd after the migration.
    pub reclaimed_bytes: u64,
}

/// One shared per-node-fleet data plane: the `Arc`-owned object under
/// every co-located job. See the module docs for the ownership model.
pub struct DataPlane {
    cluster: RealCluster,
    cache: SharedCache,
    /// Default transport for every session (sessions may override their
    /// own — e.g. one socket-transport job next to dir-transport jobs).
    transport: Box<dyn ChunkTransport>,
    bufs: BufPool,
    /// Optional RAM hot-chunk tier above the NVMe chunk files, shared by
    /// every session on the plane (like the ledgers and the buffer pool):
    /// `None` ⇒ every resident read goes to the chunk files (the pre-tier
    /// behaviour, and the default).
    ram: Option<Arc<RamTier>>,
    ledgers: Mutex<HashMap<String, Arc<Ledger>>>,
    /// Dataset layouts registered for control-plane consumers (the
    /// `/v1/jobs` HTTP endpoints build `JobSpec`s from these).
    dataset_cfgs: Mutex<HashMap<String, DataGenConfig>>,
    next_job: AtomicU64,
}

impl DataPlane {
    /// A plane over `cluster` + `cache` with the same-FS
    /// [`DirTransport`] and a bounded shared buffer pool.
    pub fn new(cluster: RealCluster, cache: SharedCache) -> Self {
        DataPlane {
            cluster,
            cache,
            transport: Box::new(DirTransport),
            bufs: BufPool::new(PLANE_BUFS, PLANE_BUF_BYTES),
            ram: None,
            ledgers: Mutex::new(HashMap::new()),
            dataset_cfgs: Mutex::new(HashMap::new()),
            next_job: AtomicU64::new(0),
        }
    }

    /// Swap the plane-wide default transport (builder-style, before the
    /// plane is `Arc`-shared).
    pub fn with_transport(mut self, transport: Box<dyn ChunkTransport>) -> Self {
        self.transport = transport;
        self
    }

    /// Attach a shared [`RamTier`] holding at most `budget_bytes` of hot
    /// chunk payloads (builder-style, before the plane is `Arc`-shared).
    /// The byte budget is the tier's only knob: sized to the hot set, warm
    /// resident reads become memcpys; sized to zero, the tier admits
    /// nothing and the plane behaves as if it had none.
    pub fn with_ram_tier(mut self, budget_bytes: u64) -> Self {
        self.ram = Some(Arc::new(RamTier::new(budget_bytes)));
        self
    }

    /// The plane's RAM tier, when one is attached (`with_ram_tier`) —
    /// experiments read its counters, the peer server can serve from it.
    pub fn ram_tier(&self) -> Option<&Arc<RamTier>> {
        self.ram.as_ref()
    }

    pub fn cluster(&self) -> &RealCluster {
        &self.cluster
    }

    pub fn cache(&self) -> &SharedCache {
        &self.cache
    }

    /// Record `dataset`'s on-disk item layout so sessions can be opened
    /// by name alone (the HTTP job endpoints go through this).
    pub fn register_dataset(&self, dataset: impl Into<String>, cfg: DataGenConfig) {
        self.dataset_cfgs.lock().unwrap().insert(dataset.into(), cfg);
    }

    /// The layout registered via [`DataPlane::register_dataset`].
    pub fn dataset_cfg(&self, dataset: &str) -> Option<DataGenConfig> {
        self.dataset_cfgs.lock().unwrap().get(dataset).cloned()
    }

    /// Remote fills completed for `dataset` across **every** session on
    /// this plane (adoptions excluded). With J co-located jobs
    /// cold-racing one chunked dataset this lands on exactly
    /// `num_chunks` — the fills-shared-once evidence.
    pub fn dataset_fills(&self, dataset: &str) -> u64 {
        self.ledgers
            .lock()
            .unwrap()
            .get(dataset)
            .map(|l| l.fill.fills_completed())
            .unwrap_or(0)
    }

    /// Invalidate `dataset`'s data-plane state after evict, re-place or
    /// node failure: retire the published residency snapshot (fast-lane
    /// readers fall back to the locked lane and see the placement gone),
    /// poison the fill ledger so sessions still holding it fail loudly
    /// with a "reset" error instead of serving stale bytes, and drop the
    /// ledger so the next session opened on the dataset starts fresh.
    pub fn reset_dataset(&self, dataset: &str) {
        self.poison_dataset(dataset, PoisonReason::Reset);
    }

    /// [`DataPlane::reset_dataset`] with an explicit lifecycle reason —
    /// what sessions still holding the ledger report instead of the
    /// generic "reset" message.
    fn poison_dataset(&self, dataset: &str, why: PoisonReason) {
        if let Ok(snap) = self.cache.snapshot(dataset) {
            snap.retire();
        }
        if let Some(l) = self.ledgers.lock().unwrap().remove(dataset) {
            l.poison(why);
        }
        // Best-effort RAM drop (generation-keyed entries are unreachable
        // from the next placement anyway — this reclaims their budget).
        // `delete_dataset` loses the name→id registration before reaching
        // here and invalidates with its pre-resolved id instead.
        if let Ok(id) = self.cache.dataset_id(dataset) {
            self.invalidate_ram(id);
        }
    }

    /// Drop every RAM-tier entry of dataset `id` (no-op without a tier).
    /// Generation-keyed entries could never serve a newer placement, but
    /// eager invalidation returns their bytes to the budget immediately.
    fn invalidate_ram(&self, id: u64) {
        if let Some(r) = &self.ram {
            r.invalidate_dataset(id);
        }
    }

    /// Evict `dataset` end to end: retire its placement in the cache
    /// manager (pin-checked), invalidate open sessions
    /// ([`DataPlane::reset_dataset`]) and delete its on-disk chunk trees
    /// on every node. Returns the bytes reclaimed from disk. The
    /// registration survives — re-[`place`](CacheManager::place) starts a
    /// fresh generation.
    ///
    /// [`CacheManager::place`]: crate::cache::CacheManager::place
    pub fn evict_dataset(&self, dataset: &str) -> Result<u64> {
        let id = self.cache.dataset_id(dataset)?;
        self.cache.with_mut(|m| m.evict(dataset))?;
        self.reset_dataset(dataset);
        Ok(gc_dataset_chunks(&self.cluster, id, None))
    }

    /// Delete `dataset` entirely: evict (pin-checked), invalidate open
    /// sessions, remove the registration, and delete its on-disk chunk
    /// trees. Returns the bytes reclaimed from disk.
    pub fn delete_dataset(&self, dataset: &str) -> Result<u64> {
        let id = self.cache.dataset_id(dataset)?;
        self.cache.with_mut(|m| m.delete(dataset))?;
        self.poison_dataset(dataset, PoisonReason::Retired);
        // The registration is gone, so reset_dataset could not resolve the
        // id — invalidate RAM with the one resolved above.
        self.invalidate_ram(id);
        Ok(gc_dataset_chunks(&self.cluster, id, None))
    }

    /// Place `dataset` on `nodes` with the eviction lifecycle wired
    /// through: when admission has to evict victims first (the LRU
    /// policy under capacity pressure), every victim is also reset on
    /// this plane and its chunk trees are deleted from disk. Returns who
    /// was evicted and how many bytes their trees freed.
    pub fn place_dataset(&self, dataset: &str, nodes: Vec<NodeId>) -> Result<PlacementOutcome> {
        let evicted = self
            .cache
            .with_mut(|m| -> Result<Vec<String>, crate::cache::CacheError> {
                let before = m.events.len();
                m.place(dataset, nodes)?;
                Ok(m.events[before..]
                    .iter()
                    .filter_map(|e| match e {
                        CacheEvent::Evicted(n) => Some(n.clone()),
                        _ => None,
                    })
                    .collect())
            })?;
        let mut reclaimed_bytes = 0;
        for victim in &evicted {
            // Evict keeps the registration, so the victim's ID is still
            // resolvable here.
            let id = self.cache.dataset_id(victim)?;
            self.reset_dataset(victim);
            reclaimed_bytes += gc_dataset_chunks(&self.cluster, id, None);
        }
        Ok(PlacementOutcome { evicted, reclaimed_bytes })
    }

    /// Mark node `n` failed and **degrade** every dataset striped on it
    /// ([`CacheManager::degrade_node`](crate::cache::CacheManager::degrade_node)):
    /// survivor chunks keep serving from their nodes while the lost
    /// chunks re-plan as remote fills — open sessions keep running
    /// mid-epoch (byte-correct, `degraded_reads` accounted) instead of
    /// dying with a reset error. Per dataset this rolls back the lost
    /// slots of the fetch-once ledger (their Done entries vouch for
    /// files that lived on the dead node) and GCs only the dead node's
    /// chunk tree. Returns the degraded dataset names and the disk bytes
    /// freed on the dead node.
    pub fn fail_node(&self, n: NodeId) -> Result<(Vec<String>, u64)> {
        let affected = self.cache.with_mut(|m| m.degrade_node(n));
        let mut reclaimed = 0;
        for name in &affected {
            let id = self.cache.dataset_id(name)?;
            if let Some(l) = self.ledgers.lock().unwrap().get(name).cloned() {
                match &l.mode {
                    LedgerMode::Chunked(geom) => {
                        for c in 0..geom.num_chunks() {
                            if geom.node_of_chunk(c) == n {
                                l.fill.abort(c);
                            }
                        }
                    }
                    LedgerMode::WholeFile => {
                        if let Ok(geom) = self.cache.geometry(name) {
                            for i in 0..geom.num_items {
                                if geom.node_of_item(i) == n {
                                    l.fill.abort(i);
                                }
                            }
                        }
                    }
                }
            }
            reclaimed += gc_node_chunks(&self.cluster, n, id);
        }
        Ok((affected, reclaimed))
    }

    /// Bring a failed node back into the fleet: degraded datasets
    /// re-admit it (reservation re-taken; the dataset leaves `Degraded`
    /// once no lost member remains). Refills that ran while the node was
    /// out wrote byte-complete chunk files into its directory but were
    /// refused residency marks (no live home) — re-admit them here by
    /// vouching every `Done` ledger slot homed on `n` whose file is on
    /// disk, so the snapshot (and peer serving) goes warm again instead
    /// of waiting for the chunks to be refetched.
    pub fn recover_node(&self, n: NodeId) {
        self.cache.with_mut(|m| m.recover_node(n));
        let ledgers: Vec<(String, Arc<Ledger>)> = self
            .ledgers
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        for (name, l) in ledgers {
            let LedgerMode::Chunked(geom) = &l.mode else { continue };
            // Skip stale ledgers from an earlier generation.
            let Ok(cur) = self.cache.geometry(&name) else { continue };
            if cur.generation != geom.generation {
                continue;
            }
            let mut landed: Vec<u64> = Vec::new();
            for c in 0..geom.num_chunks() {
                if geom.node_of_chunk(c) != n || !l.fill.is_done(c) {
                    continue;
                }
                let path = self.cluster.node_dirs[n.0].join(chunk_rel_path(
                    geom.dataset_id,
                    geom.generation,
                    geom.chunk_bytes(),
                    c,
                ));
                if path.exists() {
                    landed.push(c);
                }
            }
            if !landed.is_empty() {
                let _ = self.cache.mark_chunks(&name, &landed);
            }
        }
    }

    /// Coordinator-triggered re-stripe of `dataset` onto `nodes`
    /// (typically the survivor set after a node death): bumps the
    /// generation and re-places **without a full cold start**. Chunk
    /// payloads still resident on survivors are migrated on disk —
    /// renamed from the old generation's tree into the new one, landing
    /// on whichever node the new stripe homes them — and marked
    /// resident, so only the chunks that died with the lost node
    /// re-fetch from remote. The old ledger is poisoned with a precise
    /// "re-placed" reason; open sessions reopen to read the new
    /// generation.
    ///
    /// Migration needs the chunk grid to survive the re-place (same
    /// `chunk_bytes` — true whenever the configured chunk is ≤
    /// `total/k` on both node sets); when the grid changes, every chunk
    /// re-fetches cold.
    pub fn replace_dataset(&self, dataset: &str, nodes: Vec<NodeId>) -> Result<ReplaceOutcome> {
        let (old_geom, survivors) = self.cache.with_mut(|m| m.begin_replace(dataset))?;
        // Poison the old ledger *before* the new placement exists: no
        // session may carry Done slots across the generation bump.
        if let Some(l) = self.ledgers.lock().unwrap().remove(dataset) {
            l.poison(PoisonReason::Replaced);
        }
        self.cache.with_mut(|m| m.place(dataset, nodes))?;
        let new_geom = self.cache.geometry(dataset)?;
        let mut migrated_chunks = 0u64;
        let mut migrated_bytes = 0u64;
        if new_geom.chunk_bytes() == old_geom.chunk_bytes()
            && new_geom.total_bytes == old_geom.total_bytes
        {
            // Chunk c's payload is bytes [c·chunk, (c+1)·chunk) of the
            // dataset regardless of which node homes it — a same-grid
            // re-place moves files, not bytes.
            let mut landed: Vec<u64> = Vec::with_capacity(survivors.len());
            for &c in &survivors {
                let src = self.cluster.node_dirs[old_geom.node_of_chunk(c).0].join(
                    chunk_rel_path(
                        old_geom.dataset_id,
                        old_geom.generation,
                        old_geom.chunk_bytes(),
                        c,
                    ),
                );
                let dst = self.cluster.node_dirs[new_geom.node_of_chunk(c).0].join(
                    chunk_rel_path(
                        new_geom.dataset_id,
                        new_geom.generation,
                        new_geom.chunk_bytes(),
                        c,
                    ),
                );
                let Ok(meta) = std::fs::metadata(&src) else {
                    continue; // never landed on disk — refetches cold
                };
                if let Some(parent) = dst.parent() {
                    if std::fs::create_dir_all(parent).is_err() {
                        continue;
                    }
                }
                if std::fs::rename(&src, &dst).is_ok() {
                    landed.push(c);
                    migrated_bytes += meta.len();
                }
            }
            if !landed.is_empty() {
                self.cache.with_mut(|m| m.mark_chunks(dataset, landed.iter().copied()))?;
            }
            migrated_chunks = landed.len() as u64;
        }
        // Whatever the old generation still holds on disk is dead weight.
        let reclaimed_bytes =
            gc_dataset_chunks(&self.cluster, new_geom.dataset_id, Some(new_geom.generation));
        self.invalidate_ram(new_geom.dataset_id);
        Ok(ReplaceOutcome {
            generation: new_geom.generation,
            migrated_chunks,
            migrated_bytes,
            reclaimed_bytes,
        })
    }

    /// Human-readable lifecycle state of `dataset` for the control-plane
    /// API ("caching", "cached", "degraded(lost=2)", "replacing",
    /// "retired" once the registration is gone, ...).
    pub fn dataset_lifecycle(&self, dataset: &str) -> String {
        use crate::cache::DatasetState;
        self.cache.with(|m| match m.registry.get(dataset) {
            None => "retired".to_string(),
            Some(rec) => match &rec.state {
                DatasetState::Registered => "registered".to_string(),
                DatasetState::Caching { .. } => {
                    if rec.generation <= 1 && rec.fetched_bytes() == 0 {
                        "placing".to_string()
                    } else {
                        "caching".to_string()
                    }
                }
                DatasetState::Cached => "cached".to_string(),
                DatasetState::Degraded { lost, .. } => {
                    let l: Vec<String> = lost.iter().map(|x| x.0.to_string()).collect();
                    format!("degraded(lost={})", l.join(","))
                }
                DatasetState::Replacing => "replacing".to_string(),
                DatasetState::Evicting => "evicting".to_string(),
            },
        })
    }

    fn ledger(
        &self,
        dataset: &str,
        granularity: Granularity,
        cfg: &DataGenConfig,
    ) -> Result<Arc<Ledger>> {
        let mut map = self.ledgers.lock().unwrap();
        if let Some(l) = map.get(dataset) {
            let have = l.mode.granularity();
            if have != granularity {
                bail!(
                    "dataset '{dataset}' is already open at {} granularity \
                     (requested {})",
                    have.name(),
                    granularity.name()
                );
            }
            // Slot-count check: a job opened with a different cfg (or
            // after a re-place changed the chunk grid) must error, not
            // index a too-small table out of bounds.
            let want = match granularity {
                Granularity::WholeFile => cfg.num_items,
                Granularity::Chunked => self.cache.geometry(dataset)?.num_chunks(),
            };
            if want != l.slots {
                bail!(
                    "dataset '{dataset}' ledger has {} slots but this job needs {want} \
                     (cfg mismatch or re-placed grid — reset_dataset to start fresh)",
                    l.slots
                );
            }
            return Ok(l.clone());
        }
        let ledger = match granularity {
            Granularity::WholeFile => Arc::new(Ledger {
                fill: FillTable::new(cfg.num_items),
                mode: LedgerMode::WholeFile,
                slots: cfg.num_items,
                poison: AtomicU8::new(POISON_NONE),
            }),
            Granularity::Chunked => {
                let geom = self.cache.geometry(dataset)?;
                let slots = geom.num_chunks();
                Arc::new(Ledger {
                    fill: FillTable::new(slots),
                    mode: LedgerMode::Chunked(geom),
                    slots,
                    poison: AtomicU8::new(POISON_NONE),
                })
            }
        };
        map.insert(dataset.to_string(), ledger.clone());
        Ok(ledger)
    }

    /// Open a job session. Fills, buffers, residency and transport are
    /// shared with every other session on this plane; epoch order, seed,
    /// reader set and stats are this job's own. Chunked jobs need the
    /// dataset placed (the ledger is keyed by its chunk grid).
    pub fn open_job(self: &Arc<Self>, spec: JobSpec) -> Result<JobSession> {
        if spec.readers == 0 {
            bail!("job '{}' needs at least one reader", spec.dataset);
        }
        let ledger = self.ledger(&spec.dataset, spec.granularity, &spec.cfg)?;
        Ok(JobSession {
            plane: self.clone(),
            id: self.next_job.fetch_add(1, Ordering::Relaxed),
            dataset: spec.dataset,
            cfg: spec.cfg,
            ledger,
            readers: spec.readers,
            seed: spec.seed,
            prefetch: spec.prefetch,
            prefetch_cfg: spec.prefetch_cfg,
            transport: None,
            stats: Mutex::new(ReadStats::default()),
            epochs: AtomicU64::new(0),
            next_epoch: AtomicU64::new(0),
        })
    }
}

/// One job's handle on the shared [`DataPlane`]: its own epoch order,
/// seed, reader set and accumulated [`ReadStats`], over fills and buffers
/// shared with every co-located job.
pub struct JobSession {
    plane: Arc<DataPlane>,
    id: u64,
    dataset: String,
    cfg: DataGenConfig,
    ledger: Arc<Ledger>,
    readers: usize,
    seed: u64,
    prefetch: PrefetchStrategy,
    prefetch_cfg: PrefetchConfig,
    /// Session-level transport override (e.g. sockets for this job only);
    /// `None` ⇒ the plane default.
    transport: Option<Box<dyn ChunkTransport>>,
    /// Job-lifetime accumulator: epoch drivers and the convenience
    /// [`JobSession::read`] fold into it; never locked on the hot path.
    stats: Mutex<ReadStats>,
    /// Epochs *completed* (incremented at the end of `run_epoch_order`).
    epochs: AtomicU64,
    /// Next epoch index for [`JobSession::run_next_epoch`] — claimed
    /// atomically, so concurrent drivers never run the same permutation
    /// twice.
    next_epoch: AtomicU64,
}

impl JobSession {
    /// Toggle the background prefetcher (builder-style): `true` ⇒ the
    /// default clairvoyant strategy, `false` ⇒ off.
    pub fn with_prefetch(mut self, on: bool) -> Self {
        self.prefetch =
            if on { PrefetchStrategy::Clairvoyant } else { PrefetchStrategy::Off };
        self
    }

    /// Pick the prefetch strategy explicitly (builder-style).
    pub fn with_prefetch_strategy(mut self, s: PrefetchStrategy) -> Self {
        self.prefetch = s;
        self
    }

    /// Route this session's non-local reads through `transport` instead
    /// of the plane default (builder-style).
    pub fn with_transport(mut self, transport: Box<dyn ChunkTransport>) -> Self {
        self.transport = Some(transport);
        self
    }

    pub fn job_id(&self) -> u64 {
        self.id
    }

    pub fn dataset(&self) -> &str {
        &self.dataset
    }

    pub fn cfg(&self) -> &DataGenConfig {
        &self.cfg
    }

    pub fn readers(&self) -> usize {
        self.readers
    }

    pub fn granularity(&self) -> Granularity {
        self.ledger.mode.granularity()
    }

    /// Tag of the transport this session's reads use ("dir" / "socket").
    pub fn transport_name(&self) -> &'static str {
        self.effective_transport().name()
    }

    /// Epochs this session has completed.
    pub fn epochs_run(&self) -> u64 {
        self.epochs.load(Ordering::Relaxed)
    }

    /// This job's accumulated stats (its own reads only — co-located
    /// jobs' traffic never bleeds in).
    pub fn stats(&self) -> ReadStats {
        *self.stats.lock().unwrap()
    }

    /// Fold a stats shard into the job-lifetime accumulator (epoch
    /// drivers call this once per epoch, not per read).
    pub fn record(&self, shard: &ReadStats) {
        self.stats.lock().unwrap().merge(shard);
    }

    /// Node the `r`-th reader runs on.
    pub fn reader_node(&self, r: usize) -> NodeId {
        NodeId(r % self.plane.cluster.num_nodes())
    }

    fn effective_transport(&self) -> &dyn ChunkTransport {
        self.transport.as_deref().unwrap_or(self.plane.transport.as_ref())
    }

    /// A fresh epoch permutation (Fisher–Yates over all items),
    /// deterministic in `(self.seed, epoch)`.
    pub fn epoch_order(&self, epoch: u32) -> Vec<u64> {
        self.epoch_order_with(self.seed, epoch)
    }

    /// [`JobSession::epoch_order`] with an explicit seed (the shim's
    /// pre-DataPlane call shape).
    pub fn epoch_order_with(&self, seed: u64, epoch: u32) -> Vec<u64> {
        let mut order: Vec<u64> = (0..self.cfg.num_items).collect();
        let mut rng = Rng::new(seed ^ ((epoch as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)));
        rng.shuffle(&mut order);
        order
    }

    /// The unified read surface: resolve `req` through the session's
    /// ledger (whole-file or chunked), the lock-free residency snapshot
    /// when live, and the effective transport. Records into the job's
    /// accumulated stats and the cluster-wide accumulator.
    pub fn read(&self, req: &ReadRequest, reader: NodeId) -> Result<Vec<u8>> {
        let mut shard = ReadStats::default();
        let data = self.read_with_stats(req, reader, &mut shard)?;
        self.record(&shard);
        self.plane.cluster.merge_stats(&shard);
        Ok(data)
    }

    /// [`JobSession::read`] recording only into the caller's own shard
    /// (fold the shard back via [`JobSession::record`] /
    /// [`RealCluster::merge_stats`] when done). Note this still acquires
    /// the residency snapshot — one `SharedCache` shared-lock read — per
    /// call; hot loops should fetch [`JobSession::residency`] once per
    /// pass and drive [`JobSession::read_resolved`] instead, which is
    /// exactly what the internal epoch drivers do.
    pub fn read_with_stats(
        &self,
        req: &ReadRequest,
        reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Vec<u8>> {
        let snap = self.plane.cache.snapshot(&self.dataset).ok();
        self.read_inner(req, reader, snap.as_deref(), stats)
    }

    /// The dataset's lock-free residency snapshot: one shared-lock
    /// acquisition buys a whole pass of [`JobSession::read_resolved`]
    /// calls with **zero** further lock traffic (readers fall back to the
    /// locked lane automatically if it retires mid-pass).
    pub fn residency(&self) -> Option<Arc<ResidencySnapshot>> {
        self.plane.cache.snapshot(&self.dataset).ok()
    }

    /// The zero-lock hot form of [`JobSession::read_with_stats`]:
    /// resolves through a caller-held snapshot (from
    /// [`JobSession::residency`], fetched once per pass) instead of
    /// acquiring it per read.
    pub fn read_resolved(
        &self,
        req: &ReadRequest,
        reader: NodeId,
        snap: Option<&ResidencySnapshot>,
        stats: &mut ReadStats,
    ) -> Result<Vec<u8>> {
        self.read_inner(req, reader, snap, stats)
    }

    /// Refuse to serve through a poisoned ledger — with the *precise*
    /// lifecycle reason, not one generic message for every cause: its
    /// Done slots vouch for files that may be deleted or belong to a
    /// dead placement generation. Node degradation never poisons (the
    /// state machine decided those sessions keep running, re-planning
    /// lost segments as remote fills), so a mid-epoch node death is
    /// *not* an error here.
    fn check_reset(&self) -> Result<()> {
        match self.ledger.poisoned() {
            None => Ok(()),
            Some(PoisonReason::Reset) => bail!(
                "dataset '{}' was reset (evicted or manually invalidated); reopen the job session",
                self.dataset
            ),
            Some(PoisonReason::Replaced) => bail!(
                "dataset '{}' was re-placed onto a new node set (generation bumped); \
                 reopen the job session to read the new placement",
                self.dataset
            ),
            Some(PoisonReason::Retired) => {
                Err(DatasetRetired { dataset: self.dataset.clone() }.into())
            }
        }
    }

    fn read_inner(
        &self,
        req: &ReadRequest,
        reader: NodeId,
        snap: Option<&ResidencySnapshot>,
        stats: &mut ReadStats,
    ) -> Result<Vec<u8>> {
        self.check_reset()?;
        if let Some(want) = req.mode {
            let have = self.ledger.mode.granularity();
            if want != have {
                bail!(
                    "request insists on {} addressing but dataset '{}' is open {}",
                    want.name(),
                    self.dataset,
                    have.name()
                );
            }
        }
        let plane = &self.plane;
        let transport = self.effective_transport();
        match &self.ledger.mode {
            LedgerMode::WholeFile => {
                let dataset_id = plane.cache.dataset_id(&self.dataset)?;
                let data = read_item_concurrent_fast(
                    &plane.cluster,
                    &plane.cache,
                    &self.ledger.fill,
                    transport,
                    snap,
                    dataset_id,
                    &self.dataset,
                    &self.cfg,
                    req.item,
                    reader,
                    stats,
                )?;
                match &req.range {
                    None => Ok(data),
                    Some(r) => {
                        if r.start > r.end || r.end > data.len() as u64 {
                            bail!(
                                "range {}..{} out of bounds for item {} of {} bytes",
                                r.start,
                                r.end,
                                req.item,
                                data.len()
                            );
                        }
                        Ok(data[r.start as usize..r.end as usize].to_vec())
                    }
                }
            }
            LedgerMode::Chunked(geom) => {
                let (s, e) = geom.item_range(req.item);
                let (lo, hi) = match &req.range {
                    None => (0, e - s),
                    Some(r) => (r.start, r.end),
                };
                read_item_range_chunked_fast(
                    &plane.cluster,
                    &plane.cache,
                    &self.ledger.fill,
                    transport,
                    snap,
                    Some(&plane.bufs),
                    plane.ram.as_deref(),
                    &self.dataset,
                    &self.cfg,
                    geom,
                    req.item,
                    lo,
                    hi,
                    reader,
                    stats,
                )
            }
        }
    }

    /// Run epoch number `epoch` with this session's own seed/order.
    pub fn run_epoch(&self, epoch: u32) -> Result<EpochReport> {
        self.run_epoch_order(&self.epoch_order(epoch))
    }

    /// Run the next epoch in sequence (what the `/v1/jobs/:id/epoch`
    /// endpoint drives). The epoch index is claimed atomically, so
    /// concurrent callers each run a distinct permutation — never the
    /// same one twice. (Mixing this with explicit [`JobSession::run_epoch`]
    /// calls leaves the sequence to the caller.)
    pub fn run_next_epoch(&self) -> Result<EpochReport> {
        let epoch = self.next_epoch.fetch_add(1, Ordering::Relaxed) as u32;
        self.run_epoch(epoch)
    }

    /// Stream one epoch: partition `order` round-robin over the readers,
    /// run them in parallel (plus the prefetcher while the stripe is
    /// incomplete), and merge the stat shards. The merged shard is folded
    /// into the cluster-wide accumulator (so `take_stats()` keeps the
    /// full picture) *and* this job's own accumulator.
    pub fn run_epoch_order(&self, order: &[u64]) -> Result<EpochReport> {
        self.check_reset()?;
        let t0 = Instant::now();
        // One shared-lock acquisition per epoch: every reader thread then
        // resolves residency through the lock-free snapshot (readers fall
        // back to the locked lane if it retires mid-epoch).
        let snapshot = self.plane.cache.snapshot(&self.dataset).ok();
        // Gate the prefetcher on *full residency*, judged by the snapshot
        // bitmap when one is live — not on the registry's `Cached` state.
        // A partially-warm dataset (a `recover_node` re-admission, a
        // `Degraded` survivor set, an interrupted first epoch) is not
        // `Cached`, but it is not cold either: it should prefetch exactly
        // the missing chunks, which the clairvoyant scheduler's
        // per-unit residency skip (and the sequential pass's adoption
        // probe) already does once the pass is allowed to run.
        let fully_resident = match snapshot.as_deref() {
            Some(s) if !s.retired() => s.is_full(),
            _ => self.plane.cache.is_cached(&self.dataset),
        };
        let strategy =
            if fully_resident { PrefetchStrategy::Off } else { self.prefetch };
        let cursor = ReadCursor::new(order.len() as u64);
        // `prefetch_wasted` = credits the epoch leaves unconsumed, as a
        // delta so co-scheduled epochs on the shared ledger don't claim
        // each other's leftovers.
        let pf_out0 = self.ledger.fill.prefetch_outstanding();
        let (reader_shards, prefetch_shard) = std::thread::scope(|s| {
            let prefetcher = (strategy != PrefetchStrategy::Off).then(|| {
                s.spawn(|| self.prefetch_pass(strategy, order, &cursor, snapshot.as_deref()))
            });
            // Readers advance the cursor only when a clairvoyant
            // scheduler is actually trailing it.
            let advance = (strategy == PrefetchStrategy::Clairvoyant).then_some(&cursor);
            let mut handles = Vec::with_capacity(self.readers);
            for r in 0..self.readers {
                let items: Vec<u64> =
                    order.iter().skip(r).step_by(self.readers).copied().collect();
                let snap = snapshot.clone();
                handles
                    .push(s.spawn(move || self.reader_pass(r, &items, snap.as_deref(), advance)));
            }
            let shards: Vec<(ReadStats, Result<()>)> = handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        (ReadStats::default(), Err(anyhow!("reader thread panicked")))
                    })
                })
                .collect();
            // Readers are done (or dead): release the scheduler's parked
            // workers so the prefetcher can wind down, then join it.
            cursor.stop();
            let pf: Option<(ReadStats, Result<()>)> = prefetcher.map(|h| {
                h.join().unwrap_or_else(|_| {
                    (ReadStats::default(), Err(anyhow!("prefetcher thread panicked")))
                })
            });
            (shards, pf)
        });

        // Merge every shard — including the partial shards of passes that
        // errored — *before* propagating the first error, so the job and
        // cluster accumulators stay exact even for failed epochs.
        let mut first_err: Option<anyhow::Error> = None;
        let mut per_reader = Vec::with_capacity(self.readers);
        let mut merged = ReadStats::default();
        for (shard, res) in reader_shards {
            merged.merge(&shard);
            per_reader.push(shard);
            if let Err(e) = res {
                first_err.get_or_insert(e);
            }
        }
        let prefetcher = prefetch_shard.map(|(mut shard, res)| {
            shard.prefetch_wasted =
                self.ledger.fill.prefetch_outstanding().saturating_sub(pf_out0);
            merged.merge(&shard);
            if let Err(e) = res {
                first_err.get_or_insert(e);
            }
            shard
        });
        self.plane.cluster.merge_stats(&merged);
        self.record(&merged);
        if let Some(e) = first_err {
            return Err(e);
        }
        self.epochs.fetch_add(1, Ordering::Relaxed);
        Ok(EpochReport { wall: t0.elapsed(), merged, per_reader, prefetcher })
    }

    fn reader_pass(
        &self,
        r: usize,
        items: &[u64],
        snap: Option<&ResidencySnapshot>,
        cursor: Option<&ReadCursor>,
    ) -> (ReadStats, Result<()>) {
        let reader = self.reader_node(r);
        let plane = &self.plane;
        let mut stats = ReadStats::default();
        let res = (|| -> Result<()> {
            match &self.ledger.mode {
                LedgerMode::WholeFile => {
                    // Specialized arm: the dataset ID is resolved once per
                    // pass, not per read.
                    let transport = self.effective_transport();
                    let dataset_id = plane.cache.dataset_id(&self.dataset)?;
                    for &i in items {
                        self.check_reset()?;
                        read_item_concurrent_fast(
                            &plane.cluster,
                            &plane.cache,
                            &self.ledger.fill,
                            transport,
                            snap,
                            dataset_id,
                            &self.dataset,
                            &self.cfg,
                            i,
                            reader,
                            &mut stats,
                        )?;
                        if let Some(c) = cursor {
                            c.advance();
                        }
                    }
                }
                LedgerMode::Chunked(_) => {
                    // One dispatch implementation: the epoch driver runs
                    // the exact same path a `ReadRequest` does
                    // (read_inner), with the per-pass snapshot supplied by
                    // the caller.
                    for &i in items {
                        self.read_inner(&ReadRequest::item(i), reader, snap, &mut stats)?;
                        if let Some(c) = cursor {
                            c.advance();
                        }
                    }
                }
            }
            Ok(())
        })();
        (stats, res)
    }

    /// The background prefetcher thread body: the clairvoyant scheduler
    /// (priority by first access within the lookahead window, trailing
    /// `cursor`) or the legacy sequential walk, per `strategy`. Returns
    /// the stats shard *alongside* the result, so a mid-epoch error keeps
    /// its partial accounting.
    fn prefetch_pass(
        &self,
        strategy: PrefetchStrategy,
        order: &[u64],
        cursor: &ReadCursor,
        snap: Option<&ResidencySnapshot>,
    ) -> (ReadStats, Result<()>) {
        let plane = &self.plane;
        let mut stats = ReadStats::default();
        let res = match (&self.ledger.mode, strategy) {
            (_, PrefetchStrategy::Off) => Ok(()),
            (LedgerMode::WholeFile, PrefetchStrategy::Sequential) => prefetch_items(
                &plane.cluster,
                &plane.cache,
                &self.ledger.fill,
                &self.dataset,
                &self.cfg,
                &mut stats,
            ),
            (LedgerMode::Chunked(geom), PrefetchStrategy::Sequential) => prefetch_chunks(
                &plane.cluster,
                &plane.cache,
                &self.ledger.fill,
                plane.ram.as_deref(),
                &self.dataset,
                &self.cfg,
                geom,
                &mut stats,
            ),
            (LedgerMode::WholeFile, PrefetchStrategy::Clairvoyant) => run_clairvoyant_items(
                &plane.cluster,
                &plane.cache,
                &self.ledger.fill,
                snap,
                &self.dataset,
                &self.cfg,
                order,
                cursor,
                &self.prefetch_cfg,
                &mut stats,
            ),
            (LedgerMode::Chunked(geom), PrefetchStrategy::Clairvoyant) => run_clairvoyant_chunks(
                &plane.cluster,
                &plane.cache,
                &self.ledger.fill,
                plane.ram.as_deref(),
                snap,
                &self.dataset,
                &self.cfg,
                geom,
                order,
                cursor,
                &self.prefetch_cfg,
                &mut stats,
            ),
        };
        (stats, res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheManager, EvictionPolicy};
    use crate::storage::{Device, DeviceKind, Volume};
    use crate::workload::datagen::{self, DataGenConfig};
    use crate::workload::DatasetSpec;

    fn fixture(
        tag: &str,
        items: u64,
        chunk_bytes: u64,
    ) -> (RealCluster, SharedCache, DataGenConfig) {
        let root = std::env::temp_dir().join(format!("hoard-plane-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let cluster = RealCluster::create(&root, 4, 500e6).unwrap();
        let cfg = DataGenConfig { num_items: items, files_per_dir: 32, ..Default::default() };
        let total = datagen::generate(&cluster.remote_dir, &cfg).unwrap();
        let vols = (0..4)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
            .collect();
        let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
        manager.chunk_bytes = chunk_bytes;
        manager
            .register(DatasetSpec::new("d", cfg.num_items, total), "nfs://r/d".into())
            .unwrap();
        manager.place("d", (0..4).map(NodeId).collect()).unwrap();
        (cluster, SharedCache::new(manager), cfg)
    }

    #[test]
    fn sessions_on_one_plane_share_the_ledger() {
        let (cluster, cache, cfg) = fixture("ledger", 8, 1000);
        let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
        let a = plane.open_job(JobSpec::new("d", cfg.clone()).seed(1)).unwrap();
        let b = plane.open_job(JobSpec::new("d", cfg.clone()).seed(2)).unwrap();
        assert_ne!(a.job_id(), b.job_id());
        assert!(Arc::ptr_eq(&a.ledger, &b.ledger), "same dataset ⇒ same fill ledger");
        // A third session at the other granularity is refused (the ledger
        // keying would be incoherent).
        assert!(plane
            .open_job(JobSpec::new("d", cfg.clone()).granularity(Granularity::WholeFile))
            .is_err());
        // Zero readers is refused.
        assert!(plane.open_job(JobSpec::new("d", cfg.clone()).readers(0)).is_err());
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn session_reads_accumulate_job_stats() {
        let (cluster, cache, cfg) = fixture("stats", 8, 777);
        let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
        let sess = plane.open_job(JobSpec::new("d", cfg.clone())).unwrap();
        assert_eq!(sess.stats(), ReadStats::default());
        let (_, want) = datagen::make_record(&cfg, 0);
        let got = sess.read(&ReadRequest::item(0), NodeId(0)).unwrap();
        assert_eq!(got, want);
        let s = sess.stats();
        assert!(s.total_reads() > 0, "convenience read must accumulate job stats");
        assert_eq!(cluster.take_stats(), s, "and the cluster accumulator agrees");
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn run_epoch_counts_epochs_and_registers_dataset_cfgs() {
        let (cluster, cache, cfg) = fixture("epochs", 12, 1000);
        let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
        plane.register_dataset("d", cfg.clone());
        assert_eq!(plane.dataset_cfg("d").unwrap().num_items, cfg.num_items);
        assert!(plane.dataset_cfg("ghost").is_none());
        let sess = plane.open_job(JobSpec::new("d", cfg.clone()).readers(2)).unwrap();
        assert_eq!(sess.epochs_run(), 0);
        sess.run_next_epoch().unwrap();
        sess.run_next_epoch().unwrap();
        assert_eq!(sess.epochs_run(), 2);
        // Cold epoch filled every chunk exactly once; the second epoch
        // (warm) added none.
        let chunks = cache.geometry("d").unwrap().num_chunks();
        assert_eq!(plane.dataset_fills("d"), chunks);
        // reset_dataset drops the ledger: a fresh session starts clean.
        plane.reset_dataset("d");
        assert_eq!(plane.dataset_fills("d"), 0);
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn reset_dataset_poisons_open_sessions_and_retires_snapshot() {
        let (cluster, cache, cfg) = fixture("reset", 8, 1000);
        let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
        let sess = plane.open_job(JobSpec::new("d", cfg.clone())).unwrap();
        sess.read(&ReadRequest::item(0), NodeId(0)).unwrap();
        let snap = cache.snapshot("d").unwrap();
        plane.reset_dataset("d");
        assert!(snap.retired(), "reset must retire the published snapshot");
        let err = sess.read(&ReadRequest::item(1), NodeId(0)).unwrap_err();
        assert!(err.to_string().contains("reset"), "got: {err}");
        assert!(sess.run_epoch(0).is_err(), "epoch driver must refuse a reset session");
        // A fresh session on the same plane starts a clean ledger and can
        // read again (locked-lane fallback: the placement still stands).
        let fresh = plane.open_job(JobSpec::new("d", cfg.clone())).unwrap();
        let (_, want) = datagen::make_record(&cfg, 0);
        assert_eq!(fresh.read(&ReadRequest::item(0), NodeId(0)).unwrap(), want);
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn node_death_degrades_sessions_without_poisoning() {
        let (cluster, cache, cfg) = fixture("degrade", 8, 1000);
        let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
        let sess = plane.open_job(JobSpec::new("d", cfg.clone())).unwrap();
        sess.run_epoch(0).unwrap(); // cold epoch: every chunk lands
        let (affected, freed) = plane.fail_node(NodeId(2)).unwrap();
        assert_eq!(affected, vec!["d".to_string()]);
        assert!(freed > 0, "the dead node's chunk tree is GC'd");
        assert_eq!(plane.dataset_lifecycle("d"), "degraded(lost=2)");
        // The open session keeps serving byte-identical items — lost
        // chunks re-plan as remote fills, no reset error.
        for i in 0..cfg.num_items {
            let (_, want) = datagen::make_record(&cfg, i);
            assert_eq!(sess.read(&ReadRequest::item(i), NodeId(0)).unwrap(), want, "item {i}");
        }
        // Rejoin: the refills that landed in the dead node's directory
        // while it was out are re-admitted (Done ledger slots + on-disk
        // files), so the dataset goes straight back to fully cached.
        plane.recover_node(NodeId(2));
        assert_eq!(plane.dataset_lifecycle("d"), "cached");
        sess.run_epoch(1).unwrap();
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn replace_migrates_survivors_and_reports_precise_errors() {
        let (cluster, cache, cfg) = fixture("replace", 8, 1000);
        let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
        let sess = plane.open_job(JobSpec::new("d", cfg.clone())).unwrap();
        sess.run_epoch(0).unwrap();
        plane.fail_node(NodeId(3)).unwrap();
        let out = plane.replace_dataset("d", (0..3).map(NodeId).collect()).unwrap();
        assert_eq!(out.generation, 2, "re-place bumps the generation");
        assert!(out.migrated_chunks > 0, "survivor chunks migrate warm, not cold");
        assert_eq!(cache.geometry("d").unwrap().generation, 2);
        // The old session reports the precise lifecycle reason, not the
        // generic reset message.
        let err = sess.read(&ReadRequest::item(0), NodeId(0)).unwrap_err();
        assert!(err.to_string().contains("re-placed"), "got: {err}");
        // A fresh session reads the migrated generation byte-identically.
        let fresh = plane.open_job(JobSpec::new("d", cfg.clone())).unwrap();
        for i in 0..cfg.num_items {
            let (_, want) = datagen::make_record(&cfg, i);
            assert_eq!(fresh.read(&ReadRequest::item(i), NodeId(0)).unwrap(), want, "item {i}");
        }
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn deleted_dataset_reports_retired_marker() {
        let (cluster, cache, cfg) = fixture("retired", 8, 1000);
        let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
        let sess = plane.open_job(JobSpec::new("d", cfg.clone())).unwrap();
        sess.read(&ReadRequest::item(0), NodeId(0)).unwrap();
        plane.delete_dataset("d").unwrap();
        assert_eq!(plane.dataset_lifecycle("d"), "retired");
        let err = sess.read(&ReadRequest::item(1), NodeId(0)).unwrap_err();
        assert!(
            err.downcast_ref::<DatasetRetired>().is_some(),
            "retired reads carry the typed marker (for the 410 mapping), got: {err}"
        );
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }

    #[test]
    fn evict_dataset_gcs_chunk_trees_and_reports_bytes() {
        use crate::posix::realfs::dataset_chunk_dir;
        let (cluster, cache, cfg) = fixture("evgc", 8, 1000);
        let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
        let sess = plane.open_job(JobSpec::new("d", cfg.clone())).unwrap();
        sess.run_epoch(0).unwrap(); // cold epoch fills every chunk
        let id = cache.dataset_id("d").unwrap();
        let reclaimed = plane.evict_dataset("d").unwrap();
        assert!(reclaimed > 0, "a filled cache must reclaim on-disk bytes");
        for nd in &cluster.node_dirs {
            assert!(
                !nd.join(dataset_chunk_dir(id)).exists(),
                "chunk tree must be gone from every node dir"
            );
        }
        // Idempotent: an already-evicted dataset reclaims nothing more.
        assert_eq!(plane.evict_dataset("d").unwrap(), 0);
        // The session that filled the cache is dead; a re-place revives
        // the dataset under a new generation for fresh sessions.
        assert!(sess.read(&ReadRequest::item(0), NodeId(0)).is_err());
        plane.place_dataset("d", (0..4).map(NodeId).collect()).unwrap();
        assert_eq!(cache.geometry("d").unwrap().generation, 2);
        let fresh = plane.open_job(JobSpec::new("d", cfg.clone())).unwrap();
        let (_, want) = datagen::make_record(&cfg, 3);
        assert_eq!(fresh.read(&ReadRequest::item(3), NodeId(1)).unwrap(), want);
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }
}
