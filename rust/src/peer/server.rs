//! `PeerServer` — the per-node user-level chunk server (FanStore-style)
//! serving `GetChunk` / `GetChunkBatch` requests straight out of that
//! node's cache directory.
//!
//! Serving is event-driven: one [`Engine`](crate::net::Engine) loop thread
//! multiplexes every connection (epoll on Linux), request frames are
//! decoded incrementally ([`proto::decode_prefix`]) as bytes arrive, and
//! the actual chunk resolution — which may touch disk and sleep on the
//! NVMe token bucket — runs on the engine's worker pool so the loop never
//! blocks. That turns the old 128-thread connection cap into a
//! many-thousands connection *budget* ([`DEFAULT_MAX_CONNS`]): at the
//! budget new sockets get a best-effort `Error` frame carrying
//! [`proto::SERVER_BUSY`] (so [`PeerClient`](super::PeerClient) backs off
//! and retries instead of failing) and live connections are never
//! mid-stream dropped.
//!
//! Robustness model (unchanged semantics from the threaded server):
//!  * connections are persistent (many frames per socket);
//!  * a client that connects and sends nothing is dropped after
//!    `io_timeout` — enforced by the engine's timer wheel, and the close
//!    writes nothing;
//!  * malformed frames (lost sync, oversized length prefix) close the
//!    connection silently; the codec rejects hostile lengths from the 4
//!    header bytes alone, before any allocation;
//!  * graceful shutdown: [`PeerServer::stop`] severs every live
//!    connection and joins the loop and worker threads.
//!
//! Disk modelling: an optional [`SharedTokenBucket`] (the node's NVMe
//! bucket) is charged for every payload served, so loopback peer serving
//! consumes the same simulated node bandwidth a local read would.
//!
//! [`ThreadedPeerServer`] keeps the previous thread-per-connection
//! implementation alive as the comparison baseline for the
//! `perf_peer_transport` bench; both servers share the same request
//! resolution ([`respond`] → [`read_chunk_payload`]) and are
//! byte-identical on the wire.

use std::collections::HashMap;
use std::fs;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::Result;

use super::proto::{self, Frame};
use crate::cache::{RamTier, ResidencySnapshot};
use crate::net::{Engine, EngineConfig, Reply, Service};
use crate::posix::realfs::chunk_rel_path;
use crate::posix::throttle::SharedTokenBucket;

/// Default io deadline: long enough for any real request, short enough
/// that silent clients cannot pin a connection slot.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Default connection budget. The event-driven server holds a connection
/// in a few hundred bytes of state instead of a thread stack, so the
/// budget is thousands where the threaded cap was 128. Connections over
/// the budget are answered with an `Error` frame carrying
/// [`proto::SERVER_BUSY`] and closed.
pub const DEFAULT_MAX_CONNS: usize = 4096;

/// Requests at most this large (by grid) may be served inline on the loop
/// thread under light load — a warm ≤256 KiB read costs less than two
/// thread handoffs.
const INLINE_GRID_MAX: u64 = 256 << 10;

/// Resolver from item index to on-disk relative path, registered per
/// dataset for whole-file (item-granular) serving.
type ItemPathFn = Arc<dyn Fn(u64) -> PathBuf + Send + Sync>;

/// Source of a dataset's *current* residency snapshot, registered per
/// dataset so chunk serving consults cache state instead of bare file
/// presence. A closure (not a captured `Arc<ResidencySnapshot>`) so a
/// re-placed dataset is picked up without re-registration — the source
/// typically resolves through the `SharedCache` on every call.
type ResidencyFn = Arc<dyn Fn() -> Option<Arc<ResidencySnapshot>> + Send + Sync>;

/// What an armed fault does to the requests that trip it — the failure
/// modes a failover drill needs to rehearse without actually crashing a
/// process or losing a port binding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Close the connection without answering — what a crashed peer
    /// process looks like on the wire (reset / EOF mid-request).
    Kill,
    /// Stall for the given duration, then close without answering — what
    /// a wedged peer looks like (the client's io timeout fires).
    Hang(Duration),
    /// Answer `NotResident` — a peer that is alive but refuses to serve
    /// (drained / draining member).
    Refuse,
}

/// Fault-injection spec ([`PeerServer::inject_fault`]): serve the first
/// `after` chunk requests normally, then apply `action` to every request
/// until [`PeerServer::clear_fault`]. `after == 0` trips immediately —
/// "die at chunk N" drills pick the N.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    pub action: FaultAction,
    pub after: u64,
}

/// An armed [`FaultSpec`] plus how many chunk requests it has counted.
struct ArmedFault {
    spec: FaultSpec,
    seen: u64,
}

/// Everything request resolution needs, shared by the event-driven server,
/// the threaded baseline, and every worker thread.
struct PeerShared {
    node_dir: PathBuf,
    exports: RwLock<HashMap<u64, ItemPathFn>>,
    views: RwLock<HashMap<u64, ResidencyFn>>,
    /// Optional RAM hot-chunk tier consulted before the chunk file — only
    /// for requests that pass the residency-view gating, so eviction and
    /// generation semantics are identical to disk serving.
    ram: RwLock<Option<Arc<RamTier>>>,
    bucket: Option<SharedTokenBucket>,
    /// Armed fault injection, if any (drills only; `None` in production).
    fault: Mutex<Option<ArmedFault>>,
}

impl PeerShared {
    /// Count this request against the armed fault; returns the action to
    /// apply when it trips. Chunk requests count by chunk (a batch of K
    /// advances the counter K), so "after chunk N" means the same thing
    /// under batching.
    fn fault_trip(&self, req: &Frame) -> Option<FaultAction> {
        let n = match req {
            Frame::GetChunk { .. } => 1,
            Frame::GetChunkBatch { chunks, .. } => chunks.len().max(1) as u64,
            _ => return None,
        };
        let mut armed = self.fault.lock().unwrap();
        let st = armed.as_mut()?;
        st.seen += n;
        if st.seen > st.spec.after {
            Some(st.spec.action)
        } else {
            None
        }
    }
}

/// A running per-node chunk server (event-driven).
pub struct PeerServer {
    /// Bound address (bind to port 0 and read this back for ephemeral
    /// port discovery).
    pub addr: SocketAddr,
    engine: Engine,
    shared: Arc<PeerShared>,
}

impl PeerServer {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `node_dir` with default
    /// timeouts and no disk throttle.
    pub fn start(addr: &str, node_dir: impl Into<PathBuf>) -> Result<PeerServer> {
        Self::start_with(addr, node_dir, None, DEFAULT_IO_TIMEOUT)
    }

    /// Full-control constructor: `disk_bucket` is charged per served
    /// payload (pass the node's NVMe bucket so peer serving and local
    /// reads share one bandwidth model), `io_timeout` bounds how long a
    /// silent or stuck connection may hold its slot. The connection
    /// budget is [`DEFAULT_MAX_CONNS`] ([`PeerServer::start_with_limits`]
    /// to tune).
    pub fn start_with(
        addr: &str,
        node_dir: impl Into<PathBuf>,
        disk_bucket: Option<SharedTokenBucket>,
        io_timeout: Duration,
    ) -> Result<PeerServer> {
        Self::start_with_limits(addr, node_dir, disk_bucket, io_timeout, DEFAULT_MAX_CONNS)
    }

    /// [`PeerServer::start_with`] plus an explicit connection budget: once
    /// `max_conns` connections are live (idle ones count — they hold
    /// kernel and engine state), further sockets get a best-effort
    /// [`proto::SERVER_BUSY`] `Error` frame and are closed — a connection
    /// flood degrades into polite, retryable rejections.
    pub fn start_with_limits(
        addr: &str,
        node_dir: impl Into<PathBuf>,
        disk_bucket: Option<SharedTokenBucket>,
        io_timeout: Duration,
        max_conns: usize,
    ) -> Result<PeerServer> {
        let shared = Arc::new(PeerShared {
            node_dir: node_dir.into(),
            exports: RwLock::new(HashMap::new()),
            views: RwLock::new(HashMap::new()),
            ram: RwLock::new(None),
            bucket: disk_bucket,
            fault: Mutex::new(None),
        });
        let svc = Arc::new(PeerService { shared: shared.clone() });
        let cfg = EngineConfig { io_timeout, max_conns, ..EngineConfig::default() };
        let engine = Engine::start(addr, svc, cfg)?;
        Ok(PeerServer { addr: engine.addr, engine, shared })
    }

    /// Attach a [`RamTier`] (typically the co-located `DataPlane`'s —
    /// `DataPlane::ram_tier`): chunk requests that pass residency gating
    /// are answered from RAM when the tier holds the exact payload, before
    /// any file read. RAM serves skip the NVMe bucket — they never touch
    /// the disk. Requests for datasets without a residency view never
    /// consult the tier.
    pub fn set_ram_tier(&self, tier: Arc<RamTier>) {
        *self.shared.ram.write().unwrap() = Some(tier);
    }

    /// Register an item-path resolver for `dataset_id`, enabling
    /// whole-file requests (`grid_bytes == 0`) against this node. Chunk
    /// requests need no registration — their paths derive from the
    /// `(dataset_id, grid_bytes, chunk)` triple alone.
    pub fn register_item_paths(
        &self,
        dataset_id: u64,
        path_of: impl Fn(u64) -> PathBuf + Send + Sync + 'static,
    ) {
        self.shared.exports.write().unwrap().insert(dataset_id, Arc::new(path_of));
    }

    /// Register a residency-snapshot source for `dataset_id`, making chunk
    /// serving *snapshot-aware*: a request for an evicted / retired /
    /// stale-generation / wrong-grid / unmarked chunk answers
    /// `NotResident` instead of reading whatever file is still on disk,
    /// and served payload lengths are validated against the grid (a
    /// truncated file mid-GC answers `Error`, never short bytes). The
    /// source is re-resolved per request (returning `None` while the
    /// dataset is unplaced), so evict → re-place cycles need no
    /// re-registration. Without a registration, chunk serving keeps the
    /// file-presence behaviour with heuristic length checks only.
    pub fn register_residency(
        &self,
        dataset_id: u64,
        source: impl Fn() -> Option<Arc<ResidencySnapshot>> + Send + Sync + 'static,
    ) {
        self.shared.views.write().unwrap().insert(dataset_id, Arc::new(source));
    }

    /// Arm fault injection: serve `spec.after` more chunk requests
    /// normally, then apply `spec.action` (kill / hang / refuse) to every
    /// request until [`PeerServer::clear_fault`]. Drills use this to
    /// rehearse node death without losing the port binding, so "revive"
    /// is just clearing the fault.
    pub fn inject_fault(&self, spec: FaultSpec) {
        *self.shared.fault.lock().unwrap() = Some(ArmedFault { spec, seen: 0 });
    }

    /// Disarm fault injection (the drilled peer "revives").
    pub fn clear_fault(&self) {
        *self.shared.fault.lock().unwrap() = None;
    }

    /// Connections currently held by the engine (tests assert churn
    /// returns to zero).
    pub fn live_conns(&self) -> usize {
        self.engine.live_conns()
    }

    /// Graceful shutdown: sever every live connection, join the loop and
    /// worker threads. Idempotent (also runs on drop, via the engine).
    pub fn stop(&mut self) {
        self.engine.stop();
    }
}

/// The peer wire protocol as an engine [`Service`].
struct PeerService {
    shared: Arc<PeerShared>,
}

impl Service for PeerService {
    type Request = Frame;

    fn try_parse(&self, inbuf: &mut Vec<u8>) -> Result<Option<Frame>> {
        proto::decode_prefix(inbuf)
    }

    fn handle(&self, req: Frame) -> Reply {
        if let Some(action) = self.shared.fault_trip(&req) {
            match action {
                FaultAction::Kill => return Reply::closing(vec![]),
                FaultAction::Hang(d) => {
                    std::thread::sleep(d);
                    return Reply::closing(vec![]);
                }
                FaultAction::Refuse => {
                    return Reply::new(proto::encode_segments(Frame::NotResident));
                }
            }
        }
        Reply::new(proto::encode_segments(respond(&self.shared, req)))
    }

    /// Enough to buffer any frame the codec accepts: the old server
    /// decoded (and answered `Error` to) every well-formed frame, request
    /// or not, and the budget keeps that behaviour.
    fn max_buffered(&self) -> usize {
        proto::MAX_FRAME + 4
    }

    fn busy_reply(&self) -> Option<Reply> {
        Some(Reply::closing(vec![proto::encode(&Frame::Error(proto::SERVER_BUSY.into()))]))
    }

    /// Malformed frame ⇒ close silently (framing sync is lost; anything
    /// written could be misparsed as a frame header).
    fn parse_error_reply(&self, _err: &anyhow::Error) -> Option<Reply> {
        None
    }

    /// Single small-grid chunk requests are served on the loop thread
    /// under light load: a warm read beats two thread handoffs. Anything
    /// that can sleep (the NVMe bucket) or get large (items, batches)
    /// goes to the workers.
    fn serve_inline(&self, req: &Frame) -> bool {
        self.shared.bucket.is_none()
            // An armed fault may Hang — never on the loop thread.
            && self.shared.fault.lock().unwrap().is_none()
            && matches!(
                req,
                Frame::GetChunk { grid_bytes, .. }
                    if *grid_bytes > 0 && *grid_bytes <= INLINE_GRID_MAX
            )
    }
}

/// One chunk's resolution outcome, shared by the single and batched
/// request paths.
enum ChunkRead {
    Data(Vec<u8>),
    NotResident,
    Fail(String),
}

/// Resolve and read one addressed payload off `node_dir`, charging
/// `bucket` for served bytes (the node's simulated NVMe).
///
/// Chunk requests (`grid_bytes > 0`) are gated by the dataset's registered
/// residency view when one exists: an evicted/retired snapshot, a stale
/// generation, a mismatched grid or an unmarked chunk all answer
/// `NotResident` — file presence alone never serves. With a view the
/// payload length is validated **exactly** against the grid's
/// (tail-aware) chunk range; without one, only impossible lengths (empty,
/// or larger than the grid) are rejected. Item requests (`grid_bytes ==
/// 0`) resolve through the item export and are not length-validated (item
/// sizes are not derivable from the wire address).
#[allow(clippy::too_many_arguments)]
fn read_chunk_payload(
    node_dir: &Path,
    exports: &RwLock<HashMap<u64, ItemPathFn>>,
    views: &RwLock<HashMap<u64, ResidencyFn>>,
    ram: Option<&RamTier>,
    bucket: Option<&SharedTokenBucket>,
    dataset_id: u64,
    generation: u64,
    grid_bytes: u64,
    chunk: u64,
) -> ChunkRead {
    let (rel, expect_len) = if grid_bytes > 0 {
        let view = views.read().unwrap().get(&dataset_id).cloned();
        let expect_len = match view {
            Some(source) => {
                let Some(snap) = source() else {
                    // Not currently placed (evicted and not re-placed).
                    return ChunkRead::NotResident;
                };
                if snap.retired() {
                    return ChunkRead::NotResident;
                }
                let geom = snap.geometry();
                if geom.generation != generation || geom.chunk_bytes() != grid_bytes {
                    // A stale-generation or stale-grid address can only
                    // match leftover pre-evict files — refuse it.
                    return ChunkRead::NotResident;
                }
                if chunk >= geom.num_chunks() {
                    return ChunkRead::Fail(format!(
                        "chunk {chunk} out of range for dataset {dataset_id} ({} chunks)",
                        geom.num_chunks()
                    ));
                }
                if !snap.contains(chunk) {
                    return ChunkRead::NotResident;
                }
                let (cs, ce) = geom.chunk_range(chunk);
                // RAM tier, only past every gate above: the key carries the
                // generation (stale entries structurally cannot match) and
                // the length check mirrors the on-disk validation. No NVMe
                // bucket charge — this serve never touches the disk.
                if let Some(r) = ram {
                    if let Some(data) = r.get((dataset_id, generation, grid_bytes, chunk)) {
                        if data.len() as u64 == ce - cs && data.len() < proto::MAX_FRAME {
                            return ChunkRead::Data(data.as_ref().clone());
                        }
                    }
                }
                Some(ce - cs)
            }
            None => None,
        };
        (Some(chunk_rel_path(dataset_id, generation, grid_bytes, chunk)), expect_len)
    } else {
        (exports.read().unwrap().get(&dataset_id).map(|f| f(chunk)), None)
    };
    match rel {
        None => ChunkRead::Fail(format!("dataset {dataset_id} has no item export on this node")),
        Some(rel) => match fs::read(node_dir.join(&rel)) {
            // A payload the codec cannot frame is a request error, never a
            // handler panic (encode asserts).
            Ok(bytes) if bytes.len() >= proto::MAX_FRAME => ChunkRead::Fail(format!(
                "payload {} bytes exceeds the {} byte frame cap",
                bytes.len(),
                proto::MAX_FRAME
            )),
            Ok(bytes) => {
                if let Some(want) = expect_len {
                    if bytes.len() as u64 != want {
                        // A truncated (or oversized) chunk file — e.g. one
                        // caught mid-GC — must never reach a reader as
                        // short "successful" bytes.
                        return ChunkRead::Fail(format!(
                            "chunk {chunk} of dataset {dataset_id} is {} bytes on disk, grid says {want}",
                            bytes.len()
                        ));
                    }
                } else if grid_bytes > 0 && (bytes.is_empty() || bytes.len() as u64 > grid_bytes) {
                    // No residency view: still reject lengths the grid
                    // cannot produce (every chunk is 1..=grid_bytes long).
                    return ChunkRead::Fail(format!(
                        "chunk {chunk} of dataset {dataset_id} is {} bytes on disk, grid caps it at {grid_bytes}",
                        bytes.len()
                    ));
                }
                if let Some(b) = bucket {
                    b.acquire(bytes.len() as u64);
                }
                ChunkRead::Data(bytes)
            }
            Err(e) if e.kind() == io::ErrorKind::NotFound => ChunkRead::NotResident,
            Err(e) => ChunkRead::Fail(format!("read {}: {e}", rel.display())),
        },
    }
}

/// Answer one request frame — the single serving path both servers share.
/// The RAM tier is re-resolved per request so a tier attached after a
/// connection opened is picked up immediately.
fn respond(shared: &PeerShared, frame: Frame) -> Frame {
    let tier = shared.ram.read().unwrap().clone();
    let tier = tier.as_deref();
    let bucket = shared.bucket.as_ref();
    match frame {
        Frame::GetChunk { dataset_id, generation, chunk, grid_bytes } => {
            match read_chunk_payload(
                &shared.node_dir,
                &shared.exports,
                &shared.views,
                tier,
                bucket,
                dataset_id,
                generation,
                grid_bytes,
                chunk,
            ) {
                ChunkRead::Data(bytes) => Frame::ChunkData(bytes),
                ChunkRead::NotResident => Frame::NotResident,
                ChunkRead::Fail(msg) => Frame::Error(msg),
            }
        }
        Frame::GetChunkBatch { dataset_id, generation, grid_bytes, chunks } => {
            // One response frame for the whole batch. Any per-chunk I/O
            // failure (or a combined payload the codec cannot frame)
            // fails the batch as a request-level Error — the connection's
            // framing stays intact either way.
            let mut entries = Vec::with_capacity(chunks.len());
            // Conservative body bound: tag + count + per-entry marker
            // and length headers + payload bytes.
            let mut body = 5 + 9 * chunks.len();
            let mut failed = None;
            for &c in &chunks {
                match read_chunk_payload(
                    &shared.node_dir,
                    &shared.exports,
                    &shared.views,
                    tier,
                    bucket,
                    dataset_id,
                    generation,
                    grid_bytes,
                    c,
                ) {
                    ChunkRead::Data(bytes) => {
                        body += bytes.len();
                        if body >= proto::MAX_FRAME {
                            failed = Some(format!(
                                "batch payload exceeds the {} byte frame cap",
                                proto::MAX_FRAME
                            ));
                            break;
                        }
                        entries.push(Some(bytes));
                    }
                    ChunkRead::NotResident => entries.push(None),
                    ChunkRead::Fail(msg) => {
                        failed = Some(msg);
                        break;
                    }
                }
            }
            match failed {
                Some(msg) => Frame::Error(msg),
                None => Frame::ChunkBatchData(entries),
            }
        }
        // Only GetChunk / GetChunkBatch are valid request frames.
        _ => Frame::Error("expected a GetChunk request".into()),
    }
}

// ------------------------------------------------- threaded baseline --

/// Counting gate over live handler threads: decrements on drop so a
/// handler exit (clean, timeout, or panic unwind) always releases its
/// slot.
struct HandlerSlot(Arc<AtomicUsize>);

impl Drop for HandlerSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// The previous thread-per-connection chunk server, kept as the
/// comparison baseline for `perf_peer_transport`'s high-connection
/// scenario. Wire-identical to [`PeerServer`] (same [`respond`]); the
/// difference is purely the concurrency model — a thread, a stack, and
/// two `SO_*TIMEO` timeouts per connection.
pub struct ThreadedPeerServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Live connections only: each handler prunes its own entry on exit,
    /// so churn never accumulates file descriptors.
    conns: Arc<Mutex<Vec<(u64, TcpStream)>>>,
    shared: Arc<PeerShared>,
}

impl ThreadedPeerServer {
    pub fn start_with_limits(
        addr: &str,
        node_dir: impl Into<PathBuf>,
        disk_bucket: Option<SharedTokenBucket>,
        io_timeout: Duration,
        max_conns: usize,
    ) -> Result<ThreadedPeerServer> {
        let shared = Arc::new(PeerShared {
            node_dir: node_dir.into(),
            exports: RwLock::new(HashMap::new()),
            views: RwLock::new(HashMap::new()),
            ram: RwLock::new(None),
            bucket: disk_bucket,
            fault: Mutex::new(None),
        });
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<(u64, TcpStream)>>> = Arc::new(Mutex::new(Vec::new()));
        let (stop2, conns2, shared2) = (stop.clone(), conns.clone(), shared.clone());
        let active: Arc<AtomicUsize> = Arc::new(AtomicUsize::new(0));
        let join = std::thread::spawn(move || {
            let mut next_id = 0u64;
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((sock, _peer)) => {
                        let _ = sock.set_read_timeout(Some(io_timeout));
                        let _ = sock.set_write_timeout(Some(io_timeout));
                        let _ = sock.set_nodelay(true);
                        if active.load(Ordering::Acquire) >= max_conns {
                            // Over the gate: answer a request-level Error
                            // (best effort) and drop — never spawn.
                            let mut sock = sock;
                            let _ = proto::write_frame(
                                &mut sock,
                                &Frame::Error(proto::SERVER_BUSY.into()),
                            );
                            let _ = sock.shutdown(Shutdown::Both);
                            continue;
                        }
                        active.fetch_add(1, Ordering::AcqRel);
                        let slot = HandlerSlot(active.clone());
                        let id = next_id;
                        next_id += 1;
                        if let Ok(clone) = sock.try_clone() {
                            conns2.lock().unwrap().push((id, clone));
                        }
                        let shared = shared2.clone();
                        let stop = stop2.clone();
                        let conns = conns2.clone();
                        std::thread::spawn(move || {
                            let _slot = slot;
                            let mut sock = sock;
                            serve_conn(&mut sock, &shared, &stop);
                            let _ = sock.shutdown(Shutdown::Both);
                            // Prune this connection's registry entry so
                            // churn never accumulates fds.
                            conns.lock().unwrap().retain(|(i, _)| *i != id);
                        });
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    // A handshake aborted by the client (RST before
                    // accept) is that connection's problem, not the
                    // listener's — keep accepting.
                    Err(ref e)
                        if e.kind() == io::ErrorKind::ConnectionAborted
                            || e.kind() == io::ErrorKind::Interrupted => {}
                    Err(_) => break,
                }
            }
        });
        Ok(ThreadedPeerServer { addr: local, stop, join: Some(join), conns, shared })
    }

    /// See [`PeerServer::register_residency`].
    pub fn register_residency(
        &self,
        dataset_id: u64,
        source: impl Fn() -> Option<Arc<ResidencySnapshot>> + Send + Sync + 'static,
    ) {
        self.shared.views.write().unwrap().insert(dataset_id, Arc::new(source));
    }

    /// Graceful shutdown: stop accepting, then sever live connections.
    /// The accept thread is joined *before* the drain, so no connection
    /// accepted during the race window can escape it. Idempotent (also
    /// runs on drop).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        for (_, c) in self.conns.lock().unwrap().drain(..) {
            // Unblocks the handler's in-flight read immediately (the
            // clone shares the underlying socket), so handlers exit
            // promptly instead of sitting out their io_timeout.
            let _ = c.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for ThreadedPeerServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One connection's serve loop (threaded baseline): frames in, frames
/// out, until EOF, timeout, lost framing sync, or server shutdown.
fn serve_conn(sock: &mut TcpStream, shared: &PeerShared, stop: &AtomicBool) {
    while !stop.load(Ordering::Relaxed) {
        let frame = match proto::read_frame(sock) {
            Ok(Some(f)) => f,
            // Clean hang-up, idle timeout (the silent-client hardening),
            // or a malformed frame: drop the connection. Clients treat a
            // dead pooled connection as stale and redial.
            Ok(None) | Err(_) => return,
        };
        if let Some(action) = shared.fault_trip(&frame) {
            match action {
                FaultAction::Kill => return,
                FaultAction::Hang(d) => {
                    std::thread::sleep(d);
                    return;
                }
                FaultAction::Refuse => {
                    if proto::write_frame(sock, &Frame::NotResident).is_err() {
                        return;
                    }
                    continue;
                }
            }
        }
        if proto::write_frame(sock, &respond(shared, frame)).is_err() {
            return;
        }
    }
}
