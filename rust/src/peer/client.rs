//! `PeerClient` — the reader side of the peer data plane: one connection
//! pool per peer node, speaking the [`super::proto`] frame protocol, with
//! optional per-link NIC throttling.
//!
//!  * **Connection pooling** — requests check a socket out of the target
//!    peer's pool (dialing lazily when empty) and return it on success, so
//!    a warm epoch reuses a handful of long-lived connections per link
//!    instead of one dial per chunk. A stale pooled connection (the server
//!    idle-closed it) is detected by the failed round-trip and retried
//!    once on a fresh dial. Pooled sockets idle longer than
//!    [`DEFAULT_POOL_IDLE_TTL`] ([`PeerClient::with_idle_ttl`] to tune)
//!    are dropped at the next checkout (or explicitly via
//!    [`PeerClient::reap_idle`]) — the server will have idle-closed them
//!    anyway, so the TTL turns guaranteed-stale round trips into skipped
//!    sockets and frees both sides' descriptors between epochs.
//!  * **Busy backoff** — a server at its connection budget answers an
//!    `Error` frame carrying [`proto::SERVER_BUSY`] and closes. The
//!    client recognises the signal, backs off briefly, and redials (a
//!    bounded number of times) before surfacing the error — transient
//!    capacity spikes heal instead of failing reads.
//!  * **NIC throttling** — [`PeerClient::with_nic_bw`] attaches one
//!    [`SharedTokenBucket`] per peer link; every received payload is
//!    charged to its link's bucket, modelling the node interconnect the
//!    same way `RealCluster` models NVMe and NFS bandwidth.
//!  * **Timeouts** — every socket carries read/write timeouts, so a hung
//!    peer turns into an error instead of a stuck reader thread.

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::proto::{self, Frame, ITEM_GRID};
use super::ChunkTransport;
use crate::cache::ChunkGeometry;
use crate::netsim::NodeId;
use crate::posix::realfs::{ReadStats, RealCluster};
use crate::posix::throttle::SharedTokenBucket;

/// Idle connections kept per peer; extras are dropped on check-in.
const POOL_CAP: usize = 4;

/// Default idle TTL for pooled connections: shorter than the server's
/// default idle deadline would also work, but the point is reclaiming
/// descriptors between epochs, not racing the server — anything the
/// server closed first is caught by the stale-retry path regardless.
pub const DEFAULT_POOL_IDLE_TTL: Duration = Duration::from_secs(30);

/// Redials attempted against a [`proto::SERVER_BUSY`] rejection before
/// the error surfaces.
const BUSY_RETRIES: usize = 2;

/// Default suspect cooldown: once a peer is classified down
/// ([`super::PeerDown`]), every request to it inside this window fails
/// fast instead of re-paying the connect timeout. After the window one
/// request probes the peer again (a revived peer re-admits itself).
pub const DEFAULT_SUSPECT_COOLDOWN: Duration = Duration::from_secs(5);

/// Chunk client with a per-peer connection pool.
pub struct PeerClient {
    peers: Vec<SocketAddr>,
    /// Pooled idle sockets with their check-in time (for the idle TTL).
    pool: Vec<Mutex<Vec<(TcpStream, Instant)>>>,
    /// One bucket per peer link when NIC throttling is on.
    nic: Option<Vec<SharedTokenBucket>>,
    io_timeout: Duration,
    idle_ttl: Duration,
    /// Per-peer "suspected down until" marks: set by a connection-level
    /// failure, checked before every request (fast-fail inside the
    /// window), cleared by window expiry so the next request probes.
    suspects: Vec<Mutex<Option<Instant>>>,
    suspect_cooldown: Duration,
    /// Request/response round trips completed (batched or single) —
    /// observability for the batching win: K chunks per batch move K
    /// payloads over one round trip.
    roundtrips: AtomicU64,
}

impl PeerClient {
    /// Address book: `peers[n]` is node `n`'s [`super::PeerServer`].
    /// Connections are dialed lazily on first use.
    pub fn connect(peers: Vec<SocketAddr>) -> Self {
        let pool = peers.iter().map(|_| Mutex::new(Vec::new())).collect();
        let suspects = peers.iter().map(|_| Mutex::new(None)).collect();
        PeerClient {
            peers,
            pool,
            nic: None,
            io_timeout: super::server::DEFAULT_IO_TIMEOUT,
            idle_ttl: DEFAULT_POOL_IDLE_TTL,
            suspects,
            suspect_cooldown: DEFAULT_SUSPECT_COOLDOWN,
            roundtrips: AtomicU64::new(0),
        }
    }

    /// Throttle every peer link to `bytes_per_s` (one token bucket per
    /// link, shared by all reader threads using this client).
    pub fn with_nic_bw(mut self, bytes_per_s: f64) -> Self {
        self.nic = Some(
            self.peers
                .iter()
                .map(|_| SharedTokenBucket::new(bytes_per_s, (bytes_per_s / 8.0).max(1.0)))
                .collect(),
        );
        self
    }

    /// Socket read/write timeout for subsequently dialed connections.
    pub fn with_io_timeout(mut self, d: Duration) -> Self {
        self.io_timeout = d;
        self
    }

    /// Idle TTL for pooled connections (see [`DEFAULT_POOL_IDLE_TTL`]).
    pub fn with_idle_ttl(mut self, d: Duration) -> Self {
        self.idle_ttl = d;
        self
    }

    /// Suspect cooldown after a dead-peer classification (see
    /// [`DEFAULT_SUSPECT_COOLDOWN`]).
    pub fn with_suspect_cooldown(mut self, d: Duration) -> Self {
        self.suspect_cooldown = d;
        self
    }

    /// Is `peer` currently inside its suspect cooldown? (Observability /
    /// tests; requests check this themselves.)
    pub fn is_suspected(&self, peer: NodeId) -> bool {
        self.suspects
            .get(peer.0)
            .and_then(|m| *m.lock().unwrap())
            .is_some_and(|until| Instant::now() < until)
    }

    pub fn num_peers(&self) -> usize {
        self.peers.len()
    }

    /// Wire request/response round trips completed so far (one per
    /// `GetChunk` *or* per whole `GetChunkBatch` — the quantity batching
    /// collapses).
    pub fn wire_roundtrips(&self) -> u64 {
        self.roundtrips.load(Ordering::Relaxed)
    }

    fn dial(&self, peer: NodeId) -> Result<TcpStream> {
        let addr = self
            .peers
            .get(peer.0)
            .copied()
            .with_context(|| format!("no peer address for node{}", peer.0))?;
        let sock = TcpStream::connect(addr)
            .with_context(|| format!("connect peer node{} at {addr}", peer.0))?;
        let _ = sock.set_nodelay(true);
        sock.set_read_timeout(Some(self.io_timeout))?;
        sock.set_write_timeout(Some(self.io_timeout))?;
        Ok(sock)
    }

    fn roundtrip(sock: &mut TcpStream, req: &Frame) -> Result<Frame> {
        proto::write_frame(sock, req)?;
        proto::read_frame(sock)?.context("peer closed the connection mid-request")
    }

    fn checkin(&self, peer: NodeId, sock: TcpStream) {
        let mut pool = self.pool[peer.0].lock().unwrap();
        if pool.len() < POOL_CAP {
            pool.push((sock, Instant::now()));
        }
    }

    /// Pop the freshest pooled socket, dropping any past the idle TTL on
    /// the way (the server will have idle-closed them — skipping them
    /// saves a guaranteed-stale round trip).
    fn checkout(&self, peer: NodeId) -> Option<TcpStream> {
        let mut pool = self.pool[peer.0].lock().unwrap();
        pool.retain(|(_, at)| at.elapsed() < self.idle_ttl);
        pool.pop().map(|(s, _)| s)
    }

    /// Drop every pooled connection past the idle TTL (all peers);
    /// returns how many were dropped. Checkout reaps lazily anyway — this
    /// is for callers that go idle for long stretches (between epochs)
    /// and want the descriptors back *now*.
    pub fn reap_idle(&self) -> usize {
        let mut dropped = 0;
        for pool in &self.pool {
            let mut g = pool.lock().unwrap();
            let before = g.len();
            g.retain(|(_, at)| at.elapsed() < self.idle_ttl);
            dropped += before - g.len();
        }
        dropped
    }

    /// Idle sockets currently pooled across all peers.
    pub fn pooled_conns(&self) -> usize {
        self.pool.iter().map(|p| p.lock().unwrap().len()).sum()
    }

    /// Start `peer`'s suspect cooldown and produce the typed
    /// [`super::PeerDown`] error (as the `anyhow` source, so it survives
    /// context layers and downcasts at the reader).
    fn classify_down(&self, peer: NodeId, what: &str, err: anyhow::Error) -> anyhow::Error {
        *self.suspects[peer.0].lock().unwrap() = Some(Instant::now() + self.suspect_cooldown);
        super::PeerDown { peer: peer.0, reason: format!("{what}: {err:#}") }.into()
    }

    /// Dial + round trip on a fresh connection. Any failure here is
    /// **connection-level by construction** — the pooled-conn stale case
    /// has already had its one redial — so it classifies the peer as down.
    fn fresh_request(&self, peer: NodeId, req: &Frame) -> Result<(TcpStream, Frame)> {
        let mut fresh = match self.dial(peer) {
            Ok(s) => s,
            Err(e) => return Err(self.classify_down(peer, "connect failed", e)),
        };
        match Self::roundtrip(&mut fresh, req) {
            Ok(r) => Ok((fresh, r)),
            Err(e) => Err(self.classify_down(peer, "fresh connection died mid-request", e)),
        }
    }

    /// One request/response over a checked-out connection (dialing lazily;
    /// a stale pooled connection — the server idle-closed it, or it died
    /// under us — is detected by the failed round trip and retried
    /// **once** on a fresh dial; the failed socket is dropped, never
    /// pooled again, so a half-written conn cannot poison the pool). A
    /// failure on the fresh connection classifies the peer as down.
    fn request_once(&self, peer: NodeId, req: &Frame) -> Result<(TcpStream, Frame)> {
        match self.checkout(peer) {
            Some(mut s) => match Self::roundtrip(&mut s, req) {
                Ok(r) => Ok((s, r)),
                Err(_) => self.fresh_request(peer, req),
            },
            None => self.fresh_request(peer, req),
        }
    }

    /// [`PeerClient::request_once`] plus busy backoff: a
    /// [`proto::SERVER_BUSY`] rejection (the server's connection budget is
    /// full; it closed the socket after the frame) sleeps briefly and
    /// redials, up to [`BUSY_RETRIES`] times, before the error surfaces to
    /// the caller. A peer inside its suspect cooldown fails fast — no
    /// connect timeout re-paid per read — until the window expires and one
    /// request probes it again.
    fn pooled_request(&self, peer: NodeId, req: &Frame) -> Result<(TcpStream, Frame)> {
        if peer.0 >= self.peers.len() {
            bail!("no peer address for node{}", peer.0);
        }
        {
            let mut suspected = self.suspects[peer.0].lock().unwrap();
            if let Some(until) = *suspected {
                if Instant::now() < until {
                    return Err(super::PeerDown {
                        peer: peer.0,
                        reason: "suspected down (cooldown active)".into(),
                    }
                    .into());
                }
                // Cooldown expired: clear the mark and let this request
                // probe the peer (a revived peer re-admits itself here).
                *suspected = None;
            }
        }
        let mut attempt = 0usize;
        loop {
            let (sock, resp) = self.request_once(peer, req)?;
            if let Frame::Error(msg) = &resp {
                if proto::is_server_busy(msg) && attempt < BUSY_RETRIES {
                    attempt += 1;
                    drop(sock); // the server closed its side already
                    std::thread::sleep(Duration::from_millis(25 * attempt as u64));
                    continue;
                }
            }
            self.roundtrips.fetch_add(1, Ordering::Relaxed);
            return Ok((sock, resp));
        }
    }

    /// Request one chunk (`grid_bytes > 0`, under placement `generation`)
    /// or one item file (`grid_bytes == 0`, `chunk` = item index,
    /// `generation` ignored) from `peer`. `Ok(None)` ⇔ the peer answered
    /// `NotResident` (not held — or evicted/stale-generation on a
    /// residency-aware server).
    pub fn get_chunk(
        &self,
        peer: NodeId,
        dataset_id: u64,
        generation: u64,
        grid_bytes: u64,
        chunk: u64,
    ) -> Result<Option<Vec<u8>>> {
        let req = Frame::GetChunk { dataset_id, generation, chunk, grid_bytes };
        let (sock, resp) = self.pooled_request(peer, &req)?;
        match resp {
            Frame::ChunkData(bytes) => {
                if let Some(nic) = &self.nic {
                    nic[peer.0].acquire(bytes.len() as u64);
                }
                self.checkin(peer, sock);
                Ok(Some(bytes))
            }
            Frame::NotResident => {
                self.checkin(peer, sock);
                Ok(None)
            }
            Frame::Error(msg) => {
                // Request-level error: a complete frame was read, so the
                // connection's framing is intact — keep it pooled. A busy
                // rejection that exhausted its retries is the exception
                // (the server closed that socket after the frame).
                if !proto::is_server_busy(&msg) {
                    self.checkin(peer, sock);
                }
                bail!("peer node{} error: {msg}", peer.0)
            }
            _ => bail!("peer node{} answered GetChunk with the wrong frame kind", peer.0),
        }
    }

    /// Request `chunks.len()` chunks of one dataset from `peer` in a
    /// single round of framing. Entry `i` answers `chunks[i]`; `None` ⇔
    /// the peer does not hold that chunk. The whole batch costs one RTT
    /// instead of `chunks.len()` serial `get_chunk` calls.
    pub fn get_chunk_batch(
        &self,
        peer: NodeId,
        dataset_id: u64,
        generation: u64,
        grid_bytes: u64,
        chunks: &[u64],
    ) -> Result<Vec<Option<Vec<u8>>>> {
        if chunks.is_empty() {
            return Ok(vec![]);
        }
        if chunks.len() > proto::MAX_BATCH {
            bail!("batch of {} chunks exceeds cap {}", chunks.len(), proto::MAX_BATCH);
        }
        let req =
            Frame::GetChunkBatch { dataset_id, generation, grid_bytes, chunks: chunks.to_vec() };
        let (sock, resp) = self.pooled_request(peer, &req)?;
        match resp {
            Frame::ChunkBatchData(entries) => {
                if entries.len() != chunks.len() {
                    // Entry misalignment is a protocol violation: drop the
                    // connection rather than pool it.
                    bail!(
                        "peer node{} answered {} entries to a batch of {}",
                        peer.0,
                        entries.len(),
                        chunks.len()
                    );
                }
                if let Some(nic) = &self.nic {
                    let total: u64 = entries.iter().flatten().map(|b| b.len() as u64).sum();
                    nic[peer.0].acquire(total);
                }
                self.checkin(peer, sock);
                Ok(entries)
            }
            Frame::Error(msg) => {
                if !proto::is_server_busy(&msg) {
                    self.checkin(peer, sock);
                }
                bail!("peer node{} error: {msg}", peer.0)
            }
            _ => bail!("peer node{} answered GetChunkBatch with the wrong frame kind", peer.0),
        }
    }
}

/// Byte-bounded FIFO cache of fetched chunk payloads, keyed by the wire
/// address `(dataset_id, generation, grid_bytes, chunk)` — generation
/// included, so a re-placed dataset can never hit payloads cached under an
/// evicted placement. Within one generation chunk payloads are immutable
/// content, so hits are always valid; the bound evicts oldest first and
/// payloads larger than the bound are simply not cached.
struct ChunkCache {
    max_bytes: usize,
    /// (fifo of entries, current byte total).
    inner: Mutex<(VecDeque<((u64, u64, u64, u64), Arc<Vec<u8>>)>, usize)>,
}

impl ChunkCache {
    fn new(max_bytes: usize) -> Self {
        ChunkCache { max_bytes, inner: Mutex::new((VecDeque::new(), 0)) }
    }

    fn get(&self, key: &(u64, u64, u64, u64)) -> Option<Arc<Vec<u8>>> {
        let guard = self.inner.lock().unwrap();
        guard.0.iter().find(|(k, _)| k == key).map(|(_, v)| v.clone())
    }

    fn put(&self, key: (u64, u64, u64, u64), value: Arc<Vec<u8>>) {
        if value.len() > self.max_bytes {
            return;
        }
        let mut guard = self.inner.lock().unwrap();
        let (fifo, total) = &mut *guard;
        if fifo.iter().any(|(k, _)| *k == key) {
            return;
        }
        *total += value.len();
        fifo.push_back((key, value));
        while *total > self.max_bytes {
            match fifo.pop_front() {
                Some((_, old)) => *total -= old.len(),
                None => break,
            }
        }
    }
}

/// The TCP implementation of [`ChunkTransport`]: every non-local byte
/// crosses a socket at chunk granularity — ranged reads fetch the whole
/// chunk over the wire and slice locally (the wire unit is the chunk, per
/// the `(dataset, chunk)` addressing), and payloads are accounted as
/// `peer_net_bytes`/`peer_net_reads`, split from the same-FS disk-peer
/// counters.
///
/// With a chunk grid coarser than items, whole-chunk wire fetches amplify
/// warm-epoch traffic (every item of a chunk re-transfers the chunk).
/// [`SocketTransport::with_chunk_cache`] bounds that: recently fetched
/// chunks are served from a local byte-bounded cache (cache hits move no
/// wire bytes and are not accounted as `peer_net_*`). Off by default, so
/// the default transport's wire accounting stays exact.
pub struct SocketTransport {
    client: PeerClient,
    cache: Option<ChunkCache>,
}

impl SocketTransport {
    pub fn new(client: PeerClient) -> Self {
        SocketTransport { client, cache: None }
    }

    /// Cache up to `max_bytes` of fetched chunk payloads client-side.
    pub fn with_chunk_cache(mut self, max_bytes: usize) -> Self {
        self.cache = Some(ChunkCache::new(max_bytes));
        self
    }

    pub fn client(&self) -> &PeerClient {
        &self.client
    }

    fn account(stats: &mut ReadStats, bytes: &[u8]) {
        stats.peer_net_bytes += bytes.len() as u64;
        stats.peer_net_reads += 1;
    }

    /// Slice `offset..offset+len` out of a whole-chunk payload, erroring
    /// (never panicking) on a short payload from a buggy/hostile peer.
    fn slice_range(payload: &[u8], c: u64, offset: u64, len: u64) -> Result<Vec<u8>> {
        if (payload.len() as u64) < offset + len {
            bail!("chunk {c} payload is {} bytes, need {offset}+{len}", payload.len());
        }
        Ok(payload[offset as usize..(offset + len) as usize].to_vec())
    }
}

impl ChunkTransport for SocketTransport {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn fetch_chunk(
        &self,
        _cluster: &RealCluster,
        geom: &ChunkGeometry,
        c: u64,
        _reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Option<Vec<u8>>> {
        let key = (geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
        if let Some(cache) = &self.cache {
            if let Some(hit) = cache.get(&key) {
                // No wire traffic: not accounted as peer_net_*.
                return Ok(Some(hit.as_ref().clone()));
            }
        }
        let home = geom.node_of_chunk(c);
        let got =
            self.client.get_chunk(home, geom.dataset_id, geom.generation, geom.chunk_bytes(), c)?;
        match got {
            Some(bytes) => {
                Self::account(stats, &bytes);
                if let Some(cache) = &self.cache {
                    cache.put(key, Arc::new(bytes.clone()));
                }
                Ok(Some(bytes))
            }
            None => Ok(None),
        }
    }

    /// One `GetChunkBatch` round trip for every cache-missing chunk of the
    /// run (the wire unit stays the whole chunk; ranges are sliced
    /// locally). Wire accounting stays exact: each transferred payload is
    /// one `peer_net_read` of its full byte size, same as the unbatched
    /// path — only the framing round trips collapse.
    fn fetch_chunk_ranges(
        &self,
        _cluster: &RealCluster,
        geom: &ChunkGeometry,
        reqs: &[(u64, u64, u64)],
        _reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Vec<Option<Vec<u8>>>> {
        if reqs.is_empty() {
            return Ok(vec![]);
        }
        let home = geom.node_of_chunk(reqs[0].0);
        debug_assert!(
            reqs.iter().all(|&(c, _, _)| geom.node_of_chunk(c) == home),
            "a batch must target one serving node"
        );
        let mut out: Vec<Option<Vec<u8>>> = Vec::with_capacity(reqs.len());
        out.resize_with(reqs.len(), || None);
        // Local chunk-cache hits first: no wire traffic, no accounting.
        let mut miss_idx = Vec::with_capacity(reqs.len());
        let mut miss_chunks = Vec::with_capacity(reqs.len());
        for (k, &(c, off, len)) in reqs.iter().enumerate() {
            if let Some(cache) = &self.cache {
                let key = (geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
                if let Some(hit) = cache.get(&key) {
                    out[k] = Some(Self::slice_range(&hit, c, off, len)?);
                    continue;
                }
            }
            miss_idx.push(k);
            miss_chunks.push(c);
        }
        if miss_chunks.is_empty() {
            return Ok(out);
        }
        let got = self.client.get_chunk_batch(
            home,
            geom.dataset_id,
            geom.generation,
            geom.chunk_bytes(),
            &miss_chunks,
        )?;
        for (k, payload) in miss_idx.into_iter().zip(got) {
            let (c, off, len) = reqs[k];
            if let Some(bytes) = payload {
                Self::account(stats, &bytes);
                out[k] = Some(Self::slice_range(&bytes, c, off, len)?);
                if let Some(cache) = &self.cache {
                    let key = (geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
                    cache.put(key, Arc::new(bytes));
                }
            }
        }
        Ok(out)
    }

    fn fetch_item(
        &self,
        _cluster: &RealCluster,
        dataset_id: u64,
        _rel: &Path,
        item: u64,
        node: NodeId,
        _reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Option<Vec<u8>>> {
        match self.client.get_chunk(node, dataset_id, 0, ITEM_GRID, item)? {
            Some(bytes) => {
                Self::account(stats, &bytes);
                Ok(Some(bytes))
            }
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::peer::PeerServer;
    use crate::posix::realfs::chunk_rel_path;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hoard-peer-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn get_chunk_roundtrip_pool_reuse_and_not_resident() {
        let dir = tmpdir("client");
        let payload: Vec<u8> = (0..=255u8).cycle().take(5000).collect();
        let rel = chunk_rel_path(7, 1, 8192, 3);
        let path = dir.join(&rel);
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &payload).unwrap();

        let mut srv = PeerServer::start("127.0.0.1:0", dir.clone()).unwrap();
        let client = PeerClient::connect(vec![srv.addr]);
        assert_eq!(client.get_chunk(NodeId(0), 7, 1, 8192, 3).unwrap(), Some(payload.clone()));
        // Second request reuses the pooled connection.
        assert_eq!(client.get_chunk(NodeId(0), 7, 1, 8192, 3).unwrap(), Some(payload));
        // Missing chunk ⇒ NotResident ⇒ None (not an error).
        assert_eq!(client.get_chunk(NodeId(0), 7, 1, 8192, 4).unwrap(), None);
        // A different generation addresses a different chunk tree.
        assert_eq!(client.get_chunk(NodeId(0), 7, 2, 8192, 3).unwrap(), None);
        // A payload wider than the grid allows is a request-level error
        // even without a residency view (no exact length to check, but
        // the grid bounds every chunk).
        assert!(client.get_chunk(NodeId(0), 7, 1, 100, 3).is_err());
        // Item requests without an export are request-level errors.
        assert!(client.get_chunk(NodeId(0), 7, 0, 0, 0).is_err());
        // Registering an export makes item requests servable.
        srv.register_item_paths(7, |i| PathBuf::from(format!("items/i{i}.bin")));
        std::fs::create_dir_all(dir.join("items")).unwrap();
        std::fs::write(dir.join("items/i5.bin"), b"hello").unwrap();
        assert_eq!(client.get_chunk(NodeId(0), 7, 0, 0, 5).unwrap(), Some(b"hello".to_vec()));
        srv.stop();
        // A stopped server is a hard error, not a silent None.
        assert!(client.get_chunk(NodeId(0), 7, 1, 8192, 3).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unknown_peer_is_an_error() {
        let client = PeerClient::connect(vec![]);
        assert!(client.get_chunk(NodeId(0), 1, 1, 100, 0).is_err());
        assert!(client.get_chunk_batch(NodeId(0), 1, 1, 100, &[0]).is_err());
        // Empty batches never touch the wire, even with no peers.
        assert_eq!(client.get_chunk_batch(NodeId(0), 1, 1, 100, &[]).unwrap(), vec![]);
    }

    #[test]
    fn get_chunk_batch_one_roundtrip_mixed_residency() {
        let dir = tmpdir("batch");
        let mk = |c: u64| -> Vec<u8> { (0..100 + c as usize).map(|b| (b % 251) as u8).collect() };
        for c in [0u64, 2] {
            let rel = chunk_rel_path(9, 1, 256, c);
            std::fs::create_dir_all(dir.join(&rel).parent().unwrap()).unwrap();
            std::fs::write(dir.join(&rel), mk(c)).unwrap();
        }
        let mut srv = PeerServer::start("127.0.0.1:0", dir.clone()).unwrap();
        let client = PeerClient::connect(vec![srv.addr]);
        let before = client.wire_roundtrips();
        let got = client.get_chunk_batch(NodeId(0), 9, 1, 256, &[0, 1, 2]).unwrap();
        assert_eq!(got, vec![Some(mk(0)), None, Some(mk(2))]);
        assert_eq!(
            client.wire_roundtrips(),
            before + 1,
            "three chunks, mixed residency, exactly one round trip"
        );
        // The connection stays pooled and serves singles afterwards.
        assert_eq!(client.get_chunk(NodeId(0), 9, 1, 256, 0).unwrap(), Some(mk(0)));
        // A stale-generation batch sees none of the files.
        assert_eq!(
            client.get_chunk_batch(NodeId(0), 9, 2, 256, &[0, 1, 2]).unwrap(),
            vec![None, None, None]
        );
        // Over-cap batches are client-side errors before any wire traffic.
        let too_many: Vec<u64> = (0..=crate::peer::proto::MAX_BATCH as u64).collect();
        assert!(client.get_chunk_batch(NodeId(0), 9, 1, 256, &too_many).is_err());
        srv.stop();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pool_idle_ttl_reaps_and_redials() {
        let dir = tmpdir("ttl");
        let mut srv = PeerServer::start("127.0.0.1:0", dir.clone()).unwrap();
        let client =
            PeerClient::connect(vec![srv.addr]).with_idle_ttl(Duration::from_millis(50));
        // A request pools its connection on the way out.
        assert_eq!(client.get_chunk(NodeId(0), 1, 1, 64, 0).unwrap(), None);
        assert_eq!(client.pooled_conns(), 1);
        // Fresh sockets survive an explicit reap...
        assert_eq!(client.reap_idle(), 0);
        // ...and expired ones don't.
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(client.reap_idle(), 1);
        assert_eq!(client.pooled_conns(), 0);
        // Requests after a reap just dial fresh.
        assert_eq!(client.get_chunk(NodeId(0), 1, 1, 64, 0).unwrap(), None);
        assert_eq!(client.pooled_conns(), 1);
        // Checkout reaps lazily too: expire the pooled socket, request
        // again — the expired socket is skipped, not round-tripped.
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(client.get_chunk(NodeId(0), 1, 1, 64, 0).unwrap(), None);
        srv.stop();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dead_peer_classifies_fast_fails_and_cooldown_expires() {
        use crate::peer::{peer_down, FaultAction, FaultSpec};
        let dir = tmpdir("down");
        let mut srv = PeerServer::start("127.0.0.1:0", dir.clone()).unwrap();
        let client = PeerClient::connect(vec![srv.addr])
            .with_io_timeout(Duration::from_millis(500))
            .with_suspect_cooldown(Duration::from_millis(150));
        // Healthy: a NotResident answer, no suspicion.
        assert_eq!(client.get_chunk(NodeId(0), 1, 1, 64, 0).unwrap(), None);
        assert!(!client.is_suspected(NodeId(0)));
        // A request-level Error frame (item request without an export) is a
        // protocol error, NOT a dead-peer classification.
        let err = client.get_chunk(NodeId(0), 7, 0, 0, 0).unwrap_err();
        assert!(peer_down(&err).is_none(), "protocol errors must not classify: {err:#}");
        assert!(!client.is_suspected(NodeId(0)));
        // Kill fault: the pooled conn dies, the one redial dies too ⇒
        // typed PeerDown through the context layers + suspect mark.
        srv.inject_fault(FaultSpec { action: FaultAction::Kill, after: 0 });
        let err = client.get_chunk(NodeId(0), 1, 1, 64, 0).unwrap_err();
        let down = peer_down(&err).expect("kill must classify as PeerDown");
        assert_eq!(down.peer, 0);
        assert!(client.is_suspected(NodeId(0)));
        // Inside the cooldown: fail fast, no dial, no connect timeout.
        let t0 = Instant::now();
        let err = client.get_chunk(NodeId(0), 1, 1, 64, 0).unwrap_err();
        assert!(peer_down(&err).is_some());
        assert!(t0.elapsed() < Duration::from_millis(100), "suspected peer must fail fast");
        // Revive the peer; once the cooldown expires the next request
        // probes it and the peer serves again.
        srv.clear_fault();
        std::thread::sleep(Duration::from_millis(200));
        assert!(!client.is_suspected(NodeId(0)));
        assert_eq!(client.get_chunk(NodeId(0), 1, 1, 64, 0).unwrap(), None);
        srv.stop();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn busy_rejection_backs_off_then_surfaces_then_recovers() {
        let dir = tmpdir("busy");
        let mut srv = PeerServer::start_with_limits(
            "127.0.0.1:0",
            dir.clone(),
            None,
            Duration::from_secs(5),
            1,
        )
        .unwrap();
        // One client occupies the entire connection budget (its socket
        // stays pooled, hence live on the server).
        let holder = PeerClient::connect(vec![srv.addr]);
        assert_eq!(holder.get_chunk(NodeId(0), 1, 1, 64, 0).unwrap(), None);
        // A second client is rejected with the retryable busy signal —
        // after its backoff retries the distinguishable error surfaces.
        let rejected = PeerClient::connect(vec![srv.addr]);
        let err = rejected.get_chunk(NodeId(0), 1, 1, 64, 0).unwrap_err();
        assert!(
            format!("{err:#}").contains("server busy"),
            "busy rejection must be distinguishable, got: {err:#}"
        );
        // Freeing the slot lets the backoff-retry path get through.
        drop(holder);
        let t0 = Instant::now();
        loop {
            if rejected.get_chunk(NodeId(0), 1, 1, 64, 0).is_ok() {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "busy never cleared");
            std::thread::sleep(Duration::from_millis(20));
        }
        srv.stop();
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
