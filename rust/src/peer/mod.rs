//! The peer data plane: how non-local bytes move between cache nodes.
//!
//! The paper's core claim (§3.2, Table 3) is that striped *peer* reads
//! over the node interconnect beat the shared NFS server. Before this
//! module, real-mode peer reads were `fs::read` of another node's
//! directory on the same filesystem — the network leg was unmodeled. Now
//! every non-local byte moves through the [`ChunkTransport`] trait, with
//! two implementations:
//!
//!  * [`DirTransport`] — the degenerate same-FS peer-directory read
//!    (today's behaviour, kept as the default so every existing dir-mode
//!    path stays bit-identical);
//!  * [`SocketTransport`] — a real TCP data plane: a per-node
//!    event-driven [`PeerServer`] (FanStore-style user-level chunk
//!    server, multiplexing thousands of connections over one epoll loop)
//!    serving its node directory over the [`proto`] frame protocol, and a
//!    [`PeerClient`] with per-peer connection pools (idle-TTL reaped) and
//!    optional per-link NIC throttling.
//!
//! Wire addressing is `(dataset_id, generation, chunk, grid_bytes)` —
//! exactly the `(dataset, generation, chunk)` address the residency bitmap
//! and the on-disk chunk tree are keyed by (Clairvoyant Prefetching's
//! per-sample-ID granularity) — so a peer answers either `ChunkData` or
//! `NotResident`, and `NotResident` falls back to a remote fill that
//! records residency. A server with a registered residency view
//! ([`PeerServer::register_residency`]) additionally refuses evicted or
//! stale-generation requests with `NotResident` and validates payload
//! lengths against the grid, so eviction is visible on the wire instead of
//! being masked by leftover files.

pub mod client;
pub mod proto;
pub mod server;

pub use client::{PeerClient, SocketTransport, DEFAULT_SUSPECT_COOLDOWN};
pub use proto::Frame;
pub use server::{
    FaultAction, FaultSpec, PeerServer, ThreadedPeerServer, DEFAULT_IO_TIMEOUT, DEFAULT_MAX_CONNS,
};

use std::fmt;
use std::path::Path;

use anyhow::{bail, Result};

use crate::cache::ChunkGeometry;
use crate::netsim::NodeId;
use crate::posix::realfs::{chunk_rel_path, ReadStats, RealCluster};

/// A **connection-level** peer failure: the peer refused, reset, or timed
/// out after the client's bounded redial — the peer process is gone or
/// unreachable, as opposed to a protocol/data error (wrong frame, short
/// payload, server-side `Error` message), which stays a plain error.
///
/// Raised as the typed *source* of an `anyhow::Error`
/// (`Err(PeerDown { .. }.into())`) so it survives `.context(..)` layers
/// and is recoverable with [`peer_down`]. Readers treat it as a
/// degradation signal: re-plan the affected segments as remote fills
/// (byte-correct, fetch-once) and record
/// `peer_failures`/`degraded_reads`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PeerDown {
    /// The unreachable peer (node index in the client's address table).
    pub peer: usize,
    /// What the connection attempt saw ("connect refused", "reset
    /// mid-request", "suspected (cooldown)").
    pub reason: String,
}

impl fmt::Display for PeerDown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "peer node{} is down: {}", self.peer, self.reason)
    }
}

impl std::error::Error for PeerDown {}

/// Recover the typed [`PeerDown`] from an `anyhow::Error`, through any
/// number of `.context(..)` layers. `None` ⇔ the error is not a dead-peer
/// classification (protocol/data errors, I/O on local disk, ...).
pub fn peer_down(err: &anyhow::Error) -> Option<&PeerDown> {
    err.downcast_ref::<PeerDown>()
}

/// How non-local bytes reach a reader. Implementations must be cheap to
/// share across reader threads (`&self` methods, `Send + Sync`).
///
/// `Ok(None)` uniformly means "the serving node does not hold those
/// bytes" — the caller falls back to a remote fill and records residency;
/// `Err` is a transport-level failure (I/O error, dead peer).
#[allow(clippy::too_many_arguments)]
pub trait ChunkTransport: Send + Sync {
    /// Short tag for tables and logs ("dir" / "socket").
    fn name(&self) -> &'static str;

    /// Fetch the full payload of chunk `c` from its home node.
    fn fetch_chunk(
        &self,
        cluster: &RealCluster,
        geom: &ChunkGeometry,
        c: u64,
        reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Option<Vec<u8>>>;

    /// Ranged read within chunk `c`: `len` bytes at `offset` of the chunk
    /// payload. The default fetches the whole chunk and slices locally —
    /// what a wire transport does, since the wire unit is the chunk;
    /// [`DirTransport`] overrides it with a ranged file read so dir-mode
    /// bytes and accounting stay exactly as before.
    fn fetch_chunk_range(
        &self,
        cluster: &RealCluster,
        geom: &ChunkGeometry,
        c: u64,
        offset: u64,
        len: u64,
        reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Option<Vec<u8>>> {
        match self.fetch_chunk(cluster, geom, c, reader, stats)? {
            Some(b) => {
                // A short payload from a buggy/hostile peer is an error,
                // never a panic.
                if (b.len() as u64) < offset + len {
                    bail!("chunk {c} payload is {} bytes, need {offset}+{len}", b.len());
                }
                Ok(Some(b[offset as usize..(offset + len) as usize].to_vec()))
            }
            None => Ok(None),
        }
    }

    /// Batched ranged reads, all from **one** serving node: every
    /// `(chunk, offset, len)` request must be homed on the same node
    /// (`geom.node_of_chunk`). Entry `i` of the result aligns with request
    /// `i`; `None` ⇔ that chunk is not held by its home. The default runs
    /// the requests serially through [`ChunkTransport::fetch_chunk_range`]
    /// — bit-identical bytes and accounting for [`DirTransport`] — while
    /// [`SocketTransport`](client::SocketTransport) overrides it with a
    /// single `GetChunkBatch` wire round trip, so a reader pulling K
    /// chunks from one peer pays one round of framing instead of K serial
    /// RTTs.
    fn fetch_chunk_ranges(
        &self,
        cluster: &RealCluster,
        geom: &ChunkGeometry,
        reqs: &[(u64, u64, u64)],
        reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Vec<Option<Vec<u8>>>> {
        reqs.iter()
            .map(|&(c, off, len)| self.fetch_chunk_range(cluster, geom, c, off, len, reader, stats))
            .collect()
    }

    /// Fetch a whole peer *item file* (whole-file striping mode) from
    /// `node`. `rel` is the item's on-disk relative path (what the dir
    /// transport reads); `dataset_id`/`item` are the wire address (what
    /// the socket transport sends).
    fn fetch_item(
        &self,
        cluster: &RealCluster,
        dataset_id: u64,
        rel: &Path,
        item: u64,
        node: NodeId,
        reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Option<Vec<u8>>>;
}

/// The degenerate transport: peer reads are plain reads of the peer's
/// cache directory on the same filesystem, accounted as disk-peer traffic
/// (`peer_bytes`/`peer_reads`) through the peer node's NVMe bucket —
/// byte- and accounting-identical to the pre-transport code path.
#[derive(Debug, Default, Clone, Copy)]
pub struct DirTransport;

impl ChunkTransport for DirTransport {
    fn name(&self) -> &'static str {
        "dir"
    }

    fn fetch_chunk(
        &self,
        cluster: &RealCluster,
        geom: &ChunkGeometry,
        c: u64,
        reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Option<Vec<u8>>> {
        let home = geom.node_of_chunk(c);
        let crel = chunk_rel_path(geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
        if !cluster.node_has(home, &crel) {
            return Ok(None);
        }
        cluster.read_node_sharded(home, &crel, reader, stats).map(Some)
    }

    fn fetch_chunk_range(
        &self,
        cluster: &RealCluster,
        geom: &ChunkGeometry,
        c: u64,
        offset: u64,
        len: u64,
        reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Option<Vec<u8>>> {
        let home = geom.node_of_chunk(c);
        let crel = chunk_rel_path(geom.dataset_id, geom.generation, geom.chunk_bytes(), c);
        if !cluster.node_has(home, &crel) {
            return Ok(None);
        }
        let mut buf = vec![0u8; len as usize];
        cluster.read_node_range_into_sharded(home, &crel, offset, reader, &mut buf, stats)?;
        Ok(Some(buf))
    }

    fn fetch_item(
        &self,
        cluster: &RealCluster,
        _dataset_id: u64,
        rel: &Path,
        _item: u64,
        node: NodeId,
        reader: NodeId,
        stats: &mut ReadStats,
    ) -> Result<Option<Vec<u8>>> {
        if !cluster.node_has(node, rel) {
            return Ok(None);
        }
        cluster.read_node_sharded(node, rel, reader, stats).map(Some)
    }
}
