//! Wire protocol for the peer data plane: a length-prefixed binary frame
//! codec over TCP (std::net only, like `api::http`).
//!
//! Frame layout (little-endian):
//!
//! ```text
//! [u32 body_len][u8 tag][payload…]          body_len = 1 + payload length
//! ```
//!
//! | tag | frame            | payload                                            |
//! |-----|------------------|----------------------------------------------------|
//! | 1   | `GetChunk`       | `u64 dataset_id`, `u64 generation`, `u64 chunk`, `u64 grid_bytes` |
//! | 2   | `ChunkData`      | the raw chunk (or item-file) bytes                 |
//! | 3   | `NotResident`    | empty                                              |
//! | 4   | `Error`          | UTF-8 message                                      |
//! | 5   | `GetChunkBatch`  | `u64 dataset_id`, `u64 generation`, `u64 grid_bytes`, `u32 n`, `n × u64 chunk` |
//! | 6   | `ChunkBatchData` | `u32 n`, then per entry `u8 present` (+ `u64 len`, bytes when present) |
//!
//! The batch pair is the pipelined request path: a reader pulling K chunks
//! from one peer sends one `GetChunkBatch` and gets one `ChunkBatchData`
//! back — one round of framing instead of K serial request/response RTTs.
//! Batch entries align with the request's chunk list; `present = 0` is the
//! per-chunk `NotResident`. A batch response still obeys [`MAX_FRAME`]
//! (the server answers `Error` when the combined payload would not fit),
//! and batch sizes are capped at [`MAX_BATCH`] before any allocation.
//!
//! `GetChunk { grid_bytes: 0 }` ([`ITEM_GRID`]) addresses a whole *item
//! file* instead of a stripe chunk — `chunk` is then the item index, the
//! server resolves the path through a registered item export, and
//! `generation` is ignored (item files are not generation-scoped). Any
//! `grid_bytes > 0` addresses chunk `chunk` of that grid under placement
//! `generation`, exactly the `(dataset, generation, chunk)` address the
//! residency bitmap and the on-disk chunk tree are keyed by — a request
//! carrying a retired generation answers `NotResident` instead of serving
//! a stale file.
//!
//! Decoding is hardened: a length prefix above [`MAX_FRAME`] is rejected
//! *before* any allocation, truncated frames (header or body) error out,
//! and unknown tags / malformed payloads never panic.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Hard cap on one frame's body. Chunk payloads are bounded by the stripe
/// grid (64 MiB default, and the cache clamps grids to the dataset size),
/// so anything past this is a corrupt or hostile length prefix — reject it
/// before allocating.
pub const MAX_FRAME: usize = 256 << 20;

/// `grid_bytes` sentinel addressing whole item files (whole-file striping
/// mode); `chunk` is then the item index.
pub const ITEM_GRID: u64 = 0;

/// Hard cap on chunks per batch frame: enough for any item's chunk span,
/// small enough that a hostile count prefix cannot force a large
/// allocation before validation.
pub const MAX_BATCH: usize = 4096;

const TAG_GET_CHUNK: u8 = 1;
const TAG_CHUNK_DATA: u8 = 2;
const TAG_NOT_RESIDENT: u8 = 3;
const TAG_ERROR: u8 = 4;
const TAG_GET_CHUNK_BATCH: u8 = 5;
const TAG_CHUNK_BATCH_DATA: u8 = 6;

/// One protocol frame. Requests are always `GetChunk`; the other three are
/// responses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    /// "Send me chunk `chunk` of dataset `dataset_id`, placement
    /// `generation`, under the `grid_bytes` chunk grid" (or item `chunk`
    /// when `grid_bytes` is [`ITEM_GRID`]; `generation` is then ignored).
    GetChunk { dataset_id: u64, generation: u64, chunk: u64, grid_bytes: u64 },
    /// The full requested payload.
    ChunkData(Vec<u8>),
    /// The serving node does not hold that chunk — the caller falls back
    /// to a remote fill.
    NotResident,
    /// Request-level failure (bad request, local I/O error).
    Error(String),
    /// "Send me these chunks of dataset `dataset_id`, placement
    /// `generation`, under the `grid_bytes` grid" — K chunks, one round of
    /// framing.
    GetChunkBatch { dataset_id: u64, generation: u64, grid_bytes: u64, chunks: Vec<u64> },
    /// Batched response, entry `i` answering chunk `i` of the request
    /// (`None` ⇔ that chunk is not resident on the serving node).
    ChunkBatchData(Vec<Option<Vec<u8>>>),
}

/// Encode a frame (header + body).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut body = Vec::new();
    match frame {
        Frame::GetChunk { dataset_id, generation, chunk, grid_bytes } => {
            body.push(TAG_GET_CHUNK);
            body.extend_from_slice(&dataset_id.to_le_bytes());
            body.extend_from_slice(&generation.to_le_bytes());
            body.extend_from_slice(&chunk.to_le_bytes());
            body.extend_from_slice(&grid_bytes.to_le_bytes());
        }
        Frame::ChunkData(bytes) => {
            body.push(TAG_CHUNK_DATA);
            body.extend_from_slice(bytes);
        }
        Frame::NotResident => body.push(TAG_NOT_RESIDENT),
        Frame::Error(msg) => {
            body.push(TAG_ERROR);
            body.extend_from_slice(msg.as_bytes());
        }
        Frame::GetChunkBatch { dataset_id, generation, grid_bytes, chunks } => {
            assert!(chunks.len() <= MAX_BATCH, "batch of {} exceeds MAX_BATCH", chunks.len());
            body.push(TAG_GET_CHUNK_BATCH);
            body.extend_from_slice(&dataset_id.to_le_bytes());
            body.extend_from_slice(&generation.to_le_bytes());
            body.extend_from_slice(&grid_bytes.to_le_bytes());
            body.extend_from_slice(&(chunks.len() as u32).to_le_bytes());
            for c in chunks {
                body.extend_from_slice(&c.to_le_bytes());
            }
        }
        Frame::ChunkBatchData(entries) => {
            assert!(entries.len() <= MAX_BATCH, "batch of {} exceeds MAX_BATCH", entries.len());
            body.push(TAG_CHUNK_BATCH_DATA);
            body.extend_from_slice(&(entries.len() as u32).to_le_bytes());
            for e in entries {
                match e {
                    Some(bytes) => {
                        body.push(1);
                        body.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
                        body.extend_from_slice(bytes);
                    }
                    None => body.push(0),
                }
            }
        }
    }
    assert!(body.len() <= MAX_FRAME, "frame body {} exceeds MAX_FRAME", body.len());
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Write one frame and flush it.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    w.write_all(&encode(frame)).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Decode a frame body (tag + payload, the bytes after the length prefix).
pub fn decode(body: &[u8]) -> Result<Frame> {
    let (&tag, payload) = body.split_first().context("empty frame body")?;
    match tag {
        TAG_GET_CHUNK => {
            if payload.len() != 32 {
                bail!("GetChunk payload must be 32 bytes, got {}", payload.len());
            }
            let word = |i: usize| u64::from_le_bytes(payload[i..i + 8].try_into().unwrap());
            Ok(Frame::GetChunk {
                dataset_id: word(0),
                generation: word(8),
                chunk: word(16),
                grid_bytes: word(24),
            })
        }
        TAG_CHUNK_DATA => Ok(Frame::ChunkData(payload.to_vec())),
        TAG_NOT_RESIDENT => {
            if !payload.is_empty() {
                bail!("NotResident carries no payload, got {} bytes", payload.len());
            }
            Ok(Frame::NotResident)
        }
        TAG_ERROR => Ok(Frame::Error(String::from_utf8_lossy(payload).into_owned())),
        TAG_GET_CHUNK_BATCH => {
            if payload.len() < 28 {
                bail!("GetChunkBatch header needs 28 bytes, got {}", payload.len());
            }
            let word = |i: usize| u64::from_le_bytes(payload[i..i + 8].try_into().unwrap());
            let count = u32::from_le_bytes(payload[24..28].try_into().unwrap()) as usize;
            if count > MAX_BATCH {
                bail!("batch of {count} exceeds cap {MAX_BATCH}");
            }
            if payload.len() != 28 + 8 * count {
                bail!(
                    "GetChunkBatch of {count} chunks must be {} bytes, got {}",
                    28 + 8 * count,
                    payload.len()
                );
            }
            let chunks = (0..count).map(|k| word(28 + 8 * k)).collect();
            Ok(Frame::GetChunkBatch {
                dataset_id: word(0),
                generation: word(8),
                grid_bytes: word(16),
                chunks,
            })
        }
        TAG_CHUNK_BATCH_DATA => {
            if payload.len() < 4 {
                bail!("ChunkBatchData header needs 4 bytes, got {}", payload.len());
            }
            let count = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
            if count > MAX_BATCH {
                bail!("batch of {count} exceeds cap {MAX_BATCH}");
            }
            let mut entries = Vec::with_capacity(count);
            let mut at = 4usize;
            for k in 0..count {
                let &marker = payload.get(at).with_context(|| format!("entry {k} truncated"))?;
                at += 1;
                match marker {
                    0 => entries.push(None),
                    1 => {
                        let hdr = payload
                            .get(at..at + 8)
                            .with_context(|| format!("entry {k} length truncated"))?;
                        let len = u64::from_le_bytes(hdr.try_into().unwrap());
                        at += 8;
                        // Bounded by the remaining (already framed) bytes
                        // *before* any arithmetic or allocation, so a
                        // hostile length can neither overflow the cursor
                        // nor out-allocate the frame itself.
                        if len > (payload.len() - at) as u64 {
                            bail!("entry {k} payload truncated ({len} > remaining)");
                        }
                        let len = len as usize;
                        entries.push(Some(payload[at..at + len].to_vec()));
                        at += len;
                    }
                    m => bail!("entry {k} has unknown marker {m}"),
                }
            }
            if at != payload.len() {
                bail!("{} trailing bytes after {count} batch entries", payload.len() - at);
            }
            Ok(Frame::ChunkBatchData(entries))
        }
        t => bail!("unknown frame tag {t}"),
    }
}

/// Read one frame. `Ok(None)` ⇔ the stream closed cleanly before any byte
/// of a new frame (a client hanging up between requests). Everything else
/// partial — a truncated header, a truncated body, a read timeout — is an
/// error: framing sync is lost, so the connection must be dropped.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let mut hdr = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut hdr[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                bail!("truncated frame header ({got}/4 bytes)");
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading frame header"),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    if len == 0 {
        bail!("zero-length frame body");
    }
    if len > MAX_FRAME {
        // Reject before the allocation: a corrupt length prefix must never
        // turn into a multi-GiB Vec.
        bail!("frame length {len} exceeds cap {MAX_FRAME}");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("truncated frame body")?;
    decode(&body).map(Some)
}

/// Incremental decode for the event-driven server: if `buf` starts with a
/// complete frame, cut it out (draining the consumed bytes) and return it;
/// `Ok(None)` means more bytes are needed and `buf` is left untouched.
///
/// The hostile-input checks run as early as the bytes allow: a length
/// prefix of zero or past [`MAX_FRAME`] is rejected as soon as the 4
/// header bytes are buffered — *before* the body arrives and before any
/// allocation — so a connection spraying a multi-GiB length never costs
/// more than 4 bytes of buffer.
pub fn decode_prefix(buf: &mut Vec<u8>) -> Result<Option<Frame>> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().expect("4 bytes checked")) as usize;
    if len == 0 {
        bail!("zero-length frame body");
    }
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds cap {MAX_FRAME}");
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    let frame = decode(&buf[4..4 + len])?;
    buf.drain(..4 + len);
    Ok(Some(frame))
}

/// Encode a frame as write segments for a
/// [`BufferChain`](crate::net::BufferChain): `ChunkData` becomes
/// `[5-byte header, payload]` with the payload `Vec` moved — a chunk is
/// never memcpy'd into a staging buffer on its way out. Everything else
/// (requests, errors, batch frames, which interleave markers and bytes)
/// encodes contiguously.
pub fn encode_segments(frame: Frame) -> Vec<Vec<u8>> {
    match frame {
        Frame::ChunkData(bytes) => {
            assert!(bytes.len() < MAX_FRAME, "chunk of {} exceeds MAX_FRAME", bytes.len());
            let mut hdr = Vec::with_capacity(5);
            hdr.extend_from_slice(&(1 + bytes.len() as u32).to_le_bytes());
            hdr.push(TAG_CHUNK_DATA);
            vec![hdr, bytes]
        }
        other => vec![encode(&other)],
    }
}

/// `Error` frame message a server at its connection budget answers before
/// closing. [`is_server_busy`] recognises it (by prefix, so the server may
/// append detail) and lets clients back off and retry instead of failing
/// the read.
pub const SERVER_BUSY: &str = "server busy: connection capacity reached, retry later";

/// Whether an `Error` frame's message is the server-busy backpressure
/// signal (retryable) rather than a request failure (not retryable).
pub fn is_server_busy(msg: &str) -> bool {
    msg.starts_with("server busy")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop::forall, Rng};

    fn arbitrary_frame(rng: &mut Rng) -> Frame {
        match rng.gen_range(6) {
            0 => Frame::GetChunk {
                dataset_id: rng.next_u64(),
                generation: rng.next_u64(),
                chunk: rng.next_u64(),
                grid_bytes: rng.next_u64(),
            },
            1 => {
                let n = rng.gen_range(2048) as usize;
                let mut bytes = vec![0u8; n];
                for b in &mut bytes {
                    *b = rng.next_u64() as u8;
                }
                Frame::ChunkData(bytes)
            }
            2 => Frame::NotResident,
            3 => {
                let n = rng.gen_range(64);
                let msg: String =
                    (0..n).map(|_| (b'a' + (rng.gen_range(26) as u8)) as char).collect();
                Frame::Error(msg)
            }
            4 => Frame::GetChunkBatch {
                dataset_id: rng.next_u64(),
                generation: rng.next_u64(),
                grid_bytes: rng.next_u64(),
                chunks: (0..rng.gen_range(17)).map(|_| rng.next_u64()).collect(),
            },
            _ => Frame::ChunkBatchData(
                (0..rng.gen_range(9))
                    .map(|_| {
                        if rng.gen_range(3) == 0 {
                            None
                        } else {
                            let n = rng.gen_range(512) as usize;
                            let mut bytes = vec![0u8; n];
                            for b in &mut bytes {
                                *b = rng.next_u64() as u8;
                            }
                            Some(bytes)
                        }
                    })
                    .collect(),
            ),
        }
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        forall(200, arbitrary_frame, |frame| {
            let buf = encode(frame);
            match read_frame(&mut buf.as_slice()) {
                Ok(Some(back)) if back == *frame => Ok(()),
                Ok(other) => Err(format!("decoded {other:?} != {frame:?}")),
                Err(e) => Err(format!("decode failed: {e:#}")),
            }
        });
    }

    #[test]
    fn prop_truncated_frames_rejected_never_panic() {
        forall(100, arbitrary_frame, |frame| {
            let buf = encode(frame);
            for k in 0..buf.len() {
                match read_frame(&mut &buf[..k]) {
                    Ok(None) if k == 0 => {}
                    Ok(None) => return Err(format!("prefix {k} read as clean EOF")),
                    Ok(Some(f)) => return Err(format!("prefix {k} decoded as {f:?}")),
                    Err(_) if k > 0 => {}
                    Err(e) => return Err(format!("empty stream must be clean EOF: {e:#}")),
                }
            }
            Ok(())
        });
    }

    #[test]
    fn absurd_length_prefix_rejected_before_allocation() {
        // u32::MAX and anything past MAX_FRAME must error out without a
        // matching allocation (the cap check precedes the Vec).
        for len in [u32::MAX, (MAX_FRAME as u32) + 1] {
            let mut buf = len.to_le_bytes().to_vec();
            buf.push(TAG_CHUNK_DATA);
            let err = read_frame(&mut buf.as_slice()).unwrap_err();
            assert!(format!("{err:#}").contains("exceeds cap"), "{err:#}");
        }
    }

    #[test]
    fn zero_length_and_unknown_tag_rejected() {
        let buf = 0u32.to_le_bytes().to_vec();
        assert!(read_frame(&mut buf.as_slice()).is_err(), "zero-length body");
        let mut buf = 1u32.to_le_bytes().to_vec();
        buf.push(99);
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("unknown frame tag"), "{err:#}");
    }

    #[test]
    fn get_chunk_payload_size_enforced() {
        let mut body = vec![TAG_GET_CHUNK];
        body.extend_from_slice(&[0u8; 31]); // one byte short
        let err = decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("32 bytes"), "{err:#}");
        // A pre-generation 24-byte request is malformed too, not silently
        // decoded against shifted fields.
        let mut body = vec![TAG_GET_CHUNK];
        body.extend_from_slice(&[0u8; 24]);
        assert!(decode(&body).is_err());
    }

    #[test]
    fn clean_eof_is_none() {
        assert_eq!(read_frame(&mut &[][..]).unwrap(), None);
    }

    #[test]
    fn batch_count_cap_enforced_before_allocation() {
        // A hostile batch count past MAX_BATCH is rejected up front.
        let mut body = vec![TAG_GET_CHUNK_BATCH];
        body.extend_from_slice(&[0u8; 24]);
        body.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds cap"), "{err:#}");
        let mut body = vec![TAG_CHUNK_BATCH_DATA];
        body.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds cap"), "{err:#}");
    }

    #[test]
    fn batch_entry_hostile_length_rejected() {
        // One entry claiming u64::MAX payload bytes: rejected against the
        // remaining frame bytes, no overflow, no allocation.
        let mut body = vec![TAG_CHUNK_BATCH_DATA];
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(1);
        body.extend_from_slice(&u64::MAX.to_le_bytes());
        let err = decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn batch_trailing_bytes_rejected() {
        let mut buf = encode(&Frame::ChunkBatchData(vec![None, Some(vec![9, 9])]));
        // Graft a stray byte into the body and patch the length prefix.
        buf.push(0xAB);
        let len = (buf.len() - 4) as u32;
        buf[..4].copy_from_slice(&len.to_le_bytes());
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn empty_batch_roundtrips() {
        for f in [
            Frame::GetChunkBatch { dataset_id: 1, generation: 1, grid_bytes: 2, chunks: vec![] },
            Frame::ChunkBatchData(vec![]),
            Frame::ChunkBatchData(vec![None, Some(vec![]), None]),
        ] {
            let buf = encode(&f);
            assert_eq!(read_frame(&mut buf.as_slice()).unwrap(), Some(f));
        }
    }

    #[test]
    fn empty_chunk_data_roundtrips() {
        let f = Frame::ChunkData(vec![]);
        let buf = encode(&f);
        assert_eq!(read_frame(&mut buf.as_slice()).unwrap(), Some(f));
    }

    #[test]
    fn prop_decode_prefix_byte_at_a_time_matches_read_frame() {
        // Feeding the encoded bytes one at a time must yield exactly the
        // frame `read_frame` sees on the whole buffer, with Ok(None) at
        // every strict prefix and the buffer fully drained at the end.
        forall(100, arbitrary_frame, |frame| {
            let wire = encode(frame);
            let mut buf = Vec::new();
            for (i, b) in wire.iter().enumerate() {
                buf.push(*b);
                match decode_prefix(&mut buf) {
                    Ok(None) if i + 1 < wire.len() => {
                        if buf.len() != i + 1 {
                            return Err(format!("prefix {} bytes disturbed the buffer", i + 1));
                        }
                    }
                    Ok(None) => return Err("complete frame read as incomplete".into()),
                    Ok(Some(got)) if i + 1 == wire.len() => {
                        if got != *frame {
                            return Err(format!("decoded {got:?} != {frame:?}"));
                        }
                        if !buf.is_empty() {
                            return Err(format!("{} undrained bytes", buf.len()));
                        }
                    }
                    Ok(Some(got)) => return Err(format!("early decode at byte {i}: {got:?}")),
                    Err(e) => return Err(format!("prefix decode failed: {e:#}")),
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_decode_prefix_random_splits_preserve_pipelining() {
        // Several frames concatenated and split at arbitrary points must
        // come out as the same frame sequence, regardless of the split.
        forall(50, |rng| (0..3).map(|_| arbitrary_frame(rng)).collect::<Vec<_>>(), |frames| {
            let wire: Vec<u8> = frames.iter().flat_map(encode).collect();
            let mut rng = Rng::new(wire.len() as u64 + 7);
            let mut buf = Vec::new();
            let mut got = Vec::new();
            let mut at = 0usize;
            while at < wire.len() {
                let take = (rng.gen_range(7) as usize + 1).min(wire.len() - at);
                buf.extend_from_slice(&wire[at..at + take]);
                at += take;
                loop {
                    match decode_prefix(&mut buf) {
                        Ok(Some(f)) => got.push(f),
                        Ok(None) => break,
                        Err(e) => return Err(format!("split decode failed: {e:#}")),
                    }
                }
            }
            if got != *frames {
                return Err(format!("decoded {} frames, expected {}", got.len(), frames.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn decode_prefix_rejects_hostile_length_at_the_header() {
        // The oversize check fires with ONLY the 4 header bytes buffered —
        // before the hostile body could ever be buffered or allocated.
        for len in [0u32, (MAX_FRAME as u32) + 1, u32::MAX] {
            let mut buf = len.to_le_bytes().to_vec();
            assert!(
                decode_prefix(&mut buf).is_err(),
                "length {len} must be rejected from the header alone"
            );
        }
        // Three header bytes: undecidable, wait for more.
        let mut buf = vec![0xFF, 0xFF, 0xFF];
        assert!(decode_prefix(&mut buf).unwrap().is_none());
        assert_eq!(buf.len(), 3);
    }

    #[test]
    fn encode_segments_concatenate_to_encode() {
        forall(100, arbitrary_frame, |frame| {
            let whole = encode(frame);
            let segs = encode_segments(frame.clone());
            let glued: Vec<u8> = segs.concat();
            if glued != whole {
                return Err("segments don't concatenate to the contiguous encoding".into());
            }
            if let Frame::ChunkData(bytes) = frame {
                if segs.len() != 2 || segs[1] != *bytes {
                    return Err("ChunkData must split as [header, payload]".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn server_busy_signal_is_distinguishable() {
        assert!(is_server_busy(SERVER_BUSY));
        assert!(is_server_busy("server busy: shedding load"));
        assert!(!is_server_busy("expected a GetChunk request"));
        assert!(!is_server_busy("chunk 7 out of range"));
        // And it survives the wire.
        let buf = encode(&Frame::Error(SERVER_BUSY.to_string()));
        match read_frame(&mut buf.as_slice()).unwrap() {
            Some(Frame::Error(msg)) => assert!(is_server_busy(&msg)),
            other => panic!("expected Error frame, got {other:?}"),
        }
    }
}
