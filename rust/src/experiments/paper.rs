//! Reproductions of every table and figure in the paper's evaluation
//! (§3.3 Table 1, §4.1 Fig. 3 + Table 3, §4.2 Fig. 4, §4.3 Fig. 5,
//! §4.4 Table 4, §4.5 Table 5, plus the §4.1 2×-utilization claim).

use crate::cluster::GpuDemand;
use crate::config::ClusterConfig;
use crate::dfs::all_backends;
use crate::metrics::Table;
use crate::netsim::{LinkClass, NodeId, Topology};
use crate::remote::NfsModel;
use crate::storage::Volume;
use crate::workload::trainsim::{paper_scenario, ReadMode, TrainJobSim, TrainSim};
use crate::workload::{DatasetSpec, TrainJobSpec};

use super::mean;

/// Table 1 — distributed-FS comparison: single-epoch ResNet50 training
/// duration plus the feature matrix that drove the Spectrum Scale choice.
pub fn table1_fs_comparison() -> Table {
    let mut t = Table::new(
        "Table 1 — file systems for the distributed cache (1 epoch ResNet50, 4×P100, BS 128)",
        &["File system", "Training duration (min)", "cache mode", "node subset", "POSIX", "usable for Hoard"],
    );
    let ds = DatasetSpec::imagenet();
    let job = GpuDemand::table1_resnet_job();
    for fs in all_backends() {
        let minutes = fs.epoch_duration(&ds, &job, 1) / 60.0;
        let f = fs.features();
        t.row(vec![
            fs.name().to_string(),
            format!("{minutes:.1}"),
            yn(f.cache_mode),
            yn(f.node_subset),
            yn(f.posix),
            yn(fs.usable_for_hoard()),
        ]);
    }
    t
}

fn yn(b: bool) -> String {
    (if b { "yes" } else { "no" }).to_string()
}

/// Figure 3 — two-epoch training performance curves for REM / NVMe / Hoard.
/// Returns per-mode (time, images/s) series (job 0 of 4) plus a summary
/// table of per-epoch mean fps.
pub fn figure3_two_epochs() -> (Vec<(String, Vec<(f64, f64)>)>, Table) {
    let mut table = Table::new(
        "Figure 3 — two-epoch training performance (per 4-GPU job)",
        &["mode", "epoch-1 img/s", "epoch-2 img/s", "epoch-1 (s)", "epoch-2 (s)"],
    );
    let mut all_series = vec![];
    for (name, mode) in
        [("REM", ReadMode::Remote), ("NVMe", ReadMode::LocalNvme), ("Hoard", ReadMode::Hoard)]
    {
        let mut sim = paper_scenario(mode, 2);
        sim.sample_interval = 20.0;
        let res = sim.run();
        let job = &res.jobs[0];
        let e = &job.epoch_durations;
        let items = 1_281_167.0;
        table.row(vec![
            name.to_string(),
            format!("{:.0}", items / e[0]),
            format!("{:.0}", items / e[1]),
            format!("{:.0}", e[0]),
            format!("{:.0}", e[1]),
        ]);
        all_series.push((name.to_string(), job.fps_series.clone()));
    }
    (all_series, table)
}

/// Table 3 — long-training speedup projections vs REM.
pub fn table3_projections() -> Table {
    let mut t = Table::new(
        "Table 3 — long-training speedup projections (remote storage baseline)",
        &["", "2 epochs", "30 epochs", "60 epochs", "90 epochs"],
    );
    let epochs = [2u32, 30, 60, 90];
    let mut rows: Vec<(&str, ReadMode)> =
        vec![("REM", ReadMode::Remote), ("Hoard", ReadMode::Hoard), ("NVMe", ReadMode::LocalNvme)];
    let mut rem_time = [0.0f64; 4];
    for (i, &e) in epochs.iter().enumerate() {
        rem_time[i] = paper_scenario(ReadMode::Remote, e).run().makespan;
    }
    for (name, mode) in rows.drain(..) {
        let mut cells = vec![name.to_string()];
        for (i, &e) in epochs.iter().enumerate() {
            let t = if mode == ReadMode::Remote {
                rem_time[i]
            } else {
                paper_scenario(mode, e).run().makespan
            };
            cells.push(super::speedup(rem_time[i] / t));
        }
        t.row(cells);
    }
    t
}

/// Figure 4 — training performance vs memory-to-dataset ratio (MDR), first
/// and subsequent epochs, for all three systems. The `stress` tool is
/// modelled by shrinking the buffer cache; Hoard's pagepool is set to the
/// same MDR (paper §4.2).
pub fn figure4_mdr_sweep() -> Table {
    let mut t = Table::new(
        "Figure 4 — training performance vs memory/dataset ratio (img/s per job)",
        &["MDR", "REM e1", "REM e2+", "NVMe e1", "NVMe e2+", "Hoard e1", "Hoard e2+"],
    );
    let ds_bytes = 144e9;
    for mdr in [0.25, 0.5, 0.75, 1.0, 1.1] {
        let mut cells = vec![format!("{mdr}")];
        for mode in [ReadMode::Remote, ReadMode::LocalNvme, ReadMode::Hoard] {
            let mut sim = paper_scenario(mode, 3);
            for j in &mut sim.jobs {
                j.buffer_cache_bytes = mdr * ds_bytes;
                if mode == ReadMode::Hoard {
                    // Hoard's RAM tier is its pagepool, not the OS cache.
                    j.pagepool_bytes = mdr * ds_bytes;
                    j.buffer_cache_bytes = 0.0;
                }
            }
            let res = sim.run();
            let e = &res.jobs[0].epoch_durations;
            let items = 1_281_167.0;
            cells.push(format!("{:.0}", items / e[0]));
            cells.push(format!("{:.0}", items / mean(&e[1..])));
        }
        t.row(cells);
    }
    t
}

/// Figure 5 — training performance vs remote-storage bandwidth (the `tc`
/// throttling experiment), first and subsequent epochs.
pub fn figure5_remote_bw_sweep() -> Table {
    let mut t = Table::new(
        "Figure 5 — training performance vs remote storage bandwidth (img/s per job)",
        &["NFS peak (GB/s)", "REM e1", "REM e2+", "Hoard e1", "Hoard e2+"],
    );
    for frac in [0.2, 0.4, 0.6, 0.8, 1.0] {
        let mut cells = vec![format!("{:.2}", 1.05 * frac)];
        for mode in [ReadMode::Remote, ReadMode::Hoard] {
            let topo = Topology::paper_testbed();
            let vols: Vec<Volume> = (0..4).map(|_| Volume::paper_cache_volume()).collect();
            let mut sim = TrainSim::new(topo, Box::new(NfsModel::throttled(frac)), &vols);
            for i in 0..4 {
                let mut job = TrainJobSim::new(
                    TrainJobSpec::paper_job(format!("job{i}"), 3),
                    NodeId(i),
                    mode,
                );
                if mode == ReadMode::Hoard {
                    job.cache_nodes = (0..4).map(NodeId).collect();
                    job.pagepool_bytes = 16e9;
                }
                sim.add_job(job);
            }
            let res = sim.run();
            let e = &res.jobs[0].epoch_durations;
            let items = 1_281_167.0;
            cells.push(format!("{:.0}", items / e[0]));
            cells.push(format!("{:.0}", items / mean(&e[1..])));
        }
        t.row(cells);
    }
    t
}

/// Table 4 — network usage over a 60-epoch training (per 4-GPU job):
/// total data moved, transmission rate, training duration.
pub fn table4_network_usage() -> Table {
    let mut t = Table::new(
        "Table 4 — network usage during training (60 epochs, per 4-GPU job)",
        &["", "Total data transferred (TB)", "Transfer rate (Gb/s)", "Training duration (hours)"],
    );
    for (name, mode) in [("REM", ReadMode::Remote), ("Hoard", ReadMode::Hoard)] {
        let mut sim = paper_scenario(mode, 60);
        let res = sim.run();
        let job = &res.jobs[0];
        let dur_h = job.total_duration / 3600.0;
        // The paper's "total data transmitted" is the dataset moved per
        // epoch per job (for REM: NFS→node; for Hoard: the distributed-FS
        // exchange between cache nodes serving the job, incl. its local
        // stripe reads which GPFS still accounts as NSD traffic).
        let moved = job.bytes_from_remote + job.bytes_from_local + job.bytes_from_peers;
        let rate_gbps = moved * 8.0 / job.total_duration / 1e9;
        t.row(vec![
            name.to_string(),
            format!("{:.1}", moved / 1e12),
            format!("{rate_gbps:.2}"),
            format!("{dur_h:.2}"),
        ]);
    }
    t
}

/// Table 5 — % of a rack's 320 Gb/s uplink consumed when a fraction of 24
/// DL jobs is scheduled on a rack that does not cache their dataset.
pub fn table5_rack_uplink() -> Table {
    let mut t = Table::new(
        "Table 5 — rack up-link bandwidth used by misplaced DL jobs (24 jobs, 40G TOR, 3:1)",
        &["% jobs misplaced", "up-link BW used"],
    );
    for misplaced_pct in [20u32, 40, 60, 80] {
        let cfg = ClusterConfig::table5_datacenter(6, 4);
        let topo = cfg.topology();
        let vols: Vec<Volume> = (0..topo.num_nodes()).map(|_| Volume::paper_cache_volume()).collect();
        let mut sim = TrainSim::new(topo, Box::new(NfsModel::paper_nfs()), &vols);
        let n_jobs = 24usize;
        let n_misplaced = (n_jobs * misplaced_pct as usize + 50) / 100; // round
        for j in 0..n_jobs {
            let node = NodeId(j % sim.topology.num_nodes());
            let my_rack = sim.topology.rack_of(node);
            let mut job = TrainJobSim::new(
                TrainJobSpec::paper_job(format!("job{j}"), 1),
                node,
                ReadMode::Hoard,
            );
            job.pagepool_bytes = 0.0;
            job.set_warm(); // datasets already cached — steady-state view
            let cache_rack = if j < n_misplaced {
                // Dataset cached on the next rack over.
                crate::netsim::RackId((my_rack.0 + 1) % sim.topology.racks)
            } else {
                my_rack
            };
            job.cache_nodes = sim.topology.nodes_in_rack(cache_rack).collect();
            sim.add_job(job);
        }
        let res = sim.run();
        // Mean cross-rack transfer rate, as a fraction of one TOR uplink —
        // the paper's metric (all misplaced traffic vs the 320 Gb/s uplink).
        let mut uplink_bytes = 0.0;
        let mut uplink_cap = 1.0;
        for i in 0..res.traffic.bytes.len() {
            let id = crate::netsim::ResourceId(i);
            if let LinkClass::UplinkRx(_) = sim.topology.class_of(id) {
                uplink_bytes += res.traffic.bytes[i];
                uplink_cap = sim.topology.resources()[i].capacity;
            }
        }
        let used_frac = uplink_bytes / res.makespan / uplink_cap;
        t.row(vec![format!("{misplaced_pct}"), format!("{:.0}%", (used_frac * 100.0).ceil())]);
    }
    t
}

/// §4.1 claim — "the cluster can support 2x more jobs": hyper-parameter
/// sweep of 3 sequential rounds × 4 concurrent 10-epoch jobs over one
/// shared dataset; jobs-per-hour ratio Hoard vs REM.
pub fn utilization_2x() -> Table {
    let mut t = Table::new(
        "§4.1 — cluster utilization: hyper-parameter sweep throughput (12 jobs, 10 epochs each)",
        &["mode", "makespan (h)", "jobs/hour", "vs REM"],
    );
    let mut base = 0.0;
    for (name, mode) in [("REM", ReadMode::Remote), ("Hoard", ReadMode::Hoard)] {
        let mut total = 0.0;
        for round in 0..3 {
            let mut sim = paper_scenario(mode, 10);
            if mode == ReadMode::Hoard && round > 0 {
                // Dataset already cached from round 1 (life cycle decoupled
                // from jobs): mark jobs warm-start.
                for j in &mut sim.jobs {
                    j.buffer_cache_bytes = 0.0;
                    warm_start(j);
                }
            }
            total += sim.run().makespan;
        }
        let hours = total / 3600.0;
        let jph = 12.0 / hours;
        if name == "REM" {
            base = jph;
        }
        t.row(vec![
            name.to_string(),
            format!("{hours:.2}"),
            format!("{jph:.2}"),
            super::speedup(jph / base),
        ]);
    }
    t
}

/// Flip a Hoard job to warm-start (dataset already resident).
pub fn warm_start(job: &mut TrainJobSim) {
    // Epoch counter is private; emulate warm start by reducing the spec's
    // epoch count and accounting the skipped cold epoch as zero-cost —
    // the fluid sim treats epoch index 0 as the cold one, so instead mark
    // it via a 1-item cold epoch: set dataset as already cached through
    // `cache_nodes` and give the sim a warm hint.
    job.set_warm();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_matches_paper() {
        let t = table1_fs_comparison();
        assert_eq!(t.rows.len(), 3);
        // Durations within 5% of 28.9 / 28.6 / 27.5 and ordered.
        let mins: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        assert!((mins[0] - 28.9).abs() / 28.9 < 0.05, "{mins:?}");
        assert!((mins[1] - 28.6).abs() / 28.6 < 0.05);
        assert!((mins[2] - 27.5).abs() / 27.5 < 0.05);
        // Only spectrum-scale usable.
        assert_eq!(t.rows[2][5], "yes");
        assert_eq!(t.rows[0][5], "no");
        assert_eq!(t.rows[1][5], "no");
    }

    #[test]
    fn figure3_curve_shape() {
        let (series, table) = figure3_two_epochs();
        assert_eq!(series.len(), 3);
        // Hoard epoch-2 fps ≈ NVMe fps; epoch-1 slower than REM.
        let rem_e1: f64 = table.rows[0][1].parse().unwrap();
        let nvme_e2: f64 = table.rows[1][2].parse().unwrap();
        let hoard_e1: f64 = table.rows[2][1].parse().unwrap();
        let hoard_e2: f64 = table.rows[2][2].parse().unwrap();
        assert!(hoard_e1 < rem_e1);
        assert!(hoard_e2 > 0.9 * nvme_e2);
    }

    #[test]
    fn table3_matches_paper_within_5pct() {
        let t = table3_projections();
        let parse = |s: &str| s.trim_end_matches(" ×").parse::<f64>().unwrap();
        // rows: REM, Hoard, NVMe; cols: 2, 30, 60, 90.
        let hoard: Vec<f64> = (1..5).map(|i| parse(&t.rows[1][i])).collect();
        let nvme: Vec<f64> = (1..5).map(|i| parse(&t.rows[2][i])).collect();
        for (got, want) in hoard.iter().zip([0.93, 1.98, 2.07, 2.1]) {
            assert!((got - want).abs() / want < 0.05, "hoard {got} vs {want}");
        }
        for (got, want) in nvme.iter().zip([2.28, 2.3, 2.32, 2.32]) {
            assert!((got - want).abs() / want < 0.05, "nvme {got} vs {want}");
        }
    }

    #[test]
    fn figure4_hoard_agnostic_to_memory() {
        let t = figure4_mdr_sweep();
        // Hoard e2+ fps varies < 15% across MDR; REM e2+ varies a lot.
        let hoard: Vec<f64> = t.rows.iter().map(|r| r[6].parse().unwrap()).collect();
        let rem: Vec<f64> = t.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        let spread = |v: &[f64]| {
            (v.iter().cloned().fold(f64::MIN, f64::max) - v.iter().cloned().fold(f64::MAX, f64::min))
                / mean(v)
        };
        assert!(spread(&hoard) < 0.15, "hoard spread {hoard:?}");
        assert!(spread(&rem) > 0.5, "rem should depend on MDR: {rem:?}");
        // MDR 1.1: everything converges after warm-up.
        let last = t.rows.last().unwrap();
        let rem_e2: f64 = last[2].parse().unwrap();
        let nvme_e2: f64 = last[4].parse().unwrap();
        assert!((rem_e2 - nvme_e2).abs() / nvme_e2 < 0.05);
    }

    #[test]
    fn figure5_rem_tracks_bw_hoard_does_not() {
        let t = figure5_remote_bw_sweep();
        let rem_e2: Vec<f64> = t.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        let hoard_e2: Vec<f64> = t.rows.iter().map(|r| r[4].parse().unwrap()).collect();
        assert!(rem_e2[0] < 0.3 * rem_e2[4], "REM must scale with BW: {rem_e2:?}");
        let spread = (hoard_e2[4] - hoard_e2[0]).abs() / hoard_e2[4];
        assert!(spread < 0.05, "Hoard warm epochs BW-independent: {hoard_e2:?}");
        // Hoard cold epoch slower at low BW.
        let hoard_e1: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(hoard_e1[0] < hoard_e1[4]);
    }

    #[test]
    fn table4_matches_paper() {
        let t = table4_network_usage();
        let rem_tb: f64 = t.rows[0][1].parse().unwrap();
        let hoard_tb: f64 = t.rows[1][1].parse().unwrap();
        let rem_rate: f64 = t.rows[0][2].parse().unwrap();
        let hoard_rate: f64 = t.rows[1][2].parse().unwrap();
        let rem_h: f64 = t.rows[0][3].parse().unwrap();
        let hoard_h: f64 = t.rows[1][3].parse().unwrap();
        // Total moved matches in both systems (the paper's first check).
        assert!((rem_tb - hoard_tb).abs() / rem_tb < 0.02, "{rem_tb} vs {hoard_tb}");
        assert!((rem_tb - 8.6).abs() < 0.8); // ~144 GB × 60
        // Rate ~2.1–2.2× higher under Hoard; durations 14.9 vs 6.97 h.
        let ratio = hoard_rate / rem_rate;
        assert!((ratio - 2.14).abs() < 0.15, "rate ratio {ratio}");
        assert!((rem_h - 14.9).abs() / 14.9 < 0.03);
        assert!((hoard_h - 6.97).abs() / 6.97 < 0.05);
    }

    #[test]
    fn table5_matches_paper() {
        let t = table5_rack_uplink();
        let got: Vec<f64> = t
            .rows
            .iter()
            .map(|r| r[1].trim_end_matches('%').parse().unwrap())
            .collect();
        for (g, want) in got.iter().zip([5.0, 9.0, 13.0, 17.0]) {
            assert!((g - want).abs() <= 2.0, "uplink {got:?} vs paper [5, 9, 13, 17]");
        }
    }

    #[test]
    fn utilization_at_least_1_9x() {
        let t = utilization_2x();
        let parse = |s: &str| s.trim_end_matches(" ×").parse::<f64>().unwrap();
        let ratio = parse(&t.rows[1][3]);
        assert!(ratio > 1.9, "Hoard should roughly double utilization: {ratio}");
    }
}
