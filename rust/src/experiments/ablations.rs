//! Ablations over Hoard's design choices (DESIGN.md §5): stripe width,
//! prefetch vs demand-fetch, eviction policy under contention, and
//! co-scheduling on/off. These back the claims the paper makes in prose
//! (§3.1 on placement, §4.5 on co-scheduling).

use crate::cache::{CacheEvent, CacheManager, EvictionPolicy};
use crate::metrics::Table;
use crate::netsim::{NodeId, Topology};
use crate::remote::NfsModel;
use crate::storage::{Device, DeviceKind, Volume};
use crate::workload::trainsim::{ReadMode, TrainJobSim, TrainSim};
use crate::workload::{DatasetSpec, TrainJobSpec};

/// Stripe width 1..=4 on the paper testbed: warm-epoch fps and the
/// local-read fraction. Width 1 turns the "distributed" cache into a single
/// remote NVMe for 3 of 4 jobs.
pub fn ablation_stripe_width() -> Table {
    let mut t = Table::new(
        "Ablation — stripe width (4 jobs, warm epochs)",
        &[
            "width",
            "warm img/s per job",
            "local read fraction",
            "aggregate cache capacity (TB)",
            "makespan (s, 2 warm epochs)",
        ],
    );
    for width in 1..=4usize {
        let topo = Topology::paper_testbed();
        let vols: Vec<Volume> = (0..4).map(|_| Volume::paper_cache_volume()).collect();
        let mut sim = TrainSim::new(topo, Box::new(NfsModel::paper_nfs()), &vols);
        for i in 0..4 {
            let mut job = TrainJobSim::new(
                TrainJobSpec::paper_job(format!("job{i}"), 2),
                NodeId(i),
                ReadMode::Hoard,
            );
            job.cache_nodes = (0..width).map(NodeId).collect();
            job.set_warm();
            sim.add_job(job);
        }
        let res = sim.run();
        let job0 = &res.jobs[0];
        let items = 1_281_167.0;
        let fps = items / job0.epoch_durations[0];
        let local_frac = job0.bytes_from_local / job0.total_bytes_read();
        t.row(vec![
            format!("{width}"),
            format!("{fps:.0}"),
            format!("{local_frac:.2}"),
            format!("{:.1}", width as f64 * 1.024),
            format!("{:.0}", res.makespan),
        ]);
    }
    t
}

/// Prefetch vs demand-fetch: time until the dataset is fully resident and
/// first-epoch duration. Prefetch overlaps fetch with early training.
pub fn ablation_prefetch() -> Table {
    let mut t = Table::new(
        "Ablation — prefetch vs demand fetch (cold start)",
        &["mode", "epoch-1 (s)", "epoch-2 (s)", "NFS bytes (GB)"],
    );
    // Demand fetch: plain cold Hoard epoch.
    {
        let mut sim = crate::workload::trainsim::paper_scenario(ReadMode::Hoard, 2);
        let res = sim.run();
        let e = &res.jobs[0].epoch_durations;
        t.row(vec![
            "demand-fetch".into(),
            format!("{:.0}", e[0]),
            format!("{:.0}", e[1]),
            format!("{:.0}", res.traffic.bytes[res.nfs_resource.0] / 1e9),
        ]);
    }
    // Prefetch: dataset staged before the job starts (fetch time charged
    // up front at full NFS speed — 1 reader, no seeky degradation).
    {
        let nfs = NfsModel::paper_nfs();
        let prefetch_secs = 144e9 / crate::remote::RemoteStore::effective_bw(&nfs, 4);
        let mut sim = crate::workload::trainsim::paper_scenario(ReadMode::Hoard, 2);
        for j in &mut sim.jobs {
            j.set_warm();
        }
        let res = sim.run();
        let e = &res.jobs[0].epoch_durations;
        t.row(vec![
            format!("prefetch (+{prefetch_secs:.0}s staging)"),
            format!("{:.0}", e[0]),
            format!("{:.0}", e[1]),
            "144".into(),
        ]);
    }
    t
}

/// Eviction policy under capacity contention: manual rejects the second
/// dataset; dataset-LRU evicts the idle one and both sweeps finish.
pub fn ablation_eviction() -> Table {
    let mut t = Table::new(
        "Ablation — eviction policy under contention (2 datasets, cache fits 1.3)",
        &["policy", "dataset B admitted", "evictions", "events"],
    );
    for (name, policy) in
        [("manual", EvictionPolicy::Manual), ("dataset-lru", EvictionPolicy::DatasetLru)]
    {
        let vols: Vec<Volume> = (0..4)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 50_000_000_000)]))
            .collect();
        let mut cache = CacheManager::new(vols, policy);
        cache
            .register(DatasetSpec::new("A", 1000, 144_000_000_000), "nfs://s/A".into())
            .unwrap();
        cache.place("A", (0..4).map(NodeId).collect()).unwrap();
        cache.prefetch_tick("A", 144_000_000_000).unwrap();
        cache
            .register(DatasetSpec::new("B", 1000, 120_000_000_000), "nfs://s/B".into())
            .unwrap();
        let admitted = cache.place("B", (0..4).map(NodeId).collect()).is_ok();
        let evictions =
            cache.events.iter().filter(|e| matches!(e, CacheEvent::Evicted(_))).count();
        t.row(vec![
            name.into(),
            yn(admitted),
            format!("{evictions}"),
            format!("{}", cache.events.len()),
        ]);
    }
    t
}

fn yn(b: bool) -> String {
    (if b { "yes" } else { "no" }).to_string()
}

/// Co-scheduling on/off: warm-epoch fps when jobs run on their cache nodes
/// vs one rack over. With P100s on 100 GbE the paper "could not stress the
/// cache enough" (§4.5); with V100-class consumers (3× the demand) on a
/// 40G/3:1 fabric, misplacement saturates the rack uplink — the future the
/// paper's §4.5 warns about. Both rows are reported.
pub fn ablation_coscheduling() -> Table {
    let mut t = Table::new(
        "Ablation — co-scheduling (2 racks of 4, 40G NICs, 3:1 uplink, warm epochs)",
        &["gpu", "placement", "warm img/s per job", "uplink utilization"],
    );
    use crate::cluster::{DlModel, GpuDemand, GpuKind};
    for gpu in [GpuKind::P100, GpuKind::V100] {
        for (name, misplaced) in [("co-scheduled", false), ("misplaced (other rack)", true)] {
            // 40G NICs (5 GB/s), 3:1 oversubscribed uplink (~6.7 GB/s for
            // 4 nodes × 40G = 160G downlink ⇒ ~53 Gb/s up).
            let topo = Topology::new(2, 4, 5e9, 6.7e9);
            let vols: Vec<Volume> = (0..8).map(|_| Volume::paper_cache_volume()).collect();
            let mut sim = TrainSim::new(topo, Box::new(NfsModel::paper_nfs()), &vols);
            for i in 0..4 {
                let node = NodeId(i);
                let mut spec = TrainJobSpec::paper_job(format!("job{i}"), 1);
                spec.demand = GpuDemand { gpus: 4, gpu, model: DlModel::AlexNet, batch_per_gpu: 1536 };
                let mut job = TrainJobSim::new(spec, node, ReadMode::Hoard);
                job.cache_nodes = if misplaced {
                    (4..8).map(NodeId).collect() // rack 1 holds the data
                } else {
                    (0..4).map(NodeId).collect()
                };
                job.set_warm();
                sim.add_job(job);
            }
            let res = sim.run();
            let items = 1_281_167.0;
            let fps = items / res.jobs[0].epoch_durations[0];
            // Rack-0 uplink rx utilization: the interference the paper's
            // §4.5 worries about (bandwidth stolen from other tenants).
            let mut util = 0.0f64;
            for i in 0..res.traffic.bytes.len() {
                let id = crate::netsim::ResourceId(i);
                if let crate::netsim::LinkClass::UplinkRx(0) = sim.topology.class_of(id) {
                    util = res.traffic.bytes[i] / res.makespan
                        / sim.topology.resources()[i].capacity;
                }
            }
            t.row(vec![
                format!("{gpu:?}"),
                name.into(),
                format!("{fps:.0}"),
                format!("{:.0}%", util * 100.0),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripe_width_scales_capacity_not_throughput() {
        // The paper's point (§4.1): striping multiplies *capacity*; at the
        // testbed's NVMe/NIC headroom, warm throughput is width-invariant.
        let t = ablation_stripe_width();
        let fps: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        for f in &fps {
            assert!((f - fps[0]).abs() / fps[0] < 0.02, "{fps:?}");
        }
        // Local fraction tracks 1/width for the co-located job.
        let lf: Vec<f64> = t.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        assert!((lf[3] - 0.25).abs() < 0.05, "{lf:?}");
        assert!(lf[0] > 0.9, "width-1 job0 reads all-local: {lf:?}");
        // Capacity column grows linearly.
        let cap: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!((cap[3] / cap[0] - 4.0).abs() < 0.15, "{cap:?}");
    }

    #[test]
    fn prefetch_warm_epochs_match() {
        let t = ablation_prefetch();
        let demand_e2: f64 = t.rows[0][2].parse().unwrap();
        let prefetch_e1: f64 = t.rows[1][1].parse().unwrap();
        // With prefetch, even "epoch 1" runs at warm speed.
        assert!((prefetch_e1 - demand_e2).abs() / demand_e2 < 0.05);
    }

    #[test]
    fn eviction_policies_differ() {
        let t = ablation_eviction();
        assert_eq!(t.rows[0][1], "no");
        assert_eq!(t.rows[1][1], "yes");
        assert_eq!(t.rows[1][2], "1");
    }

    #[test]
    fn misplacement_interferes_3x_more_with_v100() {
        let t = ablation_coscheduling();
        // rows: P100 co / P100 mis / V100 co / V100 mis.
        let util = |r: usize| -> f64 { t.rows[r][3].trim_end_matches('%').parse().unwrap() };
        assert!(util(0) < 1.0, "co-scheduled jobs must not touch the uplink");
        assert!(util(2) < 1.0);
        let (p100, v100) = (util(1), util(3));
        assert!(p100 > 5.0, "misplaced P100 jobs use the uplink: {p100}%");
        assert!((v100 / p100 - 3.0).abs() < 0.3, "V100 interference ≈ 3×: {v100}% vs {p100}%");
    }
}
