//! Failover drill (`hoard exp failover`): epoch times of one striped
//! dataset as cache nodes die mid-epoch, are declared failed, rejoin,
//! and are re-placed around — the node-death lifecycle measured end to
//! end on real sockets.
//!
//! What it shows: a killed peer degrades throughput but never
//! correctness — readers classify the connection-level failure
//! ([`PeerDown`](crate::peer::PeerDown)), re-plan the affected segments
//! as byte-correct remote fills (`degraded_reads`), and the epoch
//! completes. Declaring the node failed ([`DataPlane::fail_node`])
//! turns the transient degradation into planned remote fills; a rejoin
//! ([`DataPlane::recover_node`]) re-admits the refills that landed
//! while the node was out, and a re-stripe onto the survivor set
//! ([`DataPlane::replace_dataset`]) migrates surviving chunk files
//! under a bumped generation instead of starting cold. A second table
//! drives the same story through the `/v1/jobs` HTTP surface: the
//! session answers with its lifecycle state, survives degradation, and
//! a retired dataset answers `410 Gone`. Emits the standard
//! `metrics::Table` JSON shape under `--json`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cache::{CacheManager, EvictionPolicy, SharedCache};
use crate::metrics::Table;
use crate::netsim::NodeId;
use crate::peer::{FaultAction, FaultSpec, PeerClient, PeerServer, SocketTransport};
use crate::posix::dataplane::{DataPlane, JobSession, JobSpec};
use crate::posix::realfs::{ReadStats, RealCluster};
use crate::remote::NfsModel;
use crate::storage::{Device, DeviceKind, Volume};
use crate::workload::datagen::{self, DataGenConfig};
use crate::workload::DatasetSpec;

/// Nodes in the failover testbed (the paper's 4-node cluster).
pub const FAILOVER_NODES: usize = 4;

/// Short suspect cooldown so the drill's rejoin step probes the revived
/// peer within one run instead of waiting out the production default.
const DRILL_COOLDOWN: Duration = Duration::from_millis(200);

/// One epoch of the drill: what happened, how long it took, and the
/// degradation accounting that proves correctness was never traded.
#[derive(Debug, Clone)]
pub struct FailoverStep {
    pub action: String,
    pub epoch_s: f64,
    pub items_per_s: f64,
    /// Connection-level peer failures classified this epoch.
    pub peer_failures: u64,
    /// Reads that fell back to a byte-correct remote fill after a peer
    /// failure.
    pub degraded_reads: u64,
    pub remote_reads: u64,
    /// The dataset's lifecycle state after the step.
    pub lifecycle: String,
}

fn step(
    action: &str,
    sess: &JobSession,
    plane: &DataPlane,
    cluster: &RealCluster,
    epoch: u32,
) -> Result<FailoverStep> {
    cluster.take_stats();
    let report = sess.run_epoch(epoch).with_context(|| format!("epoch '{action}'"))?;
    let s: ReadStats = report.merged;
    Ok(FailoverStep {
        action: action.to_string(),
        epoch_s: report.wall.as_secs_f64(),
        items_per_s: report.items_per_sec(sess.cfg().num_items),
        peer_failures: s.peer_failures,
        degraded_reads: s.degraded_reads,
        remote_reads: s.remote_reads,
        lifecycle: plane.dataset_lifecycle(sess.dataset()),
    })
}

/// The full drill over real sockets: baseline epochs, a peer killed
/// mid-epoch, the node declared failed, a second failure, a rejoin, and
/// a re-place onto the survivor set. Every epoch must complete
/// byte-correct; the returned steps carry the degradation accounting.
pub fn failover_run(items: u64, chunk_bytes: u64, readers: usize) -> Result<Vec<FailoverStep>> {
    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf =
        std::env::temp_dir().join(format!("hoard-failover-{}-{seq}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, FAILOVER_NODES, 200e6)
        .context("creating failover cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    let cfg = DataGenConfig { num_items: items, files_per_dir: 32, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).context("generating dataset")?;

    let vols = (0..FAILOVER_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.chunk_bytes = chunk_bytes;
    manager.register(DatasetSpec::new("d", items, total), "nfs://remote/d".into())?;
    manager.place("d", (0..FAILOVER_NODES).map(NodeId).collect())?;
    let cache = SharedCache::new(manager);

    // One PeerServer per node so "node death" is a real socket-level
    // event (fault injection), not bookkeeping.
    let mut servers: Vec<PeerServer> = Vec::new();
    for n in 0..FAILOVER_NODES {
        servers.push(
            PeerServer::start_with(
                "127.0.0.1:0",
                cluster.node_dirs[n].clone(),
                Some(cluster.node_bw[n].clone()),
                Duration::from_secs(5),
            )
            .with_context(|| format!("starting peer server for node{n}"))?,
        );
    }
    let addrs = servers.iter().map(|s| s.addr).collect();
    let client =
        PeerClient::connect(addrs).with_nic_bw(1.25e9).with_suspect_cooldown(DRILL_COOLDOWN);
    let plane = Arc::new(
        DataPlane::new(cluster.clone(), cache)
            .with_transport(Box::new(SocketTransport::new(client))),
    );
    let sess = plane.open_job(JobSpec::new("d", cfg.clone()).readers(readers).seed(0xFA11))?;

    let mut steps = Vec::new();
    steps.push(step("baseline cold", &sess, &plane, &cluster, 0)?);
    steps.push(step("baseline warm", &sess, &plane, &cluster, 1)?);

    // Kill node3's peer process mid-epoch: after a couple of served
    // chunks every request sees a connection reset — the reader pool
    // must classify, degrade, and finish the epoch.
    servers[3].inject_fault(FaultSpec { action: FaultAction::Kill, after: 2 });
    steps.push(step("node3 killed mid-epoch", &sess, &plane, &cluster, 2)?);

    // The coordinator declares the node failed: survivor chunks keep
    // serving, lost chunks re-plan as remote fills.
    plane.fail_node(NodeId(3))?;
    steps.push(step("node3 declared failed (1 lost)", &sess, &plane, &cluster, 3)?);

    // A second failure deepens the degradation.
    servers[2].inject_fault(FaultSpec { action: FaultAction::Kill, after: 0 });
    plane.fail_node(NodeId(2))?;
    steps.push(step("node2 also failed (2 lost)", &sess, &plane, &cluster, 4)?);

    // Recovery action A — node2 rejoins: clear the fault, wait out the
    // suspect cooldown, re-admit the refills that landed while it was
    // out.
    servers[2].clear_fault();
    plane.recover_node(NodeId(2));
    std::thread::sleep(DRILL_COOLDOWN + Duration::from_millis(50));
    steps.push(step("node2 rejoined", &sess, &plane, &cluster, 5)?);

    // Recovery action B — node3 stays dead: re-stripe onto the
    // survivor set under a bumped generation; surviving chunk files
    // migrate on disk, only the lost third refetches.
    plane.replace_dataset("d", (0..3).map(NodeId).collect())?;
    let fresh = plane.open_job(JobSpec::new("d", cfg).readers(readers).seed(0xFA12))?;
    steps.push(step("re-placed on [0,1,2]", &fresh, &plane, &cluster, 0)?);

    for s in &mut servers {
        s.stop();
    }
    let _ = std::fs::remove_dir_all(&root);
    Ok(steps)
}

/// The failover drill table over an explicit shape.
pub fn failover_table_with(items: u64, chunk_bytes: u64, readers: usize) -> Table {
    let mut t = Table::new(
        "Real mode — failover drill: epoch time vs node failures and recovery actions (TCP peers)",
        &[
            "action",
            "epoch (s)",
            "img/s",
            "peer failures",
            "degraded reads",
            "remote reads",
            "lifecycle",
        ],
    );
    match failover_run(items, chunk_bytes, readers) {
        Ok(steps) => {
            for s in steps {
                t.row(vec![
                    s.action,
                    format!("{:.3}", s.epoch_s),
                    format!("{:.0}", s.items_per_s),
                    format!("{}", s.peer_failures),
                    format!("{}", s.degraded_reads),
                    format!("{}", s.remote_reads),
                    s.lifecycle,
                ]);
            }
        }
        Err(e) => {
            let mut cells = vec!["-".to_string(), format!("failed: {e:#}")];
            cells.resize(7, String::new());
            t.row(cells);
        }
    }
    t
}

/// The default `hoard exp failover` table: sub-item chunks, 2 readers.
/// Honors `HOARD_BENCH_SMOKE=1`.
pub fn failover_table(items: u64) -> Table {
    let smoke = std::env::var("HOARD_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let items = if smoke { items.min(8) } else { items };
    failover_table_with(items, 1000, 2)
}

/// The jobs-level failover scenario, driven entirely through the
/// `/v1/jobs` HTTP surface: open a session, degrade the plane under it,
/// keep training, then retire the dataset and watch the API answer
/// `410 Gone` instead of a generic 500.
pub fn failover_jobs_table() -> Table {
    let mut t = Table::new(
        "Real mode — failover over /v1/jobs (session survives degradation; retired answers 410)",
        &["step", "request", "status", "lifecycle"],
    );
    match failover_jobs_run() {
        Ok(rows) => {
            for (s, req, status, lc) in rows {
                t.row(vec![s, req, format!("{status}"), lc]);
            }
        }
        Err(e) => {
            let mut cells = vec!["-".to_string(), format!("failed: {e:#}")];
            cells.resize(4, String::new());
            t.row(cells);
        }
    }
    t
}

/// (step, request, status, lifecycle-after) rows for
/// [`failover_jobs_table`] — also the jobs-level drill the tests pin.
pub fn failover_jobs_run() -> Result<Vec<(String, String, u16, String)>> {
    use crate::api::{request, serve_with_plane};
    use crate::coordinator::Hoard;
    use std::sync::Mutex;

    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf =
        std::env::temp_dir().join(format!("hoard-failover-jobs-{}-{seq}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, FAILOVER_NODES, 200e6)
        .context("creating jobs-drill cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    let cfg = DataGenConfig { num_items: 8, files_per_dir: 32, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).context("generating dataset")?;
    let vols = (0..FAILOVER_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.chunk_bytes = 1000;
    manager.register(DatasetSpec::new("d", cfg.num_items, total), "nfs://remote/d".into())?;
    manager.place("d", (0..FAILOVER_NODES).map(NodeId).collect())?;
    let plane = Arc::new(DataPlane::new(cluster.clone(), SharedCache::new(manager)));
    plane.register_dataset("d", cfg);

    let hoard = Arc::new(Mutex::new(Hoard::paper_testbed()));
    let mut srv = serve_with_plane("127.0.0.1:0", hoard, plane.clone())?;
    let addr = srv.addr;

    let mut rows: Vec<(String, String, u16, String)> = Vec::new();
    let mut push = |step: &str, req: String, status: u16, plane: &DataPlane| {
        rows.push((step.to_string(), req, status, plane.dataset_lifecycle("d")));
    };

    let (st, _) = request(
        addr,
        "POST",
        "/v1/jobs",
        r#"{"name":"train","dataset":"d","readers":1,"epochs":1}"#,
    )?;
    push("open + cold epoch", "POST /v1/jobs".into(), st, &plane);

    plane.fail_node(NodeId(1))?;
    let (st, _) = request(addr, "GET", "/v1/jobs/train", "")?;
    push("node1 failed", "GET /v1/jobs/train".into(), st, &plane);

    let (st, _) = request(addr, "POST", "/v1/jobs/train/epoch", "")?;
    push("epoch while degraded", "POST /v1/jobs/train/epoch".into(), st, &plane);

    plane.recover_node(NodeId(1));
    let (st, _) = request(addr, "POST", "/v1/jobs/train/epoch", "")?;
    push("epoch after rejoin", "POST /v1/jobs/train/epoch".into(), st, &plane);

    plane.delete_dataset("d")?;
    let (st, _) = request(addr, "GET", "/v1/jobs/train", "")?;
    push("dataset retired: GET", "GET /v1/jobs/train".into(), st, &plane);
    let (st, _) = request(addr, "POST", "/v1/jobs/train/epoch", "")?;
    push("dataset retired: epoch", "POST /v1/jobs/train/epoch".into(), st, &plane);

    srv.stop();
    let _ = std::fs::remove_dir_all(&root);
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drill_completes_every_epoch_and_degrades_without_remote_on_baseline() {
        let steps = failover_run(8, 1000, 2).unwrap();
        assert_eq!(steps.len(), 7);
        assert_eq!(steps[0].action, "baseline cold");
        assert!(steps[0].remote_reads > 0, "cold epoch fills from remote");
        // The warm baseline is clean: no failures, no degradation.
        assert_eq!(steps[1].peer_failures, 0);
        assert_eq!(steps[1].degraded_reads, 0);
        assert_eq!(steps[1].remote_reads, 0);
        assert_eq!(steps[1].lifecycle, "cached");
        // The mid-epoch kill is classified and degraded around.
        assert!(steps[2].peer_failures > 0, "kill must be classified: {steps:?}");
        assert!(steps[2].degraded_reads > 0, "kill must degrade reads: {steps:?}");
        // Declared failures show in the lifecycle; deeper failure, deeper
        // degradation.
        assert_eq!(steps[3].lifecycle, "degraded(lost=3)");
        assert_eq!(steps[4].lifecycle, "degraded(lost=3,2)");
        // The re-place lands a fresh, fully cached generation.
        assert_eq!(steps[6].lifecycle, "cached");
        assert_eq!(steps[6].peer_failures, 0, "no dead peers in the survivor set");
    }

    #[test]
    fn jobs_drill_surfaces_lifecycle_and_410() {
        let rows = failover_jobs_run().unwrap();
        assert_eq!(rows.len(), 6);
        assert_eq!((rows[0].2, rows[0].3.as_str()), (201, "cached"));
        assert_eq!((rows[1].2, rows[1].3.as_str()), (200, "degraded(lost=1)"));
        assert_eq!(rows[2].2, 200, "epoch must survive degradation: {rows:?}");
        assert_eq!(rows[3].2, 200, "epoch must survive rejoin: {rows:?}");
        assert_eq!((rows[4].2, rows[4].3.as_str()), (410, "retired"));
        assert_eq!(rows[5].2, 410, "retired epoch must answer 410: {rows:?}");
    }

    #[test]
    fn failover_table_has_one_row_per_step() {
        let t = failover_table_with(8, 1000, 1);
        assert_eq!(t.rows.len(), 7, "{:?}", t.rows);
        assert_eq!(t.rows[0][0], "baseline cold");
        assert_eq!(t.rows[6][6], "cached", "{:?}", t.rows);
    }
}
