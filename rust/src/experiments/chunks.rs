//! Chunk-size sweep on the real-mode data plane (`hoard exp chunks`):
//! cold/warm epoch time as `chunk_bytes` shrinks from whole-file fills
//! down to sub-item chunks — the knob the chunk-granular refactor added.
//!
//! What it shows: warm epochs are insensitive to chunk size (all bytes
//! stream from per-node NVMe buckets either way), while the cold path
//! with chunked fills is no worse than whole-file fills — every byte
//! still crosses the one shared remote bucket exactly once — and gains
//! partial-hit serving plus per-chunk (instead of per-file) fetch-once
//! blocking. Emits the same JSON table format as `exp readers`
//! (`metrics::Table::json`).

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cache::{CacheManager, EvictionPolicy, SharedCache};
use crate::metrics::Table;
use crate::netsim::NodeId;
use crate::posix::reader_pool::ReaderPool;
use crate::posix::realfs::{ReadStats, RealCluster};
use crate::remote::NfsModel;
use crate::storage::{Device, DeviceKind, Volume};
use crate::util::fmt;
use crate::workload::datagen::{self, DataGenConfig};
use crate::workload::DatasetSpec;

/// Nodes in the sweep testbed (matches the paper's 4-node cluster).
pub const CHUNK_NODES: usize = 4;

/// The default sweep: sub-item chunks up to whole-file fills
/// (`None` ⇒ whole-file mode, today's degenerate behaviour).
pub const CHUNK_SWEEP: [Option<u64>; 4] = [Some(256 << 10), Some(1 << 20), Some(4 << 20), None];

/// Records big enough that every swept chunk size is sub-item:
/// 1024×1024×4 px + 8 B header = 4 MiB + 8 B per item.
pub fn chunk_sweep_cfg(items: u64) -> DataGenConfig {
    DataGenConfig {
        num_items: items,
        height: 1024,
        width: 1024,
        channels: 4,
        files_per_dir: 16,
        ..Default::default()
    }
}

/// One measured point of the chunk-size sweep.
#[derive(Debug, Clone)]
pub struct ChunkPoint {
    /// `None` ⇒ whole-file fills.
    pub chunk_bytes: Option<u64>,
    pub cold_s: f64,
    pub warm_s: f64,
    pub cold: ReadStats,
    pub warm: ReadStats,
}

/// Run a cold + warm epoch through a fresh striped cluster with the given
/// chunk size (`None` ⇒ the whole-file `ReaderPool`), `readers` reader
/// threads and a per-request NVMe service time of `node_latency`.
pub fn chunk_scaling_run(
    chunk_bytes: Option<u64>,
    cfg: &DataGenConfig,
    readers: usize,
    node_latency: Duration,
) -> Result<ChunkPoint> {
    chunk_scaling_run_with_remote(chunk_bytes, cfg, readers, node_latency, None)
}

/// Like [`chunk_scaling_run`], but serving the remote store from a
/// pre-generated `shared_remote` directory when given — the dataset
/// depends only on `cfg`, not on the chunk size, so a sweep generates it
/// once and every point reuses it (fresh node cache dirs per point).
pub fn chunk_scaling_run_with_remote(
    chunk_bytes: Option<u64>,
    cfg: &DataGenConfig,
    readers: usize,
    node_latency: Duration,
    shared_remote: Option<&std::path::Path>,
) -> Result<ChunkPoint> {
    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf = std::env::temp_dir().join(format!(
        "hoard-chunks-{}-{}-{seq}",
        chunk_bytes.map_or("whole".to_string(), |b| b.to_string()),
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let mut cluster = RealCluster::create(&root, CHUNK_NODES, 200e6)
        .context("creating chunk-sweep cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    cluster.set_node_read_latency(node_latency);
    let total = match shared_remote {
        Some(dir) => {
            cluster.set_remote_dir(dir.to_path_buf());
            cfg.num_items * cfg.record_bytes() as u64
        }
        None => datagen::generate(&cluster.remote_dir, cfg).context("generating dataset")?,
    };

    let vols = (0..CHUNK_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    if let Some(cb) = chunk_bytes {
        manager.chunk_bytes = cb;
    }
    manager.register(
        DatasetSpec::new("sweep", cfg.num_items, total),
        "nfs://remote/sweep".into(),
    )?;
    manager.place("sweep", (0..CHUNK_NODES).map(NodeId).collect())?;
    let cache = SharedCache::new(manager);

    let pool = match chunk_bytes {
        Some(_) => ReaderPool::new_chunked(&cluster, cache, "sweep", cfg.clone(), readers)?,
        None => ReaderPool::new(&cluster, cache, "sweep", cfg.clone(), readers),
    };
    let cold_report = pool.run_epoch(&pool.epoch_order(0xC4AB, 0))?;
    cluster.take_stats();
    let warm_report = pool.run_epoch(&pool.epoch_order(0xC4AB, 1))?;

    let point = ChunkPoint {
        chunk_bytes,
        cold_s: cold_report.wall.as_secs_f64(),
        warm_s: warm_report.wall.as_secs_f64(),
        cold: cold_report.merged,
        warm: warm_report.merged,
    };
    let _ = std::fs::remove_dir_all(&root);
    Ok(point)
}

/// The `chunk_bytes` epoch-time table over an explicit sweep and dataset
/// shape (tests use small records; the CLI uses [`chunk_sweep_cfg`]).
pub fn chunk_size_table_with(sweep: &[Option<u64>], cfg: &DataGenConfig, readers: usize) -> Table {
    let mut t = Table::new(
        "Real mode — epoch time vs chunk size (striped over 4 nodes, shared remote bucket)",
        &[
            "chunk",
            "cold epoch (s)",
            "warm epoch (s)",
            "warm img/s",
            "cold remote reads",
            "cold remote bytes",
            "warm local/peer reads",
        ],
    );
    // Generate the dataset once for the whole sweep; every point reuses
    // the same remote store and only the node cache dirs are fresh.
    let src: PathBuf = std::env::temp_dir()
        .join(format!("hoard-chunks-src-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&src);
    let shared = datagen::generate(&src, cfg).ok().map(|_| src.clone());
    for &chunk in sweep {
        match chunk_scaling_run_with_remote(
            chunk,
            cfg,
            readers,
            Duration::from_micros(400),
            shared.as_deref(),
        ) {
            Ok(p) => t.row(vec![
                chunk.map_or("whole-file".to_string(), fmt::bytes),
                format!("{:.3}", p.cold_s),
                format!("{:.3}", p.warm_s),
                format!("{:.0}", super::items_per_sec(cfg.num_items, p.warm_s)),
                format!("{}", p.cold.remote_reads),
                format!("{}", p.cold.remote_bytes),
                format!("{}", p.warm.local_reads + p.warm.peer_reads),
            ]),
            Err(e) => {
                let mut cells = vec![
                    chunk.map_or("whole-file".to_string(), fmt::bytes),
                    format!("failed: {e:#}"),
                ];
                cells.resize(7, String::new());
                t.row(cells);
            }
        }
    }
    let _ = std::fs::remove_dir_all(&src);
    t
}

/// The default `hoard exp chunks` table: 4 MiB records, the
/// {256 KiB, 1 MiB, 4 MiB, whole-file} sweep, 4 readers.
pub fn chunk_size_table(items: u64) -> Table {
    chunk_size_table_with(&CHUNK_SWEEP, &chunk_sweep_cfg(items), 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_file_and_chunked_runs_agree_on_bytes() {
        let cfg = DataGenConfig { num_items: 12, files_per_dir: 32, ..Default::default() };
        let total = cfg.num_items * cfg.record_bytes() as u64;
        let whole = chunk_scaling_run(None, &cfg, 2, Duration::ZERO).unwrap();
        let chunked = chunk_scaling_run(Some(1000), &cfg, 2, Duration::ZERO).unwrap();
        assert_eq!(whole.cold.remote_bytes, total, "whole-file cold fetch-once");
        assert_eq!(chunked.cold.remote_bytes, total, "chunked cold fetch-once (by bytes)");
        assert_eq!(whole.warm.remote_reads, 0);
        assert_eq!(chunked.warm.remote_reads, 0, "chunked warm epoch fully cached");
    }

    #[test]
    fn chunk_table_has_one_row_per_size() {
        let cfg = DataGenConfig { num_items: 8, files_per_dir: 32, ..Default::default() };
        let t = chunk_size_table_with(&[Some(1500), None], &cfg, 2);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "1.46 KiB");
        assert_eq!(t.rows[1][0], "whole-file");
    }
}
