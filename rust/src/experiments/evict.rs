//! Eviction-lifecycle experiment (`hoard exp evict`): more datasets than
//! the cache can hold, placed one after another under the `DatasetLru`
//! admission policy, with a pinned "priority job" dataset that pressure
//! must never touch.
//!
//! What it shows — the paper's §3.1 dataset-granular eviction made real:
//! each placement beyond capacity evicts the least-recently-used
//! *unpinned* dataset end to end ([`DataPlane::place_dataset`]), which
//! retires its residency snapshot, poisons its fill ledger, and deletes
//! its on-disk chunk trees — the `reclaimed bytes` column is real
//! `remove_dir_all` accounting, not bookkeeping. Every row then streams a
//! cold epoch of the freshly placed dataset to show the cache keeps
//! serving at full rate across the churn. Emits the standard
//! `metrics::Table` JSON shape under `--json`.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::cache::{CacheManager, EvictionPolicy, SharedCache};
use crate::metrics::Table;
use crate::netsim::NodeId;
use crate::posix::dataplane::{DataPlane, JobSpec};
use crate::posix::realfs::RealCluster;
use crate::remote::NfsModel;
use crate::storage::{Device, DeviceKind, Volume};
use crate::workload::datagen::{self, DataGenConfig};
use crate::workload::DatasetSpec;

use super::items_per_sec;

/// Nodes in the eviction testbed (the paper's 4-node cluster).
pub const EVICT_NODES: usize = 4;

/// One placement + cold epoch under cache pressure.
#[derive(Debug, Clone)]
pub struct EvictStep {
    pub dataset: String,
    /// This dataset stays pinned for the whole run (the priority job) —
    /// later placements must evict around it.
    pub pinned: bool,
    /// Cold-epoch wall seconds for the freshly placed dataset.
    pub cold_s: f64,
    pub items_per_s: f64,
    /// Datasets the admission policy evicted to admit this placement.
    pub evicted: Vec<String>,
    /// On-disk bytes the victims' chunk-tree GC freed cluster-wide.
    pub reclaimed_bytes: u64,
    /// Datasets still holding a placement after this step.
    pub resident_after: usize,
}

/// Roll `k` equally sized datasets through a cache that only holds two:
/// register all, then place + pin + stream + unpin each in turn. `d0`
/// stays pinned throughout, so every over-capacity placement must pick
/// its LRU victim among the unpinned rest.
pub fn eviction_lifecycle_run(
    k: usize,
    items: u64,
    chunk_bytes: u64,
    readers: usize,
) -> Result<Vec<EvictStep>> {
    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf =
        std::env::temp_dir().join(format!("hoard-evict-{k}-{}-{seq}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, EVICT_NODES, 200e6)
        .context("creating eviction cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    // One shared remote layout: the k datasets are separate cache
    // resources (own IDs, own chunk trees, own generations) over the same
    // item files.
    let cfg = DataGenConfig { num_items: items, files_per_dir: 32, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).context("generating dataset")?;

    // Capacity that fits exactly two striped datasets: the third and
    // later placements run into admission pressure.
    let cap_per_node = 2 * total.div_ceil(EVICT_NODES as u64) + chunk_bytes;
    let vols = (0..EVICT_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, cap_per_node)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::DatasetLru);
    manager.chunk_bytes = chunk_bytes;
    for j in 0..k {
        manager.register(
            DatasetSpec::new(format!("d{j}"), items, total),
            format!("nfs://remote/d{j}"),
        )?;
    }
    let cache = SharedCache::new(manager);
    let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));

    let mut steps = Vec::with_capacity(k);
    for j in 0..k {
        let name = format!("d{j}");
        let outcome = plane.place_dataset(&name, (0..EVICT_NODES).map(NodeId).collect())?;
        // The running job pins its dataset; d0 is the priority job that
        // never unpins, so LRU pressure has to route around it.
        cache.with_mut(|m| m.registry.pin(&name))?;
        let sess = plane.open_job(
            JobSpec::new(name.as_str(), cfg.clone()).readers(readers).seed(0xE71C + j as u64),
        )?;
        let report = sess.run_epoch(0)?;
        if j != 0 {
            cache.with_mut(|m| m.registry.unpin(&name))?;
        }
        let cold_s = report.wall.as_secs_f64();
        steps.push(EvictStep {
            dataset: name,
            pinned: j == 0,
            cold_s,
            items_per_s: items_per_sec(items, cold_s),
            evicted: outcome.evicted,
            reclaimed_bytes: outcome.reclaimed_bytes,
            resident_after: cache
                .with(|m| m.registry.iter().filter(|r| r.stripe.is_some()).count()),
        });
    }
    let _ = std::fs::remove_dir_all(&root);
    Ok(steps)
}

/// The eviction-lifecycle table over an explicit shape.
pub fn eviction_lifecycle_table_with(
    k: usize,
    items: u64,
    chunk_bytes: u64,
    readers: usize,
) -> Table {
    let mut t = Table::new(
        "Real mode — eviction lifecycle under cache pressure (LRU victims, pinned priority job, on-disk GC)",
        &[
            "dataset",
            "pinned",
            "cold epoch (s)",
            "img/s",
            "evicted",
            "reclaimed bytes",
            "resident after",
        ],
    );
    match eviction_lifecycle_run(k, items, chunk_bytes, readers) {
        Ok(steps) => {
            for s in steps {
                t.row(vec![
                    s.dataset,
                    if s.pinned { "yes".into() } else { "no".into() },
                    format!("{:.3}", s.cold_s),
                    format!("{:.0}", s.items_per_s),
                    if s.evicted.is_empty() { "-".into() } else { s.evicted.join(",") },
                    format!("{}", s.reclaimed_bytes),
                    format!("{}", s.resident_after),
                ]);
            }
        }
        Err(e) => {
            let mut cells = vec!["-".to_string(), format!("failed: {e:#}")];
            cells.resize(7, String::new());
            t.row(cells);
        }
    }
    t
}

/// The default `hoard exp evict` table: 4 datasets through a 2-dataset
/// cache, sub-item chunks, 2 readers. Honors `HOARD_BENCH_SMOKE=1`.
pub fn eviction_lifecycle_table(items: u64) -> Table {
    let smoke = std::env::var("HOARD_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let items = if smoke { items.min(8) } else { items };
    eviction_lifecycle_table_with(4, items, 1000, 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pressure_evicts_lru_but_never_the_pinned_dataset() {
        let steps = eviction_lifecycle_run(4, 8, 1000, 2).unwrap();
        assert_eq!(steps.len(), 4);
        // First two placements fit without evictions.
        assert!(steps[0].evicted.is_empty() && steps[1].evicted.is_empty());
        assert_eq!(steps[0].resident_after, 1);
        assert_eq!(steps[1].resident_after, 2);
        // Every later placement evicts exactly the LRU unpinned dataset
        // and reclaims real bytes from disk.
        assert_eq!(steps[2].evicted, vec!["d1".to_string()], "d0 is pinned; d1 is LRU");
        assert_eq!(steps[3].evicted, vec!["d2".to_string()]);
        for s in &steps[2..] {
            assert!(s.reclaimed_bytes > 0, "{}: eviction must free on-disk bytes", s.dataset);
            assert_eq!(s.resident_after, 2, "cache holds exactly two datasets under churn");
        }
        assert!(steps.iter().all(|s| s.items_per_s >= 0.0));
    }

    #[test]
    fn evict_table_has_one_row_per_dataset() {
        let t = eviction_lifecycle_table_with(3, 8, 1000, 1);
        assert_eq!(t.rows.len(), 3);
        assert_eq!(t.rows[0][0], "d0");
        assert_eq!(t.rows[0][1], "yes");
        // The pressure row names a victim and a positive byte count.
        let reclaimed: u64 = t.rows[2][5]
            .parse()
            .unwrap_or_else(|_| panic!("reclaimed column not numeric: {:?}", t.rows[2]));
        assert_eq!(t.rows[2][4], "d1");
        assert!(reclaimed > 0);
    }
}
