//! Multi-job co-scheduling experiment (`hoard exp jobs`): J ∈ {1, 2, 4}
//! co-located jobs streaming **one** dataset through a shared
//! [`DataPlane`], each with its own [`JobSession`] (own seed, own epoch
//! order, own readers, own stats) over one fill ledger.
//!
//! What it shows — the paper's Table 4 cross-job point under real
//! concurrency: the cold phase's total remote-fill count equals the chunk
//! count **regardless of J** (fills are shared once, not raced J times),
//! the remote store supplies every byte exactly once, and every job's
//! warm epoch then streams from cache at full per-job throughput. Emits
//! the same JSON table shape as every other `exp`
//! (`metrics::Table::json`) — CI captures it as `BENCH_jobs.json`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::cache::{CacheManager, EvictionPolicy, RamTierStats, SharedCache};
use crate::metrics::Table;
use crate::netsim::NodeId;
use crate::posix::dataplane::{DataPlane, JobSession, JobSpec};
use crate::posix::realfs::{ReadStats, RealCluster};
use crate::remote::NfsModel;
use crate::storage::{Device, DeviceKind, Volume};
use crate::workload::datagen::{self, DataGenConfig};
use crate::workload::DatasetSpec;

use super::items_per_sec;

/// Nodes in the co-scheduling testbed (matches the paper's 4-node
/// cluster).
pub const JOB_NODES: usize = 4;

/// One measured point: J jobs over one plane.
#[derive(Debug, Clone)]
pub struct CoJobPoint {
    pub jobs: usize,
    /// Whether the plane carried the shared RAM hot-chunk tier.
    pub tier_on: bool,
    /// Tier counters after the warm phase (`None` with the tier off).
    pub ram: Option<RamTierStats>,
    /// Wall of the concurrent cold phase (all J jobs' epoch 0).
    pub cold_s: f64,
    /// Remote fills recorded by the shared ledger — `== chunks` is the
    /// fills-shared-once evidence.
    pub fills: u64,
    pub chunks: u64,
    /// Cluster-wide cold-phase stats (all jobs merged).
    pub cold: ReadStats,
    /// Per-job warm-epoch wall seconds, job order.
    pub warm_s: Vec<f64>,
    /// Per-job warm-epoch stats, job order.
    pub warm: Vec<ReadStats>,
    pub items: u64,
    pub total_bytes: u64,
}

/// Run J co-located jobs over one freshly placed dataset: a concurrent
/// cold phase (every job runs its epoch 0 at once, racing the shared
/// ledger), then a concurrent warm phase (epoch 1 each).
pub fn co_job_run(jobs: usize, items: u64, chunk_bytes: u64, readers: usize) -> Result<CoJobPoint> {
    co_job_run_tiered(jobs, items, chunk_bytes, readers, false)
}

/// [`co_job_run`] with the plane's RAM hot-chunk tier toggled: `tier_on`
/// attaches a tier budgeted to the whole dataset, so J jobs warm each
/// other's hot set — the cross-job sharing claim extended one tier up.
pub fn co_job_run_tiered(
    jobs: usize,
    items: u64,
    chunk_bytes: u64,
    readers: usize,
    tier_on: bool,
) -> Result<CoJobPoint> {
    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf = std::env::temp_dir().join(format!(
        "hoard-jobs-{jobs}-{}-{seq}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, JOB_NODES, 200e6)
        .context("creating co-job cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    let cfg = DataGenConfig { num_items: items, files_per_dir: 32, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).context("generating dataset")?;

    let vols = (0..JOB_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.chunk_bytes = chunk_bytes;
    manager.register(DatasetSpec::new("co", items, total), "nfs://remote/co".into())?;
    manager.place("co", (0..JOB_NODES).map(NodeId).collect())?;
    let cache = SharedCache::new(manager);
    let chunks = cache.geometry("co")?.num_chunks();

    // One plane; J sessions on it, each with its own seed.
    let mut plane = DataPlane::new(cluster.clone(), cache);
    if tier_on {
        plane = plane.with_ram_tier(total);
    }
    let plane = Arc::new(plane);
    let sessions: Vec<JobSession> = (0..jobs)
        .map(|j| {
            plane.open_job(JobSpec::new("co", cfg.clone()).readers(readers).seed(0xC05C + j as u64))
        })
        .collect::<Result<_>>()?;

    let run_all = |epoch: u32| -> Result<Vec<(f64, ReadStats)>> {
        let results: Vec<Result<(f64, ReadStats)>> = std::thread::scope(|s| {
            let handles: Vec<_> = sessions
                .iter()
                .map(|sess| {
                    s.spawn(move || -> Result<(f64, ReadStats)> {
                        let report = sess.run_epoch(epoch)?;
                        Ok((report.wall.as_secs_f64(), report.merged))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("job thread panicked"))))
                .collect()
        });
        results.into_iter().collect()
    };

    // Cold phase: all J jobs race epoch 0 over the shared ledger.
    let t0 = Instant::now();
    run_all(0)?;
    let cold_s = t0.elapsed().as_secs_f64();
    let fills = plane.dataset_fills("co");
    let cold = cluster.take_stats();

    // Warm phase: epoch 1 each, still concurrent.
    let warm_points = run_all(1)?;
    let (warm_s, warm): (Vec<f64>, Vec<ReadStats>) = warm_points.into_iter().unzip();

    let point = CoJobPoint {
        jobs,
        tier_on,
        ram: plane.ram_tier().map(|r| r.stats()),
        cold_s,
        fills,
        chunks,
        cold,
        warm_s,
        warm,
        items,
        total_bytes: total,
    };
    let _ = std::fs::remove_dir_all(&root);
    Ok(point)
}

/// The J-jobs epoch table over an explicit sweep: each fleet size runs
/// with the plane's RAM tier off and on (paired rows), so the table shows
/// both the fills-shared-once invariant and what the shared hot-chunk
/// tier adds on top.
pub fn co_job_table_with(sweep: &[usize], items: u64, chunk_bytes: u64, readers: usize) -> Table {
    let mut t = Table::new(
        "Real mode — co-located jobs over one DataPlane (shared fills, per-job epochs)",
        &[
            "jobs",
            "ram tier",
            "cold phase (s)",
            "fills",
            "chunks",
            "cold remote bytes",
            "dataset bytes",
            "warm epoch mean (s)",
            "warm img/s per job",
            "warm remote reads",
            "warm ram hits",
        ],
    );
    for &j in sweep {
        for tier_on in [false, true] {
            match co_job_run_tiered(j, items, chunk_bytes, readers, tier_on) {
                Ok(p) => {
                    let warm_mean = super::mean(&p.warm_s);
                    let warm_remote: u64 = p.warm.iter().map(|s| s.remote_reads).sum();
                    let warm_ram: u64 = p.warm.iter().map(|s| s.ram_hits).sum();
                    t.row(vec![
                        format!("{j}"),
                        if tier_on { "on" } else { "off" }.to_string(),
                        format!("{:.3}", p.cold_s),
                        format!("{}", p.fills),
                        format!("{}", p.chunks),
                        format!("{}", p.cold.remote_bytes),
                        format!("{}", p.total_bytes),
                        format!("{warm_mean:.3}"),
                        format!("{:.0}", items_per_sec(p.items, warm_mean)),
                        format!("{warm_remote}"),
                        format!("{warm_ram}"),
                    ]);
                }
                Err(e) => {
                    let mut cells = vec![
                        format!("{j}"),
                        if tier_on { "on" } else { "off" }.to_string(),
                        format!("failed: {e:#}"),
                    ];
                    cells.resize(11, String::new());
                    t.row(cells);
                }
            }
        }
    }
    t
}

/// The default `hoard exp jobs` table: J ∈ {1, 2, 4}, sub-item chunks,
/// 2 readers per job. Honors `HOARD_BENCH_SMOKE=1` (smaller dataset so CI
/// smoke runs stay fast).
pub fn co_job_table(items: u64) -> Table {
    let smoke = std::env::var("HOARD_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let items = if smoke { items.min(12) } else { items };
    co_job_table_with(&[1, 2, 4], items, 1000, 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn co_jobs_share_fills_once_and_warm_from_cache() {
        let p = co_job_run(2, 16, 777, 2).unwrap();
        assert_eq!(p.fills, p.chunks, "2 jobs must fill each chunk exactly once, together");
        assert_eq!(p.cold.remote_bytes, p.total_bytes, "remote supplies every byte once");
        for (j, w) in p.warm.iter().enumerate() {
            assert_eq!(w.remote_reads, 0, "job {j} warm epoch touched remote");
            assert!(w.local_reads + w.peer_reads + w.peer_net_reads > 0, "job {j} read nothing");
        }
        assert_eq!(p.warm_s.len(), 2);
    }

    #[test]
    fn co_jobs_share_the_ram_tier() {
        let p = co_job_run_tiered(2, 16, 777, 2, true).unwrap();
        assert_eq!(p.fills, p.chunks, "the tier must not change the fetch-once invariant");
        assert!(p.tier_on);
        let warm_ram: u64 = p.warm.iter().map(|s| s.ram_hits).sum();
        assert!(warm_ram > 0, "warm jobs must hit the shared tier");
        let rs = p.ram.unwrap();
        assert!(rs.hits >= warm_ram, "plane counters cover every session's hits");
    }

    #[test]
    fn jobs_table_has_tier_off_and_on_rows_per_fleet_size() {
        let t = co_job_table_with(&[1, 2], 8, 1000, 1);
        assert_eq!(t.rows.len(), 4, "each fleet size pairs an off row with an on row");
        assert_eq!((t.rows[0][0].as_str(), t.rows[0][1].as_str()), ("1", "off"));
        assert_eq!((t.rows[1][0].as_str(), t.rows[1][1].as_str()), ("1", "on"));
        assert_eq!((t.rows[2][0].as_str(), t.rows[2][1].as_str()), ("2", "off"));
        assert_eq!((t.rows[3][0].as_str(), t.rows[3][1].as_str()), ("2", "on"));
        // Fills == chunks on every row (the headline invariant). Parse
        // the cells so an error row (empty-padded columns) fails loudly
        // instead of comparing "" == "" vacuously.
        for row in &t.rows {
            let fills: u64 = row[3].parse().unwrap_or_else(|_| {
                panic!("fills column not numeric — run failed? {row:?}")
            });
            let chunks: u64 = row[4].parse().unwrap_or_else(|_| {
                panic!("chunks column not numeric — run failed? {row:?}")
            });
            assert_eq!(fills, chunks, "fills must equal chunks: {row:?}");
            // Off rows never count RAM hits.
            if row[1] == "off" {
                assert_eq!(row[10], "0", "tier-off row counted RAM hits: {row:?}");
            }
        }
    }
}
