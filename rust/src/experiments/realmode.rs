//! Real-mode reader-scaling experiment: epoch times of the concurrent
//! data plane (`posix::ReaderPool`) as the pool grows from 1 reader to
//! one per node — the `readers=N` dimension of the epoch-time results.
//!
//! What it shows (and what the `perf_concurrent_readers` bench asserts):
//! warm-epoch throughput scales with readers because each reader streams
//! its stripe share from a *different* per-node bucket in parallel, while
//! the cold epoch barely moves — every byte still funnels through the one
//! shared remote bucket (the NFS server does not speed up, the cache
//! layout does). That is exactly the paper's Table 3 asymmetry.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cache::{CacheManager, EvictionPolicy, SharedCache};
use crate::metrics::Table;
use crate::netsim::NodeId;
use crate::posix::realfs::{ReadStats, RealCluster};
use crate::posix::reader_pool::ReaderPool;
use crate::remote::NfsModel;
use crate::storage::{Device, DeviceKind, Volume};
use crate::workload::datagen::{self, DataGenConfig};
use crate::workload::DatasetSpec;

/// Nodes in the scaling testbed (matches the paper's 4-node cluster).
pub const SCALING_NODES: usize = 4;

/// One measured point of the scaling sweep.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub readers: usize,
    pub cold_s: f64,
    pub warm_s: f64,
    pub cold: ReadStats,
    pub warm: ReadStats,
}

/// Run a cold + warm epoch through a fresh striped cluster with `readers`
/// reader threads. `node_latency` models per-request NVMe/FS-client
/// service time — the quantity parallel readers overlap.
pub fn reader_scaling_run(
    readers: usize,
    items: u64,
    node_latency: Duration,
) -> Result<ScalingPoint> {
    // Unique per process *and* per call: concurrent test threads must not
    // share (or clobber) a scratch cluster.
    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf = std::env::temp_dir().join(format!(
        "hoard-scaling-r{readers}-{}-{seq}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, SCALING_NODES, 200e6)
        .context("creating scaling cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    cluster.set_node_read_latency(node_latency);
    let cfg = DataGenConfig { num_items: items, files_per_dir: 64, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).context("generating dataset")?;

    let vols = (0..SCALING_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.register(DatasetSpec::new("scale", items, total), "nfs://remote/scale".into())?;
    manager.place("scale", (0..SCALING_NODES).map(NodeId).collect())?;

    let pool = ReaderPool::new(&cluster, SharedCache::new(manager), "scale", cfg, readers);
    let cold_report = pool.run_epoch(&pool.epoch_order(0xC01D, 0))?;
    cluster.take_stats();
    let warm_report = pool.run_epoch(&pool.epoch_order(0xC01D, 1))?;

    let point = ScalingPoint {
        readers,
        cold_s: cold_report.wall.as_secs_f64(),
        warm_s: warm_report.wall.as_secs_f64(),
        cold: cold_report.merged,
        warm: warm_report.merged,
    };
    let _ = std::fs::remove_dir_all(&root);
    Ok(point)
}

/// The `readers=N` epoch-time table (real bytes, wall-clock — unlike the
/// fluid tables this one is hardware-dependent and not byte-stable).
pub fn realmode_reader_scaling(readers_list: &[usize], items: u64) -> Table {
    let mut t = Table::new(
        "Real mode — epoch time vs reader threads (striped over 4 nodes, shared remote bucket)",
        &[
            "readers",
            "cold epoch (s)",
            "warm epoch (s)",
            "warm img/s",
            "warm speedup",
            "remote reads",
            "local/peer reads",
        ],
    );
    let mut base_warm = None;
    for &n in readers_list {
        match reader_scaling_run(n, items, Duration::from_micros(400)) {
            Ok(p) => {
                let base = *base_warm.get_or_insert(p.warm_s);
                t.row(vec![
                    format!("{n}"),
                    format!("{:.3}", p.cold_s),
                    format!("{:.3}", p.warm_s),
                    format!("{:.0}", super::items_per_sec(items, p.warm_s)),
                    format!("{:.2} ×", base / p.warm_s.max(1e-9)),
                    format!("{}", p.cold.remote_reads),
                    format!("{}", p.warm.local_reads + p.warm.peer_reads),
                ]);
            }
            Err(e) => {
                let mut cells = vec![format!("{n}"), format!("failed: {e:#}")];
                cells.resize(7, String::new());
                t.row(cells);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_run_fetches_once_and_warms() {
        let p = reader_scaling_run(2, 32, Duration::ZERO).unwrap();
        assert_eq!(p.cold.remote_reads, 32, "cold epoch fetch-once");
        assert_eq!(p.warm.remote_reads, 0, "warm epoch fully cached");
        assert_eq!(p.warm.local_reads + p.warm.peer_reads, 32);
    }

    #[test]
    fn scaling_table_has_one_row_per_pool_size() {
        let t = realmode_reader_scaling(&[1, 2], 24);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "1");
        assert_eq!(t.rows[1][0], "2");
    }
}
