//! Real-mode reader-scaling experiment: epoch times of the concurrent
//! data plane (`posix::ReaderPool`) as the pool grows from 1 reader to
//! one per node — the `readers=N` dimension of the epoch-time results.
//!
//! What it shows (and what the `perf_concurrent_readers` bench asserts):
//! warm-epoch throughput scales with readers because each reader streams
//! its stripe share from a *different* per-node bucket in parallel, while
//! the cold epoch barely moves — every byte still funnels through the one
//! shared remote bucket (the NFS server does not speed up, the cache
//! layout does). That is exactly the paper's Table 3 asymmetry.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cache::{CacheManager, EvictionPolicy, RamTierStats, SharedCache};
use crate::metrics::Table;
use crate::netsim::NodeId;
use crate::posix::dataplane::{DataPlane, JobSpec};
use crate::posix::realfs::{ReadStats, RealCluster};
use crate::posix::reader_pool::ReaderPool;
use crate::remote::NfsModel;
use crate::storage::{Device, DeviceKind, Volume};
use crate::workload::datagen::{self, DataGenConfig};
use crate::workload::DatasetSpec;

/// Nodes in the scaling testbed (matches the paper's 4-node cluster).
pub const SCALING_NODES: usize = 4;

/// One measured point of the scaling sweep.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub readers: usize,
    pub cold_s: f64,
    pub warm_s: f64,
    pub cold: ReadStats,
    pub warm: ReadStats,
}

/// Run a cold + warm epoch through a fresh striped cluster with `readers`
/// reader threads. `node_latency` models per-request NVMe/FS-client
/// service time — the quantity parallel readers overlap.
pub fn reader_scaling_run(
    readers: usize,
    items: u64,
    node_latency: Duration,
) -> Result<ScalingPoint> {
    // Unique per process *and* per call: concurrent test threads must not
    // share (or clobber) a scratch cluster.
    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf = std::env::temp_dir().join(format!(
        "hoard-scaling-r{readers}-{}-{seq}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, SCALING_NODES, 200e6)
        .context("creating scaling cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    cluster.set_node_read_latency(node_latency);
    let cfg = DataGenConfig { num_items: items, files_per_dir: 64, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).context("generating dataset")?;

    let vols = (0..SCALING_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.register(DatasetSpec::new("scale", items, total), "nfs://remote/scale".into())?;
    manager.place("scale", (0..SCALING_NODES).map(NodeId).collect())?;

    let pool = ReaderPool::new(&cluster, SharedCache::new(manager), "scale", cfg, readers);
    let cold_report = pool.run_epoch(&pool.epoch_order(0xC01D, 0))?;
    cluster.take_stats();
    let warm_report = pool.run_epoch(&pool.epoch_order(0xC01D, 1))?;

    let point = ScalingPoint {
        readers,
        cold_s: cold_report.wall.as_secs_f64(),
        warm_s: warm_report.wall.as_secs_f64(),
        cold: cold_report.merged,
        warm: warm_report.merged,
    };
    let _ = std::fs::remove_dir_all(&root);
    Ok(point)
}

/// The `readers=N` epoch-time table (real bytes, wall-clock — unlike the
/// fluid tables this one is hardware-dependent and not byte-stable).
pub fn realmode_reader_scaling(readers_list: &[usize], items: u64) -> Table {
    let mut t = Table::new(
        "Real mode — epoch time vs reader threads (striped over 4 nodes, shared remote bucket)",
        &[
            "readers",
            "cold epoch (s)",
            "warm epoch (s)",
            "warm img/s",
            "warm speedup",
            "remote reads",
            "local/peer reads",
        ],
    );
    let mut base_warm = None;
    for &n in readers_list {
        match reader_scaling_run(n, items, Duration::from_micros(400)) {
            Ok(p) => {
                let base = *base_warm.get_or_insert(p.warm_s);
                t.row(vec![
                    format!("{n}"),
                    format!("{:.3}", p.cold_s),
                    format!("{:.3}", p.warm_s),
                    format!("{:.0}", super::items_per_sec(items, p.warm_s)),
                    format!("{:.2} ×", base / p.warm_s.max(1e-9)),
                    format!("{}", p.cold.remote_reads),
                    format!("{}", p.warm.local_reads + p.warm.peer_reads),
                ]);
            }
            Err(e) => {
                let mut cells = vec![format!("{n}"), format!("failed: {e:#}")];
                cells.resize(7, String::new());
                t.row(cells);
            }
        }
    }
    t
}

/// One measured point of the RAM-tier on/off comparison: a warm epoch
/// over a chunked plane, with or without the in-memory hot-chunk tier.
#[derive(Debug, Clone)]
pub struct TierPoint {
    pub tier_on: bool,
    pub warm_s: f64,
    pub warm: ReadStats,
    /// Tier counters after the measured epoch (`None` with the tier off).
    pub ram: Option<RamTierStats>,
}

/// Run a chunked plane to a *hot* warm state and measure one more epoch:
/// epoch 0 fills from remote (fill-path `offer`s record first touches),
/// epoch 1 completes second-touch promotion, epoch 2 is the measured warm
/// epoch. With `tier_on` the plane carries a [`RamTier`] budgeted to the
/// whole dataset (every hot chunk fits — the ≥-1.5×-regime of the bench);
/// off, the identical run hits the chunk files for every segment.
///
/// [`RamTier`]: crate::cache::RamTier
pub fn ram_tier_run(
    readers: usize,
    items: u64,
    chunk_bytes: u64,
    tier_on: bool,
    node_latency: Duration,
) -> Result<TierPoint> {
    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf = std::env::temp_dir().join(format!(
        "hoard-ramtier-{}-{}-{seq}",
        if tier_on { "on" } else { "off" },
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, SCALING_NODES, 200e6)
        .context("creating ram-tier cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    cluster.set_node_read_latency(node_latency);
    let cfg = DataGenConfig { num_items: items, files_per_dir: 64, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).context("generating dataset")?;

    let vols = (0..SCALING_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.chunk_bytes = chunk_bytes;
    manager.register(DatasetSpec::new("scale", items, total), "nfs://remote/scale".into())?;
    manager.place("scale", (0..SCALING_NODES).map(NodeId).collect())?;

    let mut plane = DataPlane::new(cluster.clone(), SharedCache::new(manager));
    if tier_on {
        plane = plane.with_ram_tier(total);
    }
    let plane = std::sync::Arc::new(plane);
    let sess = plane.open_job(JobSpec::new("scale", cfg).readers(readers).seed(0x7157))?;
    sess.run_epoch(0)?; // cold fill (tier records first touches)
    sess.run_epoch(1)?; // promotion epoch (second touches admit)
    cluster.take_stats();
    let warm = sess.run_epoch(2)?; // the measured hot epoch

    let point = TierPoint {
        tier_on,
        warm_s: warm.wall.as_secs_f64(),
        warm: warm.merged,
        ram: plane.ram_tier().map(|r| r.stats()),
    };
    let _ = std::fs::remove_dir_all(&root);
    Ok(point)
}

/// The RAM-tier on/off table (second table of `hoard exp readers`): the
/// same warm epoch with and without the in-memory hot-chunk tier. Honors
/// `HOARD_BENCH_SMOKE=1` (smaller dataset so CI smoke runs stay fast).
pub fn ram_tier_table(items: u64) -> Table {
    let smoke = std::env::var("HOARD_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let items = if smoke { items.min(16) } else { items };
    let mut t = Table::new(
        "Real mode — warm epoch with the RAM hot-chunk tier off vs on (4 readers, chunked)",
        &[
            "ram tier",
            "warm epoch (s)",
            "warm img/s",
            "speedup",
            "ram hits",
            "ram bytes",
            "disk local reads",
            "peer reads",
        ],
    );
    let mut base_warm = None;
    for tier_on in [false, true] {
        match ram_tier_run(4, items, 1000, tier_on, Duration::from_micros(400)) {
            Ok(p) => {
                let base = *base_warm.get_or_insert(p.warm_s);
                t.row(vec![
                    if tier_on { "on" } else { "off" }.to_string(),
                    format!("{:.3}", p.warm_s),
                    format!("{:.0}", super::items_per_sec(items, p.warm_s)),
                    format!("{:.2} ×", base / p.warm_s.max(1e-9)),
                    format!("{}", p.warm.ram_hits),
                    format!("{}", p.warm.ram_bytes),
                    format!("{}", p.warm.local_reads),
                    format!("{}", p.warm.peer_reads + p.warm.peer_net_reads),
                ]);
            }
            Err(e) => {
                let mut cells = vec![
                    if tier_on { "on" } else { "off" }.to_string(),
                    format!("failed: {e:#}"),
                ];
                cells.resize(8, String::new());
                t.row(cells);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_run_fetches_once_and_warms() {
        let p = reader_scaling_run(2, 32, Duration::ZERO).unwrap();
        assert_eq!(p.cold.remote_reads, 32, "cold epoch fetch-once");
        assert_eq!(p.warm.remote_reads, 0, "warm epoch fully cached");
        assert_eq!(p.warm.local_reads + p.warm.peer_reads, 32);
    }

    #[test]
    fn scaling_table_has_one_row_per_pool_size() {
        let t = realmode_reader_scaling(&[1, 2], 24);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "1");
        assert_eq!(t.rows[1][0], "2");
    }

    #[test]
    fn ram_tier_warm_epoch_hits_ram_and_cuts_disk_reads() {
        let off = ram_tier_run(2, 16, 1000, false, Duration::ZERO).unwrap();
        let on = ram_tier_run(2, 16, 1000, true, Duration::ZERO).unwrap();
        assert_eq!(off.warm.ram_hits, 0, "tier off must never count RAM hits");
        assert!(off.ram.is_none());
        assert_eq!(on.warm.remote_reads, 0, "hot epoch must not touch remote");
        assert!(on.warm.ram_hits > 0, "hot epoch must hit the tier");
        assert!(
            on.warm.local_reads < off.warm.local_reads,
            "tier must cut disk local reads ({} vs {})",
            on.warm.local_reads,
            off.warm.local_reads
        );
        let rs = on.ram.unwrap();
        assert!(rs.inserted > 0 && rs.hits > 0);
        assert!(rs.bytes <= rs.inserted.max(1) * 1000, "budget accounting is per payload");
    }

    #[test]
    fn ram_tier_table_has_off_and_on_rows() {
        let t = ram_tier_table(8);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "off");
        assert_eq!(t.rows[1][0], "on");
        let off_hits: u64 = t.rows[0][4]
            .parse()
            .unwrap_or_else(|_| panic!("ram hits column not numeric — run failed? {:?}", t.rows[0]));
        let on_hits: u64 = t.rows[1][4]
            .parse()
            .unwrap_or_else(|_| panic!("ram hits column not numeric — run failed? {:?}", t.rows[1]));
        assert_eq!(off_hits, 0);
        assert!(on_hits > 0, "the on row must show RAM hits");
    }
}
