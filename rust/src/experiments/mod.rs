//! Experiment harness: one function per paper table/figure (DESIGN.md §5),
//! shared by `cargo bench` targets and the `hoard exp` CLI. Each returns a
//! [`metrics::Table`] (and, for figures, fps series) so callers can render
//! console, markdown, or CSV.

pub mod ablations;
pub mod chunks;
pub mod evict;
pub mod failover;
pub mod jobs;
pub mod paper;
pub mod peers;
pub mod prefetch;
pub mod realmode;

pub use chunks::{chunk_scaling_run, chunk_size_table};
pub use evict::{eviction_lifecycle_run, eviction_lifecycle_table};
pub use failover::{failover_jobs_table, failover_run, failover_table};
pub use jobs::{co_job_run, co_job_run_tiered, co_job_table};
pub use paper::*;
pub use peers::{peer_transport_run, peer_transport_table};
pub use prefetch::{prefetch_run, prefetch_table};
pub use realmode::{ram_tier_run, ram_tier_table, realmode_reader_scaling, reader_scaling_run};

/// Calibration constants derived from the paper's own numbers; the deeper
/// story for each lives next to its definition.
pub mod calib {
    /// ImageNet train split: ~1.28 M images, ~144 GB ⇒ 112.4 KB average.
    pub const IMAGENET_ITEMS: u64 = 1_281_167;
    pub const IMAGENET_BYTES: u64 = 144_000_000_000;

    /// Table 4 anchor points.
    pub const REM_60_EPOCH_HOURS: f64 = 14.9;
    pub const HOARD_60_EPOCH_HOURS: f64 = 6.97;

    /// Table 3 anchor points (speedup vs REM).
    pub const NVME_SPEEDUP_90: f64 = 2.32;
    pub const HOARD_SPEEDUP_90: f64 = 2.1;
    pub const HOARD_SPEEDUP_2: f64 = 0.93;

    pub use crate::workload::trainsim::{AFM_COLD_BW_PER_JOB, SPECTRUM_CLIENT_EFF};
}

/// Format a speedup like the paper's Table 3 ("2.07 ×").
pub fn speedup(x: f64) -> String {
    format!("{x:.2} ×")
}

/// Throughput guarded against zero-duration epochs (smoke-mode runs can
/// finish in ~0 ns): delegates to the one canonical guard in
/// [`crate::util::per_sec`].
pub fn items_per_sec(items: u64, secs: f64) -> f64 {
    crate::util::per_sec(items, secs)
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Write series as CSV ("t,series1,series2" long format: name,t,value).
pub fn series_csv(series: &[(&str, &[(f64, f64)])]) -> String {
    let mut out = String::from("series,t_seconds,images_per_sec\n");
    for (name, pts) in series {
        for (t, v) in *pts {
            out.push_str(&format!("{name},{t:.1},{v:.1}\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn helpers() {
        assert_eq!(speedup(2.0666), "2.07 ×");
        assert_eq!(mean(&[1.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(items_per_sec(100, 2.0), 50.0);
        assert_eq!(items_per_sec(100, 0.0), 0.0, "zero-duration epochs must not yield inf");
        assert_eq!(items_per_sec(100, -1.0), 0.0);
        let pts = [(0.0, 1.0)];
        let csv = series_csv(&[("a", &pts)]);
        assert!(csv.contains("a,0.0,1.0"));
    }
}
