//! Peer-transport experiment (`hoard exp peers`): cold + warm epoch times
//! of the chunked reader pool with the same-FS `DirTransport` versus the
//! real TCP `SocketTransport` (one `PeerServer` per node on an ephemeral
//! loopback port).
//!
//! What it shows: the socket data plane moves every non-local warm-epoch
//! byte across the node interconnect (`peer_net_bytes`) instead of
//! pretending peers share a filesystem, with zero remote reads either way
//! — the network leg of the paper's §3.2 peer-read claim, measured on
//! real sockets. Emits the same JSON table shape as every other `exp`
//! (`metrics::Table::json`).

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cache::{CacheManager, EvictionPolicy, SharedCache};
use crate::metrics::Table;
use crate::netsim::NodeId;
use crate::peer::{PeerClient, PeerServer, SocketTransport};
use crate::posix::reader_pool::ReaderPool;
use crate::posix::realfs::{ReadStats, RealCluster};
use crate::remote::NfsModel;
use crate::storage::{Device, DeviceKind, Volume};
use crate::workload::datagen::{self, DataGenConfig};
use crate::workload::DatasetSpec;

/// Nodes in the testbed (matches the paper's 4-node cluster).
pub const PEER_NODES: usize = 4;

/// One measured transport point.
#[derive(Debug, Clone)]
pub struct PeerPoint {
    /// "dir" or "socket".
    pub transport: &'static str,
    pub cold_s: f64,
    pub warm_s: f64,
    pub cold: ReadStats,
    pub warm: ReadStats,
    /// Dataset size, for fetch-once assertions downstream.
    pub total_bytes: u64,
}

/// Run a cold + warm chunked epoch through a fresh striped cluster with
/// the chosen transport. Socket mode starts one [`PeerServer`] per node on
/// an ephemeral loopback port (each charging that node's NVMe bucket for
/// served payloads) and a pooled [`PeerClient`] over the discovered
/// addresses.
pub fn peer_transport_run(
    socket: bool,
    items: u64,
    chunk_bytes: u64,
    readers: usize,
) -> Result<PeerPoint> {
    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf = std::env::temp_dir().join(format!(
        "hoard-peers-{}-{}-{seq}",
        if socket { "socket" } else { "dir" },
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, PEER_NODES, 200e6)
        .context("creating peer-transport cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    let cfg = DataGenConfig { num_items: items, files_per_dir: 32, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).context("generating dataset")?;

    let vols = (0..PEER_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.chunk_bytes = chunk_bytes;
    manager.register(DatasetSpec::new("peers", items, total), "nfs://remote/peers".into())?;
    manager.place("peers", (0..PEER_NODES).map(NodeId).collect())?;
    let cache = SharedCache::new(manager);

    let mut servers: Vec<PeerServer> = Vec::new();
    let mut pool = ReaderPool::new_chunked(&cluster, cache, "peers", cfg, readers)?;
    if socket {
        for n in 0..PEER_NODES {
            servers.push(
                PeerServer::start_with(
                    "127.0.0.1:0",
                    cluster.node_dirs[n].clone(),
                    Some(cluster.node_bw[n].clone()),
                    Duration::from_secs(5),
                )
                .with_context(|| format!("starting peer server for node{n}"))?,
            );
        }
        let addrs = servers.iter().map(|s| s.addr).collect();
        // 10 GbE-class links: visible as a knob, invisible at this scale.
        let client = PeerClient::connect(addrs).with_nic_bw(1.25e9);
        pool = pool.with_transport(Box::new(SocketTransport::new(client)));
    }

    let cold_report = pool.run_epoch(&pool.epoch_order(0x9EE5, 0))?;
    cluster.take_stats();
    let warm_report = pool.run_epoch(&pool.epoch_order(0x9EE5, 1))?;

    let point = PeerPoint {
        transport: if socket { "socket" } else { "dir" },
        cold_s: cold_report.wall.as_secs_f64(),
        warm_s: warm_report.wall.as_secs_f64(),
        cold: cold_report.merged,
        warm: warm_report.merged,
        total_bytes: total,
    };
    for s in &mut servers {
        s.stop();
    }
    let _ = std::fs::remove_dir_all(&root);
    Ok(point)
}

/// The dir-vs-socket transport epoch table.
pub fn peer_transport_table_with(items: u64, chunk_bytes: u64, readers: usize) -> Table {
    let mut t = Table::new(
        "Real mode — peer transport: same-FS dir reads vs TCP chunk protocol (4 nodes)",
        &[
            "transport",
            "cold epoch (s)",
            "warm epoch (s)",
            "warm img/s",
            "warm peer reads (disk)",
            "warm peer-net reads",
            "warm peer-net bytes",
            "warm remote reads",
        ],
    );
    for socket in [false, true] {
        match peer_transport_run(socket, items, chunk_bytes, readers) {
            Ok(p) => t.row(vec![
                p.transport.to_string(),
                format!("{:.3}", p.cold_s),
                format!("{:.3}", p.warm_s),
                format!("{:.0}", super::items_per_sec(items, p.warm_s)),
                format!("{}", p.warm.peer_reads),
                format!("{}", p.warm.peer_net_reads),
                format!("{}", p.warm.peer_net_bytes),
                format!("{}", p.warm.remote_reads),
            ]),
            Err(e) => {
                let mut cells = vec![
                    if socket { "socket" } else { "dir" }.to_string(),
                    format!("failed: {e:#}"),
                ];
                cells.resize(8, String::new());
                t.row(cells);
            }
        }
    }
    t
}

/// The default `hoard exp peers` table: sub-item chunks, 4 readers.
pub fn peer_transport_table(items: u64) -> Table {
    peer_transport_table_with(items, 1000, 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dir_and_socket_runs_agree_on_fetch_once_and_split_peer_stats() {
        let dir = peer_transport_run(false, 12, 777, 2).unwrap();
        let socket = peer_transport_run(true, 12, 777, 2).unwrap();
        // Cold epochs: the remote store supplies every byte exactly once,
        // transport regardless (fills are remote→home either way).
        assert_eq!(dir.cold.remote_bytes, dir.total_bytes, "dir cold fetch-once");
        assert_eq!(socket.cold.remote_bytes, socket.total_bytes, "socket cold fetch-once");
        // Warm epochs: zero remote; the socket run moves its non-local
        // bytes over the wire (and none through the peer's directory).
        assert_eq!(dir.warm.remote_reads, 0);
        assert_eq!(socket.warm.remote_reads, 0);
        assert_eq!(dir.warm.peer_net_reads, 0, "dir transport never touches the wire");
        assert!(dir.warm.peer_reads > 0);
        assert!(socket.warm.peer_net_bytes > 0, "socket warm epoch moved no wire bytes");
        assert_eq!(socket.warm.peer_reads, 0, "socket transport bypasses peer dirs");
        // Same epoch order + same stripe ⇒ the same segment reads resolve
        // to the same homes: the wire sees exactly the requests the dir
        // transport served from peer directories, and — since the wire
        // unit is the whole chunk while dir reads are ranged — at least as
        // many bytes. Local segments are identical either way.
        assert_eq!(socket.warm.peer_net_reads, dir.warm.peer_reads);
        assert!(socket.warm.peer_net_bytes >= dir.warm.peer_bytes);
        assert_eq!(socket.warm.local_reads, dir.warm.local_reads);
        assert_eq!(socket.warm.local_bytes, dir.warm.local_bytes);
    }

    #[test]
    fn table_has_one_row_per_transport() {
        let t = peer_transport_table_with(8, 1000, 2);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "dir");
        assert_eq!(t.rows[1][0], "socket");
    }
}
