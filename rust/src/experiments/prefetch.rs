//! Clairvoyant vs blind prefetch experiment (`hoard exp prefetch`): the
//! cold/first-epoch ablation the new [`crate::prefetch`] subsystem
//! exists for.
//!
//! Setup: one freshly generated dataset behind a remote store with a
//! per-request latency knob turned on (2 ms — the regime where *order*
//! matters; pure bandwidth-bound fills finish in the same wall time no
//! matter the order, because every byte must cross the same pipe
//! exactly once). J ∈ {1, 2} co-scheduled jobs on one [`DataPlane`]
//! then run their first epoch concurrently with the prefetch strategy
//! swept: the legacy sequential stripe walk vs the clairvoyant
//! scheduler, plus a pressure-constrained clairvoyant variant (a tight
//! explicit ahead-bytes budget, showing graceful degradation toward
//! just-in-time rather than collapse).
//!
//! Invariant checked on every point, J=1 and J=2 alike: the shared
//! ledger records exactly `num_chunks` fills and the remote store
//! supplies the dataset's bytes once — co-scheduled clairvoyant
//! schedulers dedup through `FillTable` claims, never double-fetch.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::cache::{CacheManager, EvictionPolicy, SharedCache};
use crate::metrics::Table;
use crate::netsim::NodeId;
use crate::posix::dataplane::{DataPlane, JobSession, JobSpec};
use crate::posix::realfs::{ReadStats, RealCluster};
use crate::prefetch::{PrefetchStrategy, Pressure};
use crate::remote::NfsModel;
use crate::storage::{Device, DeviceKind, Volume};
use crate::workload::datagen::{self, DataGenConfig};
use crate::workload::DatasetSpec;

use super::items_per_sec;

/// Nodes in the prefetch testbed (matches the co-job testbed).
pub const PREFETCH_NODES: usize = 4;

/// Per-request remote latency the testbed injects: makes the cold epoch
/// latency-bound, the regime where prefetch order and parallelism are
/// visible in wall time.
pub const REMOTE_LATENCY: Duration = Duration::from_millis(2);

/// Readers per job in the sweep.
const SWEEP_READERS: usize = 2;

/// Clairvoyant knobs pinned for the sweep: enough lookahead to keep the
/// scheduler busy, 4 in-flight fills.
const SWEEP_LOOKAHEAD: u64 = 256;
const SWEEP_INFLIGHT: usize = 4;

/// One measured point: J cold jobs, one strategy.
#[derive(Debug, Clone)]
pub struct PrefetchPoint {
    pub jobs: usize,
    pub strategy: PrefetchStrategy,
    /// The pressure rule, when the point ran constrained.
    pub pressure: Option<Pressure>,
    /// Wall of the concurrent cold phase (all J jobs' epoch 0).
    pub cold_s: f64,
    /// Aggregate first-epoch throughput (J × items / cold wall).
    pub items_per_sec: f64,
    /// Remote fills recorded by the shared ledger — `== chunks` on every
    /// strategy (fetch-once holds under prefetch races too).
    pub fills: u64,
    pub chunks: u64,
    /// Sum over jobs of `ReadStats::prefetch_issued` / `prefetch_hits` /
    /// `prefetch_wasted`.
    pub issued: u64,
    pub hits: u64,
    pub wasted: u64,
    /// Cluster-wide cold-phase stats (all jobs merged).
    pub cold: ReadStats,
    pub items: u64,
    pub total_bytes: u64,
}

/// Run J co-scheduled jobs' first epoch over one freshly placed dataset
/// with the given prefetch strategy (and optional pressure rule).
pub fn prefetch_run(
    jobs: usize,
    strategy: PrefetchStrategy,
    pressure: Option<Pressure>,
    items: u64,
    chunk_bytes: u64,
) -> Result<PrefetchPoint> {
    static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let root: PathBuf = std::env::temp_dir().join(format!(
        "hoard-prefetch-{jobs}-{}-{}-{seq}",
        strategy.name(),
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, PREFETCH_NODES, 200e6)
        .context("creating prefetch cluster")?
        .with_remote_model(Box::new(NfsModel::new(200e6)));
    cluster.set_remote_read_latency(REMOTE_LATENCY);
    let cfg = DataGenConfig { num_items: items, files_per_dir: 32, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).context("generating dataset")?;

    let vols = (0..PREFETCH_NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.chunk_bytes = chunk_bytes;
    manager.register(DatasetSpec::new("pf", items, total), "nfs://remote/pf".into())?;
    manager.place("pf", (0..PREFETCH_NODES).map(NodeId).collect())?;
    let cache = SharedCache::new(manager);
    let chunks = cache.geometry("pf")?.num_chunks();

    let plane = Arc::new(DataPlane::new(cluster.clone(), cache));
    let sessions: Vec<JobSession> = (0..jobs)
        .map(|j| {
            let mut spec = JobSpec::new("pf", cfg.clone())
                .readers(SWEEP_READERS)
                .seed(0xC05C + j as u64)
                .prefetch_strategy(strategy)
                .lookahead(SWEEP_LOOKAHEAD)
                .prefetch_inflight(SWEEP_INFLIGHT);
            if let Some(p) = pressure {
                spec = spec.prefetch_pressure(p);
            }
            plane.open_job(spec)
        })
        .collect::<Result<_>>()?;

    // Cold phase: all J jobs race their first epoch over the shared
    // ledger at once.
    let t0 = Instant::now();
    let per_job: Vec<ReadStats> = {
        let results: Vec<Result<ReadStats>> = std::thread::scope(|s| {
            let handles: Vec<_> = sessions
                .iter()
                .map(|sess| s.spawn(move || sess.run_epoch(0).map(|r| r.merged)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("job thread panicked"))))
                .collect()
        });
        results.into_iter().collect::<Result<_>>()?
    };
    let cold_s = t0.elapsed().as_secs_f64();
    let fills = plane.dataset_fills("pf");
    let cold = cluster.take_stats();
    ensure!(
        fills == chunks,
        "fetch-once violated: {fills} fills for {chunks} chunks (J={jobs}, {})",
        strategy.name()
    );

    let point = PrefetchPoint {
        jobs,
        strategy,
        pressure,
        cold_s,
        items_per_sec: items_per_sec(items * jobs as u64, cold_s),
        fills,
        chunks,
        issued: per_job.iter().map(|s| s.prefetch_issued).sum(),
        hits: per_job.iter().map(|s| s.prefetch_hits).sum(),
        wasted: per_job.iter().map(|s| s.prefetch_wasted).sum(),
        cold,
        items,
        total_bytes: total,
    };
    let _ = std::fs::remove_dir_all(&root);
    Ok(point)
}

/// The `hoard exp prefetch` sweep: blind vs clairvoyant at J ∈ {1, 2},
/// plus one pressure-constrained clairvoyant point.
pub fn prefetch_table_with(items: u64, chunk_bytes: u64) -> Table {
    let mut t = Table::new(
        "Real mode — cold first epoch, blind vs clairvoyant prefetch (shared fills)",
        &[
            "jobs",
            "strategy",
            "pressure",
            "cold phase (s)",
            "img/s",
            "fills",
            "chunks",
            "issued",
            "hits",
            "wasted",
            "cold remote bytes",
            "dataset bytes",
        ],
    );
    // The constrained point budgets ahead-bytes to a handful of chunks —
    // tight enough to bite, loose enough to finish (the gauge degrades to
    // just-in-time, never deadlocks).
    let budget = Pressure::Budget(4 * chunk_bytes);
    let points: Vec<(usize, PrefetchStrategy, Option<Pressure>)> = vec![
        (1, PrefetchStrategy::Sequential, None),
        (1, PrefetchStrategy::Clairvoyant, None),
        (2, PrefetchStrategy::Sequential, None),
        (2, PrefetchStrategy::Clairvoyant, None),
        (1, PrefetchStrategy::Clairvoyant, Some(budget)),
    ];
    for (jobs, strategy, pressure) in points {
        match prefetch_run(jobs, strategy, pressure, items, chunk_bytes) {
            Ok(p) => {
                t.row(vec![
                    format!("{jobs}"),
                    strategy.name().to_string(),
                    pressure.map(|pr| pr.name().to_string()).unwrap_or_else(|| "-".into()),
                    format!("{:.3}", p.cold_s),
                    format!("{:.0}", p.items_per_sec),
                    format!("{}", p.fills),
                    format!("{}", p.chunks),
                    format!("{}", p.issued),
                    format!("{}", p.hits),
                    format!("{}", p.wasted),
                    format!("{}", p.cold.remote_bytes),
                    format!("{}", p.total_bytes),
                ]);
            }
            Err(e) => {
                let mut cells = vec![
                    format!("{jobs}"),
                    strategy.name().to_string(),
                    pressure.map(|pr| pr.name().to_string()).unwrap_or_else(|| "-".into()),
                    format!("failed: {e:#}"),
                ];
                cells.resize(12, String::new());
                t.row(cells);
            }
        }
    }
    t
}

/// The default `hoard exp prefetch` table. Honors `HOARD_BENCH_SMOKE=1`
/// (smaller dataset so CI smoke runs stay fast).
pub fn prefetch_table(items: u64) -> Table {
    let smoke = std::env::var("HOARD_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let items = if smoke { items.min(16) } else { items };
    prefetch_table_with(items, 1000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clairvoyant_point_fills_once_and_counts_issues() {
        let p = prefetch_run(2, PrefetchStrategy::Clairvoyant, None, 12, 777).unwrap();
        assert_eq!(p.fills, p.chunks, "co-scheduled clairvoyant jobs must share fills once");
        assert_eq!(p.cold.remote_bytes, p.total_bytes, "remote supplies every byte once");
        assert!(p.issued > 0, "a cold epoch must issue prefetches");
        assert!(p.hits <= p.issued, "each prefetched unit yields at most one hit");
        assert!(p.issued <= p.chunks, "cannot issue more than the chunk grid");
    }

    #[test]
    fn pressure_constrained_point_still_completes() {
        let p = prefetch_run(
            1,
            PrefetchStrategy::Clairvoyant,
            Some(Pressure::Budget(2 * 777)),
            8,
            777,
        )
        .unwrap();
        assert_eq!(p.fills, p.chunks, "a tight budget defers, it must not drop chunks");
    }

    #[test]
    fn table_has_the_five_sweep_rows() {
        let t = prefetch_table_with(8, 1000);
        assert_eq!(t.rows.len(), 5);
        assert_eq!((t.rows[0][0].as_str(), t.rows[0][1].as_str()), ("1", "sequential"));
        assert_eq!((t.rows[1][0].as_str(), t.rows[1][1].as_str()), ("1", "clairvoyant"));
        assert_eq!((t.rows[2][0].as_str(), t.rows[2][1].as_str()), ("2", "sequential"));
        assert_eq!((t.rows[3][0].as_str(), t.rows[3][1].as_str()), ("2", "clairvoyant"));
        assert_eq!(t.rows[4][2].as_str(), "budget");
        for row in &t.rows {
            let fills: u64 = row[5]
                .parse()
                .unwrap_or_else(|_| panic!("fills column not numeric — run failed? {row:?}"));
            let chunks: u64 = row[6]
                .parse()
                .unwrap_or_else(|_| panic!("chunks column not numeric — run failed? {row:?}"));
            assert_eq!(fills, chunks, "fills must equal chunks: {row:?}");
        }
    }
}
