//! Storage device models: capacity accounting plus service-rate parameters
//! consumed by the fluid simulation. Presets match the paper's testbed
//! (Table 2: Samsung NVMe SSD 960 Pro, 4 × 512 GB per node, 2 used for the
//! Hoard cache).

use crate::util::fmt::{GB, MB};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    /// PCIe NVMe flash (960 Pro class).
    Nvme,
    /// SATA SSD.
    Ssd,
    /// 7.2k spinning disk.
    Hdd,
    /// DRAM-backed (pagepool / buffer cache).
    Ram,
}

impl DeviceKind {
    /// Sequential read bandwidth, bytes/s.
    pub fn read_bw(self) -> f64 {
        match self {
            DeviceKind::Nvme => 3.2e9, // 960 Pro datasheet ~3.2 GB/s
            DeviceKind::Ssd => 0.55e9,
            DeviceKind::Hdd => 0.18e9,
            DeviceKind::Ram => 20e9,
        }
    }

    /// Sequential write bandwidth, bytes/s.
    pub fn write_bw(self) -> f64 {
        match self {
            DeviceKind::Nvme => 1.8e9,
            DeviceKind::Ssd => 0.50e9,
            DeviceKind::Hdd => 0.16e9,
            DeviceKind::Ram => 20e9,
        }
    }

    /// Random-access degradation factor for small-file reads (the DL
    /// training pattern: ~112 KB images in random order). NVMe barely
    /// cares; spinning disks collapse.
    pub fn random_read_factor(self) -> f64 {
        match self {
            DeviceKind::Nvme => 0.85,
            DeviceKind::Ssd => 0.75,
            DeviceKind::Hdd => 0.15,
            DeviceKind::Ram => 1.0,
        }
    }
}

/// A device with capacity accounting.
#[derive(Debug, Clone)]
pub struct Device {
    pub kind: DeviceKind,
    pub capacity: u64,
    pub used: u64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StorageError {
    Full { need: u64, free: u64 },
    Underflow { release: u64, used: u64 },
}

impl std::fmt::Display for StorageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StorageError::Full { need, free } => {
                write!(f, "device full: need {need} bytes, {free} free")
            }
            StorageError::Underflow { release, used } => {
                write!(f, "releasing {release} bytes but only {used} used")
            }
        }
    }
}

impl std::error::Error for StorageError {}

impl Device {
    pub fn new(kind: DeviceKind, capacity: u64) -> Self {
        Device { kind, capacity, used: 0 }
    }

    /// Paper cache device: one 512 GB 960 Pro.
    pub fn nvme_960pro() -> Self {
        Device::new(DeviceKind::Nvme, 512 * GB)
    }

    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn allocate(&mut self, bytes: u64) -> Result<(), StorageError> {
        if bytes > self.free() {
            return Err(StorageError::Full { need: bytes, free: self.free() });
        }
        self.used += bytes;
        Ok(())
    }

    pub fn release(&mut self, bytes: u64) -> Result<(), StorageError> {
        if bytes > self.used {
            return Err(StorageError::Underflow { release: bytes, used: self.used });
        }
        self.used -= bytes;
        Ok(())
    }

    /// Effective read bandwidth for the DL access pattern.
    pub fn effective_read_bw(&self) -> f64 {
        self.kind.read_bw() * self.kind.random_read_factor()
    }
}

/// A node's cache volume: several devices treated as one striped pool
/// (Spectrum Scale stripes across local NSDs; 2 NVMe per node in Table 2).
#[derive(Debug, Clone)]
pub struct Volume {
    pub devices: Vec<Device>,
}

impl Volume {
    pub fn new(devices: Vec<Device>) -> Self {
        Volume { devices }
    }

    /// The paper's per-node cache: 2 × 512 GB NVMe.
    pub fn paper_cache_volume() -> Self {
        Volume::new(vec![Device::nvme_960pro(), Device::nvme_960pro()])
    }

    pub fn capacity(&self) -> u64 {
        self.devices.iter().map(|d| d.capacity).sum()
    }

    pub fn used(&self) -> u64 {
        self.devices.iter().map(|d| d.used).sum()
    }

    pub fn free(&self) -> u64 {
        self.capacity() - self.used()
    }

    /// Aggregate effective read bandwidth (devices striped ⇒ additive).
    pub fn read_bw(&self) -> f64 {
        self.devices.iter().map(|d| d.effective_read_bw()).sum()
    }

    pub fn write_bw(&self) -> f64 {
        self.devices.iter().map(|d| d.kind.write_bw()).sum()
    }

    /// Spread an allocation across devices proportionally to free space.
    pub fn allocate(&mut self, bytes: u64) -> Result<(), StorageError> {
        if bytes > self.free() {
            return Err(StorageError::Full { need: bytes, free: self.free() });
        }
        let mut remaining = bytes;
        let n = self.devices.len();
        for (i, d) in self.devices.iter_mut().enumerate() {
            let share = if i == n - 1 { remaining } else { (remaining / (n - i) as u64).min(d.free()) };
            let share = share.min(d.free()).min(remaining);
            d.allocate(share).expect("bounded by free");
            remaining -= share;
        }
        if remaining > 0 {
            // Pack leftovers anywhere with room.
            for d in &mut self.devices {
                let take = remaining.min(d.free());
                d.allocate(take).expect("bounded by free");
                remaining -= take;
                if remaining == 0 {
                    break;
                }
            }
        }
        debug_assert_eq!(remaining, 0);
        Ok(())
    }

    pub fn release(&mut self, bytes: u64) -> Result<(), StorageError> {
        if bytes > self.used() {
            return Err(StorageError::Underflow { release: bytes, used: self.used() });
        }
        let mut remaining = bytes;
        for d in &mut self.devices {
            let take = remaining.min(d.used);
            d.release(take).expect("bounded by used");
            remaining -= take;
            if remaining == 0 {
                break;
            }
        }
        Ok(())
    }
}

#[allow(dead_code)]
const _SMALL_FILE: u64 = 112 * MB / 1000; // ~112 KB avg ImageNet JPEG

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fmt::GB;

    #[test]
    fn device_allocate_release() {
        let mut d = Device::new(DeviceKind::Nvme, 100);
        d.allocate(60).unwrap();
        assert_eq!(d.free(), 40);
        assert!(matches!(d.allocate(50), Err(StorageError::Full { .. })));
        d.release(60).unwrap();
        assert_eq!(d.used, 0);
        assert!(matches!(d.release(1), Err(StorageError::Underflow { .. })));
    }

    #[test]
    fn paper_volume_capacity() {
        let v = Volume::paper_cache_volume();
        assert_eq!(v.capacity(), 1024 * GB); // 1 TB cache per node
        assert!(v.read_bw() > 5e9); // 2 NVMe striped
    }

    #[test]
    fn volume_spreads_and_releases() {
        let mut v = Volume::new(vec![
            Device::new(DeviceKind::Nvme, 100),
            Device::new(DeviceKind::Nvme, 100),
        ]);
        v.allocate(150).unwrap();
        assert_eq!(v.used(), 150);
        assert!(v.devices.iter().all(|d| d.used > 0), "should stripe: {v:?}");
        v.release(150).unwrap();
        assert_eq!(v.used(), 0);
    }

    #[test]
    fn volume_full() {
        let mut v = Volume::new(vec![Device::new(DeviceKind::Ssd, 10)]);
        assert!(v.allocate(11).is_err());
        v.allocate(10).unwrap();
        assert_eq!(v.free(), 0);
    }

    #[test]
    fn hdd_random_read_collapses() {
        let hdd = Device::new(DeviceKind::Hdd, GB);
        let nvme = Device::new(DeviceKind::Nvme, GB);
        assert!(hdd.effective_read_bw() < 0.05 * nvme.effective_read_bw());
    }

    #[test]
    fn prop_volume_alloc_release_conserves() {
        use crate::util::{prop::forall, Rng};
        forall(
            200,
            |rng: &mut Rng| {
                let ops: Vec<(bool, u64)> = (0..rng.gen_range(20) + 1)
                    .map(|_| (rng.bool(0.6), rng.gen_range(64) + 1))
                    .collect();
                ops
            },
            |ops| {
                let mut v = Volume::new(vec![
                    Device::new(DeviceKind::Nvme, 200),
                    Device::new(DeviceKind::Nvme, 100),
                ]);
                let mut expect: u64 = 0;
                for &(alloc, n) in ops {
                    if alloc {
                        if v.allocate(n).is_ok() {
                            expect += n;
                        }
                    } else if v.release(n).is_ok() {
                        expect -= n;
                    }
                    if v.used() != expect {
                        return Err(format!("used {} != expected {}", v.used(), expect));
                    }
                    if v.used() > v.capacity() {
                        return Err("over capacity".into());
                    }
                }
                Ok(())
            },
        );
    }
}
