//! The Hoard distributed cache layer — the paper's core contribution.
//!
//! Responsibilities (paper §3.2, "distributed cache layer" + "dataset
//! management layer" data plane):
//!  * accept *what/where* commands from the coordinator (it never makes
//!    placement choices on its own),
//!  * stripe each dataset over the chosen node subset ([`stripe`]),
//!  * track dataset life cycles decoupled from jobs ([`registry`]),
//!  * serve reads with AFM-style transparent miss handling / prefetch
//!    ([`CacheManager::read_location`], [`CacheManager::prefetch_tick`]),
//!  * evict at dataset granularity ([`eviction`]).

pub mod eviction;
pub mod ramtier;
pub mod registry;
pub mod stripe;

pub use eviction::{plan_admission, Admission, EvictionPolicy};
pub use ramtier::{ChunkKey, RamTier, RamTierStats};
pub use registry::{DatasetRecord, DatasetState, Registry, RegistryError};
pub use stripe::{item_range, ChunkSet, StripeMap};

use crate::netsim::NodeId;
use crate::storage::Volume;
use crate::workload::DatasetSpec;

/// Where a read is served from — drives both the fluid simulation and the
/// real-mode VFS.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadLocation {
    /// On the reader's own cache volume.
    Local,
    /// On a peer cache node.
    Peer(NodeId),
    /// Not cached (yet): fetch from the remote store via the AFM gateway,
    /// then it will live on `fill_node`.
    RemoteFill { fill_node: NodeId },
}

/// Chunk-granular answer to "where do I read item `i` from?": one
/// `(item-local byte range, location)` segment per chunk the item
/// overlaps. A partially cached item yields *mixed* segments — resident
/// chunks served local/peer, missing chunks remote-filled — which is what
/// lets a reader blocked on chunk `k` proceed with every other chunk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReadPlan {
    pub segments: Vec<(std::ops::Range<u64>, ReadLocation)>,
}

impl ReadPlan {
    /// No segment needs a remote fill.
    pub fn fully_resident(&self) -> bool {
        self.segments.iter().all(|(_, l)| !matches!(l, ReadLocation::RemoteFill { .. }))
    }

    /// Total bytes covered by the plan (== the item's length).
    pub fn len_bytes(&self) -> u64 {
        self.segments.iter().map(|(r, _)| r.end - r.start).sum()
    }

    /// Merge adjacent segments served from the same location into one
    /// ranged segment, so a consumer issues one request per *location
    /// run* instead of one per chunk (e.g. the two halves of an item
    /// whose chunks both home on the same peer, or a run of missing
    /// chunks all remote-filling to the same node). Preserves order and
    /// total bytes.
    ///
    /// Note the limits of a run: on-disk chunks are one *file each*, so a
    /// local run cannot become one `pread` — the hot path's equivalent is
    /// the per-peer **batched** fetch
    /// ([`ChunkTransport::fetch_chunk_ranges`](crate::peer::ChunkTransport::fetch_chunk_ranges)),
    /// which groups every resident chunk homed on one peer (a superset of
    /// adjacent runs) into a single wire round trip. `coalesced()` is the
    /// plan-level view of those runs for consumers that reason about
    /// location spans (benches, planners, future eviction-aware serving).
    pub fn coalesced(&self) -> Vec<(std::ops::Range<u64>, ReadLocation)> {
        let mut out: Vec<(std::ops::Range<u64>, ReadLocation)> = Vec::new();
        for (r, l) in &self.segments {
            if let Some((last_r, last_l)) = out.last_mut() {
                if last_r.end == r.start && last_l == l {
                    last_r.end = r.end;
                    continue;
                }
            }
            out.push((r.clone(), *l));
        }
        out
    }
}

/// Immutable snapshot of one placed dataset's chunk addressing: the
/// dataset's own [`StripeMap`] (cloned — chunk grid and node round-robin
/// come from the single implementation in [`stripe`]) plus its item
/// dimensions. Shared by the cache manager, the reader pool and the
/// chunked mounts so control plane and data plane agree on the grid by
/// construction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkGeometry {
    stripe: StripeMap,
    pub total_bytes: u64,
    pub num_items: u64,
    /// Stable registry-assigned dataset ID: the wire address of the peer
    /// chunk protocol (`GetChunk { dataset_id, chunk, grid_bytes }`) and
    /// the namespace of the on-disk chunk files, so two datasets sharing
    /// a grid can never serve each other's chunks.
    pub dataset_id: u64,
    /// Placement generation the geometry was cut from (bumped on every
    /// `place`). Part of the on-disk chunk path and the wire address, so
    /// chunks written under an evicted placement are invisible to the
    /// re-placed dataset — even on the same grid.
    pub generation: u64,
}

impl ChunkGeometry {
    pub fn chunk_bytes(&self) -> u64 {
        self.stripe.chunk_bytes
    }

    pub fn num_chunks(&self) -> u64 {
        self.stripe.num_chunks(self.total_bytes)
    }

    pub fn nodes(&self) -> &[NodeId] {
        self.stripe.nodes()
    }

    /// Home node of chunk `c`.
    pub fn node_of_chunk(&self, c: u64) -> NodeId {
        self.stripe.node_of_chunk(c)
    }

    /// Home node of item `i` (file-granular round robin — the serving
    /// home `read_location` summarises an item by).
    pub fn node_of_item(&self, i: u64) -> NodeId {
        self.stripe.node_of_item(i)
    }

    /// Global byte range `[start, end)` of chunk `c` (tail may be short).
    pub fn chunk_range(&self, c: u64) -> (u64, u64) {
        self.stripe.chunk_range(c, self.total_bytes)
    }

    /// Global byte range of item `i` (the [`item_range`] partition).
    pub fn item_range(&self, i: u64) -> (u64, u64) {
        item_range(i, self.num_items, self.total_bytes)
    }

    /// Chunk IDs overlapping item `i`.
    pub fn chunks_of_item(&self, i: u64) -> std::ops::Range<u64> {
        self.stripe.chunks_of_item(i, self.num_items, self.total_bytes)
    }

    /// Item holding global byte `off` (the unique non-empty item whose
    /// range contains it).
    pub fn item_of_offset(&self, off: u64) -> u64 {
        debug_assert!(off < self.total_bytes);
        let (mut lo, mut hi) = (0u64, self.num_items - 1);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if item_range(mid, self.num_items, self.total_bytes).1 > off {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    }

    /// Item IDs overlapping chunk `c` — what a chunk fill must fetch from
    /// the per-item remote files.
    pub fn items_of_chunk(&self, c: u64) -> std::ops::Range<u64> {
        let (cs, ce) = self.chunk_range(c);
        if cs >= ce {
            return 0..0;
        }
        self.item_of_offset(cs)..self.item_of_offset(ce - 1) + 1
    }
}

/// Lock-free view of one placed dataset's residency: the chunk grid
/// ([`ChunkGeometry`]) plus an atomic mirror of the registry's [`ChunkSet`]
/// bitmap. Published by [`CacheManager::place`] and updated (under the
/// manager's exclusive lock) by every path that marks chunks —
/// `mark_chunks`, `mark_item`, `prefetch_tick` — so readers holding the
/// `Arc` resolve [`ResidencySnapshot::read_plan`] /
/// [`ResidencySnapshot::read_location`] with plain atomic loads and **zero**
/// `RwLock` acquisitions. The locked [`CacheManager`] lane stays the
/// authoritative slow path (and the differential-testing oracle).
///
/// Publication rules:
///  * bits are **monotone** while the placement lives — writers only set
///    them, and only *after* the payload landed (the write lock orders the
///    store after the filesystem write), so a reader observing a set bit
///    (`Acquire`) sees the chunk's bytes;
///  * a cleared bit may be stale (a fill can land between load and use);
///    readers already treat "resident but gone at the source" / "missing
///    but present on disk" leniently, so staleness only costs a fallback,
///    never correctness;
///  * eviction / node failure **retires** the snapshot instead of clearing
///    bits: `read_plan`/`read_location` answer `None` and callers fall
///    back to the locked lane (which reports the placement as gone).
#[derive(Debug)]
pub struct ResidencySnapshot {
    geom: ChunkGeometry,
    words: Vec<std::sync::atomic::AtomicU64>,
    marked: std::sync::atomic::AtomicU64,
    full: std::sync::atomic::AtomicBool,
    retired: std::sync::atomic::AtomicBool,
}

impl ResidencySnapshot {
    fn new(geom: ChunkGeometry) -> std::sync::Arc<Self> {
        use std::sync::atomic::{AtomicBool, AtomicU64};
        let n = geom.num_chunks();
        let words = (0..(n as usize).div_ceil(64).max(1)).map(|_| AtomicU64::new(0)).collect();
        std::sync::Arc::new(ResidencySnapshot {
            geom,
            words,
            marked: AtomicU64::new(0),
            full: AtomicBool::new(n == 0),
            retired: AtomicBool::new(false),
        })
    }

    /// The dataset's chunk grid (shared with the locked lane by
    /// construction — the snapshot embeds the placed stripe).
    pub fn geometry(&self) -> &ChunkGeometry {
        &self.geom
    }

    /// The placement this snapshot mirrors is gone (evicted / failed
    /// node): fall back to the locked lane.
    pub fn retired(&self) -> bool {
        self.retired.load(std::sync::atomic::Ordering::Acquire)
    }

    /// Force-retire outside the manager (e.g. `DataPlane::reset_dataset`
    /// invalidating in-flight sessions). Idempotent; evict/fail_node call
    /// it too.
    pub(crate) fn retire(&self) {
        self.retired.store(true, std::sync::atomic::Ordering::Release);
    }

    /// Every chunk resident (the `Cached` state, observed lock-free).
    pub fn is_full(&self) -> bool {
        self.full.load(std::sync::atomic::Ordering::Acquire)
    }

    pub fn marked_chunks(&self) -> u64 {
        self.marked.load(std::sync::atomic::Ordering::Acquire)
    }

    /// Is chunk `c` resident? One or two atomic loads, no locks.
    pub fn contains(&self, c: u64) -> bool {
        debug_assert!(c < self.geom.num_chunks(), "chunk {c} out of range");
        if self.is_full() {
            return true;
        }
        let w = self.words[(c / 64) as usize].load(std::sync::atomic::Ordering::Acquire);
        w & (1u64 << (c % 64)) != 0
    }

    /// Writer side — called only by the [`CacheManager`] under its
    /// exclusive lock, after the corresponding [`ChunkSet`] mark.
    fn set(&self, c: u64) {
        use std::sync::atomic::Ordering;
        let bit = 1u64 << (c % 64);
        let prev = self.words[(c / 64) as usize].fetch_or(bit, Ordering::AcqRel);
        if prev & bit == 0 {
            let m = self.marked.fetch_add(1, Ordering::AcqRel) + 1;
            if m == self.geom.num_chunks() {
                self.full.store(true, Ordering::Release);
            }
        }
    }

    fn set_full(&self) {
        self.full.store(true, std::sync::atomic::Ordering::Release);
    }

    /// Every chunk of `item` resident? `None` ⇔ retired.
    pub fn item_resident(&self, item: u64) -> Option<bool> {
        if self.retired() {
            return None;
        }
        Some(self.is_full() || self.geom.chunks_of_item(item).all(|c| self.contains(c)))
    }

    /// Lock-free twin of [`CacheManager::read_location`]. `None` ⇔ the
    /// snapshot is retired — resolve through the locked lane instead.
    pub fn read_location(&self, item: u64, reader: NodeId) -> Option<ReadLocation> {
        if self.retired() {
            return None;
        }
        let home = self.geom.node_of_item(item);
        let resident =
            self.is_full() || self.geom.chunks_of_item(item).all(|c| self.contains(c));
        Some(if resident {
            if home == reader {
                ReadLocation::Local
            } else {
                ReadLocation::Peer(home)
            }
        } else {
            ReadLocation::RemoteFill { fill_node: home }
        })
    }

    /// Lock-free twin of [`CacheManager::read_plan`]: identical segments
    /// for identical residency. `None` ⇔ retired.
    pub fn read_plan(&self, item: u64, reader: NodeId) -> Option<ReadPlan> {
        if self.retired() {
            return None;
        }
        let (s, e) = self.geom.item_range(item);
        let mut segments = Vec::new();
        for c in self.geom.chunks_of_item(item) {
            let (cs, ce) = self.geom.chunk_range(c);
            let seg = s.max(cs) - s..e.min(ce) - s;
            let home = self.geom.node_of_chunk(c);
            let loc = if self.contains(c) {
                if home == reader {
                    ReadLocation::Local
                } else {
                    ReadLocation::Peer(home)
                }
            } else {
                ReadLocation::RemoteFill { fill_node: home }
            };
            segments.push((seg, loc));
        }
        Some(ReadPlan { segments })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum CacheError {
    Registry(RegistryError),
    NotPlaced(String),
    Full { need: u64, reclaimable: u64 },
    NotAMember(usize, String),
}

impl std::fmt::Display for CacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            // Transparent: delegate to the registry error.
            CacheError::Registry(e) => write!(f, "{e}"),
            CacheError::NotPlaced(n) => write!(f, "dataset '{n}' has no stripe placement yet"),
            CacheError::Full { need, reclaimable } => {
                write!(f, "cache admission rejected: need {need} bytes, reclaimable {reclaimable}")
            }
            CacheError::NotAMember(node, ds) => {
                write!(f, "node {node} is not a cache member for dataset '{ds}'")
            }
        }
    }
}

impl std::error::Error for CacheError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CacheError::Registry(e) => Some(e),
            _ => None,
        }
    }
}

impl From<RegistryError> for CacheError {
    fn from(e: RegistryError) -> Self {
        CacheError::Registry(e)
    }
}

/// Cache-layer events, for observability and tests.
#[derive(Debug, Clone, PartialEq)]
pub enum CacheEvent {
    Registered(String),
    Placed { dataset: String, nodes: Vec<usize> },
    PrefetchStarted(String),
    FullyCached(String),
    Evicted(String),
    Deleted(String),
    NodeFailed { node: usize, datasets_lost: Vec<String> },
    NodeRecovered(usize),
}

/// The per-cluster cache manager: registry + node volumes + policy.
#[derive(Debug)]
pub struct CacheManager {
    pub registry: Registry,
    volumes: Vec<Volume>,
    /// Per-node health; failed nodes hold no data and accept no placements
    /// until recovered.
    healthy: Vec<bool>,
    pub policy: EvictionPolicy,
    pub chunk_bytes: u64,
    pub events: Vec<CacheEvent>,
}

impl CacheManager {
    pub fn new(volumes: Vec<Volume>, policy: EvictionPolicy) -> Self {
        let healthy = vec![true; volumes.len()];
        CacheManager {
            registry: Registry::new(),
            volumes,
            healthy,
            policy,
            chunk_bytes: 64 << 20,
            events: vec![],
        }
    }

    pub fn node_healthy(&self, n: NodeId) -> bool {
        self.healthy[n.0]
    }

    /// A cache node died (disk loss / node loss). Every dataset striped on
    /// it loses its placement — striping without replication means a lost
    /// stripe invalidates the *dataset* (Requirement 2 granularity: a
    /// partial dataset is as good as none). Reservations are released
    /// everywhere; affected datasets revert to `Registered` so the
    /// coordinator's repair loop re-places them on healthy nodes and AFM
    /// re-fetches from the authoritative remote copy. Returns the affected
    /// dataset names.
    pub fn fail_node(&mut self, n: NodeId) -> Vec<String> {
        if !self.healthy[n.0] {
            return vec![];
        }
        self.healthy[n.0] = false;
        let affected: Vec<String> = self
            .registry
            .iter()
            .filter(|r| r.stripe.as_ref().is_some_and(|s| s.contains(n)))
            .map(|r| r.spec.name.clone())
            .collect();
        for name in &affected {
            let rec = self.registry.get_mut(name).expect("listed above");
            let total = rec.spec.total_bytes;
            let stripe = rec.stripe.take().expect("filtered on stripe");
            if let Some(snap) = rec.snapshot.take() {
                snap.retire();
            }
            rec.state = DatasetState::Registered;
            for &sn in stripe.nodes() {
                let share = stripe.bytes_on_node(sn, total);
                self.volumes[sn.0].release(share).expect("reserved at placement");
            }
        }
        self.events.push(CacheEvent::NodeFailed {
            node: n.0,
            datasets_lost: affected.clone(),
        });
        affected
    }

    /// A cache node died but its datasets should **degrade, not vanish**
    /// — the real-mode failure model. Every placed dataset striped on `n`
    /// keeps its stripe and its surviving chunks: only the chunks homed on
    /// the dead node are cleared, so survivors keep serving warm while the
    /// lost chunks re-plan as remote fills. The published residency
    /// snapshot is retired and a fresh one — **same generation**, so
    /// surviving chunk files keep their on-disk and wire addresses — is
    /// republished with the survivor bits. Only the dead node's
    /// reservation is released. Returns the degraded dataset names.
    ///
    /// Contrast [`CacheManager::fail_node`], the simulated-coordinator
    /// path, where losing a stripe member invalidates the whole placement
    /// and the repair loop re-places cold.
    pub fn degrade_node(&mut self, n: NodeId) -> Vec<String> {
        if !self.healthy[n.0] {
            return vec![];
        }
        self.healthy[n.0] = false;
        let affected: Vec<String> = self
            .registry
            .iter()
            .filter(|r| r.stripe.as_ref().is_some_and(|s| s.contains(n)))
            .map(|r| r.spec.name.clone())
            .collect();
        for name in &affected {
            let rec = self.registry.get_mut(name).expect("listed above");
            let total = rec.spec.total_bytes;
            let stripe = rec.stripe.as_ref().expect("filtered on stripe").clone();
            let state = std::mem::replace(&mut rec.state, DatasetState::Registered);
            let (mut chunks, mut lost) = match state {
                DatasetState::Cached => {
                    let mut full = ChunkSet::new(total, stripe.chunk_bytes);
                    for c in 0..full.num_chunks() {
                        full.mark(c);
                    }
                    (full, vec![])
                }
                DatasetState::Caching { chunks } => (chunks, vec![]),
                DatasetState::Degraded { chunks, lost } => (chunks, lost),
                other => {
                    // A stripe in Evicting/Replacing holds no serving
                    // residency — leave it to its own transition.
                    rec.state = other;
                    continue;
                }
            };
            for c in 0..chunks.num_chunks() {
                if stripe.node_of_chunk(c) == n {
                    chunks.clear(c);
                }
            }
            lost.push(n);
            if let Some(snap) = rec.snapshot.take() {
                snap.retire();
            }
            let snap = ResidencySnapshot::new(ChunkGeometry {
                stripe: stripe.clone(),
                total_bytes: total,
                num_items: rec.spec.num_items,
                dataset_id: rec.id,
                generation: rec.generation,
            });
            for c in 0..chunks.num_chunks() {
                if chunks.contains(c) {
                    snap.set(c);
                }
            }
            rec.snapshot = Some(snap);
            rec.state = DatasetState::Degraded { chunks, lost };
            // The dead node's share is gone; survivors keep theirs.
            let share = stripe.bytes_on_node(n, total);
            self.volumes[n.0].release(share).expect("reserved at placement");
        }
        self.events.push(CacheEvent::NodeFailed {
            node: n.0,
            datasets_lost: affected.clone(),
        });
        affected
    }

    /// Coordinator-triggered re-stripe of a placed dataset: tear down the
    /// placement bookkeeping (state → `Replacing`, snapshot retired,
    /// surviving reservations released, stripe cleared so
    /// [`CacheManager::place`] accepts a new node set) and return what a
    /// warm migration needs — the old chunk geometry plus the chunk IDs
    /// still resident on survivors. The caller re-places on the survivor
    /// set (generation bump) and copies the surviving chunk payloads
    /// instead of re-fetching the whole dataset from remote.
    pub fn begin_replace(&mut self, name: &str) -> Result<(ChunkGeometry, Vec<u64>), CacheError> {
        let geom = self.geometry(name)?;
        let rec = self.registry.get_mut(name)?;
        let total = rec.spec.total_bytes;
        let stripe = rec.stripe.take().expect("geometry() ensured a placement");
        let (survivors, lost): (Vec<u64>, Vec<NodeId>) =
            match std::mem::replace(&mut rec.state, DatasetState::Replacing) {
                DatasetState::Cached => ((0..geom.num_chunks()).collect(), vec![]),
                DatasetState::Caching { chunks } => (
                    (0..chunks.num_chunks()).filter(|&c| chunks.contains(c)).collect(),
                    vec![],
                ),
                DatasetState::Degraded { chunks, lost } => (
                    (0..chunks.num_chunks()).filter(|&c| chunks.contains(c)).collect(),
                    lost,
                ),
                other => {
                    let why = format!("replace in state {other:?}");
                    rec.state = other;
                    rec.stripe = Some(stripe);
                    return Err(CacheError::Registry(RegistryError::BadTransition(
                        name.into(),
                        why,
                    )));
                }
            };
        if let Some(snap) = rec.snapshot.take() {
            snap.retire();
        }
        for &sn in stripe.nodes() {
            if lost.contains(&sn) {
                continue; // released when the node failed
            }
            let share = stripe.bytes_on_node(sn, total);
            self.volumes[sn.0].release(share).expect("reserved at placement");
        }
        Ok((geom, survivors))
    }

    /// Bring a failed node back (empty — its old data is considered
    /// gone). Datasets degraded on it re-admit the node: its reservation
    /// is re-taken and its chunks — still cleared — refill through the
    /// normal mark paths; the dataset leaves `Degraded` once no lost
    /// member remains.
    pub fn recover_node(&mut self, n: NodeId) {
        if self.healthy[n.0] {
            return;
        }
        self.healthy[n.0] = true;
        let degraded: Vec<String> = self
            .registry
            .iter()
            .filter(|r| {
                matches!(&r.state, DatasetState::Degraded { lost, .. } if lost.contains(&n))
            })
            .map(|r| r.spec.name.clone())
            .collect();
        for name in &degraded {
            let rec = self.registry.get_mut(name).expect("listed above");
            let total = rec.spec.total_bytes;
            let stripe = rec.stripe.as_ref().expect("degraded keeps its stripe").clone();
            // Re-reserve the share released at failure; if the capacity
            // was taken meanwhile, the dataset stays degraded on `n`.
            if self.volumes[n.0].allocate(stripe.bytes_on_node(n, total)).is_err() {
                continue;
            }
            let state = std::mem::replace(&mut rec.state, DatasetState::Registered);
            rec.state = match state {
                DatasetState::Degraded { chunks, mut lost } => {
                    lost.retain(|&m| m != n);
                    if !lost.is_empty() {
                        DatasetState::Degraded { chunks, lost }
                    } else if chunks.is_full() {
                        DatasetState::Cached
                    } else {
                        DatasetState::Caching { chunks }
                    }
                }
                other => other,
            };
        }
        self.events.push(CacheEvent::NodeRecovered(n.0));
    }

    pub fn num_nodes(&self) -> usize {
        self.volumes.len()
    }

    pub fn volume(&self, n: NodeId) -> &Volume {
        &self.volumes[n.0]
    }

    /// Total cache capacity across all nodes (the paper's "4 TB for any
    /// single job" aggregate-capacity point, §4.1).
    pub fn total_capacity(&self) -> u64 {
        self.volumes.iter().map(|v| v.capacity()).sum()
    }

    /// Register a dataset custom resource (no placement yet).
    pub fn register(&mut self, spec: DatasetSpec, url: String) -> Result<(), CacheError> {
        let name = spec.name.clone();
        self.registry.register(spec, url)?;
        self.events.push(CacheEvent::Registered(name));
        Ok(())
    }

    /// Place a dataset on `nodes` (chosen by the coordinator), reserving
    /// capacity — evicting per policy if needed. Transitions to `Caching`.
    pub fn place(&mut self, name: &str, nodes: Vec<NodeId>) -> Result<(), CacheError> {
        if let Some(&bad) = nodes.iter().find(|n| !self.healthy[n.0]) {
            return Err(CacheError::NotAMember(bad.0, format!("{name} (node failed)")));
        }
        let need = {
            let rec = self.registry.get_mut(name)?;
            if rec.stripe.is_some() {
                return Ok(()); // already placed
            }
            rec.spec.total_bytes
        };
        // Capacity check against the *chosen subset*.
        let subset_capacity: u64 = nodes.iter().map(|n| self.volumes[n.0].capacity()).sum();
        let subset_used: u64 = nodes.iter().map(|n| self.volumes[n.0].used()).sum();
        if need > subset_capacity.saturating_sub(subset_used) {
            match plan_admission(self.policy, &self.registry, self.total_capacity(), need) {
                Admission::Fits => {}
                Admission::EvictFirst(victims) => {
                    for v in victims {
                        self.evict(&v)?;
                    }
                }
                Admission::Rejected { need, reclaimable } => {
                    return Err(CacheError::Full { need, reclaimable });
                }
            }
        }
        // Adapt the chunk so small datasets still spread over the whole
        // subset (each node holds ≈ total/k, the large-dataset behaviour).
        let k = nodes.len() as u64;
        let chunk = self.chunk_bytes.min(need.div_ceil(k)).max(1);
        let stripe = StripeMap::new(nodes.clone(), chunk);
        // Reserve per-node shares.
        for &n in &nodes {
            let share = stripe.bytes_on_node(n, need);
            self.volumes[n.0]
                .allocate(share)
                .map_err(|_| CacheError::Full { need: share, reclaimable: 0 })?;
        }
        let chunks = ChunkSet::new(need, chunk);
        let rec = self.registry.get_mut(name)?;
        // Every placement is a new generation: files and wire requests
        // from earlier placements no longer address this dataset.
        rec.generation += 1;
        // Publish the lock-free residency snapshot alongside the placement:
        // same stripe, empty bitmap, bits set under this manager's
        // exclusive lock as fills land.
        rec.snapshot = Some(ResidencySnapshot::new(ChunkGeometry {
            stripe: stripe.clone(),
            total_bytes: need,
            num_items: rec.spec.num_items,
            dataset_id: rec.id,
            generation: rec.generation,
        }));
        rec.stripe = Some(stripe);
        rec.state = DatasetState::Caching { chunks };
        self.events.push(CacheEvent::Placed {
            dataset: name.to_string(),
            nodes: nodes.iter().map(|n| n.0).collect(),
        });
        Ok(())
    }

    /// Record `bytes` of *sequential* remote fetch progress (the modelled
    /// AFM prefetch walking the stripe in order): advances the chunk fill
    /// front, marking every chunk it fully covers and skipping chunks that
    /// already landed out of order.
    pub fn prefetch_tick(&mut self, name: &str, bytes: u64) -> Result<(), CacheError> {
        let rec = self.registry.get_mut(name)?;
        let snap = rec.snapshot.clone();
        match &mut rec.state {
            DatasetState::Caching { chunks } => {
                let before = chunks.front();
                chunks.advance(bytes);
                if let Some(s) = &snap {
                    // Every chunk below the front is marked; mirror the
                    // advance as a contiguous range of bit sets.
                    for c in before..chunks.front() {
                        s.set(c);
                    }
                }
                if chunks.is_full() {
                    rec.state = DatasetState::Cached;
                    if let Some(s) = &snap {
                        s.set_full();
                    }
                    self.events.push(CacheEvent::FullyCached(name.to_string()));
                }
                Ok(())
            }
            DatasetState::Cached => Ok(()),
            s => Err(CacheError::Registry(RegistryError::BadTransition(
                name.into(),
                format!("prefetch in state {s:?}"),
            ))),
        }
    }

    /// Mark specific chunks resident (real-mode fills land out of order —
    /// this is the exact counterpart of the sequential `prefetch_tick`).
    pub fn mark_chunks(
        &mut self,
        name: &str,
        chunk_ids: impl IntoIterator<Item = u64>,
    ) -> Result<(), CacheError> {
        let rec = self.registry.get_mut(name)?;
        let snap = rec.snapshot.clone();
        let stripe = rec.stripe.clone();
        match &mut rec.state {
            DatasetState::Caching { chunks } => {
                for c in chunk_ids {
                    if chunks.mark(c) {
                        if let Some(s) = &snap {
                            s.set(c);
                        }
                    }
                }
                if chunks.is_full() {
                    rec.state = DatasetState::Cached;
                    if let Some(s) = &snap {
                        s.set_full();
                    }
                    self.events.push(CacheEvent::FullyCached(name.to_string()));
                }
                Ok(())
            }
            DatasetState::Degraded { chunks, lost } => {
                let stripe = stripe.as_ref().expect("degraded keeps its stripe");
                for c in chunk_ids {
                    // A chunk homed on a lost member has no live node to
                    // hold it — it cannot be admitted until the node
                    // rejoins or the dataset is re-placed.
                    if lost.contains(&stripe.node_of_chunk(c)) {
                        continue;
                    }
                    if chunks.mark(c) {
                        if let Some(s) = &snap {
                            s.set(c);
                        }
                    }
                }
                Ok(())
            }
            DatasetState::Cached => Ok(()),
            s => Err(CacheError::Registry(RegistryError::BadTransition(
                name.into(),
                format!("chunk mark in state {s:?}"),
            ))),
        }
    }

    /// Record a whole-*item* fill: credit each overlapped chunk with
    /// exactly the bytes the item contributes to it, keyed by the item ID
    /// (idempotent — racing observers reporting the same fill twice never
    /// double-count). A chunk (which may span many items) is marked
    /// resident only once every one of its bytes has been credited — so
    /// coarse chunks never over-report residency after a few item fills.
    pub fn mark_item(&mut self, name: &str, item: u64) -> Result<(), CacheError> {
        let overlaps: Vec<(u64, u64)> = {
            let rec = self
                .registry
                .get(name)
                .ok_or_else(|| CacheError::Registry(RegistryError::NotFound(name.to_string())))?;
            let stripe =
                rec.stripe.as_ref().ok_or_else(|| CacheError::NotPlaced(name.into()))?;
            let total = rec.spec.total_bytes;
            let (s, e) = item_range(item, rec.spec.num_items, total);
            stripe
                .chunks_of_item(item, rec.spec.num_items, total)
                .map(|c| {
                    let (cs, ce) = stripe.chunk_range(c, total);
                    (c, e.min(ce) - s.max(cs))
                })
                .collect()
        };
        let rec = self.registry.get_mut(name)?;
        let snap = rec.snapshot.clone();
        let stripe = rec.stripe.clone();
        match &mut rec.state {
            DatasetState::Degraded { chunks, lost } => {
                let stripe = stripe.as_ref().expect("degraded keeps its stripe");
                for (c, bytes) in overlaps {
                    if lost.contains(&stripe.node_of_chunk(c)) {
                        continue;
                    }
                    if chunks.credit_unit(c, item, bytes) {
                        if let Some(s) = &snap {
                            s.set(c);
                        }
                    }
                }
                Ok(())
            }
            DatasetState::Caching { chunks } => {
                for (c, bytes) in overlaps {
                    if chunks.credit_unit(c, item, bytes) {
                        if let Some(s) = &snap {
                            s.set(c);
                        }
                    }
                }
                if chunks.is_full() {
                    rec.state = DatasetState::Cached;
                    if let Some(s) = &snap {
                        s.set_full();
                    }
                    self.events.push(CacheEvent::FullyCached(name.to_string()));
                }
                Ok(())
            }
            DatasetState::Cached => Ok(()),
            s => Err(CacheError::Registry(RegistryError::BadTransition(
                name.into(),
                format!("item mark in state {s:?}"),
            ))),
        }
    }

    /// Chunk-addressing snapshot for a placed dataset (what the real-mode
    /// chunked data plane keys its fill table and on-disk layout by).
    pub fn geometry(&self, name: &str) -> Result<ChunkGeometry, CacheError> {
        let rec = self
            .registry
            .get(name)
            .ok_or_else(|| CacheError::Registry(RegistryError::NotFound(name.to_string())))?;
        let stripe = rec.stripe.as_ref().ok_or_else(|| CacheError::NotPlaced(name.into()))?;
        Ok(ChunkGeometry {
            stripe: stripe.clone(),
            total_bytes: rec.spec.total_bytes,
            num_items: rec.spec.num_items,
            dataset_id: rec.id,
            generation: rec.generation,
        })
    }

    /// The lock-free residency snapshot of a placed dataset — the warm
    /// path's fast lane. Hold the `Arc` and resolve reads without touching
    /// this manager again; fall back to the locked lane when it retires.
    pub fn residency_snapshot(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<ResidencySnapshot>, CacheError> {
        let rec = self
            .registry
            .get(name)
            .ok_or_else(|| CacheError::Registry(RegistryError::NotFound(name.to_string())))?;
        rec.snapshot.clone().ok_or_else(|| CacheError::NotPlaced(name.into()))
    }

    /// Stable numeric ID of a registered dataset (the peer protocol's
    /// wire address for it; valid even before placement).
    pub fn dataset_id(&self, name: &str) -> Result<u64, CacheError> {
        self.registry
            .get(name)
            .map(|r| r.id)
            .ok_or_else(|| CacheError::Registry(RegistryError::NotFound(name.to_string())))
    }

    /// Resolve where item `item` of `name` is served for a reader on
    /// `reader` — the transparent-caching decision point, summarised at
    /// item granularity (the serving home is the item's round-robin home;
    /// see [`CacheManager::read_plan`] for the per-chunk answer).
    ///
    /// Exact: while caching, an item is resident iff **every** chunk it
    /// overlaps is marked in the residency bitmap. The old scalar fill
    /// front approximated this through an f64 item fraction, which could
    /// report `RemoteFill` for the last items of a fully fetched dataset
    /// before the state flipped; a full bitmap can never do that.
    pub fn read_location(&self, name: &str, item: u64, reader: NodeId) -> Result<ReadLocation, CacheError> {
        let rec = self.registry.get(name).ok_or_else(|| {
            CacheError::Registry(RegistryError::NotFound(name.to_string()))
        })?;
        let stripe = rec.stripe.as_ref().ok_or_else(|| CacheError::NotPlaced(name.into()))?;
        let home = stripe.node_of_item(item);
        let resident = match &rec.state {
            DatasetState::Cached => true,
            DatasetState::Caching { chunks } | DatasetState::Degraded { chunks, .. } => stripe
                .chunks_of_item(item, rec.spec.num_items, rec.spec.total_bytes)
                .all(|c| chunks.contains(c)),
            _ => false,
        };
        if resident {
            if home == reader {
                Ok(ReadLocation::Local)
            } else {
                Ok(ReadLocation::Peer(home))
            }
        } else {
            Ok(ReadLocation::RemoteFill { fill_node: home })
        }
    }

    /// Chunk-granular read plan for one item: one segment per overlapped
    /// chunk, each with its own location. Resident chunks are served from
    /// their chunk home (`node_of_chunk`); missing chunks are remote
    /// fills homed the same way — a single item can mix all three.
    pub fn read_plan(&self, name: &str, item: u64, reader: NodeId) -> Result<ReadPlan, CacheError> {
        let rec = self.registry.get(name).ok_or_else(|| {
            CacheError::Registry(RegistryError::NotFound(name.to_string()))
        })?;
        let stripe = rec.stripe.as_ref().ok_or_else(|| CacheError::NotPlaced(name.into()))?;
        let (s, e) = item_range(item, rec.spec.num_items, rec.spec.total_bytes);
        let mut segments = Vec::new();
        for c in stripe.chunks_of_item(item, rec.spec.num_items, rec.spec.total_bytes) {
            let (cs, ce) = stripe.chunk_range(c, rec.spec.total_bytes);
            let seg = s.max(cs) - s..e.min(ce) - s;
            let home = stripe.node_of_chunk(c);
            let resident = match &rec.state {
                DatasetState::Cached => true,
                DatasetState::Caching { chunks } | DatasetState::Degraded { chunks, .. } => {
                    chunks.contains(c)
                }
                _ => false,
            };
            let loc = if resident {
                if home == reader {
                    ReadLocation::Local
                } else {
                    ReadLocation::Peer(home)
                }
            } else {
                ReadLocation::RemoteFill { fill_node: home }
            };
            segments.push((seg, loc));
        }
        Ok(ReadPlan { segments })
    }

    /// Evict a dataset's bytes (keeps the registration, per §3.1: the
    /// resource exists; its cache residency is gone).
    pub fn evict(&mut self, name: &str) -> Result<(), CacheError> {
        let rec = self.registry.get_mut(name)?;
        if rec.pin_count > 0 {
            return Err(CacheError::Registry(RegistryError::Pinned(name.into(), rec.pin_count)));
        }
        let resident = rec.resident_bytes();
        let total = rec.spec.total_bytes;
        if let Some(snap) = rec.snapshot.take() {
            // Fast-lane readers fall back to the locked lane from here on.
            snap.retire();
        }
        if let Some(stripe) = rec.stripe.take() {
            let lost = match std::mem::replace(&mut rec.state, DatasetState::Registered) {
                DatasetState::Degraded { lost, .. } => lost,
                _ => vec![],
            };
            // Release per-node reservations (reservation was for the full
            // dataset regardless of fetch progress). A lost member's share
            // was already released when it failed.
            let _ = resident;
            for &n in stripe.nodes() {
                if lost.contains(&n) {
                    continue;
                }
                let share = stripe.bytes_on_node(n, total);
                self.volumes[n.0].release(share).expect("reserved earlier");
            }
            self.events.push(CacheEvent::Evicted(name.to_string()));
        }
        Ok(())
    }

    /// Delete the dataset resource entirely (evicts first if needed).
    pub fn delete(&mut self, name: &str) -> Result<(), CacheError> {
        self.evict(name)?;
        self.registry.remove(name)?;
        self.events.push(CacheEvent::Deleted(name.to_string()));
        Ok(())
    }

    /// Used bytes on node `n`'s cache volume.
    pub fn node_used(&self, n: NodeId) -> u64 {
        self.volumes[n.0].used()
    }

    /// Bytes on node `n` held by *evictable* datasets — space the LRU
    /// policy could reclaim for a new placement.
    pub fn evictable_bytes_on(&self, n: NodeId) -> u64 {
        if self.policy == EvictionPolicy::Manual {
            return 0;
        }
        self.registry
            .iter()
            .filter(|r| r.is_evictable())
            .filter_map(|r| r.stripe.as_ref().map(|s| s.bytes_on_node(n, r.spec.total_bytes)))
            .sum()
    }

    /// Unreserved capacity on node `n`'s cache volume — bytes a new
    /// placement could take *without* the admission planner having to
    /// evict anything. (Placement reserves a dataset's full footprint up
    /// front, so reserved-but-not-yet-filled space is already excluded.)
    pub fn node_headroom(&self, n: NodeId) -> u64 {
        self.volumes[n.0].free()
    }

    /// Cluster-wide unreserved cache capacity — what the prefetch
    /// pressure rule ([`crate::prefetch::Pressure::Headroom`]) budgets
    /// speculative ahead-bytes against.
    pub fn headroom_bytes(&self) -> u64 {
        (0..self.volumes.len()).map(|n| self.node_headroom(NodeId(n))).sum()
    }
}

/// Thread-safe handle over a [`CacheManager`] for the concurrent real-mode
/// data plane: reads (`read_location`) take a shared lock so N reader
/// threads resolve placements in parallel; fill bookkeeping
/// (`prefetch_tick`) takes the exclusive lock briefly. Clone freely —
/// clones share the one manager.
///
/// This locked lane is the **slow/fallback** path: warm readers should
/// fetch the per-dataset [`ResidencySnapshot`] once
/// ([`SharedCache::snapshot`]) and resolve reads through it with zero lock
/// acquisitions, falling back here only when the snapshot is absent or
/// retired. Every mutation still goes through this handle, which keeps
/// the snapshot coherent under the exclusive lock.
#[derive(Debug, Clone)]
pub struct SharedCache {
    inner: std::sync::Arc<std::sync::RwLock<CacheManager>>,
}

impl SharedCache {
    pub fn new(manager: CacheManager) -> Self {
        SharedCache { inner: std::sync::Arc::new(std::sync::RwLock::new(manager)) }
    }

    /// Resolve where item `item` of `name` is served (shared lock).
    pub fn read_location(
        &self,
        name: &str,
        item: u64,
        reader: NodeId,
    ) -> Result<ReadLocation, CacheError> {
        self.inner.read().unwrap().read_location(name, item, reader)
    }

    /// Chunk-granular read plan for one item (shared lock).
    pub fn read_plan(&self, name: &str, item: u64, reader: NodeId) -> Result<ReadPlan, CacheError> {
        self.inner.read().unwrap().read_plan(name, item, reader)
    }

    /// Chunk-addressing snapshot for a placed dataset (shared lock).
    pub fn geometry(&self, name: &str) -> Result<ChunkGeometry, CacheError> {
        self.inner.read().unwrap().geometry(name)
    }

    /// Stable numeric dataset ID (shared lock).
    pub fn dataset_id(&self, name: &str) -> Result<u64, CacheError> {
        self.inner.read().unwrap().dataset_id(name)
    }

    /// Lock-free residency snapshot of a placed dataset (one shared-lock
    /// acquisition to fetch the `Arc`; every read resolved through it
    /// afterwards takes zero locks).
    pub fn snapshot(&self, name: &str) -> Result<std::sync::Arc<ResidencySnapshot>, CacheError> {
        self.inner.read().unwrap().residency_snapshot(name)
    }

    /// Record fill progress (exclusive lock, held only for the registry
    /// update — never across I/O).
    pub fn prefetch_tick(&self, name: &str, bytes: u64) -> Result<(), CacheError> {
        self.inner.write().unwrap().prefetch_tick(name, bytes)
    }

    /// Mark specific chunks resident (exclusive lock, registry-only).
    pub fn mark_chunks(&self, name: &str, chunk_ids: &[u64]) -> Result<(), CacheError> {
        self.inner.write().unwrap().mark_chunks(name, chunk_ids.iter().copied())
    }

    /// Mark every chunk of one item resident (whole-file fill landed).
    pub fn mark_item(&self, name: &str, item: u64) -> Result<(), CacheError> {
        self.inner.write().unwrap().mark_item(name, item)
    }

    /// Cluster-wide unreserved cache capacity (shared lock) — the
    /// prefetch pressure budget source.
    pub fn headroom_bytes(&self) -> u64 {
        self.inner.read().unwrap().headroom_bytes()
    }

    /// Is the dataset fully resident? (Used to skip the prefetcher.)
    pub fn is_cached(&self, name: &str) -> bool {
        self.inner
            .read()
            .unwrap()
            .registry
            .get(name)
            .is_some_and(|r| r.state == DatasetState::Cached)
    }

    /// Run a read-only closure against the manager (shared lock).
    pub fn with<R>(&self, f: impl FnOnce(&CacheManager) -> R) -> R {
        f(&self.inner.read().unwrap())
    }

    /// Run a mutating closure against the manager (exclusive lock). Do
    /// not perform I/O inside `f`.
    pub fn with_mut<R>(&self, f: impl FnOnce(&mut CacheManager) -> R) -> R {
        f(&mut self.inner.write().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{Device, DeviceKind};

    fn manager(nodes: usize, cap_each: u64, policy: EvictionPolicy) -> CacheManager {
        let vols = (0..nodes)
            .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, cap_each)]))
            .collect();
        CacheManager::new(vols, policy)
    }

    fn ds(name: &str, items: u64, bytes: u64) -> DatasetSpec {
        DatasetSpec::new(name, items, bytes)
    }

    #[test]
    fn register_place_fetch_read() {
        let mut m = manager(4, 1000, EvictionPolicy::Manual);
        m.register(ds("a", 100, 400), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        assert_eq!(m.node_used(NodeId(0)), 200);
        assert_eq!(m.node_used(NodeId(2)), 0);

        // Cold read: remote fill.
        match m.read_location("a", 0, NodeId(0)).unwrap() {
            ReadLocation::RemoteFill { .. } => {}
            other => panic!("{other:?}"),
        }
        // Fetch everything.
        m.prefetch_tick("a", 400).unwrap();
        assert_eq!(m.registry.get("a").unwrap().state, DatasetState::Cached);
        // Item 0 homes on node 0 (round robin over [0, 1]).
        assert_eq!(m.read_location("a", 0, NodeId(0)).unwrap(), ReadLocation::Local);
        assert_eq!(m.read_location("a", 1, NodeId(0)).unwrap(), ReadLocation::Peer(NodeId(1)));
    }

    #[test]
    fn aggregate_capacity_allows_bigger_than_node() {
        // Paper §4.1: 4 × 1 TB nodes ⇒ a single job can use ~4 TB.
        let mut m = manager(4, 1000, EvictionPolicy::Manual);
        m.register(ds("big", 10, 3500), "nfs://s/big".into()).unwrap();
        m.place("big", (0..4).map(NodeId).collect()).unwrap();
        assert!(m.node_used(NodeId(0)) >= 800);
    }

    #[test]
    fn manual_policy_rejects_overflow() {
        let mut m = manager(2, 100, EvictionPolicy::Manual);
        m.register(ds("a", 10, 180), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        m.register(ds("b", 10, 100), "nfs://s/b".into()).unwrap();
        assert!(matches!(
            m.place("b", vec![NodeId(0), NodeId(1)]),
            Err(CacheError::Full { .. })
        ));
    }

    #[test]
    fn lru_policy_evicts_idle_dataset() {
        let mut m = manager(2, 100, EvictionPolicy::DatasetLru);
        m.register(ds("a", 10, 180), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        m.prefetch_tick("a", 180).unwrap();
        m.register(ds("b", 10, 100), "nfs://s/b".into()).unwrap();
        m.place("b", vec![NodeId(0), NodeId(1)]).unwrap();
        assert!(m.events.contains(&CacheEvent::Evicted("a".into())));
        assert_eq!(m.registry.get("a").unwrap().state, DatasetState::Registered);
        assert!(m.registry.get("a").unwrap().stripe.is_none());
    }

    #[test]
    fn pinned_dataset_survives_pressure() {
        let mut m = manager(2, 100, EvictionPolicy::DatasetLru);
        m.register(ds("a", 10, 180), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        m.registry.pin("a").unwrap();
        m.register(ds("b", 10, 100), "nfs://s/b".into()).unwrap();
        assert!(matches!(
            m.place("b", vec![NodeId(0), NodeId(1)]),
            Err(CacheError::Full { .. })
        ));
        assert!(m.registry.get("a").unwrap().stripe.is_some());
    }

    #[test]
    fn evict_releases_capacity_exactly() {
        let mut m = manager(3, 500, EvictionPolicy::Manual);
        m.register(ds("a", 30, 299), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1), NodeId(2)]).unwrap();
        let used: u64 = (0..3).map(|i| m.node_used(NodeId(i))).sum();
        assert_eq!(used, 299);
        m.evict("a").unwrap();
        assert_eq!((0..3).map(|i| m.node_used(NodeId(i))).sum::<u64>(), 0);
    }

    #[test]
    fn delete_removes_registration() {
        let mut m = manager(2, 100, EvictionPolicy::Manual);
        m.register(ds("a", 10, 50), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0)]).unwrap();
        m.delete("a").unwrap();
        assert!(m.registry.get("a").is_none());
        assert_eq!(m.node_used(NodeId(0)), 0);
    }

    #[test]
    fn partial_fetch_serves_mixed_locations() {
        let mut m = manager(2, 1000, EvictionPolicy::Manual);
        m.register(ds("a", 100, 1000), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        m.prefetch_tick("a", 500).unwrap();
        // Items below the fill front are cached, above are remote.
        let low = m.read_location("a", 0, NodeId(0)).unwrap();
        let high = m.read_location("a", 99, NodeId(0)).unwrap();
        assert!(matches!(low, ReadLocation::Local | ReadLocation::Peer(_)));
        assert!(matches!(high, ReadLocation::RemoteFill { .. }));
    }

    #[test]
    fn full_bitmap_never_yields_remote_fill() {
        // Regression for the old f64 fill-front rounding hazard: a dataset
        // whose every chunk is resident must never answer `RemoteFill`,
        // even before `prefetch_tick` flips the state to `Cached`.
        let mut m = manager(3, 10_000, EvictionPolicy::Manual);
        m.register(ds("a", 101, 9_999), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1), NodeId(2)]).unwrap();
        // Mark every chunk directly (no state flip happens mid-loop since
        // mark_chunks flips only when full — so check the moment after).
        let n_chunks = m.geometry("a").unwrap().num_chunks();
        {
            // Force a full bitmap while *staying* in Caching state.
            let rec = m.registry.get_mut("a").unwrap();
            if let DatasetState::Caching { chunks } = &mut rec.state {
                for c in 0..n_chunks {
                    chunks.mark(c);
                }
                assert!(chunks.is_full());
            } else {
                panic!("expected Caching state after place");
            }
        }
        for item in [0u64, 50, 99, 100] {
            for reader in 0..3 {
                let loc = m.read_location("a", item, NodeId(reader)).unwrap();
                assert!(
                    !matches!(loc, ReadLocation::RemoteFill { .. }),
                    "item {item} reader {reader}: full bitmap gave {loc:?}"
                );
                assert!(m.read_plan("a", item, NodeId(reader)).unwrap().fully_resident());
            }
        }
    }

    #[test]
    fn read_plan_mixes_locations_within_one_item() {
        // 1 item of 1000 bytes over 2 nodes ⇒ chunk = 500, the single item
        // spans both chunks. Mark only chunk 0: the plan must mix a
        // resident segment and a remote-fill segment for the same item.
        let mut m = manager(2, 10_000, EvictionPolicy::Manual);
        m.register(ds("a", 1, 1000), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        m.mark_chunks("a", [0u64]).unwrap();
        let plan = m.read_plan("a", 0, NodeId(0)).unwrap();
        assert_eq!(plan.segments.len(), 2);
        assert_eq!(plan.segments[0], (0..500, ReadLocation::Local));
        assert_eq!(
            plan.segments[1],
            (500..1000, ReadLocation::RemoteFill { fill_node: NodeId(1) })
        );
        assert!(!plan.fully_resident());
        assert_eq!(plan.len_bytes(), 1000);
        // Summary view agrees: not all chunks resident ⇒ RemoteFill.
        assert!(matches!(
            m.read_location("a", 0, NodeId(0)).unwrap(),
            ReadLocation::RemoteFill { .. }
        ));
        // Marking the missing chunk flips the dataset to Cached.
        m.mark_chunks("a", [1u64]).unwrap();
        assert_eq!(m.registry.get("a").unwrap().state, DatasetState::Cached);
        assert!(m.read_plan("a", 0, NodeId(0)).unwrap().fully_resident());
    }

    #[test]
    fn geometry_maps_items_and_chunks_both_ways() {
        let mut m = manager(2, 10_000, EvictionPolicy::Manual);
        m.register(ds("a", 10, 1000), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        let g = m.geometry("a").unwrap();
        assert_eq!(g.chunk_bytes(), 500);
        assert_eq!(g.num_chunks(), 2);
        // Items are 100 bytes each: items 0..5 in chunk 0, 5..10 in chunk 1.
        assert_eq!(g.items_of_chunk(0), 0..5);
        assert_eq!(g.items_of_chunk(1), 5..10);
        for i in 0..10u64 {
            let (s, e) = g.item_range(i);
            assert_eq!(g.item_of_offset(s), i);
            assert_eq!(g.item_of_offset(e - 1), i);
            for c in g.chunks_of_item(i) {
                assert!(g.items_of_chunk(c).contains(&i), "item {i} chunk {c}");
            }
        }
    }

    #[test]
    fn shared_cache_parallel_readers_resolve_locations() {
        let mut m = manager(4, 1000, EvictionPolicy::Manual);
        m.register(ds("a", 100, 400), "nfs://s/a".into()).unwrap();
        m.place("a", (0..4).map(NodeId).collect()).unwrap();
        m.prefetch_tick("a", 400).unwrap();
        let shared = SharedCache::new(m);
        std::thread::scope(|s| {
            for r in 0..4usize {
                let shared = shared.clone();
                s.spawn(move || {
                    for i in 0..100u64 {
                        let loc = shared.read_location("a", i, NodeId(r)).unwrap();
                        match loc {
                            ReadLocation::Local => assert_eq!(i % 4, r as u64),
                            ReadLocation::Peer(p) => assert_eq!(p, NodeId((i % 4) as usize)),
                            other => panic!("cached dataset gave {other:?}"),
                        }
                    }
                });
            }
        });
        assert!(shared.is_cached("a"));
    }

    #[test]
    fn shared_cache_tick_flips_state_under_lock() {
        let mut m = manager(2, 1000, EvictionPolicy::Manual);
        m.register(ds("a", 10, 100), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        let shared = SharedCache::new(m);
        assert!(!shared.is_cached("a"));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let shared = shared.clone();
                s.spawn(move || {
                    for _ in 0..5 {
                        shared.prefetch_tick("a", 5).unwrap();
                    }
                });
            }
        });
        assert!(shared.is_cached("a"), "4 threads × 25 bytes ≥ 100-byte dataset");
        let state = shared.with(|m| m.registry.get("a").unwrap().state.clone());
        assert_eq!(state, DatasetState::Cached);
    }

    #[test]
    fn snapshot_mirrors_every_mark_path() {
        let mut m = manager(2, 10_000, EvictionPolicy::Manual);
        m.register(ds("a", 10, 1000), "nfs://s/a".into()).unwrap();
        assert!(m.residency_snapshot("a").is_err(), "no snapshot before placement");
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        let snap = m.residency_snapshot("a").unwrap();
        assert_eq!(snap.geometry().num_chunks(), 2);
        assert_eq!(snap.marked_chunks(), 0);
        assert!(!snap.is_full() && !snap.retired());

        // mark_chunks path.
        m.mark_chunks("a", [1u64]).unwrap();
        assert!(snap.contains(1) && !snap.contains(0));
        // mark_item path: items are 100 B, chunk 0 covers items 0..5 —
        // crediting all five marks chunk 0 and flips the snapshot full.
        for i in 0..5u64 {
            m.mark_item("a", i).unwrap();
        }
        assert!(snap.contains(0));
        assert!(snap.is_full(), "all chunks marked ⇒ snapshot full");
        assert_eq!(m.registry.get("a").unwrap().state, DatasetState::Cached);

        // prefetch_tick path, on a fresh dataset.
        m.register(ds("b", 10, 1000), "nfs://s/b".into()).unwrap();
        m.place("b", vec![NodeId(0), NodeId(1)]).unwrap();
        let snap_b = m.residency_snapshot("b").unwrap();
        m.prefetch_tick("b", 499).unwrap();
        assert!(!snap_b.contains(0), "front mid-chunk: nothing marked yet");
        m.prefetch_tick("b", 1).unwrap();
        assert!(snap_b.contains(0), "front crossed the chunk boundary");
        m.prefetch_tick("b", 500).unwrap();
        assert!(snap_b.is_full());
    }

    #[test]
    fn snapshot_agrees_with_locked_lane_and_retires_on_evict() {
        let mut m = manager(3, 100_000, EvictionPolicy::Manual);
        m.register(ds("a", 37, 10_007), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1), NodeId(2)]).unwrap();
        m.mark_chunks("a", [0u64, 2]).unwrap();
        m.mark_item("a", 17).unwrap();
        let snap = m.residency_snapshot("a").unwrap();
        for item in 0..37u64 {
            for reader in 0..3 {
                let r = NodeId(reader);
                assert_eq!(
                    snap.read_location(item, r),
                    Some(m.read_location("a", item, r).unwrap()),
                    "item {item} reader {reader}"
                );
                assert_eq!(
                    snap.read_plan(item, r),
                    Some(m.read_plan("a", item, r).unwrap()),
                    "item {item} reader {reader}"
                );
            }
        }
        m.evict("a").unwrap();
        assert!(snap.retired(), "evict must retire the published snapshot");
        assert_eq!(snap.read_location(0, NodeId(0)), None, "retired ⇒ fall back");
        assert_eq!(snap.read_plan(0, NodeId(0)), None);
        assert!(m.residency_snapshot("a").is_err(), "placement gone");
        // Re-placement publishes a fresh, empty snapshot under a new
        // generation — old-generation chunk files no longer address it.
        m.place("a", vec![NodeId(0)]).unwrap();
        let fresh = m.residency_snapshot("a").unwrap();
        assert!(!fresh.retired());
        assert_eq!(fresh.marked_chunks(), 0);
        assert_eq!(snap.geometry().generation, 1);
        assert_eq!(fresh.geometry().generation, 2);
        assert_eq!(m.geometry("a").unwrap().generation, 2);
    }

    #[test]
    fn snapshot_retired_on_node_failure() {
        let mut m = manager(2, 10_000, EvictionPolicy::Manual);
        m.register(ds("a", 10, 1000), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        let snap = m.residency_snapshot("a").unwrap();
        m.fail_node(NodeId(1));
        assert!(snap.retired(), "losing a stripe member retires the snapshot");
    }

    #[test]
    fn degrade_keeps_survivors_and_rejoin_readmits() {
        let mut m = manager(2, 10_000, EvictionPolicy::Manual);
        m.register(ds("a", 10, 1000), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        m.prefetch_tick("a", 1000).unwrap();
        let old_snap = m.residency_snapshot("a").unwrap();
        // Grid: chunk = 500 ⇒ chunk 0 → node 0, chunk 1 → node 1.
        let degraded = m.degrade_node(NodeId(1));
        assert_eq!(degraded, vec!["a".to_string()]);
        assert!(old_snap.retired(), "degrade retires the published snapshot");
        let rec = m.registry.get("a").unwrap();
        assert!(rec.stripe.is_some(), "degraded keeps the stripe");
        assert_eq!(rec.generation, 1, "no generation bump on degrade");
        assert!(matches!(&rec.state, DatasetState::Degraded { lost, .. } if lost == &[NodeId(1)]));
        let snap = m.residency_snapshot("a").unwrap();
        assert!(snap.contains(0) && !snap.contains(1), "survivor bits republished");
        // Survivor chunk keeps serving; lost chunk re-plans remote.
        assert_eq!(m.read_location("a", 0, NodeId(0)).unwrap(), ReadLocation::Local);
        assert!(matches!(
            m.read_location("a", 9, NodeId(0)).unwrap(),
            ReadLocation::RemoteFill { .. }
        ));
        // A lost-homed chunk cannot be re-admitted while its node is gone.
        m.mark_chunks("a", [1u64]).unwrap();
        assert!(!snap.contains(1));
        // Only the dead node's reservation was released.
        assert_eq!(m.node_used(NodeId(0)), 500);
        assert_eq!(m.node_used(NodeId(1)), 0);
        // Rejoin: reservation re-taken, refills admit again, and the
        // same-generation snapshot keeps mirroring them.
        m.recover_node(NodeId(1));
        assert_eq!(m.node_used(NodeId(1)), 500);
        m.mark_chunks("a", [1u64]).unwrap();
        assert!(snap.contains(1));
        assert_eq!(m.registry.get("a").unwrap().state, DatasetState::Cached);
        assert_eq!(m.read_location("a", 9, NodeId(1)).unwrap(), ReadLocation::Local);
    }

    #[test]
    fn second_failure_deepens_degradation_and_evict_releases_exactly() {
        let mut m = manager(3, 10_000, EvictionPolicy::Manual);
        m.chunk_bytes = 250;
        m.register(ds("a", 6, 1500), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1), NodeId(2)]).unwrap();
        m.prefetch_tick("a", 1500).unwrap();
        m.degrade_node(NodeId(2));
        m.degrade_node(NodeId(1));
        let rec = m.registry.get("a").unwrap();
        match &rec.state {
            DatasetState::Degraded { chunks, lost } => {
                assert_eq!(lost, &[NodeId(2), NodeId(1)]);
                // Chunks 0 and 3 (node 0) survive; 1, 2, 4, 5 are lost.
                assert_eq!(chunks.marked_chunks(), 2);
            }
            other => panic!("expected Degraded, got {other:?}"),
        }
        // Evict must release exactly the survivor's share — the lost
        // members' shares were released at failure time.
        m.evict("a").unwrap();
        assert_eq!((0..3).map(|i| m.node_used(NodeId(i))).sum::<u64>(), 0);
    }

    #[test]
    fn replace_restripes_on_survivors_with_generation_bump() {
        let mut m = manager(3, 10_000, EvictionPolicy::Manual);
        m.chunk_bytes = 250;
        m.register(ds("a", 6, 1500), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1), NodeId(2)]).unwrap();
        m.prefetch_tick("a", 1500).unwrap();
        m.degrade_node(NodeId(2));
        // Chunks 2 and 5 homed on the dead node; 0, 1, 3, 4 survive.
        let (old_geom, survivors) = m.begin_replace("a").unwrap();
        assert_eq!(old_geom.generation, 1);
        assert_eq!(survivors, vec![0, 1, 3, 4]);
        assert_eq!(m.registry.get("a").unwrap().state, DatasetState::Replacing);
        assert_eq!(
            (0..3).map(|i| m.node_used(NodeId(i))).sum::<u64>(),
            0,
            "replace releases the surviving reservations for the re-place"
        );
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        let rec = m.registry.get("a").unwrap();
        assert_eq!(rec.generation, 2, "re-place is a new generation");
        assert!(matches!(rec.state, DatasetState::Caching { .. }));
        let g = m.geometry("a").unwrap();
        assert_eq!(g.chunk_bytes(), old_geom.chunk_bytes(), "grid preserved for migration");
        // Migrated survivors + refetched lost chunks complete the fill.
        m.mark_chunks("a", survivors.clone()).unwrap();
        m.mark_chunks("a", [2u64, 5]).unwrap();
        assert_eq!(m.registry.get("a").unwrap().state, DatasetState::Cached);
    }

    #[test]
    fn read_plan_coalesces_adjacent_same_location_runs() {
        // 1 item of 1000 B over 1 node ⇒ chunk = 1000/1 … force several
        // chunks on one node instead: single-node stripe, chunk 250 ⇒ all
        // four chunks home on node 0 and coalesce into one run per
        // residency class.
        let mut m = manager(1, 10_000, EvictionPolicy::Manual);
        m.chunk_bytes = 250;
        m.register(ds("a", 1, 1000), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0)]).unwrap();
        m.mark_chunks("a", [0u64, 1]).unwrap();
        let plan = m.read_plan("a", 0, NodeId(0)).unwrap();
        assert_eq!(plan.segments.len(), 4);
        let runs = plan.coalesced();
        assert_eq!(
            runs,
            vec![
                (0..500, ReadLocation::Local),
                (500..1000, ReadLocation::RemoteFill { fill_node: NodeId(0) }),
            ]
        );
        assert_eq!(runs.iter().map(|(r, _)| r.end - r.start).sum::<u64>(), plan.len_bytes());
    }

    #[test]
    fn life_cycle_decoupled_from_jobs() {
        // Requirement 2: data survives job completion; a returning job
        // re-pins warm data.
        let mut m = manager(2, 1000, EvictionPolicy::DatasetLru);
        m.register(ds("a", 10, 100), "nfs://s/a".into()).unwrap();
        m.place("a", vec![NodeId(0), NodeId(1)]).unwrap();
        m.prefetch_tick("a", 100).unwrap();
        m.registry.pin("a").unwrap(); // job 1 mounts
        m.registry.unpin("a").unwrap(); // job 1 finishes
        assert_eq!(m.registry.get("a").unwrap().state, DatasetState::Cached);
        m.registry.pin("a").unwrap(); // job 2 (same data) mounts — warm
        assert_eq!(m.read_location("a", 0, NodeId(0)).unwrap(), ReadLocation::Local);
    }
}
