//! Stripe placement: how a dataset's items/bytes spread over the selected
//! cache nodes (paper Requirement 1: aggregate the capacity of a *subset*
//! of nodes; the subset is chosen by the coordinator, not the FS), plus
//! the chunk-granular residency bitmap ([`ChunkSet`]) every layer above
//! uses to answer "which bytes are cached?" exactly.
//!
//! Chunk addressing: a dataset is one logical byte stream (items
//! concatenated in index order, partitioned by [`item_range`]); chunk `c`
//! covers bytes `[c·B, (c+1)·B)` of that stream (`B = chunk_bytes`), the
//! last chunk may be short. Chunk `c` homes on `nodes[c mod k]`
//! ([`StripeMap::node_of_chunk`]); residency is tracked per chunk, not per
//! file, so partial hits are servable and prefetch order is precise
//! (FanStore / NoPFS-style block granularity).

use crate::netsim::NodeId;

/// Byte range `[start, end)` of item `i` within the dataset's logical byte
/// stream: the unique monotone partition `start = ⌊i·total/n⌋`. For
/// real-mode datasets with uniform records this is exactly
/// `i × record_bytes`; for fluid-mode specs it is the average-size model.
pub fn item_range(i: u64, num_items: u64, total: u64) -> (u64, u64) {
    assert!(i < num_items, "item {i} out of range {num_items}");
    let n = num_items as u128;
    let t = total as u128;
    let start = (i as u128 * t / n) as u64;
    let end = ((i as u128 + 1) * t / n) as u64;
    (start, end)
}

/// Deterministic mapping of dataset items and byte ranges onto a fixed,
/// ordered set of cache nodes. Items are round-robined (file-granular
/// striping, what AFM filesets give us); byte ranges use fixed-size chunks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StripeMap {
    nodes: Vec<NodeId>,
    /// Chunk size for byte-range striping.
    pub chunk_bytes: u64,
}

impl StripeMap {
    pub fn new(nodes: Vec<NodeId>, chunk_bytes: u64) -> Self {
        assert!(!nodes.is_empty(), "stripe set must be non-empty");
        assert!(chunk_bytes > 0);
        StripeMap { nodes, chunk_bytes }
    }

    pub fn width(&self) -> usize {
        self.nodes.len()
    }

    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    pub fn contains(&self, n: NodeId) -> bool {
        self.nodes.contains(&n)
    }

    /// Cache node holding item `i` (file-granular placement).
    pub fn node_of_item(&self, i: u64) -> NodeId {
        self.nodes[(i % self.nodes.len() as u64) as usize]
    }

    /// Cache node holding byte `offset` (chunk-granular placement).
    pub fn node_of_offset(&self, offset: u64) -> NodeId {
        self.node_of_chunk(self.chunk_of_offset(offset))
    }

    /// Chunk ID covering byte `offset`.
    pub fn chunk_of_offset(&self, offset: u64) -> u64 {
        offset / self.chunk_bytes
    }

    /// Cache node holding chunk `c` (round-robin over the member list —
    /// the AFM-fileset-style fixed assignment).
    pub fn node_of_chunk(&self, c: u64) -> NodeId {
        self.nodes[(c % self.nodes.len() as u64) as usize]
    }

    /// Number of chunks in a `total`-byte dataset (the last may be short).
    pub fn num_chunks(&self, total: u64) -> u64 {
        total.div_ceil(self.chunk_bytes)
    }

    /// Global byte range `[start, end)` of chunk `c` in a `total`-byte
    /// dataset (the tail chunk may be short) — the one place the
    /// tail-clamped range is derived.
    pub fn chunk_range(&self, c: u64, total: u64) -> (u64, u64) {
        let s = c * self.chunk_bytes;
        (s, s.saturating_add(self.chunk_bytes).min(total))
    }

    /// Chunk IDs overlapping item `i` of an `(num_items, total)` dataset,
    /// per the [`item_range`] byte partition. Empty for zero-length items.
    pub fn chunks_of_item(&self, i: u64, num_items: u64, total: u64) -> std::ops::Range<u64> {
        let (start, end) = item_range(i, num_items, total);
        if start == end {
            return 0..0;
        }
        self.chunk_of_offset(start)..self.chunk_of_offset(end - 1) + 1
    }

    /// Bytes of a `total`-byte dataset stored on node `n` — **exact**,
    /// including the short tail chunk (the remainder is distributed
    /// chunk-by-chunk in node order, matching `node_of_chunk`).
    pub fn bytes_on_node(&self, n: NodeId, total: u64) -> u64 {
        if !self.contains(n) {
            return 0;
        }
        let k = self.nodes.len() as u64;
        let full_rounds = total / (self.chunk_bytes * k);
        let base = full_rounds * self.chunk_bytes;
        let rem = total - full_rounds * self.chunk_bytes * k;
        // Distribute the remainder chunk-by-chunk in node order.
        let pos = self.nodes.iter().position(|&x| x == n).unwrap() as u64;
        let extra_full_chunks = rem / self.chunk_bytes;
        let tail = rem % self.chunk_bytes;
        let extra = if pos < extra_full_chunks {
            self.chunk_bytes
        } else if pos == extra_full_chunks {
            tail
        } else {
            0
        };
        base + extra
    }

    /// Fraction of reads served locally for a consumer on node `n`.
    pub fn local_fraction(&self, n: NodeId) -> f64 {
        if self.contains(n) {
            1.0 / self.nodes.len() as f64
        } else {
            0.0
        }
    }
}

/// Chunk-granular residency bitmap: which chunks of a dataset are resident
/// on its stripe set. This replaces the old scalar fill front
/// (`fetched_bytes`) everywhere residency is asked about — the registry,
/// `read_location`/`read_plan`, the reader pool, and the fluid sim all
/// answer from the same bitmap, so partial hits are exact by construction.
///
/// Three ways to make progress coexist:
///  * [`ChunkSet::mark`] — a whole chunk landed (chunked real-mode fill);
///  * [`ChunkSet::credit_unit`] — one *sub-unit* of a chunk landed (a
///    whole-item fill whose item is finer than the chunk grid credits each
///    overlapped chunk, keyed by item ID so duplicate reports of the same
///    fill are idempotent; the chunk is marked only once every byte of it
///    is credited, so coarse chunks never over-report residency);
///  * [`ChunkSet::advance`] — the sequential AFM prefetch front moved by
///    `n` bytes (control-plane `prefetch_tick`). Byte-exact: it credits
///    the front chunk and skips chunks already marked out of order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkSet {
    words: Vec<u64>,
    num_chunks: u64,
    chunk_bytes: u64,
    total_bytes: u64,
    /// Marked chunk count and their exact byte sum (tail-aware).
    marked: u64,
    marked_bytes: u64,
    /// First unmarked chunk (== `num_chunks` when full) — the fill front.
    front: u64,
    /// Partial credits of in-progress chunks: `(chunk, unit) → bytes`,
    /// where `unit` is the crediting sub-unit (item ID, or
    /// [`FRONT_UNIT`] for the anonymous sequential front). Per-chunk
    /// totals never exceed the chunk length; entries are purged on mark.
    credits: std::collections::BTreeMap<(u64, u64), u64>,
}

/// Reserved [`ChunkSet::credit_unit`] unit for sequential-front progress
/// (`advance`), which accumulates instead of being idempotent.
pub const FRONT_UNIT: u64 = u64::MAX;

impl ChunkSet {
    pub fn new(total_bytes: u64, chunk_bytes: u64) -> Self {
        assert!(chunk_bytes > 0);
        let num_chunks = total_bytes.div_ceil(chunk_bytes);
        ChunkSet {
            words: vec![0u64; (num_chunks as usize).div_ceil(64)],
            num_chunks,
            chunk_bytes,
            total_bytes,
            marked: 0,
            marked_bytes: 0,
            front: 0,
            credits: std::collections::BTreeMap::new(),
        }
    }

    pub fn num_chunks(&self) -> u64 {
        self.num_chunks
    }

    pub fn chunk_bytes(&self) -> u64 {
        self.chunk_bytes
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Length of chunk `c` in bytes (the tail chunk may be short).
    pub fn chunk_len(&self, c: u64) -> u64 {
        assert!(c < self.num_chunks, "chunk {c} out of range {}", self.num_chunks);
        if c + 1 == self.num_chunks {
            self.total_bytes - c * self.chunk_bytes
        } else {
            self.chunk_bytes
        }
    }

    pub fn contains(&self, c: u64) -> bool {
        assert!(c < self.num_chunks, "chunk {c} out of range {}", self.num_chunks);
        self.words[(c / 64) as usize] & (1u64 << (c % 64)) != 0
    }

    /// Mark chunk `c` resident. Returns `true` if newly marked.
    pub fn mark(&mut self, c: u64) -> bool {
        if self.contains(c) {
            return false;
        }
        self.words[(c / 64) as usize] |= 1u64 << (c % 64);
        self.marked += 1;
        self.marked_bytes += self.chunk_len(c);
        self.purge_credits(c);
        if c == self.front {
            self.reseek_front();
        }
        true
    }

    /// Unmark chunk `c` — its bytes were **lost** (the node holding it
    /// died or its file was reclaimed), the inverse of [`ChunkSet::mark`].
    /// Returns `true` if it was marked. The fill front moves back to `c`
    /// when needed so "every chunk below the front is marked" stays true.
    pub fn clear(&mut self, c: u64) -> bool {
        if !self.contains(c) {
            return false;
        }
        self.words[(c / 64) as usize] &= !(1u64 << (c % 64));
        self.marked -= 1;
        self.marked_bytes -= self.chunk_len(c);
        if c < self.front {
            self.front = c;
        }
        true
    }

    /// Bytes credited toward (unmarked) chunk `c` so far.
    fn credited(&self, c: u64) -> u64 {
        self.credits.range((c, 0)..=(c, u64::MAX)).map(|(_, b)| b).sum()
    }

    fn purge_credits(&mut self, c: u64) {
        let keys: Vec<(u64, u64)> =
            self.credits.range((c, 0)..=(c, u64::MAX)).map(|(&k, _)| k).collect();
        for k in keys {
            self.credits.remove(&k);
        }
    }

    /// Credit `bytes` of chunk `c` as landed on behalf of sub-unit `unit`
    /// (an item ID — a fill unit finer than the chunk). Idempotent per
    /// `(c, unit)`: racing observers reporting the same item fill twice
    /// never sum their overlapping bytes, so a chunk cannot be marked by
    /// one item's bytes alone. ([`FRONT_UNIT`] is the reserved
    /// accumulating unit used by `advance`.) The chunk is marked resident
    /// only once its credited bytes reach its full length; until then they
    /// count toward [`ChunkSet::fetched_bytes`] but not residency.
    /// Returns `true` when this credit completed (marked) the chunk.
    pub fn credit_unit(&mut self, c: u64, unit: u64, bytes: u64) -> bool {
        if self.contains(c) {
            return false;
        }
        let key = (c, unit);
        if unit != FRONT_UNIT && self.credits.contains_key(&key) {
            return false; // duplicate report of the same sub-unit
        }
        let len = self.chunk_len(c);
        let have = self.credited(c);
        let add = bytes.min(len - have); // cap: totals never exceed len
        *self.credits.entry(key).or_insert(0) += add;
        if have + add >= len {
            self.mark(c) // purges the credit entries
        } else {
            false
        }
    }

    /// Advance the sequential fill front by `bytes`, crediting (and so
    /// marking, once complete) chunks in order. Chunks already marked out
    /// of order are skipped without consuming budget. Surplus past the end
    /// is dropped (the old `min(total)` saturation).
    pub fn advance(&mut self, mut bytes: u64) {
        while bytes > 0 && self.front < self.num_chunks {
            let f = self.front;
            let need = self.chunk_len(f) - self.credited(f);
            let add = bytes.min(need);
            self.credit_unit(f, FRONT_UNIT, add); // re-seeks when f completes
            bytes -= add;
        }
    }

    fn reseek_front(&mut self) {
        while self.front < self.num_chunks && self.contains(self.front) {
            self.front += 1;
        }
    }

    /// All chunks resident?
    pub fn is_full(&self) -> bool {
        self.marked == self.num_chunks
    }

    /// The sequential fill front: the first unmarked chunk (== `num_chunks`
    /// when full). Every chunk below the front is marked, which is what
    /// lets `prefetch_tick` mirror front advances into the lock-free
    /// residency snapshot as a contiguous range.
    pub fn front(&self) -> u64 {
        self.front
    }

    pub fn marked_chunks(&self) -> u64 {
        self.marked
    }

    /// Exact bytes resident: the sum of marked chunk sizes (tail-aware).
    pub fn resident_bytes(&self) -> u64 {
        self.marked_bytes
    }

    /// Total fetch progress: resident bytes plus partial chunk credits —
    /// the derived replacement for the old scalar `fetched_bytes`
    /// (byte-identical when only `advance`/`credit` record progress).
    pub fn fetched_bytes(&self) -> u64 {
        self.marked_bytes + self.credits.values().sum::<u64>()
    }

    /// Fraction of the dataset resident (0 ⇒ empty, 1 ⇒ full).
    pub fn resident_fraction(&self) -> f64 {
        if self.total_bytes == 0 {
            1.0
        } else {
            self.marked_bytes as f64 / self.total_bytes as f64
        }
    }

    /// Fold `other`'s residency into `self` (same geometry required).
    /// Commutative and idempotent: marked sets are OR-ed; per-unit
    /// credits merge by max per `(chunk, unit)` key (two observers of the
    /// same sub-unit fill never sum their overlapping bytes, while
    /// *different* units of one chunk combine), chunks whose merged
    /// credits reach their length are marked, and credits of marked
    /// chunks are dropped.
    pub fn union(&mut self, other: &ChunkSet) {
        assert_eq!(self.num_chunks, other.num_chunks, "chunk-set geometry mismatch");
        assert_eq!(self.chunk_bytes, other.chunk_bytes, "chunk-set geometry mismatch");
        assert_eq!(self.total_bytes, other.total_bytes, "chunk-set geometry mismatch");
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w |= o;
        }
        // Recount exactly (popcount; tail chunk may be short).
        self.marked = self.words.iter().map(|w| w.count_ones() as u64).sum();
        self.marked_bytes = self.marked * self.chunk_bytes;
        if self.num_chunks > 0 && self.contains(self.num_chunks - 1) {
            self.marked_bytes -= self.chunk_bytes - self.chunk_len(self.num_chunks - 1);
        }
        for (&(c, u), &b) in &other.credits {
            if !self.contains(c) {
                let have = self.credits.entry((c, u)).or_insert(0);
                *have = (*have).max(b);
            }
        }
        // Purge credits of chunks marked by the merge, then mark any
        // chunk whose combined credits now cover it entirely.
        let candidates: Vec<u64> = {
            let mut cs: Vec<u64> = self.credits.keys().map(|&(c, _)| c).collect();
            cs.dedup();
            cs
        };
        for c in candidates {
            if self.contains(c) {
                self.purge_credits(c);
            } else if self.credited(c) >= self.chunk_len(c) {
                self.mark(c);
            }
        }
        self.reseek_front();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(ids: &[usize]) -> Vec<NodeId> {
        ids.iter().map(|&i| NodeId(i)).collect()
    }

    #[test]
    fn round_robin_items() {
        let s = StripeMap::new(nodes(&[0, 2, 3]), 1 << 20);
        assert_eq!(s.node_of_item(0), NodeId(0));
        assert_eq!(s.node_of_item(1), NodeId(2));
        assert_eq!(s.node_of_item(2), NodeId(3));
        assert_eq!(s.node_of_item(3), NodeId(0));
    }

    #[test]
    fn offset_striping() {
        let s = StripeMap::new(nodes(&[0, 1]), 100);
        assert_eq!(s.node_of_offset(0), NodeId(0));
        assert_eq!(s.node_of_offset(99), NodeId(0));
        assert_eq!(s.node_of_offset(100), NodeId(1));
        assert_eq!(s.node_of_offset(250), NodeId(0));
    }

    #[test]
    fn bytes_on_node_sums_to_total() {
        for total in [0u64, 1, 99, 100, 350, 1000, 12345] {
            let s = StripeMap::new(nodes(&[0, 1, 2]), 100);
            let sum: u64 = (0..3).map(|i| s.bytes_on_node(NodeId(i), total)).sum();
            assert_eq!(sum, total, "total={total}");
        }
    }

    #[test]
    fn bytes_on_node_exact_vs_chunk_walk() {
        // `bytes_on_node` is exact (tail chunk included): it must equal an
        // independent walk over every chunk of the dataset, not ±1 chunk.
        let s = StripeMap::new(nodes(&[0, 1, 2, 3]), 1 << 20);
        let total = 144_000_000_000u64;
        let mut per_node = [0u64; 4];
        for c in 0..s.num_chunks(total) {
            let start = c * s.chunk_bytes;
            let len = (total - start).min(s.chunk_bytes);
            per_node[s.node_of_chunk(c).0] += len;
        }
        for i in 0..4 {
            assert_eq!(s.bytes_on_node(NodeId(i), total), per_node[i], "node {i}");
        }
        assert_eq!(per_node.iter().sum::<u64>(), total);
        let max = *per_node.iter().max().unwrap();
        let min = *per_node.iter().min().unwrap();
        assert!(max - min <= 1 << 20, "balance within one chunk");
    }

    #[test]
    fn chunk_addressing_helpers() {
        let s = StripeMap::new(nodes(&[0, 1, 2]), 100);
        assert_eq!(s.chunk_of_offset(0), 0);
        assert_eq!(s.chunk_of_offset(99), 0);
        assert_eq!(s.chunk_of_offset(100), 1);
        assert_eq!(s.node_of_chunk(0), NodeId(0));
        assert_eq!(s.node_of_chunk(4), NodeId(1));
        assert_eq!(s.num_chunks(0), 0);
        assert_eq!(s.num_chunks(100), 1);
        assert_eq!(s.num_chunks(101), 2);
        // 10 items × 35 bytes: item 3 covers [105, 140) ⇒ chunks 1..2.
        assert_eq!(s.chunks_of_item(3, 10, 350), 1..2);
        // Item 2 covers [70, 105) ⇒ straddles chunks 0 and 1.
        assert_eq!(s.chunks_of_item(2, 10, 350), 0..2);
    }

    #[test]
    fn item_range_partitions_exactly() {
        for (n, total) in [(10u64, 350u64), (7, 100), (3, 2), (1, 0), (5, 5)] {
            let mut covered = 0u64;
            let mut prev_end = 0u64;
            for i in 0..n {
                let (s, e) = item_range(i, n, total);
                assert_eq!(s, prev_end, "contiguous at item {i}");
                assert!(e >= s);
                covered += e - s;
                prev_end = e;
            }
            assert_eq!(covered, total, "n={n} total={total}");
        }
    }

    #[test]
    fn chunkset_mark_contains_roundtrip() {
        let mut cs = ChunkSet::new(1050, 100); // 11 chunks, tail = 50
        assert_eq!(cs.num_chunks(), 11);
        assert_eq!(cs.chunk_len(10), 50);
        assert!(!cs.contains(7));
        assert!(cs.mark(7));
        assert!(cs.contains(7));
        assert!(!cs.mark(7), "re-mark is a no-op");
        assert_eq!(cs.marked_chunks(), 1);
        assert_eq!(cs.resident_bytes(), 100);
        cs.mark(10);
        assert_eq!(cs.resident_bytes(), 150, "tail chunk counts its short length");
        assert!(!cs.is_full());
    }

    #[test]
    fn chunkset_clear_unmarks_and_pulls_front_back() {
        let mut cs = ChunkSet::new(1050, 100); // 11 chunks, tail = 50
        for c in 0..cs.num_chunks() {
            cs.mark(c);
        }
        assert!(cs.is_full());
        assert!(cs.clear(10), "tail chunk clears");
        assert_eq!(cs.resident_bytes(), 1000, "tail chunk gives back its short length");
        assert!(cs.clear(3));
        assert!(!cs.clear(3), "re-clear is a no-op");
        assert!(!cs.contains(3));
        assert_eq!(cs.marked_chunks(), 9);
        assert_eq!(cs.front(), 3, "front pulled back to the first hole");
        // Re-marking the holes restores fullness exactly.
        cs.mark(3);
        cs.mark(10);
        assert!(cs.is_full());
        assert_eq!(cs.resident_bytes(), 1050);
    }

    #[test]
    fn chunkset_advance_matches_scalar_front() {
        // Byte-exact compatibility with the old `fetched_bytes` scalar:
        // sequential ticks accumulate exactly, chunk boundaries or not.
        let mut cs = ChunkSet::new(1000, 64);
        let mut scalar = 0u64;
        for tick in [10u64, 54, 64, 1, 200, 500, 999] {
            cs.advance(tick);
            scalar = (scalar + tick).min(1000);
            assert_eq!(cs.fetched_bytes(), scalar, "after tick {tick}");
        }
        assert!(cs.is_full());
        assert_eq!(cs.resident_bytes(), 1000);
    }

    #[test]
    fn chunkset_advance_skips_out_of_order_marks() {
        let mut cs = ChunkSet::new(300, 100);
        cs.mark(1); // a reader filled the middle chunk out of order
        cs.advance(100); // front fills chunk 0…
        assert!(cs.contains(0));
        assert_eq!(cs.resident_bytes(), 200);
        cs.advance(100); // …and the front skips marked chunk 1 ⇒ chunk 2
        assert!(cs.is_full(), "front must skip already-marked chunks");
    }

    #[test]
    fn chunkset_credit_marks_only_complete_chunks() {
        // One 100-byte chunk covering several 30-byte "items": crediting
        // item-sized pieces must not claim the chunk resident early.
        let mut cs = ChunkSet::new(300, 100);
        assert!(!cs.credit_unit(0, 1, 30));
        assert!(!cs.credit_unit(0, 2, 30));
        assert!(!cs.contains(0), "60/100 credited is not resident");
        assert_eq!(cs.resident_bytes(), 0);
        assert_eq!(cs.fetched_bytes(), 60, "credits count as fetch progress");
        // Idempotent per unit: a racing duplicate report of item 2's fill
        // adds nothing — the chunk cannot fill up from one item's bytes.
        assert!(!cs.credit_unit(0, 2, 30));
        assert!(!cs.credit_unit(0, 2, 50));
        assert_eq!(cs.fetched_bytes(), 60, "duplicate unit credits ignored");
        assert!(cs.credit_unit(0, 3, 40), "completing credit marks the chunk");
        assert!(cs.contains(0));
        assert_eq!(cs.resident_bytes(), 100);
        assert!(!cs.credit_unit(0, 4, 10), "credit on a marked chunk is a no-op");
        assert_eq!(cs.fetched_bytes(), 100);
        // Over-credit saturates at the chunk length.
        cs.credit_unit(2, 7, 1_000_000);
        assert!(cs.contains(2));
        assert_eq!(cs.resident_bytes(), 200);
    }

    #[test]
    fn chunkset_union_combines_distinct_unit_credits() {
        // Observer A credited item 1, observer B credited item 2 — their
        // union covers the whole chunk and must mark it.
        let mut a = ChunkSet::new(100, 100);
        let mut b = ChunkSet::new(100, 100);
        a.credit_unit(0, 1, 60);
        b.credit_unit(0, 2, 40);
        a.union(&b);
        assert!(a.contains(0), "combined units cover the chunk");
        assert!(a.is_full());
        // Same-unit credits merge by max, not sum.
        let mut c = ChunkSet::new(100, 100);
        let mut d = ChunkSet::new(100, 100);
        c.credit_unit(0, 1, 60);
        d.credit_unit(0, 1, 60);
        c.union(&d);
        assert!(!c.contains(0), "duplicate unit must not double-count");
        assert_eq!(c.fetched_bytes(), 60);
    }

    #[test]
    fn chunkset_union_and_empty_dataset() {
        let mut a = ChunkSet::new(500, 100);
        let mut b = ChunkSet::new(500, 100);
        a.mark(0);
        b.mark(3);
        b.mark(0);
        a.union(&b);
        assert_eq!(a.marked_chunks(), 2);
        assert_eq!(a.resident_bytes(), 200);
        let empty = ChunkSet::new(0, 100);
        assert!(empty.is_full(), "zero-byte dataset is trivially resident");
        assert_eq!(empty.resident_bytes(), 0);
    }

    #[test]
    fn non_member_holds_nothing() {
        let s = StripeMap::new(nodes(&[1, 2]), 100);
        assert_eq!(s.bytes_on_node(NodeId(0), 1000), 0);
        assert_eq!(s.local_fraction(NodeId(0)), 0.0);
        assert!((s.local_fraction(NodeId(1)) - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn empty_stripe_rejected() {
        StripeMap::new(vec![], 100);
    }

    #[test]
    fn prop_item_mapping_balanced_and_member() {
        use crate::util::{prop::forall, Rng};
        forall(
            100,
            |rng: &mut Rng| {
                let k = 1 + rng.gen_range(8) as usize;
                let mut ids: Vec<usize> = (0..16).collect();
                rng.shuffle(&mut ids);
                ids.truncate(k);
                (ids, 1 + rng.gen_range(10_000))
            },
            |(ids, items)| {
                let s = StripeMap::new(nodes(ids), 1 << 20);
                let mut counts = std::collections::HashMap::new();
                for i in 0..*items {
                    let n = s.node_of_item(i);
                    if !s.contains(n) {
                        return Err(format!("item {i} on non-member {n:?}"));
                    }
                    *counts.entry(n).or_insert(0u64) += 1;
                }
                let max = counts.values().max().unwrap();
                let min = counts.values().min().copied().unwrap_or(0);
                if max - min > 1 {
                    return Err(format!("imbalance {max}-{min}"));
                }
                Ok(())
            },
        );
    }
}
