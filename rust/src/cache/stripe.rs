//! Stripe placement: how a dataset's items/bytes spread over the selected
//! cache nodes (paper Requirement 1: aggregate the capacity of a *subset*
//! of nodes; the subset is chosen by the coordinator, not the FS).

use crate::netsim::NodeId;

/// Deterministic mapping of dataset items and byte ranges onto a fixed,
/// ordered set of cache nodes. Items are round-robined (file-granular
/// striping, what AFM filesets give us); byte ranges use fixed-size chunks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StripeMap {
    nodes: Vec<NodeId>,
    /// Chunk size for byte-range striping.
    pub chunk_bytes: u64,
}

impl StripeMap {
    pub fn new(nodes: Vec<NodeId>, chunk_bytes: u64) -> Self {
        assert!(!nodes.is_empty(), "stripe set must be non-empty");
        assert!(chunk_bytes > 0);
        StripeMap { nodes, chunk_bytes }
    }

    pub fn width(&self) -> usize {
        self.nodes.len()
    }

    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    pub fn contains(&self, n: NodeId) -> bool {
        self.nodes.contains(&n)
    }

    /// Cache node holding item `i` (file-granular placement).
    pub fn node_of_item(&self, i: u64) -> NodeId {
        self.nodes[(i % self.nodes.len() as u64) as usize]
    }

    /// Cache node holding byte `offset` (chunk-granular placement).
    pub fn node_of_offset(&self, offset: u64) -> NodeId {
        let chunk = offset / self.chunk_bytes;
        self.nodes[(chunk % self.nodes.len() as u64) as usize]
    }

    /// Bytes of a `total`-byte dataset stored on node `n` (± one chunk).
    pub fn bytes_on_node(&self, n: NodeId, total: u64) -> u64 {
        if !self.contains(n) {
            return 0;
        }
        let k = self.nodes.len() as u64;
        let full_rounds = total / (self.chunk_bytes * k);
        let base = full_rounds * self.chunk_bytes;
        let rem = total - full_rounds * self.chunk_bytes * k;
        // Distribute the remainder chunk-by-chunk in node order.
        let pos = self.nodes.iter().position(|&x| x == n).unwrap() as u64;
        let extra_full_chunks = rem / self.chunk_bytes;
        let tail = rem % self.chunk_bytes;
        let extra = if pos < extra_full_chunks {
            self.chunk_bytes
        } else if pos == extra_full_chunks {
            tail
        } else {
            0
        };
        base + extra
    }

    /// Fraction of reads served locally for a consumer on node `n`.
    pub fn local_fraction(&self, n: NodeId) -> f64 {
        if self.contains(n) {
            1.0 / self.nodes.len() as f64
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(ids: &[usize]) -> Vec<NodeId> {
        ids.iter().map(|&i| NodeId(i)).collect()
    }

    #[test]
    fn round_robin_items() {
        let s = StripeMap::new(nodes(&[0, 2, 3]), 1 << 20);
        assert_eq!(s.node_of_item(0), NodeId(0));
        assert_eq!(s.node_of_item(1), NodeId(2));
        assert_eq!(s.node_of_item(2), NodeId(3));
        assert_eq!(s.node_of_item(3), NodeId(0));
    }

    #[test]
    fn offset_striping() {
        let s = StripeMap::new(nodes(&[0, 1]), 100);
        assert_eq!(s.node_of_offset(0), NodeId(0));
        assert_eq!(s.node_of_offset(99), NodeId(0));
        assert_eq!(s.node_of_offset(100), NodeId(1));
        assert_eq!(s.node_of_offset(250), NodeId(0));
    }

    #[test]
    fn bytes_on_node_sums_to_total() {
        for total in [0u64, 1, 99, 100, 350, 1000, 12345] {
            let s = StripeMap::new(nodes(&[0, 1, 2]), 100);
            let sum: u64 = (0..3).map(|i| s.bytes_on_node(NodeId(i), total)).sum();
            assert_eq!(sum, total, "total={total}");
        }
    }

    #[test]
    fn bytes_on_node_balanced() {
        let s = StripeMap::new(nodes(&[0, 1, 2, 3]), 1 << 20);
        let total = 144_000_000_000u64;
        for i in 0..4 {
            let b = s.bytes_on_node(NodeId(i), total);
            let want = total / 4;
            assert!((b as i64 - want as i64).unsigned_abs() <= 1 << 20);
        }
    }

    #[test]
    fn non_member_holds_nothing() {
        let s = StripeMap::new(nodes(&[1, 2]), 100);
        assert_eq!(s.bytes_on_node(NodeId(0), 1000), 0);
        assert_eq!(s.local_fraction(NodeId(0)), 0.0);
        assert!((s.local_fraction(NodeId(1)) - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn empty_stripe_rejected() {
        StripeMap::new(vec![], 100);
    }

    #[test]
    fn prop_item_mapping_balanced_and_member() {
        use crate::util::{prop::forall, Rng};
        forall(
            100,
            |rng: &mut Rng| {
                let k = 1 + rng.gen_range(8) as usize;
                let mut ids: Vec<usize> = (0..16).collect();
                rng.shuffle(&mut ids);
                ids.truncate(k);
                (ids, 1 + rng.gen_range(10_000))
            },
            |(ids, items)| {
                let s = StripeMap::new(nodes(ids), 1 << 20);
                let mut counts = std::collections::HashMap::new();
                for i in 0..*items {
                    let n = s.node_of_item(i);
                    if !s.contains(n) {
                        return Err(format!("item {i} on non-member {n:?}"));
                    }
                    *counts.entry(n).or_insert(0u64) += 1;
                }
                let max = counts.values().max().unwrap();
                let min = counts.values().min().copied().unwrap_or(0);
                if max - min > 1 {
                    return Err(format!("imbalance {max}-{min}"));
                }
                Ok(())
            },
        );
    }
}
