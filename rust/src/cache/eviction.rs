//! Cache eviction at **dataset granularity** (paper §3.1): when the cache
//! is full, either (i) refuse new datasets until the user evicts manually,
//! or (ii) evict whole least-recently-used datasets. Never partial files —
//! evicting a fraction of a dataset is as good as evicting all of it
//! (Requirement 2 discussion).

use crate::cache::registry::Registry;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictionPolicy {
    /// Option (i): admission fails until the user deletes something.
    #[default]
    Manual,
    /// Option (ii): evict unpinned datasets in LRU order.
    DatasetLru,
}

impl EvictionPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "manual" => Some(EvictionPolicy::Manual),
            "lru" | "dataset-lru" => Some(EvictionPolicy::DatasetLru),
            _ => None,
        }
    }
}

/// Outcome of an admission attempt for `need` new bytes against `capacity`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Admission {
    /// Fits without evicting.
    Fits,
    /// Fits after evicting these datasets (in eviction order).
    EvictFirst(Vec<String>),
    /// Cannot fit even after all permissible evictions.
    Rejected { need: u64, reclaimable: u64 },
}

/// Decide how to admit `need` bytes. Pure planning — the manager applies it.
pub fn plan_admission(
    policy: EvictionPolicy,
    registry: &Registry,
    capacity: u64,
    need: u64,
) -> Admission {
    let used = registry.resident_bytes();
    let free = capacity.saturating_sub(used);
    if need <= free {
        return Admission::Fits;
    }
    match policy {
        EvictionPolicy::Manual => Admission::Rejected { need, reclaimable: 0 },
        EvictionPolicy::DatasetLru => {
            // Walk LRU order accumulating reclaimable bytes.
            let mut candidates: Vec<_> = registry
                .iter()
                .filter(|r| r.is_evictable() && r.resident_bytes() > 0)
                .collect();
            candidates.sort_by_key(|r| r.last_access);
            let mut reclaimed = 0u64;
            let mut victims = vec![];
            for r in candidates {
                if need <= free + reclaimed {
                    break;
                }
                reclaimed += r.resident_bytes();
                victims.push(r.spec.name.clone());
            }
            if need <= free + reclaimed {
                Admission::EvictFirst(victims)
            } else {
                Admission::Rejected { need, reclaimable: reclaimed }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::registry::DatasetState;
    use crate::workload::DatasetSpec;

    fn registry(datasets: &[(&str, u64, bool)]) -> Registry {
        // (name, bytes, pinned)
        let mut r = Registry::new();
        for (n, b, pinned) in datasets {
            r.register(DatasetSpec::new(*n, 1, *b), format!("nfs://x/{n}")).unwrap();
            r.get_mut(n).unwrap().state = DatasetState::Cached;
            if *pinned {
                r.pin(n).unwrap();
            }
        }
        r
    }

    #[test]
    fn fits_when_free() {
        let r = registry(&[("a", 30, false)]);
        assert_eq!(plan_admission(EvictionPolicy::Manual, &r, 100, 50), Admission::Fits);
    }

    #[test]
    fn manual_rejects_when_full() {
        let r = registry(&[("a", 80, false)]);
        assert!(matches!(
            plan_admission(EvictionPolicy::Manual, &r, 100, 50),
            Admission::Rejected { need: 50, .. }
        ));
    }

    #[test]
    fn lru_evicts_oldest_first() {
        let mut r = registry(&[("old", 40, false), ("new", 40, false)]);
        r.pin("new").unwrap();
        r.unpin("new").unwrap(); // bump access clock
        match plan_admission(EvictionPolicy::DatasetLru, &r, 100, 50) {
            Admission::EvictFirst(v) => assert_eq!(v, vec!["old".to_string()]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lru_evicts_multiple_if_needed() {
        let r = registry(&[("a", 40, false), ("b", 40, false)]);
        match plan_admission(EvictionPolicy::DatasetLru, &r, 100, 95) {
            Admission::EvictFirst(v) => assert_eq!(v.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pinned_datasets_never_victims() {
        // capacity 100, used 90 (a pinned 60 + b 30) ⇒ free 10; need 35
        // fits only by evicting b — a must never be chosen.
        let r = registry(&[("a", 60, true), ("b", 30, false)]);
        match plan_admission(EvictionPolicy::DatasetLru, &r, 100, 35) {
            Admission::EvictFirst(v) => assert_eq!(v, vec!["b".to_string()]),
            other => panic!("{other:?}"),
        }
        // Need more than unpinned space ⇒ rejected even under LRU.
        assert!(matches!(
            plan_admission(EvictionPolicy::DatasetLru, &r, 100, 80),
            Admission::Rejected { reclaimable: 30, .. }
        ));
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(EvictionPolicy::parse("manual"), Some(EvictionPolicy::Manual));
        assert_eq!(EvictionPolicy::parse("lru"), Some(EvictionPolicy::DatasetLru));
        assert_eq!(EvictionPolicy::parse("???"), None);
    }

    #[test]
    fn prop_admission_is_sound() {
        use crate::util::{prop::forall, Rng};
        forall(
            200,
            |rng: &mut Rng| {
                let n = rng.gen_range(6) as usize;
                let datasets: Vec<(String, u64, bool)> = (0..n)
                    .map(|i| (format!("d{i}"), rng.gen_range(50) + 1, rng.bool(0.3)))
                    .collect();
                let capacity = 60 + rng.gen_range(100);
                let need = rng.gen_range(120) + 1;
                (datasets, capacity, need)
            },
            |(datasets, capacity, need)| {
                let ds: Vec<(&str, u64, bool)> =
                    datasets.iter().map(|(n, b, p)| (n.as_str(), *b, *p)).collect();
                let r = registry(&ds);
                let used = r.resident_bytes();
                if used > *capacity {
                    return Ok(()); // over-packed fixture; skip
                }
                match plan_admission(EvictionPolicy::DatasetLru, &r, *capacity, *need) {
                    Admission::Fits => {
                        if *need > capacity - used {
                            return Err("claimed fit without space".into());
                        }
                    }
                    Admission::EvictFirst(victims) => {
                        let reclaimed: u64 = victims
                            .iter()
                            .map(|v| r.get(v).unwrap().resident_bytes())
                            .sum();
                        for v in &victims {
                            if !r.get(v).unwrap().is_evictable() {
                                return Err(format!("victim {v} not evictable"));
                            }
                        }
                        if *need > capacity - used + reclaimed {
                            return Err("eviction plan insufficient".into());
                        }
                    }
                    Admission::Rejected { .. } => {
                        let max_reclaim: u64 = r
                            .iter()
                            .filter(|x| x.is_evictable())
                            .map(|x| x.resident_bytes())
                            .sum();
                        if *need <= capacity - used + max_reclaim {
                            return Err("rejected despite feasible eviction".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
