//! `RamTier` — the bounded in-memory hot-chunk cache above the NVMe chunk
//! files (the bi-level cache of the ROADMAP, SNIPPETS' BiLevelCache shape).
//!
//! The warm fast lane pays one chunk-file open + read per resident local
//! segment; for the hot set that disk I/O is the whole remaining cost of a
//! warm item. The tier keeps whole chunk payloads in RAM under a byte
//! budget so a hot read is one `copy_from_slice` into the caller's final
//! buffer — no file open, no syscall.
//!
//! Design:
//!
//!  * **Keys are `(dataset_id, generation, grid_bytes, chunk)`** — the
//!    same address the peer wire uses. Because the placement generation is
//!    *in the key*, a re-placed dataset structurally cannot hit the dead
//!    placement's bytes: gen-N entries are unreachable from gen-N+1 reads.
//!    On top of that, [`RamTier::invalidate_dataset`] drops every entry of
//!    a dataset eagerly (wired into `DataPlane::reset_dataset`), so dead
//!    generations also stop occupying budget.
//!  * **Admission on second touch**: the first touch of a chunk only
//!    records the key in a bounded touch filter; the payload is kept only
//!    when the chunk comes back. A one-pass scan (cold fill, one-epoch
//!    job) therefore cannot flush the hot set — classic scan resistance.
//!  * **CLOCK eviction**: one reference bit per entry, a clock hand over
//!    fixed slots. A hit sets the bit; the hand clears bits until it finds
//!    a cold entry to evict. Approximates LRU at a fraction of the
//!    bookkeeping and needs no per-hit list surgery.
//!  * **Copy outside the lock**: entries hold `Arc<Vec<u8>>`; a lookup
//!    clones the `Arc` under a short mutex hold and the memcpy into the
//!    caller's buffer happens lock-free, so 8 readers hitting one hot
//!    chunk do not serialize their copies.
//!  * **Atomic counters** (`hits`/`misses`/`inserted`/`evicted`) readable
//!    without the lock — the experiment tables and benches report them.
//!
//! Shared across co-scheduled jobs via the `DataPlane` (one tier per
//! plane, like the fill ledgers and the `BufPool`): J jobs streaming one
//! dataset warm each other's hot set.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One cached chunk's address: `(dataset_id, generation, grid_bytes,
/// chunk)` — identical to the peer wire address, so a stale generation or
/// a re-gridded placement can never alias a live entry.
pub type ChunkKey = (u64, u64, u64, u64);

/// Touch-filter capacity (keys, not bytes): when the filter fills, it is
/// cleared wholesale — coarse aging that bounds memory at a few MB while
/// keeping the second-touch property for any realistically hot set.
const TOUCH_CAP: usize = 1 << 16;

/// One resident entry on the clock ring.
#[derive(Debug)]
struct Slot {
    key: ChunkKey,
    data: Arc<Vec<u8>>,
    /// CLOCK reference bit: set on hit, cleared by the sweeping hand.
    referenced: bool,
}

#[derive(Debug, Default)]
struct Inner {
    /// Key → slot index. Slots never move, so indices stay valid.
    map: HashMap<ChunkKey, usize>,
    /// Fixed-position slots (`None` ⇒ free); the clock hand walks this.
    slots: Vec<Option<Slot>>,
    free: Vec<usize>,
    hand: usize,
    bytes: u64,
    /// First-touch filter for second-touch admission.
    touched: HashSet<ChunkKey>,
}

/// Counter snapshot ([`RamTier::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RamTierStats {
    pub hits: u64,
    pub misses: u64,
    pub inserted: u64,
    pub evicted: u64,
    /// Payload bytes currently cached.
    pub bytes: u64,
    /// Entries currently cached.
    pub entries: u64,
}

/// Bounded-bytes in-memory hot-chunk cache. See the module docs for the
/// admission/eviction/invalidation model.
#[derive(Debug)]
pub struct RamTier {
    budget_bytes: u64,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    inserted: AtomicU64,
    evicted: AtomicU64,
}

impl RamTier {
    /// A tier that holds at most `budget_bytes` of chunk payloads.
    pub fn new(budget_bytes: u64) -> Self {
        RamTier {
            budget_bytes,
            inner: Mutex::new(Inner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserted: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Copy `dst.len()` bytes starting at `off` of `key`'s payload into
    /// `dst`. `true` ⇔ hit (and the entry's reference bit is set). A
    /// cached payload too short for the requested window counts as a miss
    /// — the caller falls through to disk, never reads garbage.
    pub fn read_into(&self, key: ChunkKey, off: u64, dst: &mut [u8]) -> bool {
        let data = self.lookup(key);
        match data {
            Some(d) => {
                let off = off as usize;
                if off.checked_add(dst.len()).map(|end| end <= d.len()) != Some(true) {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    return false;
                }
                dst.copy_from_slice(&d[off..off + dst.len()]);
                self.hits.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// The whole cached payload (the peer-serving path). Hit/miss counted
    /// like [`RamTier::read_into`].
    pub fn get(&self, key: ChunkKey) -> Option<Arc<Vec<u8>>> {
        match self.lookup(key) {
            Some(d) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(d)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Whether `key` is currently cached, with **no** counter or reference
    /// side effects (tests and introspection).
    pub fn contains(&self, key: ChunkKey) -> bool {
        self.inner.lock().unwrap().map.contains_key(&key)
    }

    fn lookup(&self, key: ChunkKey) -> Option<Arc<Vec<u8>>> {
        let mut inner = self.inner.lock().unwrap();
        let idx = inner.map.get(&key).copied()?;
        let slot = inner.slots[idx].as_mut().expect("mapped slot must be occupied");
        slot.referenced = true;
        Some(slot.data.clone())
    }

    /// Record a touch of `key` without supplying bytes. `true` ⇔ the tier
    /// now wants the payload (second or later touch, not yet cached): the
    /// caller should read the **full** chunk and [`RamTier::insert`] it.
    /// Idempotent in the wanting state — asking again keeps answering
    /// `true` until the payload arrives (or the filter ages out).
    pub fn note_touch(&self, key: ChunkKey) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.map.contains_key(&key) {
            return false;
        }
        if inner.touched.contains(&key) {
            return true;
        }
        if inner.touched.len() >= TOUCH_CAP {
            inner.touched.clear();
        }
        inner.touched.insert(key);
        false
    }

    /// Offer a payload already in hand (the fill path): records the touch
    /// and inserts on the second one. `true` ⇔ inserted.
    pub fn offer(&self, key: ChunkKey, payload: &[u8]) -> bool {
        if self.note_touch(key) {
            self.insert(key, payload)
        } else {
            false
        }
    }

    /// Insert unconditionally (admission already decided), evicting via
    /// CLOCK until the payload fits the budget. Refuses empty payloads and
    /// payloads larger than the whole budget. Re-inserting a cached key
    /// refreshes its reference bit and payload.
    pub fn insert(&self, key: ChunkKey, payload: &[u8]) -> bool {
        let len = payload.len() as u64;
        if len == 0 || len > self.budget_bytes {
            return false;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.touched.remove(&key);
        if let Some(idx) = inner.map.get(&key).copied() {
            let slot = inner.slots[idx].as_mut().expect("mapped slot must be occupied");
            let old = slot.data.len() as u64;
            slot.data = Arc::new(payload.to_vec());
            slot.referenced = true;
            inner.bytes = inner.bytes - old + len;
            // Same-key refresh can still overflow the budget when the
            // payload grew: sweep below.
        } else {
            let data = Arc::new(payload.to_vec());
            let idx = match inner.free.pop() {
                Some(i) => i,
                None => {
                    inner.slots.push(None);
                    inner.slots.len() - 1
                }
            };
            inner.slots[idx] = Some(Slot { key, data, referenced: false });
            inner.map.insert(key, idx);
            inner.bytes += len;
            self.inserted.fetch_add(1, Ordering::Relaxed);
        }
        while inner.bytes > self.budget_bytes {
            if Self::evict_one(&mut inner, Some(key)) == 0 {
                break;
            }
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
        true
    }

    /// One CLOCK sweep step: clear reference bits until a cold entry
    /// falls, never evicting `protect` (the entry just inserted). Returns
    /// the bytes freed (0 ⇔ nothing evictable).
    fn evict_one(inner: &mut Inner, protect: Option<ChunkKey>) -> u64 {
        let n = inner.slots.len();
        if n == 0 || inner.map.len() <= usize::from(protect.is_some()) {
            return 0;
        }
        // Two full revolutions always suffice: the first clears every
        // reference bit, the second must find a cold victim.
        for _ in 0..2 * n {
            let idx = inner.hand;
            inner.hand = (inner.hand + 1) % n;
            let Some(slot) = inner.slots[idx].as_mut() else { continue };
            if protect == Some(slot.key) {
                continue;
            }
            if slot.referenced {
                slot.referenced = false;
                continue;
            }
            let victim = inner.slots[idx].take().expect("checked occupied above");
            inner.map.remove(&victim.key);
            inner.free.push(idx);
            let freed = victim.data.len() as u64;
            inner.bytes -= freed;
            return freed;
        }
        0
    }

    /// Drop every cached entry and pending touch of `dataset_id`
    /// (evict / re-place / GC — wired into `DataPlane::reset_dataset`).
    /// Returns the payload bytes released.
    pub fn invalidate_dataset(&self, dataset_id: u64) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let victims: Vec<(ChunkKey, usize)> = inner
            .map
            .iter()
            .filter(|(k, _)| k.0 == dataset_id)
            .map(|(k, &i)| (*k, i))
            .collect();
        let mut dropped = 0u64;
        for (key, idx) in victims {
            inner.map.remove(&key);
            if let Some(slot) = inner.slots[idx].take() {
                dropped += slot.data.len() as u64;
                inner.free.push(idx);
            }
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
        inner.bytes -= dropped;
        inner.touched.retain(|k| k.0 != dataset_id);
        dropped
    }

    /// Payload bytes currently cached.
    pub fn bytes_cached(&self) -> u64 {
        self.inner.lock().unwrap().bytes
    }

    /// Entries currently cached.
    pub fn len(&self) -> u64 {
        self.inner.lock().unwrap().map.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter + occupancy snapshot (counters are monotone; occupancy is
    /// instantaneous).
    pub fn stats(&self) -> RamTierStats {
        let (bytes, entries) = {
            let inner = self.inner.lock().unwrap();
            (inner.bytes, inner.map.len() as u64)
        };
        RamTierStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserted: self.inserted.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            bytes,
            entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(d: u64, g: u64, c: u64) -> ChunkKey {
        (d, g, 1000, c)
    }

    #[test]
    fn second_touch_admission_resists_one_pass_scans() {
        let tier = RamTier::new(1 << 20);
        // One pass over 10 chunks: touches only, nothing admitted.
        for c in 0..10 {
            assert!(!tier.offer(key(1, 1, c), &[7u8; 100]), "first touch must not admit");
        }
        assert_eq!(tier.len(), 0, "a one-pass scan must not populate the tier");
        assert_eq!(tier.stats().inserted, 0);
        // Second pass: every chunk admitted.
        for c in 0..10 {
            assert!(tier.offer(key(1, 1, c), &[7u8; 100]), "second touch must admit");
        }
        assert_eq!(tier.len(), 10);
        assert_eq!(tier.bytes_cached(), 1000);
        // note_touch on a cached key answers false (nothing wanted).
        assert!(!tier.note_touch(key(1, 1, 3)));
        // ...and on a once-touched key keeps answering true until insert.
        assert!(!tier.note_touch(key(1, 1, 77)));
        assert!(tier.note_touch(key(1, 1, 77)));
        assert!(tier.note_touch(key(1, 1, 77)));
    }

    #[test]
    fn read_into_copies_exact_window_and_counts() {
        let tier = RamTier::new(1 << 20);
        let payload: Vec<u8> = (0..=255u8).collect();
        tier.insert(key(1, 1, 0), &payload);
        let mut dst = [0u8; 16];
        assert!(tier.read_into(key(1, 1, 0), 100, &mut dst));
        assert_eq!(&dst[..], &payload[100..116]);
        // Whole-payload window.
        let mut all = vec![0u8; 256];
        assert!(tier.read_into(key(1, 1, 0), 0, &mut all));
        assert_eq!(all, payload);
        // Out-of-window requests miss instead of serving short bytes.
        let mut over = [0u8; 16];
        assert!(!tier.read_into(key(1, 1, 0), 250, &mut over));
        assert!(!tier.read_into(key(1, 1, 9), 0, &mut over), "absent key misses");
        let s = tier.stats();
        assert_eq!((s.hits, s.misses), (2, 2));
    }

    #[test]
    fn clock_evicts_cold_entries_and_keeps_hot_ones() {
        // Budget fits exactly 4 × 100-byte payloads.
        let tier = RamTier::new(400);
        for c in 0..4 {
            tier.insert(key(1, 1, c), &[c as u8; 100]);
        }
        assert_eq!(tier.bytes_cached(), 400);
        // Heat chunks 2 and 3 (sets their reference bits).
        let mut dst = [0u8; 1];
        assert!(tier.read_into(key(1, 1, 2), 0, &mut dst));
        assert!(tier.read_into(key(1, 1, 3), 0, &mut dst));
        // Two more inserts: the hand must fell the cold 0 and 1, not the
        // hot 2 and 3.
        tier.insert(key(1, 1, 4), &[4u8; 100]);
        tier.insert(key(1, 1, 5), &[5u8; 100]);
        assert_eq!(tier.bytes_cached(), 400);
        assert!(tier.contains(key(1, 1, 2)), "hot entry evicted");
        assert!(tier.contains(key(1, 1, 3)), "hot entry evicted");
        assert!(!tier.contains(key(1, 1, 0)), "cold entry survived");
        assert!(!tier.contains(key(1, 1, 1)), "cold entry survived");
        assert_eq!(tier.stats().evicted, 2);
        // Oversized and empty payloads are refused outright.
        assert!(!tier.insert(key(1, 1, 9), &[0u8; 500]));
        assert!(!tier.insert(key(1, 1, 9), &[]));
        // Same-key refresh replaces the payload without a second entry.
        tier.insert(key(1, 1, 4), &[9u8; 50]);
        assert_eq!(tier.len(), 4);
        assert!(tier.read_into(key(1, 1, 4), 0, &mut dst));
        assert_eq!(dst[0], 9);
    }

    #[test]
    fn generation_keys_never_alias_and_invalidate_drops_dataset() {
        let tier = RamTier::new(1 << 20);
        tier.insert(key(1, 1, 0), &[0xAA; 64]); // gen 1 bytes
        tier.insert(key(2, 1, 0), &[0xBB; 64]); // another dataset
        // A gen-2 read of the same chunk misses structurally.
        let mut dst = [0u8; 8];
        assert!(!tier.read_into(key(1, 2, 0), 0, &mut dst), "generation must key the entry");
        assert!(tier.get(key(1, 2, 0)).is_none());
        // Invalidation drops dataset 1 (entries and pending touches) and
        // leaves dataset 2 untouched.
        tier.note_touch(key(1, 1, 7));
        assert_eq!(tier.invalidate_dataset(1), 64);
        assert!(!tier.contains(key(1, 1, 0)));
        assert!(tier.contains(key(2, 1, 0)));
        assert_eq!(tier.bytes_cached(), 64);
        // The dropped touch is gone too: the next touch is a *first* touch.
        assert!(!tier.note_touch(key(1, 1, 7)));
        // Idempotent.
        assert_eq!(tier.invalidate_dataset(1), 0);
    }

    #[test]
    fn shared_across_threads_stays_within_budget() {
        let tier = Arc::new(RamTier::new(10_000));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let tier = tier.clone();
                s.spawn(move || {
                    let mut dst = [0u8; 32];
                    for round in 0..50u64 {
                        let c = (t * 50 + round) % 64;
                        tier.offer(key(1, 1, c), &[c as u8; 200]);
                        tier.read_into(key(1, 1, c), 0, &mut dst);
                    }
                });
            }
        });
        assert!(tier.bytes_cached() <= 10_000, "budget must hold under concurrency");
        let s = tier.stats();
        assert_eq!(s.bytes, tier.bytes_cached());
        assert!(s.hits + s.misses > 0);
    }
}
