//! Dataset registry: the cache-resident state machine whose life cycle is
//! *decoupled from job life cycles* (paper Requirement 2). A dataset stays
//! cached after its jobs finish, so repeated runs ("think time") and
//! hyper-parameter sweeps hit warm data.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cache::stripe::{ChunkSet, StripeMap};
use crate::cache::ResidencySnapshot;
use crate::workload::DatasetSpec;

/// Life-cycle states (§3.1/§3.2).
#[derive(Debug, Clone, PartialEq)]
pub enum DatasetState {
    /// Custom resource created; nothing placed yet.
    Registered,
    /// Cache nodes selected, fetch in progress (on-demand or prefetch).
    /// Residency is chunk-granular: `chunks` records exactly which chunks
    /// of the stripe have landed (replacing the old `fetched_bytes`
    /// scalar; byte progress is derived via [`ChunkSet::fetched_bytes`]).
    Caching { chunks: ChunkSet },
    /// Fully resident on its stripe set.
    Cached,
    /// One or more stripe nodes died mid-life: `chunks` is the survivor
    /// residency (the dead nodes' chunks cleared), `lost` the failed
    /// nodes. Survivor chunks keep serving; lost chunks re-plan as remote
    /// fills. Left by a coordinator re-stripe ([`Replacing`]) or a node
    /// rejoin re-admitting the lost chunks.
    Degraded { chunks: ChunkSet, lost: Vec<crate::netsim::NodeId> },
    /// Coordinator-triggered re-stripe onto the survivor set is in flight:
    /// the generation is being bumped and chunks migrated/re-fetched. Not
    /// evictable while moving.
    Replacing,
    /// Being removed from the cache.
    Evicting,
}

/// One cached (or cacheable) dataset.
#[derive(Debug, Clone)]
pub struct DatasetRecord {
    /// Stable numeric ID assigned at registration (unique per registry,
    /// never reused) — the wire address of the peer chunk protocol and
    /// the namespace of the on-disk chunk files.
    pub id: u64,
    pub spec: DatasetSpec,
    /// Remote source, e.g. "nfs://storage1/exports/imagenet".
    pub url: String,
    pub state: DatasetState,
    pub stripe: Option<StripeMap>,
    /// Lock-free mirror of the `Caching` bitmap, published at placement
    /// and retired on evict/failure — the warm path's fast lane
    /// ([`ResidencySnapshot`]). `Some` ⇔ `stripe` is `Some`.
    pub snapshot: Option<Arc<ResidencySnapshot>>,
    /// Logical clock of the last job access (drives dataset-granular LRU).
    pub last_access: u64,
    /// Jobs currently mounting this dataset (pinned ⇒ not evictable).
    pub pin_count: u32,
    /// Placement generation: 0 while never placed, bumped on **every**
    /// successful placement. Stamped into the chunk geometry, the on-disk
    /// chunk paths and the peer wire protocol, so a re-placed dataset can
    /// never adopt or serve files written under an earlier placement.
    pub generation: u64,
}

impl DatasetRecord {
    pub fn is_evictable(&self) -> bool {
        self.pin_count == 0
            && !matches!(self.state, DatasetState::Evicting | DatasetState::Replacing)
    }

    /// Bytes currently occupying cache space (sum of resident chunk
    /// sizes, tail chunk included, while caching).
    pub fn resident_bytes(&self) -> u64 {
        match &self.state {
            DatasetState::Registered | DatasetState::Replacing => 0,
            DatasetState::Caching { chunks } | DatasetState::Degraded { chunks, .. } => {
                chunks.resident_bytes()
            }
            DatasetState::Cached | DatasetState::Evicting => self.spec.total_bytes,
        }
    }

    /// Total fetch progress in bytes — the derived accessor replacing the
    /// old `Caching { fetched_bytes }` scalar (resident chunks plus the
    /// sequential front's partial progress).
    pub fn fetched_bytes(&self) -> u64 {
        match &self.state {
            DatasetState::Registered | DatasetState::Replacing => 0,
            DatasetState::Caching { chunks } | DatasetState::Degraded { chunks, .. } => {
                chunks.fetched_bytes()
            }
            DatasetState::Cached | DatasetState::Evicting => self.spec.total_bytes,
        }
    }

    /// Chunk residency bitmap while the dataset is filling or degraded.
    pub fn chunk_set(&self) -> Option<&ChunkSet> {
        match &self.state {
            DatasetState::Caching { chunks } | DatasetState::Degraded { chunks, .. } => {
                Some(chunks)
            }
            _ => None,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    Duplicate(String),
    NotFound(String),
    Pinned(String, u32),
    BadTransition(String, String),
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::Duplicate(n) => write!(f, "dataset '{n}' already registered"),
            RegistryError::NotFound(n) => write!(f, "dataset '{n}' not found"),
            RegistryError::Pinned(n, c) => write!(f, "dataset '{n}' is pinned by {c} job(s)"),
            RegistryError::BadTransition(n, why) => {
                write!(f, "invalid state transition for '{n}': {why}")
            }
        }
    }
}

impl std::error::Error for RegistryError {}

/// Name-keyed registry with a logical access clock.
#[derive(Debug, Default)]
pub struct Registry {
    entries: BTreeMap<String, DatasetRecord>,
    clock: u64,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, spec: DatasetSpec, url: String) -> Result<(), RegistryError> {
        if self.entries.contains_key(&spec.name) {
            return Err(RegistryError::Duplicate(spec.name));
        }
        self.clock += 1;
        let rec = DatasetRecord {
            id: self.clock,
            url,
            state: DatasetState::Registered,
            stripe: None,
            snapshot: None,
            last_access: self.clock,
            pin_count: 0,
            generation: 0,
            spec,
        };
        self.entries.insert(rec.spec.name.clone(), rec);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&DatasetRecord> {
        self.entries.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut DatasetRecord, RegistryError> {
        self.entries
            .get_mut(name)
            .ok_or_else(|| RegistryError::NotFound(name.to_string()))
    }

    pub fn remove(&mut self, name: &str) -> Result<DatasetRecord, RegistryError> {
        let rec = self
            .entries
            .get(name)
            .ok_or_else(|| RegistryError::NotFound(name.to_string()))?;
        if rec.pin_count > 0 {
            return Err(RegistryError::Pinned(name.to_string(), rec.pin_count));
        }
        Ok(self.entries.remove(name).unwrap())
    }

    pub fn iter(&self) -> impl Iterator<Item = &DatasetRecord> {
        self.entries.values()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Mark a job access (bumps the LRU clock, pins while mounted).
    pub fn pin(&mut self, name: &str) -> Result<(), RegistryError> {
        self.clock += 1;
        let clock = self.clock;
        let rec = self.get_mut(name)?;
        rec.last_access = clock;
        rec.pin_count += 1;
        Ok(())
    }

    pub fn unpin(&mut self, name: &str) -> Result<(), RegistryError> {
        let rec = self.get_mut(name)?;
        if rec.pin_count == 0 {
            return Err(RegistryError::BadTransition(name.into(), "unpin at 0".into()));
        }
        rec.pin_count -= 1;
        Ok(())
    }

    /// Total bytes resident across all datasets.
    pub fn resident_bytes(&self) -> u64 {
        self.entries.values().map(|r| r.resident_bytes()).sum()
    }

    /// Least-recently-used evictable dataset, if any.
    pub fn lru_candidate(&self) -> Option<&DatasetRecord> {
        self.entries
            .values()
            .filter(|r| r.is_evictable() && r.resident_bytes() > 0)
            .min_by_key(|r| r.last_access)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, bytes: u64) -> DatasetSpec {
        DatasetSpec::new(name, 100, bytes)
    }

    fn reg_with(names: &[(&str, u64)]) -> Registry {
        let mut r = Registry::new();
        for (n, b) in names {
            r.register(spec(n, *b), format!("nfs://x/{n}")).unwrap();
        }
        r
    }

    #[test]
    fn register_assigns_stable_unique_ids() {
        let mut r = reg_with(&[("a", 10), ("b", 10)]);
        let (ida, idb) = (r.get("a").unwrap().id, r.get("b").unwrap().id);
        assert_ne!(ida, idb, "ids are unique");
        // Ids survive unrelated registry activity (they are stable
        // addresses, not positions).
        r.pin("a").unwrap();
        r.unpin("a").unwrap();
        r.register(spec("c", 10), "nfs://x/c".into()).unwrap();
        assert_eq!(r.get("a").unwrap().id, ida);
        assert_eq!(r.get("b").unwrap().id, idb);
        assert_ne!(r.get("c").unwrap().id, ida);
        assert_ne!(r.get("c").unwrap().id, idb);
    }

    #[test]
    fn register_and_duplicate() {
        let mut r = reg_with(&[("a", 10)]);
        assert!(matches!(
            r.register(spec("a", 10), "nfs://x/a".into()),
            Err(RegistryError::Duplicate(_))
        ));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn pin_blocks_removal() {
        let mut r = reg_with(&[("a", 10)]);
        r.pin("a").unwrap();
        assert!(matches!(r.remove("a"), Err(RegistryError::Pinned(_, 1))));
        r.unpin("a").unwrap();
        r.remove("a").unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn unpin_at_zero_fails() {
        let mut r = reg_with(&[("a", 10)]);
        assert!(r.unpin("a").is_err());
    }

    #[test]
    fn lru_candidate_ordering() {
        let mut r = reg_with(&[("a", 10), ("b", 10), ("c", 10)]);
        for n in ["a", "b", "c"] {
            r.get_mut(n).unwrap().state = DatasetState::Cached;
        }
        // Access order: a (oldest), then c, then b was never re-touched.
        r.pin("a").unwrap();
        r.unpin("a").unwrap();
        r.pin("c").unwrap();
        r.unpin("c").unwrap();
        assert_eq!(r.lru_candidate().unwrap().spec.name, "b");
        // Pin b: next candidate is a.
        r.pin("b").unwrap();
        assert_eq!(r.lru_candidate().unwrap().spec.name, "a");
    }

    #[test]
    fn resident_bytes_by_state() {
        let mut r = reg_with(&[("a", 100), ("b", 50)]);
        assert_eq!(r.resident_bytes(), 0);
        let mut chunks = ChunkSet::new(100, 10);
        chunks.advance(30); // 3 of 10 chunks resident
        r.get_mut("a").unwrap().state = DatasetState::Caching { chunks };
        r.get_mut("b").unwrap().state = DatasetState::Cached;
        assert_eq!(r.resident_bytes(), 80);
        assert_eq!(r.get("a").unwrap().fetched_bytes(), 30);
        assert_eq!(r.get("a").unwrap().chunk_set().unwrap().marked_chunks(), 3);
    }

    #[test]
    fn evicting_not_a_candidate() {
        let mut r = reg_with(&[("a", 10)]);
        r.get_mut("a").unwrap().state = DatasetState::Evicting;
        assert!(r.lru_candidate().is_none());
    }

    #[test]
    fn missing_dataset_errors() {
        let mut r = Registry::new();
        assert!(matches!(r.pin("nope"), Err(RegistryError::NotFound(_))));
        assert!(matches!(r.remove("nope"), Err(RegistryError::NotFound(_))));
    }
}
