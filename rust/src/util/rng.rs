//! Deterministic PRNG (SplitMix64) — crates.io `rand` is unavailable in the
//! offline build, and the simulators need reproducible streams anyway.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes (placement,
/// shuffles, synthetic data). Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`; bound must be > 0. Lemire-style rejection to
    /// avoid modulo bias.
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be > 0");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Fisher–Yates.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.gen_range(xs.len() as u64) as usize])
        }
    }

    /// A fresh, independent stream (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_5A5A_5A5A)
    }

    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..50 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn choose_empty_none() {
        let mut r = Rng::new(1);
        assert!(r.choose::<u8>(&[]).is_none());
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(3);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
