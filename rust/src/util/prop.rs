//! Micro property-testing harness (proptest is unavailable offline).
//!
//! `forall(cases, gen, check)` runs `check` on `cases` generated inputs; on
//! failure it panics with the failing seed so the case can be replayed with
//! `replay(seed, gen, check)`. No shrinking — generators are kept small
//! enough that raw failures are readable.

use super::rng::Rng;

/// Run `check` against `cases` random inputs. Panics with the failing seed
/// and input debug representation on the first failure.
pub fn forall<T: std::fmt::Debug>(
    cases: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    let base = BASE_SEED;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed (seed={seed:#x}, case={case}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Base seed for property runs ("HOARD" in ASCII) — one obvious place to
/// change when hunting flaky generators.
const BASE_SEED: u64 = 0x48_4F_41_52_44;

/// Replay a single case by seed (copy the seed from the failure message).
pub fn replay<T: std::fmt::Debug>(
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    if let Err(msg) = check(&input) {
        panic!("replayed property failed (seed={seed:#x}): {msg}\ninput: {input:#?}");
    }
}

/// Convenience: assert with a formatted message inside property checks.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(100, |r| r.gen_range(100), |&x| {
            if x < 100 { Ok(()) } else { Err(format!("{x} out of range")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure_with_seed() {
        forall(100, |r| r.gen_range(10), |&x| {
            if x < 5 { Ok(()) } else { Err("too big".into()) }
        });
    }
}
