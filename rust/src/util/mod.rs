//! Shared utilities: deterministic RNG, JSON codec, formatting, and the
//! property-testing micro-harness. All hand-rolled because the offline build
//! has no access to rand/serde/proptest (DESIGN.md §8).

pub mod fmt;
pub mod json;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use rng::Rng;

/// Rate guarded against zero/negative durations: smoke-mode epochs can
/// finish in ~0 ns, and `count / 0` poisons tables and `BENCH_*.json`
/// with inf/NaN — report `0.0` instead. The single implementation behind
/// `EpochReport::items_per_sec` and `experiments::items_per_sec`.
pub fn per_sec(count: u64, secs: f64) -> f64 {
    if secs > 0.0 {
        count as f64 / secs
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn per_sec_guards_zero_durations() {
        assert_eq!(super::per_sec(100, 2.0), 50.0);
        assert_eq!(super::per_sec(100, 0.0), 0.0);
        assert_eq!(super::per_sec(100, -1.0), 0.0);
    }
}
