//! Shared utilities: deterministic RNG, JSON codec, formatting, and the
//! property-testing micro-harness. All hand-rolled because the offline build
//! has no access to rand/serde/proptest (DESIGN.md §8).

pub mod fmt;
pub mod json;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
