//! Minimal JSON parser/serializer (serde is unavailable in the offline
//! build). Covers the full JSON grammar; numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our configs; map
                            // lone surrogates to the replacement character.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "tru", "{\"a\":}", "1 2", "{\"a\":1,}"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5").unwrap().as_f64(), Some(-2.5));
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-42").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn int_serialization_compact() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn prop_roundtrip_random_documents() {
        use crate::util::{prop::forall, Rng};

        fn gen_value(rng: &mut Rng, depth: u32) -> Json {
            match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::num((rng.next_u32() as f64) / 8.0),
                3 => {
                    let n = rng.gen_range(12);
                    Json::Str((0..n).map(|_| (b'a' + rng.gen_range(26) as u8) as char).collect())
                }
                4 => Json::Arr((0..rng.gen_range(4)).map(|_| gen_value(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.gen_range(4))
                        .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                        .collect(),
                ),
            }
        }

        forall(
            200,
            |rng: &mut Rng| gen_value(rng, 3),
            |doc| {
                let text = doc.to_string();
                let parsed = Json::parse(&text)
                    .map_err(|e| format!("failed to reparse {text}: {e}"))?;
                if parsed != *doc {
                    return Err(format!("roundtrip mismatch: {doc:?} -> {text}"));
                }
                Ok(())
            },
        );
    }
}
