//! Human-readable byte/throughput/duration formatting and parsing.

/// 2^k byte constants.
pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;

/// Decimal (storage vendor / network) constants.
pub const KB: u64 = 1_000;
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;
pub const TB: u64 = 1_000_000_000_000;

/// "1.34 GiB"-style rendering of a byte count.
pub fn bytes(n: u64) -> String {
    let nf = n as f64;
    if n >= TIB {
        format!("{:.2} TiB", nf / TIB as f64)
    } else if n >= GIB {
        format!("{:.2} GiB", nf / GIB as f64)
    } else if n >= MIB {
        format!("{:.2} MiB", nf / MIB as f64)
    } else if n >= KIB {
        format!("{:.2} KiB", nf / KIB as f64)
    } else {
        format!("{n} B")
    }
}

/// Bytes/second as "x.xx GB/s" (decimal, matching the paper's units).
pub fn rate(bytes_per_s: f64) -> String {
    if bytes_per_s >= GB as f64 {
        format!("{:.2} GB/s", bytes_per_s / GB as f64)
    } else if bytes_per_s >= MB as f64 {
        format!("{:.1} MB/s", bytes_per_s / MB as f64)
    } else if bytes_per_s >= KB as f64 {
        format!("{:.1} KB/s", bytes_per_s / KB as f64)
    } else {
        format!("{bytes_per_s:.0} B/s")
    }
}

/// Bits/second as "x.xx Gb/s" (network convention, Table 4/5 units).
pub fn bitrate(bits_per_s: f64) -> String {
    if bits_per_s >= 1e9 {
        format!("{:.2} Gb/s", bits_per_s / 1e9)
    } else if bits_per_s >= 1e6 {
        format!("{:.1} Mb/s", bits_per_s / 1e6)
    } else {
        format!("{:.0} b/s", bits_per_s)
    }
}

/// Seconds as "1h 23m 45s" / "12m 3s" / "4.20s".
pub fn duration(secs: f64) -> String {
    if secs >= 3600.0 {
        let h = (secs / 3600.0).floor();
        let m = ((secs - h * 3600.0) / 60.0).floor();
        format!("{h:.0}h {m:.0}m")
    } else if secs >= 60.0 {
        let m = (secs / 60.0).floor();
        format!("{m:.0}m {:.0}s", secs - m * 60.0)
    } else {
        format!("{secs:.2}s")
    }
}

/// Parse "150GB", "1.5 GiB", "512MB", "4096" (bytes) etc.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(s.len());
    if split == 0 {
        return None;
    }
    let (num, unit) = s.split_at(split);
    let num: f64 = num.parse().ok()?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "b" | "" => 1,
        "kb" => KB,
        "mb" => MB,
        "gb" => GB,
        "tb" => TB,
        "kib" => KIB,
        "mib" => MIB,
        "gib" => GIB,
        "tib" => TIB,
        _ => return None,
    };
    Some((num * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_rendering() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2 * KIB), "2.00 KiB");
        assert_eq!(bytes(3 * GIB + GIB / 2), "3.50 GiB");
    }

    #[test]
    fn rate_rendering() {
        assert_eq!(rate(1.05e9), "1.05 GB/s");
        assert_eq!(rate(616e6), "616.0 MB/s");
    }

    #[test]
    fn bitrate_rendering() {
        assert_eq!(bitrate(2.7e9), "2.70 Gb/s");
    }

    #[test]
    fn duration_rendering() {
        assert_eq!(duration(14.9 * 3600.0), "14h 54m");
        assert_eq!(duration(150.0), "2m 30s");
        assert_eq!(duration(4.2), "4.20s");
    }

    #[test]
    fn parse_roundtrips() {
        assert_eq!(parse_bytes("150GB"), Some(150 * GB));
        assert_eq!(parse_bytes("1.5 GiB"), Some(GIB + GIB / 2));
        assert_eq!(parse_bytes("4096"), Some(4096));
        assert_eq!(parse_bytes("512MB"), Some(512 * MB));
        assert_eq!(parse_bytes("xyz"), None);
        assert_eq!(parse_bytes(""), None);
    }
}
