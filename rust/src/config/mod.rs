//! Cluster/experiment configuration: JSON-file loadable, with defaults
//! matching the paper's testbed (Table 2).

use std::path::Path;

use anyhow::{Context, Result};

use crate::cache::EvictionPolicy;
use crate::cluster::{GpuKind, NodeSpec};
use crate::coordinator::Hoard;
use crate::netsim::Topology;
use crate::storage::{Device, DeviceKind, Volume};
use crate::util::fmt::{parse_bytes, GB};
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub racks: usize,
    pub nodes_per_rack: usize,
    pub gpus_per_node: u32,
    pub gpu_kind: GpuKind,
    pub memory_per_node: u64,
    pub cache_devices_per_node: usize,
    pub cache_device_bytes: u64,
    /// NIC bandwidth, bytes/s (100 GbE = 12.5e9).
    pub nic_bw: f64,
    /// Rack uplink bandwidth, bytes/s.
    pub uplink_bw: f64,
    /// Remote store peak bandwidth, bytes/s.
    pub remote_bw: f64,
    pub eviction: EvictionPolicy,
    /// Spectrum-style pagepool per node, bytes.
    pub pagepool: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self::paper_testbed()
    }
}

impl ClusterConfig {
    /// Table 2: 4 × POWER8, 4 × P100 each, 512 GB RAM, 2 × 512 GB NVMe for
    /// the cache, 100 GbE, 1.05 GB/s NFS.
    pub fn paper_testbed() -> Self {
        ClusterConfig {
            racks: 1,
            nodes_per_rack: 4,
            gpus_per_node: 4,
            gpu_kind: GpuKind::P100,
            memory_per_node: 512 * GB,
            cache_devices_per_node: 2,
            cache_device_bytes: 512 * GB,
            nic_bw: 12.5e9,
            uplink_bw: f64::INFINITY,
            remote_bw: 1.05e9,
            eviction: EvictionPolicy::Manual,
            pagepool: 16 * GB,
        }
    }

    /// The Table 5 data-center model: racks of 32-port 40G TORs with 3:1
    /// oversubscription ⇒ 320 Gb/s uplink.
    pub fn table5_datacenter(racks: usize, nodes_per_rack: usize) -> Self {
        ClusterConfig {
            racks,
            nodes_per_rack,
            nic_bw: 5e9,       // 40G NICs
            uplink_bw: 40e9,   // 320 Gb/s
            ..Self::paper_testbed()
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.racks * self.nodes_per_rack
    }

    pub fn topology(&self) -> Topology {
        Topology::new(self.racks, self.nodes_per_rack, self.nic_bw, self.uplink_bw)
    }

    pub fn node_specs(&self) -> Vec<NodeSpec> {
        (0..self.num_nodes())
            .map(|i| NodeSpec {
                name: format!("node{i}"),
                cpu_cores: 16,
                memory: self.memory_per_node,
                gpus: self.gpus_per_node,
                gpu_kind: self.gpu_kind,
                cache_volume: Volume::new(
                    (0..self.cache_devices_per_node)
                        .map(|_| Device::new(DeviceKind::Nvme, self.cache_device_bytes))
                        .collect(),
                ),
                nic_bw: self.nic_bw,
            })
            .collect()
    }

    /// Assemble the full control plane from this config.
    pub fn build(&self) -> Hoard {
        let mut h = Hoard::new(self.node_specs(), self.topology(), self.eviction);
        for n in &mut h.nodes {
            n.set_pagepool(self.pagepool);
        }
        h
    }

    /// Load from a JSON file; missing keys fall back to paper defaults.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("config is not valid json")?;
        let mut c = Self::paper_testbed();
        let get_u = |k: &str| j.get(k).and_then(|v| v.as_u64());
        let get_f = |k: &str| j.get(k).and_then(|v| v.as_f64());
        let get_b = |k: &str| j.get(k).and_then(|v| v.as_str()).and_then(parse_bytes);
        if let Some(v) = get_u("racks") {
            c.racks = v as usize;
        }
        if let Some(v) = get_u("nodes_per_rack") {
            c.nodes_per_rack = v as usize;
        }
        if let Some(v) = get_u("gpus_per_node") {
            c.gpus_per_node = v as u32;
        }
        if let Some(v) = j.get("gpu") .and_then(|v| v.as_str()) {
            c.gpu_kind = match v {
                "p100" | "P100" => GpuKind::P100,
                "v100" | "V100" => GpuKind::V100,
                other => anyhow::bail!("unknown gpu '{other}'"),
            };
        }
        if let Some(v) = get_b("memory_per_node") {
            c.memory_per_node = v;
        }
        if let Some(v) = get_u("cache_devices_per_node") {
            c.cache_devices_per_node = v as usize;
        }
        if let Some(v) = get_b("cache_device_bytes") {
            c.cache_device_bytes = v;
        }
        if let Some(v) = get_f("nic_gbps") {
            c.nic_bw = v * 1e9 / 8.0;
        }
        if let Some(v) = get_f("uplink_gbps") {
            c.uplink_bw = v * 1e9 / 8.0;
        }
        if let Some(v) = get_f("remote_gbps") {
            c.remote_bw = v * 1e9 / 8.0;
        }
        if let Some(v) = get_b("remote_bytes_per_s") {
            c.remote_bw = v as f64;
        }
        if let Some(v) = j.get("eviction").and_then(|v| v.as_str()) {
            c.eviction = EvictionPolicy::parse(v)
                .with_context(|| format!("unknown eviction policy '{v}'"))?;
        }
        if let Some(v) = get_b("pagepool") {
            c.pagepool = v;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_table2() {
        let c = ClusterConfig::paper_testbed();
        assert_eq!(c.num_nodes(), 4);
        assert_eq!(c.gpus_per_node, 4);
        assert_eq!(c.memory_per_node, 512 * GB);
        assert_eq!(c.cache_devices_per_node, 2);
        let h = c.build();
        assert_eq!(h.nodes.len(), 4);
        assert_eq!(h.cache.total_capacity(), 4 * 1024 * GB);
    }

    #[test]
    fn parse_overrides() {
        let c = ClusterConfig::parse(
            r#"{"racks": 2, "nodes_per_rack": 8, "gpu": "v100",
                "eviction": "lru", "pagepool": "32GB", "nic_gbps": 40}"#,
        )
        .unwrap();
        assert_eq!(c.num_nodes(), 16);
        assert_eq!(c.gpu_kind, GpuKind::V100);
        assert_eq!(c.eviction, EvictionPolicy::DatasetLru);
        assert_eq!(c.pagepool, 32 * GB);
        assert!((c.nic_bw - 5e9).abs() < 1.0);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ClusterConfig::parse("not json").is_err());
        assert!(ClusterConfig::parse(r#"{"gpu": "tpu"}"#).is_err());
        assert!(ClusterConfig::parse(r#"{"eviction": "fifo"}"#).is_err());
    }

    #[test]
    fn table5_shape() {
        let c = ClusterConfig::table5_datacenter(3, 8);
        assert_eq!(c.num_nodes(), 24);
        assert!((c.uplink_bw - 40e9).abs() < 1.0);
        let t = c.topology();
        assert_eq!(t.racks, 3);
    }
}
