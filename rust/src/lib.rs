//! # Hoard — distributed data caching for deep-learning training
//!
//! A from-scratch reproduction of *"Hoard: A Distributed Data Caching
//! System to Accelerate Deep Learning Training on the Cloud"* (Pinto et
//! al., 2018) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the Hoard system itself: a dataset-granular
//!   distributed cache striped over compute-node NVMe ([`cache`]), a
//!   mini-Kubernetes orchestration substrate ([`k8s`]), the co-scheduling
//!   coordinator ([`coordinator`]), a POSIX-style VFS ([`posix`]), the REST
//!   API ([`api`]), and calibrated simulations of every piece of the
//!   paper's testbed ([`netsim`], [`storage`], [`cluster`], [`remote`],
//!   [`dfs`], [`workload`]).
//! * **L2/L1 (python/, build-time only)** — the training *consumer*: a JAX
//!   CNN whose hot-spots are Pallas kernels, AOT-lowered to HLO text and
//!   executed from Rust via PJRT ([`runtime`]).
//!
//! See DESIGN.md for the system inventory and the experiment index, and
//! EXPERIMENTS.md for paper-vs-measured numbers.

pub mod api;
pub mod cache;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dfs;
pub mod experiments;
pub mod k8s;
pub mod metrics;
pub mod net;
pub mod peer;
pub mod posix;
pub mod prefetch;
pub mod runtime;
pub mod netsim;
pub mod remote;
pub mod storage;
pub mod util;
pub mod workload;
