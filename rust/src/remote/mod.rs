//! Remote central-storage models: the shared NFS server (the paper's
//! baseline) and an S3-style object store. Both expose `RemoteStore`:
//! a capacity (bytes/s the server can push) plus a concurrency-degradation
//! curve — NFS servers deliver less aggregate bandwidth as concurrent
//! random-readers pile up (seeky request streams defeat server readahead).
//!
//! Calibration: the paper measured 1.05 GB/s peak from applications, yet
//! Table 4's REM row implies only ~644 MB/s aggregate while 4 jobs × 4 GPUs
//! stream random 112 KB images (REM 60-epoch training = 14.9 h ⇒ 894 s per
//! epoch ⇒ 4 × 161 MB/s). `NfsModel` reproduces that with
//! `effective_bw(16 readers) ≈ 0.613 × peak`.

use crate::util::fmt::GB;

/// A remote dataset source outside the cluster.
pub trait RemoteStore: std::fmt::Debug + Send + Sync {
    /// Scheme tag for dataset URLs ("nfs", "s3").
    fn scheme(&self) -> &'static str;
    /// Peak aggregate read bandwidth (single well-formed stream), bytes/s.
    fn peak_bw(&self) -> f64;
    /// Aggregate bandwidth the server sustains with `readers` concurrent
    /// random-access readers, bytes/s.
    fn effective_bw(&self, readers: u32) -> f64;
    /// Per-request overhead in seconds (metadata round trip); object stores
    /// pay more per GET than NFS pays per read().
    fn request_overhead(&self) -> f64;
}

/// NFS over a 10 Gb/s-class storage network (paper: different network from
/// the 100 GbE cluster fabric, 1.05 GB/s measured peak).
#[derive(Debug, Clone)]
pub struct NfsModel {
    pub peak: f64,
    /// Fraction of peak retained per doubling of concurrent seeky readers.
    pub concurrency_retention: f64,
}

impl NfsModel {
    pub fn new(peak: f64) -> Self {
        // Calibrated so 16 readers ⇒ ~0.613 × peak (Table 4 REM row).
        NfsModel { peak, concurrency_retention: 0.885 }
    }

    /// The paper's server: 1.05 GB/s measured from applications.
    pub fn paper_nfs() -> Self {
        NfsModel::new(1.05e9)
    }

    /// Figure 5: the same server throttled with `tc` to `frac` of peak.
    pub fn throttled(frac: f64) -> Self {
        NfsModel::new(1.05e9 * frac)
    }
}

impl RemoteStore for NfsModel {
    fn scheme(&self) -> &'static str {
        "nfs"
    }

    fn peak_bw(&self) -> f64 {
        self.peak
    }

    fn effective_bw(&self, readers: u32) -> f64 {
        if readers <= 1 {
            return self.peak;
        }
        let doublings = (readers as f64).log2();
        self.peak * self.concurrency_retention.powf(doublings)
    }

    fn request_overhead(&self) -> f64 {
        300e-6 // NFSv3 read RTT on a busy 10G net
    }
}

/// S3-compatible object store: flatter concurrency curve (scale-out
/// frontends) but higher per-GET overhead.
#[derive(Debug, Clone)]
pub struct S3Model {
    pub peak: f64,
}

impl S3Model {
    pub fn new(peak: f64) -> Self {
        S3Model { peak }
    }
}

impl RemoteStore for S3Model {
    fn scheme(&self) -> &'static str {
        "s3"
    }

    fn peak_bw(&self) -> f64 {
        self.peak
    }

    fn effective_bw(&self, readers: u32) -> f64 {
        // Object stores parallelize well; mild degradation only.
        if readers <= 1 {
            self.peak
        } else {
            self.peak * 0.97f64.powf((readers as f64).log2())
        }
    }

    fn request_overhead(&self) -> f64 {
        8e-3 // HTTP GET latency
    }
}

/// Live gauge of concurrent remote readers — the accounting hook the
/// real-mode data plane uses to re-rate the shared remote bucket per
/// reader (`effective_bw(active)`), and to report fairness after the run.
#[derive(Debug, Default)]
pub struct RemoteReaderGauge {
    active: std::sync::atomic::AtomicU32,
    peak: std::sync::atomic::AtomicU32,
    sessions: std::sync::atomic::AtomicU64,
}

impl RemoteReaderGauge {
    /// A reader entered the remote path. Returns the active count
    /// *including* this reader.
    pub fn enter(&self) -> u32 {
        use std::sync::atomic::Ordering::SeqCst;
        let now = self.active.fetch_add(1, SeqCst) + 1;
        self.peak.fetch_max(now, SeqCst);
        self.sessions.fetch_add(1, SeqCst);
        now
    }

    pub fn exit(&self) {
        use std::sync::atomic::Ordering::SeqCst;
        let prev = self.active.fetch_sub(1, SeqCst);
        debug_assert!(prev > 0, "gauge exit without enter");
    }

    pub fn active(&self) -> u32 {
        self.active.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// High-water mark of concurrent remote readers.
    pub fn peak(&self) -> u32 {
        self.peak.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// Total remote read sessions since creation.
    pub fn sessions(&self) -> u64 {
        self.sessions.load(std::sync::atomic::Ordering::SeqCst)
    }
}

/// Bandwidth one of `readers` concurrent readers can expect from `model`
/// under fair sharing of the degraded aggregate (the per-reader view of
/// the Table 4 calibration).
pub fn fair_reader_bw(model: &dyn RemoteStore, readers: u32) -> f64 {
    let readers = readers.max(1);
    model.effective_bw(readers) / readers as f64
}

/// Parse a dataset URL like "nfs://server/path" or "s3://bucket/key".
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DatasetUrl {
    pub scheme: String,
    pub host: String,
    pub path: String,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UrlError(pub String);

impl std::fmt::Display for UrlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid dataset url '{}' (expected scheme://host/path)", self.0)
    }
}

impl std::error::Error for UrlError {}

impl DatasetUrl {
    pub fn parse(s: &str) -> Result<Self, UrlError> {
        let (scheme, rest) = s.split_once("://").ok_or_else(|| UrlError(s.into()))?;
        if scheme.is_empty() || rest.is_empty() {
            return Err(UrlError(s.into()));
        }
        let (host, path) = match rest.split_once('/') {
            Some((h, p)) => (h.to_string(), format!("/{p}")),
            None => (rest.to_string(), "/".to_string()),
        };
        if host.is_empty() {
            return Err(UrlError(s.into()));
        }
        Ok(DatasetUrl { scheme: scheme.to_string(), host, path })
    }
}

#[allow(dead_code)]
const _TYPICAL_CLOUD_NFS: f64 = 1.05 * GB as f64;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nfs_peak_single_reader() {
        let n = NfsModel::paper_nfs();
        assert_eq!(n.effective_bw(1), 1.05e9);
    }

    #[test]
    fn nfs_degrades_to_table4_point() {
        // 16 concurrent GPU readers ⇒ ~644 MB/s (Table 4 REM: 894 s/epoch
        // for 4 jobs × 144 GB).
        let n = NfsModel::paper_nfs();
        let bw = n.effective_bw(16);
        assert!((bw - 644e6).abs() / 644e6 < 0.02, "bw = {bw}");
    }

    #[test]
    fn nfs_monotone_in_readers() {
        let n = NfsModel::paper_nfs();
        let mut last = f64::INFINITY;
        for r in [1u32, 2, 4, 8, 16, 32] {
            let bw = n.effective_bw(r);
            assert!(bw <= last);
            last = bw;
        }
    }

    #[test]
    fn s3_flatter_than_nfs() {
        let nfs = NfsModel::new(1e9);
        let s3 = S3Model::new(1e9);
        assert!(s3.effective_bw(16) > nfs.effective_bw(16));
        assert!(s3.request_overhead() > nfs.request_overhead());
    }

    #[test]
    fn throttled_scales_peak() {
        let t = NfsModel::throttled(0.4);
        assert!((t.peak_bw() - 0.42e9).abs() < 1e3);
    }

    #[test]
    fn reader_gauge_tracks_active_and_peak() {
        let g = RemoteReaderGauge::default();
        assert_eq!(g.enter(), 1);
        assert_eq!(g.enter(), 2);
        g.exit();
        assert_eq!(g.enter(), 2);
        g.exit();
        g.exit();
        assert_eq!(g.active(), 0);
        assert_eq!(g.peak(), 2);
        assert_eq!(g.sessions(), 3);
    }

    #[test]
    fn fair_reader_bw_splits_degraded_aggregate() {
        let n = NfsModel::paper_nfs();
        let one = fair_reader_bw(&n, 1);
        let sixteen = fair_reader_bw(&n, 16);
        assert_eq!(one, 1.05e9);
        // 16 readers share ~644 MB/s ⇒ ~40 MB/s each.
        assert!((sixteen - 644e6 / 16.0).abs() / sixteen < 0.03, "{sixteen}");
        // Per-reader share is monotone decreasing.
        let mut last = f64::INFINITY;
        for r in [1u32, 2, 4, 8, 16] {
            let bw = fair_reader_bw(&n, r);
            assert!(bw < last);
            last = bw;
        }
    }

    #[test]
    fn url_parsing() {
        let u = DatasetUrl::parse("nfs://storage1/exports/imagenet").unwrap();
        assert_eq!(u.scheme, "nfs");
        assert_eq!(u.host, "storage1");
        assert_eq!(u.path, "/exports/imagenet");
        let u = DatasetUrl::parse("s3://bucket").unwrap();
        assert_eq!(u.path, "/");
        assert!(DatasetUrl::parse("not a url").is_err());
        assert!(DatasetUrl::parse("://x/y").is_err());
        assert!(DatasetUrl::parse("nfs://").is_err());
    }
}
