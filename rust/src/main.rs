//! `hoard` — CLI for the Hoard reproduction.
//!
//! Subcommands:
//!   exp <id|all>        reproduce a paper table/figure (t1 f3 t3 f4 f5 t4
//!                       t5 util readers chunks peers jobs evict failover
//!                       prefetch ablations)
//!   serve [--addr A]    run the Hoard API server over an in-process cluster
//!   datagen --out DIR   generate a synthetic real-mode dataset
//!   sim --mode M        run the paper 4-job scenario (rem|nvme|hoard)
//!   info                print the testbed configuration (Table 2)

use std::sync::{Arc, Mutex};

use hoard::config::ClusterConfig;
use hoard::experiments::{self, ablations};
use hoard::metrics::ascii_plot;
use hoard::util::fmt;
use hoard::workload::datagen::{generate, DataGenConfig};
use hoard::workload::trainsim::{paper_scenario, ReadMode};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("exp") => cmd_exp(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("datagen") => cmd_datagen(&args[1..]),
        Some("sim") => cmd_sim(&args[1..]),
        Some("info") => cmd_info(),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    eprintln!(
        "hoard — distributed data caching for DL training (paper reproduction)\n\n\
         USAGE:\n  hoard exp <t1|f3|t3|f4|f5|t4|t5|util|readers|chunks|peers|jobs|evict|failover|prefetch|ablations|all> [--json]\n  \
         hoard serve [--addr 127.0.0.1:7070] [--config FILE] [--max-conns N]\n        \
         [--data-root DIR] [--data-items N] [--data-chunk BYTES]\n  \
         hoard datagen --out DIR [--items N]\n  \
         hoard sim --mode <rem|nvme|hoard> [--epochs N] [--readers N]\n  \
         hoard info"
    );
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
fn flag<'a>(args: &'a [String], key: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn cmd_exp(args: &[String]) -> i32 {
    let json = args.iter().any(|a| a == "--json");
    let which = args
        .iter()
        .map(String::as_str)
        .find(|a| !a.starts_with("--"))
        .unwrap_or("all");
    // Every experiment table shares one machine-readable form
    // (`metrics::Table::json`), so `readers`, `chunks` and the paper
    // tables all emit the same JSON shape under --json.
    let emit = |t: hoard::metrics::Table| {
        println!("{}", if json { t.json() } else { t.console() })
    };
    let run = |id: &str| -> bool {
        match id {
            "t1" => emit(experiments::table1_fs_comparison()),
            "f3" => {
                let (series, table) = experiments::figure3_two_epochs();
                if !json {
                    let refs: Vec<(&str, &[(f64, f64)])> =
                        series.iter().map(|(n, s)| (n.as_str(), s.as_slice())).collect();
                    println!("{}", ascii_plot("Figure 3 — img/s over time", &refs, 72, 16));
                }
                emit(table);
            }
            "t3" => emit(experiments::table3_projections()),
            "f4" => emit(experiments::figure4_mdr_sweep()),
            "f5" => emit(experiments::figure5_remote_bw_sweep()),
            "t4" => emit(experiments::table4_network_usage()),
            "t5" => emit(experiments::table5_rack_uplink()),
            "util" => emit(experiments::utilization_2x()),
            "readers" => {
                emit(experiments::realmode_reader_scaling(&[1, 2, 4], 256));
                emit(experiments::ram_tier_table(128));
            }
            "chunks" => emit(experiments::chunk_size_table(24)),
            "peers" => emit(experiments::peer_transport_table(24)),
            "jobs" => emit(experiments::co_job_table(24)),
            "evict" => emit(experiments::eviction_lifecycle_table(24)),
            "failover" => {
                emit(experiments::failover_table(24));
                emit(experiments::failover_jobs_table());
            }
            "prefetch" => emit(experiments::prefetch_table(96)),
            "ablations" => {
                emit(ablations::ablation_stripe_width());
                emit(ablations::ablation_prefetch());
                emit(ablations::ablation_eviction());
                emit(ablations::ablation_coscheduling());
            }
            _ => return false,
        }
        true
    };
    if which == "all" {
        for id in [
            "t1", "f3", "t3", "f4", "f5", "t4", "t5", "util", "readers", "chunks", "peers",
            "jobs", "evict", "failover", "prefetch", "ablations",
        ] {
            run(id);
        }
        return 0;
    }
    if run(which) {
        0
    } else {
        eprintln!("unknown experiment '{which}'");
        2
    }
}

/// Build the real-mode data plane behind `/v1/jobs`: a 4-node cluster of
/// cache directories under `root`, one generated dataset ("default",
/// reused when the remote store already holds it) striped over all
/// nodes, and a `DataPlane` with the dataset's layout registered.
fn build_data_plane(
    root: &str,
    items: u64,
    chunk_bytes: u64,
) -> anyhow::Result<Arc<hoard::posix::DataPlane>> {
    use hoard::cache::{CacheManager, EvictionPolicy, SharedCache};
    use hoard::netsim::NodeId;
    use hoard::posix::{DataPlane, RealCluster};
    use hoard::storage::{Device, DeviceKind, Volume};
    use hoard::workload::DatasetSpec;
    const NODES: usize = 4;
    let cluster = RealCluster::create(root, NODES, 500e6)?;
    let cfg = DataGenConfig { num_items: items, files_per_dir: 64, ..Default::default() };
    let total = if cluster.remote_dir.join(cfg.item_rel_path(0)).exists() {
        // Remote store already generated (a previous serve): reuse it.
        items * cfg.record_bytes() as u64
    } else {
        generate(&cluster.remote_dir, &cfg)?
    };
    let vols = (0..NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 32)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.chunk_bytes = chunk_bytes;
    manager.register(DatasetSpec::new("default", items, total), format!("nfs://{root}/default"))?;
    manager.place("default", (0..NODES).map(NodeId).collect())?;
    let plane = Arc::new(DataPlane::new(cluster, SharedCache::new(manager)));
    plane.register_dataset("default", cfg);
    println!("data plane at {root}: dataset 'default' ({items} items) striped over {NODES} nodes");
    Ok(plane)
}

fn cmd_serve(args: &[String]) -> i32 {
    let addr = flag(args, "--addr").unwrap_or("127.0.0.1:7070");
    let config = match flag(args, "--config") {
        Some(path) => match ClusterConfig::load(std::path::Path::new(path)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e:#}");
                return 1;
            }
        },
        None => ClusterConfig::paper_testbed(),
    };
    let hoard = Arc::new(Mutex::new(config.build()));
    let plane = match flag(args, "--data-root") {
        Some(root) => {
            let items = flag(args, "--data-items").and_then(|s| s.parse().ok()).unwrap_or(256);
            let chunk =
                flag(args, "--data-chunk").and_then(|s| s.parse().ok()).unwrap_or(1 << 20);
            match build_data_plane(root, items, chunk) {
                Ok(p) => Some(p),
                Err(e) => {
                    eprintln!("data plane setup failed: {e:#}");
                    return 1;
                }
            }
        }
        None => None,
    };
    let max_conns = flag(args, "--max-conns")
        .and_then(|s| s.parse().ok())
        .unwrap_or(hoard::api::http::DEFAULT_MAX_CONNS);
    let has_plane = plane.is_some();
    let served = hoard::api::serve_with_opts(addr, hoard, plane, max_conns);
    match served {
        Ok(server) => {
            println!("hoard api listening on http://{}", server.addr);
            println!("  GET  /healthz");
            println!("  GET|POST /v1/datasets       DELETE /v1/datasets/NAME");
            println!("  GET  /v1/stats              (legacy aliases under /api/v1/)");
            if has_plane {
                println!("  GET|POST /v1/jobs           job sessions (dataset 'default')");
            } else {
                println!("  GET|POST /v1/jobs           503 — attach with --data-root DIR");
            }
            println!("  GET  /v1/jobs/NAME/stats    POST /v1/jobs/NAME/epoch");
            println!("  DELETE /v1/jobs/NAME");
            println!("  GET|POST /api/v1/jobs       POST /api/v1/jobs/NAME/complete (control)");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            1
        }
    }
}

fn cmd_datagen(args: &[String]) -> i32 {
    let Some(out) = flag(args, "--out") else {
        eprintln!("datagen requires --out DIR");
        return 2;
    };
    let items: u64 = flag(args, "--items").and_then(|s| s.parse().ok()).unwrap_or(4096);
    let cfg = DataGenConfig { num_items: items, ..Default::default() };
    match generate(std::path::Path::new(out), &cfg) {
        Ok(bytes) => {
            println!("wrote {} items ({}) under {out}", cfg.num_items, fmt::bytes(bytes));
            0
        }
        Err(e) => {
            eprintln!("datagen failed: {e:#}");
            1
        }
    }
}

fn cmd_sim(args: &[String]) -> i32 {
    let mode = match flag(args, "--mode").unwrap_or("hoard") {
        "rem" | "remote" => ReadMode::Remote,
        "nvme" | "local" => ReadMode::LocalNvme,
        "hoard" => ReadMode::Hoard,
        other => {
            eprintln!("unknown mode '{other}' (rem|nvme|hoard)");
            return 2;
        }
    };
    let epochs: u32 = flag(args, "--epochs").and_then(|s| s.parse().ok()).unwrap_or(2);
    let readers: usize = flag(args, "--readers").and_then(|s| s.parse().ok()).unwrap_or(1);
    let mut sim = paper_scenario(mode, epochs);
    sim.reader_threads = readers;
    let res = sim.run();
    println!(
        "4 jobs × 4 GPUs, AlexNet BS=1536, ImageNet, {epochs} epochs, mode {mode:?}, \
         reader threads (real-mode hint) {readers}"
    );
    for j in &res.jobs {
        println!(
            "  {}: total {}  epochs [{}]",
            j.name,
            fmt::duration(j.total_duration),
            j.epoch_durations
                .iter()
                .map(|e| format!("{e:.0}s"))
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    println!(
        "makespan {}  NFS bytes {}",
        fmt::duration(res.makespan),
        fmt::bytes(res.traffic.bytes[res.nfs_resource.0] as u64)
    );
    0
}

fn cmd_info() -> i32 {
    let c = ClusterConfig::paper_testbed();
    println!("Paper testbed (Table 2):");
    println!("  nodes: {} × IBM Power S822LC (model)", c.num_nodes());
    println!("  gpus:  {} × P100 per node", c.gpus_per_node);
    println!("  mem:   {} per node", fmt::bytes(c.memory_per_node));
    println!(
        "  cache: {} × {} NVMe per node ({} aggregate)",
        c.cache_devices_per_node,
        fmt::bytes(c.cache_device_bytes),
        fmt::bytes(c.num_nodes() as u64 * c.cache_devices_per_node as u64 * c.cache_device_bytes)
    );
    println!("  net:   {} NIC", fmt::rate(c.nic_bw));
    println!("  nfs:   {} remote store", fmt::rate(c.remote_bw));
    0
}
