//! Integration tests for the clairvoyant prefetch subsystem
//! (`rust/src/prefetch/`): fetch-once under prefetcher/reader races,
//! byte-identity across strategies, the lookahead window bound, the
//! partially-warm gate (prefetch only missing chunks; skip entirely when
//! fully resident), partial-stats merging on mid-epoch errors, and the
//! FillTable prefetch-credit protocol.

use std::sync::Arc;
use std::time::{Duration, Instant};

use hoard::cache::{CacheManager, EvictionPolicy, SharedCache};
use hoard::netsim::NodeId;
use hoard::posix::dataplane::{DataPlane, JobSpec, ReadRequest};
use hoard::posix::reader_pool::{Claim, FillTable};
use hoard::posix::realfs::{ReadStats, RealCluster};
use hoard::prefetch::{
    run_scheduled_chunks, EpochSchedule, PrefetchConfig, PrefetchStrategy, ReadCursor,
};
use hoard::storage::{Device, DeviceKind, Volume};
use hoard::workload::datagen::{self, DataGenConfig};
use hoard::workload::DatasetSpec;

const NODES: usize = 4;

fn fixture(tag: &str, items: u64, chunk_bytes: u64) -> (RealCluster, SharedCache, DataGenConfig) {
    let root = std::env::temp_dir().join(format!("hoard-prefetch-t-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let cluster = RealCluster::create(&root, NODES, 500e6).unwrap();
    let cfg = DataGenConfig { num_items: items, files_per_dir: 32, ..Default::default() };
    let total = datagen::generate(&cluster.remote_dir, &cfg).unwrap();
    let vols = (0..NODES)
        .map(|_| Volume::new(vec![Device::new(DeviceKind::Nvme, 1 << 30)]))
        .collect();
    let mut manager = CacheManager::new(vols, EvictionPolicy::Manual);
    manager.chunk_bytes = chunk_bytes;
    manager.register(DatasetSpec::new("d", items, total), "nfs://r/d".into()).unwrap();
    manager.place("d", (0..NODES).map(NodeId).collect()).unwrap();
    (cluster, SharedCache::new(manager), cfg)
}

/// The tentpole race: two clairvoyant sessions × 4 readers each, cold,
/// racing one shared ledger — exactly `num_chunks` fills, the remote
/// store supplies every byte once, and the prefetch counters obey their
/// invariants (`hits ≤ issued ≤ fills`).
#[test]
fn clairvoyant_cold_race_fills_each_chunk_once() {
    let (cluster, cache, cfg) = fixture("race", 24, 777);
    let total = cfg.num_items * cfg.record_bytes() as u64;
    let chunks = cache.geometry("d").unwrap().num_chunks();
    let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
    let a = plane
        .open_job(
            JobSpec::new("d", cfg.clone())
                .readers(4)
                .seed(1)
                .prefetch_strategy(PrefetchStrategy::Clairvoyant)
                .prefetch_inflight(4),
        )
        .unwrap();
    let b = plane
        .open_job(
            JobSpec::new("d", cfg.clone())
                .readers(4)
                .seed(2)
                .prefetch_strategy(PrefetchStrategy::Clairvoyant)
                .prefetch_inflight(4),
        )
        .unwrap();
    let (ra, rb) = std::thread::scope(|s| {
        let ha = s.spawn(|| a.run_epoch(0).unwrap());
        let hb = s.spawn(|| b.run_epoch(0).unwrap());
        (ha.join().unwrap(), hb.join().unwrap())
    });
    assert_eq!(
        plane.dataset_fills("d"),
        chunks,
        "2 racing clairvoyant jobs must fill every chunk exactly once, together"
    );
    let stats = cluster.take_stats();
    assert_eq!(stats.remote_bytes, total, "remote supplied every byte exactly once");
    let issued = ra.merged.prefetch_issued + rb.merged.prefetch_issued;
    let hits = ra.merged.prefetch_hits + rb.merged.prefetch_hits;
    assert!(issued <= chunks, "cannot issue more prefetches than chunks ({issued} > {chunks})");
    assert!(hits <= issued, "each prefetched chunk yields at most one credit ({hits} > {issued})");
    std::fs::remove_dir_all(&cluster.root).unwrap();
}

/// Byte-identity ablation: off / sequential / clairvoyant cold epochs all
/// produce generator-exact bytes for every item.
#[test]
fn epochs_byte_identical_across_strategies() {
    for (tag, strategy) in [
        ("id-off", PrefetchStrategy::Off),
        ("id-seq", PrefetchStrategy::Sequential),
        ("id-cv", PrefetchStrategy::Clairvoyant),
    ] {
        let (cluster, cache, cfg) = fixture(tag, 10, 777);
        let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
        let sess = plane
            .open_job(JobSpec::new("d", cfg.clone()).readers(2).prefetch_strategy(strategy))
            .unwrap();
        sess.run_epoch(0).unwrap();
        for i in 0..cfg.num_items {
            let (_, want) = datagen::make_record(&cfg, i);
            let got = sess.read(&ReadRequest::item(i), NodeId(0)).unwrap();
            assert_eq!(got, want, "item {i} under {} prefetch", strategy.name());
        }
        std::fs::remove_dir_all(&cluster.root).unwrap();
    }
}

/// Poll until `fill.fills_completed()` reaches `want` (progress) and then
/// *stays* there (bound) — the scheduler must neither stall inside the
/// window nor issue a single unit beyond it.
fn expect_fills_exactly(fill: &FillTable, want: u64, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while fill.fills_completed() < want {
        assert!(Instant::now() < deadline, "{what}: stuck at {} of {want}", fill.fills_completed());
        std::thread::sleep(Duration::from_millis(5));
    }
    // Settle time ≫ the scheduler's poll interval: any unit past the
    // window would have been issued by now.
    std::thread::sleep(Duration::from_millis(60));
    assert_eq!(fill.fills_completed(), want, "{what}: issued past the lookahead window");
}

/// The lookahead window bound, driven directly: a frozen cursor admits
/// exactly the units whose first access is inside the window, advancing
/// the cursor widens it by exactly that much, and `prefetch_issued`
/// matches the ledger fill count at the end.
#[test]
fn lookahead_window_never_issues_beyond_bound() {
    let (cluster, cache, cfg) = fixture("window", 16, 777);
    let geom = cache.geometry("d").unwrap();
    let order: Vec<u64> = (0..cfg.num_items).rev().collect();
    let schedule = EpochSchedule::for_chunks(&order, &geom);
    let fill = FillTable::new(geom.num_chunks());
    let cursor = ReadCursor::new(order.len() as u64);
    const LOOKAHEAD: u64 = 3;
    let pcfg = PrefetchConfig::default().lookahead(LOOKAHEAD).inflight(2);
    let in_window =
        |hi: u64| schedule.entries().iter().filter(|&&(p, _)| p < hi).count() as u64;

    let mut stats = ReadStats::default();
    std::thread::scope(|s| {
        let h = s.spawn(|| {
            let mut st = ReadStats::default();
            run_scheduled_chunks(
                &cluster, &cache, &fill, None, None, "d", &cfg, &geom, &schedule, &cursor,
                &pcfg, &mut st,
            )
            .unwrap();
            st
        });
        // Cursor frozen at 0: only first accesses in 0..LOOKAHEAD may go.
        expect_fills_exactly(&fill, in_window(LOOKAHEAD), "frozen cursor");
        // Advance 4 positions: the window slides to 0..4+LOOKAHEAD.
        for _ in 0..4 {
            cursor.advance();
        }
        expect_fills_exactly(&fill, in_window(4 + LOOKAHEAD), "advanced cursor");
        // Epoch over: parked workers exit without issuing the rest.
        cursor.stop();
        stats = h.join().unwrap();
    });
    assert_eq!(
        stats.prefetch_issued,
        fill.fills_completed(),
        "issued counter must match the ledger exactly"
    );
    assert_eq!(fill.fills_completed(), in_window(4 + LOOKAHEAD));
    assert!(fill.fills_completed() < geom.num_chunks(), "the bound must have bitten");
    std::fs::remove_dir_all(&cluster.root).unwrap();
}

/// The partially-warm satellite: a dataset warmed over half its items is
/// *not* `Cached`, but the clairvoyant epoch must fetch exactly the
/// missing chunks' bytes (resident chunks are skipped without a claim) —
/// and once fully resident, the prefetcher is skipped outright.
#[test]
fn partially_warm_prefetches_only_missing_chunks() {
    let (cluster, cache, cfg) = fixture("warm", 16, 777);
    let total = cfg.num_items * cfg.record_bytes() as u64;
    let geom = cache.geometry("d").unwrap();
    // Warm half the items (a prefix of chunks) through a no-prefetch job.
    let plane_a = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
    let a = plane_a.open_job(JobSpec::new("d", cfg.clone()).prefetch(false)).unwrap();
    let half: Vec<u64> = (0..cfg.num_items / 2).collect();
    a.run_epoch_order(&half).unwrap();
    assert!(!cache.is_cached("d"), "half-warm must not be Cached");
    let snap = cache.snapshot("d").unwrap();
    let missing_bytes: u64 = (0..geom.num_chunks())
        .filter(|&c| !snap.contains(c))
        .map(|c| {
            let (s, e) = geom.chunk_range(c);
            e - s
        })
        .sum();
    assert!(missing_bytes > 0 && missing_bytes < total, "fixture must be partially warm");
    cluster.take_stats();

    // A fresh plane (fresh ledger — nothing pre-claimed) runs clairvoyant:
    // exactly the missing bytes cross the remote link.
    let plane_b = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
    let b = plane_b
        .open_job(
            JobSpec::new("d", cfg.clone())
                .readers(2)
                .prefetch_strategy(PrefetchStrategy::Clairvoyant),
        )
        .unwrap();
    let rb = b.run_epoch(0).unwrap();
    assert!(rb.prefetcher.is_some(), "partially-warm dataset must still run the prefetcher");
    let stats = cluster.take_stats();
    assert_eq!(
        stats.remote_bytes, missing_bytes,
        "clairvoyant epoch must fetch exactly the missing chunks"
    );
    assert!(cache.is_cached("d"), "epoch over a half-warm dataset completes the stripe");

    // Fully resident now: the prefetcher must not run at all.
    let c = plane_b.open_job(JobSpec::new("d", cfg.clone()).seed(9)).unwrap();
    let rc = c.run_epoch(0).unwrap();
    assert!(rc.prefetcher.is_none(), "fully-resident dataset must skip the prefetcher");
    std::fs::remove_dir_all(&cluster.root).unwrap();
}

/// The partial-stats satellite: a prefetcher that dies mid-epoch (remote
/// file vanished) fails the epoch, but the bytes it *did* move stay in
/// the job accumulator — accounting is exact even for failed epochs.
#[test]
fn prefetcher_error_keeps_partial_stats() {
    let (cluster, cache, cfg) = fixture("err", 16, 777);
    // Vaporize the last item's remote file: the sequential pass (stripe
    // order) fills every earlier chunk, then dies on the tail.
    std::fs::remove_file(cluster.remote_dir.join(cfg.item_rel_path(cfg.num_items - 1))).unwrap();
    let plane = Arc::new(DataPlane::new(cluster.clone(), cache.clone()));
    let sess = plane
        .open_job(
            JobSpec::new("d", cfg.clone()).prefetch_strategy(PrefetchStrategy::Sequential),
        )
        .unwrap();
    // Readers touch only item 0 (which exists) — the epoch's error comes
    // from the prefetcher alone.
    sess.run_epoch_order(&[0]).unwrap_err();
    let stats = sess.stats();
    assert!(
        stats.prefetch_issued > 0,
        "the prefetcher's partial shard must be merged, not dropped"
    );
    assert!(stats.remote_bytes > 0, "partial fills happened and must be accounted");
    std::fs::remove_dir_all(&cluster.root).unwrap();
}

/// The FillTable prefetch-credit protocol: `complete_prefetched` arms a
/// one-shot credit, the first crediting claim consumes it, `abort` clears
/// it, and `prefetch_outstanding` tracks the armed count.
#[test]
fn fill_table_prefetch_credit_protocol() {
    let t = FillTable::new(40);
    assert_eq!(t.prefetch_outstanding(), 0);
    // Prefetcher claims and completes slot 7.
    assert!(t.try_claim(7));
    t.complete_prefetched(7);
    assert_eq!(t.fills_completed(), 1);
    assert_eq!(t.prefetch_outstanding(), 1);
    // First reader takes the credit; second sees plain residency.
    assert_eq!(t.claim_or_wait_credit(7), (Claim::Resident, true));
    assert_eq!(t.prefetch_outstanding(), 0);
    assert_eq!(t.claim_or_wait_credit(7), (Claim::Resident, false));
    // The legacy claim never consumes a credit.
    assert!(t.try_claim(23));
    t.complete_prefetched(23);
    assert_eq!(t.claim_or_wait(23), Claim::Resident);
    assert_eq!(t.prefetch_outstanding(), 1, "claim_or_wait must leave the credit armed");
    assert_eq!(t.claim_or_wait_credit(23), (Claim::Resident, true));
    // Abort rolls the slot *and* its credit back.
    assert!(t.try_claim(8));
    t.complete_prefetched(8);
    assert_eq!(t.prefetch_outstanding(), 1);
    t.abort(8);
    assert_eq!(t.prefetch_outstanding(), 0);
    assert_eq!(t.claim_or_wait_credit(8), (Claim::Filler, false));
    // A plain demand fill never arms a credit.
    t.complete(8);
    assert_eq!(t.claim_or_wait_credit(8), (Claim::Resident, false));
    assert_eq!(t.prefetch_outstanding(), 0);
}
